"""Shared benchmark utilities: datasets, timing, CSV rows, and the
machine-readable per-bench JSON results (``BENCH_<name>.json``) that track
the perf trajectory across PRs."""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.data import vectors

# Scaled statistical twins of Table 1 (full-size shapes live in the dry-run).
# FULL_* are the committed-baseline workload sizes: every BENCH_*.json
# records the workload it was measured at, and the run.py root-mirror
# refuses to overwrite a committed full-size baseline with rows from a
# smaller (e.g. --smoke) workload.
FULL_BENCH_N = 8000
FULL_BENCH_QUERIES = 1024
BENCH_N = int(os.environ.get("BENCH_N", FULL_BENCH_N))
# LeanVec-Sphering requires m >~ D learning queries: K_Q = QQ^T must have
# full rank or W's pseudo-inverse collapses the query projection (measured:
# m=128 at D=512 flips the Fig-5 ordering). The paper uses 10k.
BENCH_QUERIES = int(os.environ.get("BENCH_QUERIES", FULL_BENCH_QUERIES))

ROWS: List[str] = []
RESULTS: List[Dict] = []
# Row-name prefixes the bench modules have DECLARED they will emit this
# run (``declare``): ``write_json_results`` fails if any is missing, so a
# silently-skipped row (an early return, a renamed mode string) breaks
# smoke instead of passing it.
DECLARED: List[str] = []


def declare(*prefixes: str) -> None:
    """Register row-name prefixes this bench run MUST emit (idempotent)."""
    for p in prefixes:
        if p not in DECLARED:
            DECLARED.append(p)


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    spec = dict(vectors.DATASETS[name])
    spec["n"] = min(spec["n"], BENCH_N)
    return vectors.make_dataset(name, n=spec["n"], d=spec["d"],
                                n_queries=BENCH_QUERIES, ood=spec["ood"],
                                seed=17)


def rerank_traffic_bound(m: int, kappa: int, dim: int,
                         bytes_per: int = 4) -> int:
    """Lower bound on host->device rerank traffic for the two-level tier:
    ``m`` queries each promote exactly ``kappa`` full-D candidate rows, so
    a correct pipeline moves ``m * kappa * dim * bytes_per`` bytes -- a
    function of the CANDIDATE set, not the ``n * dim * bytes_per`` store
    size. Benches assert measured traffic stays within a small factor of
    this bound (padding to the batch size is the only slack)."""
    return int(m) * int(kappa) * int(dim) * int(bytes_per)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _parse_derived(value: str):
    """Numeric when possible ('3.20x' -> 3.2), else the raw string."""
    for v in (value, value[:-1] if value.endswith("x") else value):
        try:
            return float(v)
        except ValueError:
            continue
    return value


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    entry = {"name": name, "us_per_call": round(us_per_call, 1),
             "ops_per_s": (round(1e6 / us_per_call, 2)
                           if us_per_call > 0 else None)}
    for kv in derived.split(";"):
        if "=" in kv:
            k, v = kv.split("=", 1)
            entry[k] = _parse_derived(v)
    RESULTS.append(entry)
    print(row, flush=True)


def write_json_results(out_dir: str) -> List[str]:
    """One ``BENCH_<name>.json`` per top-level bench group (the prefix of
    each row name, e.g. ``table1/...`` -> BENCH_table1.json), each holding
    the structured rows emitted so far: us_per_call, ops_per_s and every
    parsed ``derived`` field (recall10, bytes_per_vec, qps, ...).

    Raises if any ``declare``-d row prefix has no emitted row -- declared
    rows must reach the written JSON for smoke to pass."""
    missing = [p for p in DECLARED
               if not any(e["name"].startswith(p) for e in RESULTS)]
    if missing:
        raise RuntimeError(
            f"declared bench rows missing from results: {missing}")
    groups: Dict[str, List[Dict]] = {}
    for entry in RESULTS:
        groups.setdefault(entry["name"].split("/")[0], []).append(entry)
    paths = []
    os.makedirs(out_dir, exist_ok=True)
    for group, entries in sorted(groups.items()):
        path = os.path.join(out_dir, f"BENCH_{group}.json")
        with open(path, "w") as f:
            json.dump({"bench": group,
                       "workload": {"bench_n": BENCH_N,
                                    "bench_queries": BENCH_QUERIES},
                       "results": entries}, f, indent=2)
            f.write("\n")
        paths.append(path)
    return paths


def workload_of(path: str) -> Dict[str, int]:
    """The workload a BENCH_*.json was measured at. Files predating the
    workload field are the committed FULL-SIZE baselines -- that default
    is what makes the run.py mirror guard refuse to clobber them with
    smaller-workload rows."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {"bench_n": FULL_BENCH_N, "bench_queries": FULL_BENCH_QUERIES}
    return payload.get("workload", {"bench_n": FULL_BENCH_N,
                                    "bench_queries": FULL_BENCH_QUERIES})
