"""Shared benchmark utilities: datasets, timing, CSV rows."""
from __future__ import annotations

import functools
import os
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.data import vectors

# Scaled statistical twins of Table 1 (full-size shapes live in the dry-run).
BENCH_N = int(os.environ.get("BENCH_N", 8000))
# LeanVec-Sphering requires m >~ D learning queries: K_Q = QQ^T must have
# full rank or W's pseudo-inverse collapses the query projection (measured:
# m=128 at D=512 flips the Fig-5 ordering). The paper uses 10k.
BENCH_QUERIES = int(os.environ.get("BENCH_QUERIES", 1024))

ROWS: List[str] = []


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    spec = dict(vectors.DATASETS[name])
    spec["n"] = min(spec["n"], BENCH_N)
    return vectors.make_dataset(name, n=spec["n"], d=spec["d"],
                                n_queries=BENCH_QUERIES, ood=spec["ood"],
                                seed=17)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
