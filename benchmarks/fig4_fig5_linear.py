"""Paper Figures 4 (ID) and 5 (OOD): linear DR methods -- LeanVec loss and
brute-force search recall across target dimensionalities.

Claims validated:
  * Fig 4 (ID): all methods (incl. plain SVD) perform similarly;
  * Fig 5 (OOD): LeanVec-Sphering wins both loss and recall.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_fn
from repro.core import baselines, leanvec_sphering as lvs, metrics
from repro.data import vectors


def _recall(ds, a, b, k=10):
    qv = ds.queries_test @ np.asarray(a).T
    xv = ds.database @ np.asarray(b).T
    ids = vectors.exact_topk(qv, xv, k)
    return float(metrics.recall_at_k(jnp.asarray(ids),
                                     jnp.asarray(ds.gt[:, :k])))


def run():
    results = {}
    for name in ("deep-ID", "laion-OOD", "t2i-OOD"):
        ds = dataset(name)
        X, Q = jnp.asarray(ds.database), jnp.asarray(ds.queries_learn)
        kq = jnp.einsum("nd,ne->de", Q, Q)
        kx = jnp.einsum("nd,ne->de", X, X)
        d = max(16, ds.database.shape[1] // 4)
        methods = {
            "svd": lambda: baselines.svd_fit(kx, d),
            "sphering": lambda: lvs.fit(Q, X, d),
            "fw": lambda: baselines.leanvec_fw(kq, kx, d),
            "es": lambda: baselines.leanvec_es(kq, kx, d),
            "es+fw": lambda: baselines.leanvec_es_fw(kq, kx, d),
        }
        for mname, fit in methods.items():
            us = time_fn(lambda f=fit: f(), warmup=1, iters=1)
            m = fit()
            a, b = (m.a, m.b)
            loss = float(metrics.leanvec_loss(a, b, Q, X))
            rec = _recall(ds, a, b)
            results[(name, mname)] = (loss, rec)
            fig = "fig4" if name.endswith("ID") else "fig5"
            emit(f"{fig}/{name}/{mname}", us,
                 f"loss={loss:.4f};recall10={rec:.3f};d={d}")
    # assertion-style derived summaries
    for name in ("laion-OOD", "t2i-OOD"):
        better = (results[(name, "sphering")][1]
                  >= results[(name, "svd")][1])
        emit(f"fig5/{name}/claim_sphering_beats_svd", 0.0, str(better))
    return results


if __name__ == "__main__":
    run()
