"""Paper Figure 6: spherical k-means clusters of an OOD dataset have lower
intrinsic dimensionality than the full set -- per-cluster captured-variance
profiles dominate the global profile."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_fn
from repro.core import gleanvec as gv, metrics, spherical_kmeans as skm


def _d_for_variance(profile: np.ndarray, frac: float = 0.8) -> int:
    return int(np.searchsorted(profile, frac) + 1)


def run():
    ds = dataset("laion-OOD")
    X = jnp.asarray(ds.database)
    c = 16
    us = time_fn(lambda: skm.fit(jax.random.PRNGKey(0), X, c, 15))
    km = skm.fit(jax.random.PRNGKey(0), X, c, 15)
    x_unit = skm.normalize_rows(X)
    tags = skm.assign(x_unit, km.centers)

    global_profile = np.asarray(metrics.captured_variance_profile(
        jnp.einsum("nd,ne->de", X, X)))
    d80_global = _d_for_variance(global_profile)

    k_x_c = gv.per_cluster_moments(X, tags, c)
    d80_clusters = []
    for ci in range(c):
        prof = np.asarray(metrics.captured_variance_profile(k_x_c[ci]))
        d80_clusters.append(_d_for_variance(prof))
    frac_lower = float(np.mean([d <= d80_global for d in d80_clusters]))
    emit("fig6/laion-OOD/kmeans_fit", us,
         f"d80_global={d80_global};d80_cluster_mean="
         f"{np.mean(d80_clusters):.1f};frac_clusters_lower={frac_lower:.2f}")
    return d80_global, d80_clusters


if __name__ == "__main__":
    run()
