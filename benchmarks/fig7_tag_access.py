"""Paper Figure 7: tag-access pattern during graph search.

Measures (mean over queries, +/- std):
  * cumulative distinct tags visited vs hop (red curve): elbow well below C;
  * distinct tags in a sliding window (blue curve): small fraction of C,
    which is what makes the eager Algorithm 4 cache-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_fn
from repro.core import gleanvec as gv
from repro.index import graph


def run(c: int = 48, window: int = 10):
    ds = dataset("laion-OOD")
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    model = gv.fit(jax.random.PRNGKey(0), Q, X, c=c, d=64)
    tags, x_low = gv.encode_database(model, X)
    g = graph.build(ds.database, r=24, n_iters=5, seed=0)
    q_views = gv.project_queries_eager(model, jnp.asarray(ds.queries_test))

    us = time_fn(lambda: graph.beam_search_traced(
        q_views, tags, x_low, g, k=10, beam=96, max_hops=200)[1])
    _, _, hops, tag_hist = graph.beam_search_traced(
        q_views, tags, x_low, g, k=10, beam=96, max_hops=200)
    th = np.asarray(tag_hist)

    total_distinct, window_distinct = [], []
    for row in th:
        valid = row[row >= 0]
        if len(valid) == 0:
            continue
        total_distinct.append(len(np.unique(valid)))
        wd = [len(np.unique(valid[max(0, i - window):i + 1]))
              for i in range(len(valid))]
        window_distinct.append(np.mean(wd[window:]) if len(wd) > window
                               else np.mean(wd))
    emit(f"fig7/laion-OOD/C{c}", us,
         f"hops={int(hops)};total_tags_mean={np.mean(total_distinct):.1f}"
         f"(of {c});window{window}_tags_mean={np.mean(window_distinct):.2f}"
         f";eager_favored={np.mean(window_distinct) < c / 4}")
    return total_distinct, window_distinct


if __name__ == "__main__":
    run()
