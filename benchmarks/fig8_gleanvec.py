"""Paper Figure 8: GleanVec vs LeanVec-Sphering search accuracy across
target dimensionality d and cluster counts C in {16, 48} (OOD data),
including the multi-step rerank (Algorithm 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_fn
from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.index import bruteforce


def run():
    ds = dataset("t2i-OOD")
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    dim = X.shape[1]
    out = {}
    for d in (dim // 8, dim // 4, dim // 2):
        m = lvs.fit(Q, X, d)
        q_low = QT @ m.a.T
        x_low = X @ m.b.T
        us = time_fn(lambda: bruteforce.search(q_low, x_low, 10)[1])
        _, ids = bruteforce.search(q_low, x_low, 10)
        r_lin = float(metrics.recall_at_k(ids, gt))
        emit(f"fig8/t2i-OOD/sphering/d{d}", us, f"recall10={r_lin:.3f}")
        out[("sphering", d)] = r_lin
        for c in (16, 48):
            model = gv.fit(jax.random.PRNGKey(0), Q, X, c=c, d=d)
            tags, xg_low = gv.encode_database(model, X)
            q_views = gv.project_queries_eager(model, QT)
            us = time_fn(lambda: bruteforce.search_gleanvec(
                q_views, tags, xg_low, 10)[1])
            _, ids = bruteforce.search_gleanvec(q_views, tags, xg_low, 10)
            r_gv = float(metrics.recall_at_k(ids, gt))
            emit(f"fig8/t2i-OOD/gleanvec-C{c}/d{d}", us,
                 f"recall10={r_gv:.3f};vs_linear={r_gv - r_lin:+.3f}")
            out[(f"gleanvec{c}", d)] = r_gv
    return out


if __name__ == "__main__":
    run()
