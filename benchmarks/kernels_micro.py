"""Kernel microbenchmarks: jnp reference path wall-time on CPU plus the
HBM-bytes-per-query analytic model that determines TPU throughput (the
quantity the paper's DR reduces). Pallas kernels themselves are validated in
interpret mode by the test suite; their VMEM tiling is recorded here."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.quantization import quantize, quantize_per_cluster
from repro.kernels import (gleanvec_ip_ref, gleanvec_sq, ip_topk_ref,
                           kmeans_assign_ref, sq_dot_ref)


def run(n: int = 100_000, dim: int = 512, d: int = 160, c: int = 48,
        m: int = 64):
    rng = np.random.default_rng(0)
    x_full = jnp.asarray(rng.standard_normal((n, dim)).astype(np.float32))
    x_low = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    q_full = jnp.asarray(rng.standard_normal((m, dim)).astype(np.float32))
    q_low = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    tags = jnp.asarray(rng.integers(0, c, n).astype(np.int32))
    q_views = jnp.asarray(rng.standard_normal((m, c, d)).astype(np.float32))
    cent = jnp.asarray(rng.standard_normal((c, dim)).astype(np.float32))

    f_full = jax.jit(lambda q, x: ip_topk_ref(q, x, 10))
    us = time_fn(f_full, q_full, x_full)
    emit("kernel/ip_topk/fullD", us,
         f"bytes_per_vec={dim * 4};tile=(128,512)xD")

    us = time_fn(f_full, q_low, x_low)
    emit("kernel/ip_topk/reduced", us,
         f"bytes_per_vec={d * 4};bw_saving={dim / d:.2f}x")

    f_gv = jax.jit(lambda qv, t, x: gleanvec_ip_ref(qv, t, x))
    us = time_fn(f_gv, q_views, tags, x_low)
    emit("kernel/gleanvec_ip/reduced", us,
         f"bytes_per_vec={d * 4 + 4};vmem_qviews_kb={c * d * 4 // 1024}")

    db = quantize(x_low)
    f_sq = jax.jit(lambda q, cds, lo, dl: sq_dot_ref(q, cds, lo, dl))
    us = time_fn(f_sq, q_low, db.codes, db.lo, db.delta)
    emit("kernel/sq_dot/int8", us,
         f"bytes_per_vec={d + 8};bw_saving={dim * 4 / (d + 8):.1f}x")

    # fused GleanVec∘int8 (gleanvec_sq, via the dispatcher: Pallas on TPU,
    # jnp mirror here): tag-select + int8 dot + per-cluster affine in ONE
    # pass over the codes, versus dequantize-then-gleanvec_ip, which reads
    # the codes, round-trips a dense f32 reduced matrix through HBM and
    # re-reads it with the tag. Byte counts come from the ACTUAL array
    # dtypes, so a representation regression (e.g. f32 codes) shows up here.
    sqc = quantize_per_cluster(x_low, tags, c)
    q_scaled = q_views * sqc.delta[None]
    q_lo = jnp.einsum("mcd,cd->mc", q_views, sqc.lo)
    f_fused = jax.jit(lambda qs, ql, t, cd: gleanvec_sq(qs, ql, t, cd))
    us_fused = time_fn(f_fused, q_scaled, q_lo, tags, sqc.codes)
    code_b = sqc.codes.dtype.itemsize          # 1 (u8 codes)
    tag_b = tags.dtype.itemsize                # 4 (i32 tag)
    f32_b = x_low.dtype.itemsize               # 4 (dequant round-trip)
    fused_bytes = d * code_b + tag_b           # one pass over the codes
    dequant_bytes = (d * code_b + tag_b        # dequant: read codes + tag
                     + d * f32_b               #   write dense f32 matrix
                     + d * f32_b + tag_b)      # gleanvec_ip: re-read + tag
    emit("kernel/gleanvec_sq/fused-int8", us_fused,
         f"bytes_per_vec={fused_bytes};"
         f"vs_dequant_bytes={dequant_bytes / fused_bytes:.1f}x;"
         f"bw_saving={(dim * 4) / fused_bytes:.1f}x")

    def dequant_then_ip(qv, t, cd, lo, dl):
        x = cd.astype(jnp.float32) * dl[t] + lo[t]
        return gleanvec_ip_ref(qv, t, x)

    us_deq = time_fn(jax.jit(dequant_then_ip), q_views, tags, sqc.codes,
                     sqc.lo, sqc.delta)
    emit("kernel/gleanvec_sq/dequant-then-ip", us_deq,
         f"bytes_per_vec={dequant_bytes};fused_speedup="
         f"{us_deq / max(us_fused, 1e-9):.2f}x")

    f_km = jax.jit(lambda x, ce: kmeans_assign_ref(x, ce))
    us = time_fn(f_km, x_full, cent)
    emit("kernel/kmeans_assign", us, f"C={c};D={dim}")


if __name__ == "__main__":
    run()
