"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes them to results/bench.csv.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (common, fig4_fig5_linear, fig6_cluster_structure,
                            fig7_tag_access, fig8_gleanvec, kernels_micro,
                            table1_search)
    print("name,us_per_call,derived")
    fig4_fig5_linear.run()
    fig6_cluster_structure.run()
    fig7_tag_access.run()
    fig8_gleanvec.run()
    table1_search.run()
    kernels_micro.run()
    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "bench.csv"), "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(common.ROWS) + "\n")
    print(f"# wrote {len(common.ROWS)} rows to results/bench.csv")


if __name__ == '__main__':
    main()
