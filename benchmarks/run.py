"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit),
writes them to results/bench.csv, and writes one machine-readable
``BENCH_<name>.json`` per bench group (ops/s, HBM bytes moved, recall@10,
...) next to the CSV -- mirrored to the repo root -- so the perf
trajectory is diffable across PRs.

``--smoke`` shrinks the datasets and runs the search-path modules only
(table1 + kernel micros) so the perf harness itself is exercisable in CI;
the numbers it prints characterize the harness, not the hardware.
"""
import argparse
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny datasets, search-path modules only")
    ap.add_argument("--out", default=None,
                    help="CSV output path (default results/bench.csv)")
    args = ap.parse_args(argv)

    from benchmarks import (common, fig4_fig5_linear, fig6_cluster_structure,
                            fig7_tag_access, fig8_gleanvec, kernels_micro,
                            serving_stream, table1_search)
    saved = (common.BENCH_N, common.BENCH_QUERIES)
    try:
        if args.smoke:
            common.BENCH_N = 1500
            common.BENCH_QUERIES = 64
            common.dataset.cache_clear()
            common.ROWS.clear()
            common.RESULTS.clear()
            common.DECLARED.clear()
        print("name,us_per_call,derived")
        if args.smoke:
            table1_search.run()
            kernels_micro.run(n=4000, dim=128, d=48, c=8, m=8)
            serving_stream.run(cycles=2, batch=32)
        else:
            fig4_fig5_linear.run()
            fig6_cluster_structure.run()
            fig7_tag_access.run()
            fig8_gleanvec.run()
            table1_search.run()
            kernels_micro.run()
            serving_stream.run()
        out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                       "results", "bench.csv")
        os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
        with open(out, "w") as f:
            f.write("name,us_per_call,derived\n")
            f.write("\n".join(common.ROWS) + "\n")
        print(f"# wrote {len(common.ROWS)} rows to {out}")
        # every BENCH_<name>.json also lands at the repo ROOT so the perf
        # trajectory is visible without digging into results/ -- but the
        # root copies are the COMMITTED full-size baselines, so the mirror
        # is guarded: a --smoke run never mirrors, and a run at any other
        # workload than the baseline's recorded one is refused (the rows
        # stay in results/, the baseline stays intact)
        repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                 ".."))
        for p in common.write_json_results(os.path.dirname(
                os.path.abspath(out))):
            print(f"# wrote {p}")
            dst = os.path.join(repo_root, os.path.basename(p))
            if os.path.abspath(p) == dst:
                continue
            if args.smoke:
                print(f"# smoke workload: NOT mirrored to {dst}")
                continue
            have = common.workload_of(dst) if os.path.exists(dst) else None
            ran = {"bench_n": common.BENCH_N,
                   "bench_queries": common.BENCH_QUERIES}
            if have is not None and have != ran:
                print(f"# REFUSED to overwrite {dst}: baseline workload "
                      f"{have} != this run's {ran} (rows kept in {p})")
                continue
            shutil.copyfile(p, dst)
            print(f"# wrote {dst}")
    finally:
        if args.smoke:    # restore for in-process callers (tests)
            common.BENCH_N, common.BENCH_QUERIES = saved
            common.dataset.cache_clear()


if __name__ == '__main__':
    main()
