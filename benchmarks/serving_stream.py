"""Streaming serving (Section 3.2) through the state-passing engine:
steady-state QPS / p50 / p99 under live traffic, hot-swap latency,
refresh-cycle cost, and -- the redesign's whole point -- recompile counts
per swap for the state-passing engine (0) vs the closure-rebuild baseline
the serving stack used before (1 full re-jit per artifact swap). Rows land
in ``BENCH_serving_stream.json`` via ``common.write_json_results``.

CPU wall times characterize the harness; the recompile counts and the
state-swap vs re-jit latency RATIO are the architecture's signal.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BENCH_QUERIES, BENCH_N, declare, emit,
                               rerank_traffic_bound, time_fn)
from repro.core import gleanvec as gv, metrics, streaming
from repro.core import search as msearch
from repro.data import vectors
from repro.serve import faults, frontend, lifecycle
from repro.serve.engine import ServingEngine, make_search_fn

MODES = ("gleanvec-int8", "gleanvec-int8-sorted")

# Smoke-enforced ceiling on measured host<->HBM rerank traffic relative to
# the m*kappa*D*4 lower bound (rerank_traffic_bound). The pipeline gathers
# exactly kappa rows per PADDED query, so batch padding is the only slack;
# 2x leaves room for a ragged final chunk without hiding an accidental
# full-store promotion (which would be n/(m*kappa) ~ 20x+ over the bound).
HOST_RERANK_MAX_RATIO = 2.0


def _compile_count():
    """Process-wide XLA backend-compile counter via jax.monitoring."""
    counter = {"n": 0}

    def listener(event, duration, **kwargs):
        if event == "/jax/core/compile/backend_compile_duration":
            counter["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(listener)
    return counter


def run(cycles: int = 3, batch: int = 64):
    n = min(BENCH_N, 8000)
    dim, d, c = 128, 32, 8
    n0 = int(n * 0.8)
    step = max(1, (n - n0) // (cycles + 1))   # +1: warmup cycle inserts too
    ds = vectors.make_dataset("serving-stream", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 4 * batch),
                              ood=True, seed=5)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, n0, 512)] \
        + 0.1 * rng.standard_normal((512, dim)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                   c=c, d=d)
    counter = _compile_count()

    for mode in MODES:
        arts = streaming.build_streaming_artifacts(
            mode, X[:n0], model, capacity=n, sort_block=256, slack_blocks=2)
        engine = ServingEngine(msearch.make_state(arts), k=10, kappa=50,
                               batch_size=batch, dim=dim)
        stream = streaming.init_from_artifacts(arts, q_init,
                                               refresh_every=step)
        # steady-state serving (post-warmup)
        engine.submit(QT[:batch])
        engine.stats.latencies_ms.clear()
        engine.stats.n_queries = engine.stats.n_batches = 0
        engine.stats.total_s = 0.0
        t_steady = time_fn(lambda: engine.submit(QT[:4 * batch]))
        s = engine.stats
        emit(f"serving_stream/steady-{mode}", t_steady / 4,
             f"qps={s.qps:.0f};p50_ms={s.percentile_ms(50):.2f};"
             f"p99_ms={s.percentile_ms(99):.2f}")

        # streaming refresh cycles: observe -> insert -> refresh -> swap;
        # cycle 0 is the warmup (compiles the eager host-loop ops once)
        # and is excluded from the recompile count and the timers
        c0, refresh_us, inserted, swaps0 = counter["n"], [], 0, 0
        for cycle in range(cycles + 1):
            obs = QT[(cycle * batch) % len(QT):][:batch]
            engine.submit(obs)
            stream = streaming.observe_queries(stream, jnp.asarray(obs))
            rows = X[n0 + cycle * step: n0 + (cycle + 1) * step]
            t0 = time.perf_counter()
            arts2, _ = streaming.insert_rows(engine.state.artifacts, rows)
            engine.swap(engine.state._replace(artifacts=arts2))
            stream = streaming.insert(stream, rows)
            stream = streaming.refresh(stream)
            engine.swap(streaming.refresh_state(engine.state, stream,
                                                source="full"))
            jax.block_until_ready(engine.state.artifacts.scorer)
            refresh_us.append((time.perf_counter() - t0) * 1e6)
            inserted += rows.shape[0]
            if cycle == 0:      # end of warmup: start counting
                c0, refresh_us, inserted = counter["n"], [], 0
                engine.stats.swap_ms.clear()
                swaps0 = engine.n_swaps
        recompiles = counter["n"] - c0
        swap_us = float(np.median(engine.stats.swap_ms)) * 1e3
        emit(f"serving_stream/swap-{mode}", swap_us,
             f"recompiles={recompiles};cycles={cycles};"
             f"inserted={inserted};swaps={engine.n_swaps - swaps0}")
        emit(f"serving_stream/refresh_cycle-{mode}",
             float(np.median(refresh_us)),
             f"recompiles={recompiles};rows_per_cycle={step}")

        # post-stream quality on the drifted distribution
        live = streaming.live_mask(engine.state.artifacts)
        gt = np.nonzero(live)[0][vectors.exact_topk(
            QT[:128], np.asarray(engine.state.artifacts.x_full)[live], 10)]
        rec = float(metrics.recall_at_k(
            jnp.asarray(engine.submit(QT[:128])), jnp.asarray(gt)))
        emit(f"serving_stream/recall-{mode}", 0.0, f"recall10={rec:.3f}")

        # the pre-redesign baseline: every artifact swap rebuilds + re-jits
        # the closure -- measure one full re-jit + first batch per swap
        c1 = counter["n"]
        t0 = time.perf_counter()
        fn = jax.jit(make_search_fn(engine.state.artifacts, k=10, kappa=50))
        jax.block_until_ready(fn(jnp.asarray(QT[:batch])))
        rebuild_us = (time.perf_counter() - t0) * 1e6
        emit(f"serving_stream/rebuild_swap-{mode}", rebuild_us,
             f"recompiles={counter['n'] - c1};"
             f"speedup={rebuild_us / max(swap_us, 1e-9):.0f}x")

    _run_faults(counter, batch=batch)
    _run_host_rerank(counter, batch=batch)
    _run_frontend(counter, batch=batch)


def _run_host_rerank(counter, batch: int = 32):
    """``serving_stream/host_rerank/*``: the two-level memory hierarchy.
    The same engine serves the same traffic twice -- full-D store in HBM
    vs demoted to the host tier (double-buffered kappa-row prefetch) --
    and the section asserts the hierarchy's three contracts: exact
    (value, id) parity, zero recompiles during steady serving, and
    measured host<->HBM traffic within HOST_RERANK_MAX_RATIO of the
    m*kappa*D*4 bound. The qps_ratio is reported UNASSERTED: on CPU both
    "tiers" are the same DRAM, so wall-clock parity is a harness check,
    not the hardware signal."""
    declare("serving_stream/host_rerank/steady",
            "serving_stream/host_rerank/bytes")
    n = min(BENCH_N, 4000)
    dim, d, c = 128, 32, 8
    n0 = int(n * 0.8)
    ds = vectors.make_dataset("serving-hostrr", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 4 * batch),
                              ood=True, seed=11)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, n0, 512)] \
        + 0.1 * rng.standard_normal((512, dim)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                   c=c, d=d)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:n0], model, capacity=n, sort_block=256,
        slack_blocks=2)
    arts_host = msearch.demote_rerank_tier(arts)

    engines = {}
    for tier, a in (("hbm", arts), ("host", arts_host)):
        eng = ServingEngine(msearch.make_state(a), k=10, kappa=50,
                            batch_size=batch, dim=dim)
        eng.submit(QT[:batch])              # warmup: compile both stages
        eng.stats.latencies_ms.clear()
        eng.stats.n_queries = eng.stats.n_batches = 0
        eng.stats.total_s = 0.0
        eng.stats.host_bytes = eng.stats.host_bytes_lb = 0
        engines[tier] = eng

    # exact (value, id) parity on identical traffic, both tiers
    ids_hbm = np.asarray(engines["hbm"].submit(QT[:2 * batch]))
    ids_host = np.asarray(engines["host"].submit(QT[:2 * batch]))
    if not np.array_equal(ids_hbm, ids_host):
        raise AssertionError(
            "host-tier rerank diverged from the all-HBM engine")

    c0 = counter["n"]
    t_hbm = time_fn(lambda: engines["hbm"].submit(QT[:4 * batch]))
    t_host = time_fn(lambda: engines["host"].submit(QT[:4 * batch]))
    recompiles = counter["n"] - c0
    if recompiles:
        raise AssertionError(
            f"steady host-tier serving recompiled {recompiles}x")
    s = engines["host"].stats
    emit("serving_stream/host_rerank/steady", t_host / 4,
         f"qps={s.qps:.0f};qps_ratio={t_hbm / max(t_host, 1e-9):.2f};"
         f"p50_ms={s.percentile_ms(50):.2f};parity=1;"
         f"prefetch_p50_ms={float(np.median(s.prefetch_ms)):.2f}")

    # traffic accounting: measured bytes vs the m*kappa*D*4 bound
    bound = rerank_traffic_bound(s.n_queries, engines["host"].kappa, dim)
    ratio = s.host_bytes / max(bound, 1)
    if ratio > HOST_RERANK_MAX_RATIO:
        raise AssertionError(
            f"host<->HBM rerank traffic {s.host_bytes}B exceeds "
            f"{HOST_RERANK_MAX_RATIO}x the m*kappa*D*4 bound {bound}B")
    emit("serving_stream/host_rerank/bytes", 0.0,
         f"host_mb={s.host_bytes / 2**20:.2f};ratio={ratio:.2f};"
         f"max_ratio={HOST_RERANK_MAX_RATIO};recompiles={recompiles};"
         f"store_mb={n * dim * 4 / 2**20:.2f}")


# Declared SLO the frontend rows report request p50/p99 against. On CPU
# the absolute numbers characterize the harness; the CONTRACT the section
# hard-asserts is shape-independent: zero recompiles after warmup across
# every arrival process, and under overload the frontend sheds/rejects
# (bounding served-request p99 under the SLO) instead of letting every
# request's latency collapse together.
FRONTEND_SLO_MS = 250.0


def _frontend_wave(fe, queries, deadlines_ms, rng, burst_lam, gap_s):
    """Drive one arrival process: enqueue seeded Poisson-ish bursts with
    exponential gaps, then resolve everything. Returns (served, refused,
    wall_s)."""
    futures, refused = [], 0
    t0 = time.perf_counter()
    i = 0
    while i < len(queries):
        burst = max(1, int(rng.poisson(burst_lam)))
        for q in queries[i: i + burst]:
            try:
                futures.append(fe.enqueue(q, deadline_ms=deadlines_ms))
            except frontend.Rejected:
                refused += 1
        i += burst
        time.sleep(float(rng.exponential(gap_s)))
    served = 0
    for f in futures:
        try:
            f.result(timeout=60)
            served += 1
        except frontend.Rejected:
            refused += 1
    return served, refused, time.perf_counter() - t0


def _run_frontend(counter, batch: int = 32):
    """``serving_stream/frontend/*``: the async coalescing frontend under
    production traffic shapes -- bursty (Poisson bursts) and diurnal
    (sinusoidally-modulated rate) arrivals of mixed ID/OOD queries,
    sustained overload against a tight deadline, and swap staleness under
    a slowed background refresh. Request p50/p99 (enqueue -> resolved,
    queue wait included) is reported against FRONTEND_SLO_MS; recompiles
    after warmup across every arrival section are hard-asserted zero."""
    declare("serving_stream/frontend/bursty",
            "serving_stream/frontend/diurnal",
            "serving_stream/frontend/overload",
            "serving_stream/frontend/staleness")
    n = min(BENCH_N, 4000)
    dim, d, c = 128, 32, 8
    ds = vectors.make_dataset("serving-frontend", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 8 * batch),
                              ood=True, seed=13)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_id = np.asarray(X)[rng.integers(0, n, len(QT))] \
        + 0.1 * rng.standard_normal((len(QT), dim)).astype(np.float32)
    mixed = np.empty((2 * len(QT), dim), np.float32)
    mixed[0::2], mixed[1::2] = q_id, QT
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_id[:512]), X,
                   c=c, d=d)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X, model, capacity=n, sort_block=256,
        slack_blocks=2)
    engine = ServingEngine(msearch.make_state(arts), k=10, kappa=50,
                           batch_size=batch, dim=dim)
    guarded = lifecycle.GuardedEngine(engine, canary_queries=QT[:batch])
    stats = engine.stats

    def section(fe, n_queries, deadlines_ms, lam, gap_s, seed):
        stats.request_ms.clear()
        s0 = (stats.n_rejected, stats.n_shed, stats.n_deadline_miss)
        served, refused, wall = _frontend_wave(
            fe, mixed[:n_queries], deadlines_ms,
            np.random.default_rng(seed), lam, gap_s)
        dr, dsh, dm = (stats.n_rejected - s0[0], stats.n_shed - s0[1],
                       stats.n_deadline_miss - s0[2])
        offered = served + refused
        assert offered == n_queries, \
            f"frontend lost requests: {offered}/{n_queries} accounted"
        return dict(served=served, refused=refused, wall=wall,
                    rejected=dr, shed=dsh, miss=dm,
                    p50=stats.request_percentile_ms(50),
                    p99=stats.request_percentile_ms(99))

    # clients attach a deadline derived from the SLO (80%, leaving one
    # batch window of slack): the overload-safe configuration -- when the
    # arrival process outruns this machine, the frontend sheds the tail
    # (reported as shed_rate) and the SERVED p99 stays under the SLO,
    # instead of every request's queue wait collapsing together
    client_deadline = FRONTEND_SLO_MS * 0.8
    with frontend.ServingFrontend(guarded, capacity=8 * batch) as fe:
        c0 = counter["n"]       # warmup (ctor) compiled every bucket shape

        # bursty arrivals: Poisson bursts around one compiled batch
        r = section(fe, 8 * batch, client_deadline, lam=batch, gap_s=2e-3,
                    seed=1)
        emit("serving_stream/frontend/bursty",
             r["wall"] / max(r["served"], 1) * 1e6,
             f"qps={r['served'] / r['wall']:.0f};p50_ms={r['p50']:.2f};"
             f"p99_ms={r['p99']:.2f};slo_ms={FRONTEND_SLO_MS};"
             f"slo_ok={int(r['p99'] <= FRONTEND_SLO_MS)};"
             f"shed_rate={(r['rejected'] + r['shed']) / 8 / batch:.3f}")

        # diurnal arrivals: rate swept through a full sinusoidal period
        total = 0
        refused_total = 0
        stats.request_ms.clear()
        t0 = time.perf_counter()
        for j in range(8):
            lam = max(1, int(batch / 2 * (1 + np.sin(2 * np.pi * j / 8))))
            served, refused, _ = _frontend_wave(
                fe, mixed[j * 2 * batch:][: 2 * lam], client_deadline,
                np.random.default_rng(100 + j), lam, 1e-3)
            assert served + refused == 2 * lam, "diurnal lost requests"
            total += served
            refused_total += refused
        wall = time.perf_counter() - t0
        p99 = stats.request_percentile_ms(99)
        emit("serving_stream/frontend/diurnal",
             wall / max(total, 1) * 1e6,
             f"qps={total / wall:.0f};"
             f"p50_ms={stats.request_percentile_ms(50):.2f};"
             f"p99_ms={p99:.2f};slo_ms={FRONTEND_SLO_MS};"
             f"slo_ok={int(p99 <= FRONTEND_SLO_MS)};"
             f"shed_rate={refused_total / max(total + refused_total, 1):.3f};"
             f"rounds=8")

    # sustained overload: a tiny queue + a tight deadline, offered load >>
    # capacity -- the frontend MUST shed/reject (loud backpressure), which
    # is exactly what keeps the SERVED requests' p99 under the SLO
    with frontend.ServingFrontend(guarded, capacity=batch,
                                  warmup=False) as fe_ov:
        r = section(fe_ov, 16 * batch, 50.0, lam=4 * batch, gap_s=1e-4,
                    seed=2)
    assert r["rejected"] + r["shed"] > 0, \
        "overload produced no backpressure: queue/deadline admission dead"
    assert r["p99"] <= FRONTEND_SLO_MS, \
        f"overload blew served p99 to {r['p99']:.1f}ms > SLO " \
        f"{FRONTEND_SLO_MS}ms instead of shedding"
    shed_rate = (r["rejected"] + r["shed"]) / (16 * batch)
    emit("serving_stream/frontend/overload",
         r["wall"] / max(r["served"], 1) * 1e6,
         f"qps={r['served'] / r['wall']:.0f};p99_ms={r['p99']:.2f};"
         f"slo_ms={FRONTEND_SLO_MS};slo_ok={int(r['p99'] <= FRONTEND_SLO_MS)};"
         f"shed_rate={shed_rate:.3f};rejected={r['rejected']};"
         f"shed={r['shed']};deadline_miss={r['miss']}")
    recompiles = counter["n"] - c0
    assert recompiles == 0, \
        f"frontend recompiled {recompiles}x after warmup: bucket-shape " \
        "contract broken"

    # swap staleness under a slowed background refresh: serving continues
    # on the stale state, then the supervised worker lands the swap. The
    # refresh path compiles its own (eager, host-loop) ops on first use --
    # reported as refresh_compiles, separate from the SERVING-step cache,
    # which is asserted frozen across the whole section.
    n_exec = engine.n_compiles
    c1 = counter["n"]
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0)
    stream = streaming.init_from_artifacts(arts, q_id[:512],
                                           refresh_every=256)
    slow = faults.slow_refresh(delay_s=0.05)
    worker = frontend.RefreshWorker(sup, stream, source="stored",
                                    refresh_fn=slow).start()
    v0 = guarded.version
    with frontend.ServingFrontend(guarded, capacity=8 * batch,
                                  warmup=False) as fe_st:
        worker.observe(QT[:batch])
        worker.request_refresh()
        stale_peak = 0.0
        served_during = 0
        t0 = time.perf_counter()
        while guarded.version == v0 and time.perf_counter() - t0 < 30:
            for q in mixed[served_during % batch::batch][:4]:
                try:
                    fe_st.enqueue(q).result(timeout=30)
                    served_during += 1
                except frontend.Rejected:
                    pass
            stale_peak = max(stale_peak, worker.staleness_s)
    worker.stop(timeout=2.0)
    assert guarded.version > v0, "slowed refresh never swapped"
    assert engine.n_compiles == n_exec, \
        f"background refresh grew the serving-step cache " \
        f"{n_exec} -> {engine.n_compiles}"
    emit("serving_stream/frontend/staleness", slow.delay_s * 1e6,
         f"stale_peak_ms={stale_peak * 1e3:.0f};"
         f"served_while_stale={served_during};swaps={guarded.version - v0};"
         f"cycles={worker.n_cycles};refresh_compiles={counter['n'] - c1};"
         f"serving_recompiles=0")


def _recall(engine, queries, k=10):
    live = streaming.live_mask(engine.state.artifacts)
    gt = np.nonzero(live)[0][vectors.exact_topk(
        queries, np.asarray(engine.state.artifacts.x_full)[live], k)]
    return float(metrics.recall_at_k(jnp.asarray(engine.submit(queries)),
                                     jnp.asarray(gt)))


def _run_faults(counter, batch: int = 32):
    """``serving_stream/faults/*``: the fault-tolerance section -- guarded
    swap rejection latency (non-finite scan, canary battery), the
    degrade -> recover -> swap cycle with recall measured while degraded,
    and the corrupted-snapshot restore fallback with its recompile count.
    Every row is DECLARED up front so ``run.py --smoke`` fails if a
    refactor silently skips one."""
    declare("serving_stream/faults/reject-nonfinite",
            "serving_stream/faults/reject-canary",
            "serving_stream/faults/recover-nan-moments",
            "serving_stream/faults/restore-fallback")
    n = min(BENCH_N, 4000)
    dim, d, c = 128, 32, 8
    n0 = int(n * 0.8)
    ds = vectors.make_dataset("serving-faults", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 4 * batch),
                              ood=True, seed=7)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, n0, 512)] \
        + 0.1 * rng.standard_normal((512, dim)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                   c=c, d=d)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:n0], model, capacity=n, sort_block=256,
        slack_blocks=2)
    engine = ServingEngine(msearch.make_state(arts), k=10, kappa=50,
                           batch_size=batch, dim=dim)
    guarded = lifecycle.GuardedEngine(engine, canary_queries=QT[:batch])
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0)
    stream = streaming.init_from_artifacts(arts, q_init, refresh_every=256)
    sup.note_queries(QT[: 4 * batch])
    probe = QT[: 2 * batch]
    # warm cycle: insert + supervised refresh through the guard
    arts2, _ = streaming.insert_rows(engine.state.artifacts, X[n0:])
    stream = streaming.insert(stream, X[n0:])
    guarded.swap(engine.state._replace(artifacts=arts2))
    stream, _ = sup.refresh_and_swap(stream, source="full")
    before = guarded.submit(probe)

    # guarded-swap rejection latency: non-finite scan, then canary battery
    for row, inject, _reason in (
            ("reject-nonfinite", faults.corrupt_scorer_leaf, "non-finite"),
            ("reject-canary", faults.scramble_scorer_leaf,
             "canary-overlap")):
        bad = inject(engine.state)
        t0 = time.perf_counter()
        try:
            guarded.swap(bad)
            raise AssertionError(f"{row}: corrupted state was accepted")
        except lifecycle.SwapRejected:
            t_reject = (time.perf_counter() - t0) * 1e6
        bitident = int(np.array_equal(guarded.submit(probe), before))
        emit(f"serving_stream/faults/{row}", t_reject,
             f"swaps_rejected={guarded.health.rejected};"
             f"bitident={bitident}")

    # degrade -> recover -> swap: poisoned Eq. 11 moments; the engine keeps
    # serving the stale-but-valid state (recall measured while degraded),
    # then the moments are rebuilt and the next refresh swaps clean
    stream, rep = sup.refresh_and_swap(faults.nan_moments(stream),
                                       source="stored")
    recall_degraded = _recall(engine, probe)
    t0 = time.perf_counter()
    stream = sup.recover(stream)
    stream, rep2 = sup.refresh_and_swap(stream, source="stored")
    t_recover = (time.perf_counter() - t0) * 1e6
    recall_recovered = _recall(engine, probe)
    emit("serving_stream/faults/recover-nan-moments", t_recover,
         f"degraded={sup.n_degraded};attempts={rep.attempts};"
         f"outcome={rep2.outcome};recall_degraded={recall_degraded:.3f};"
         f"recall_recovered={recall_recovered:.3f}")

    # corrupted-snapshot restore: truncate the newest step, fall back to
    # the previous one, reinstall through the guard -- zero recompiles
    before = guarded.submit(probe)
    snap = tempfile.mkdtemp(prefix="bench-snap-")
    try:
        lifecycle.snapshot(snap, engine.state, stream)
        lifecycle.snapshot(snap, engine.state, stream)
        faults.truncate_snapshot(snap, what="leaf")
        c0 = counter["n"]
        t0 = time.perf_counter()
        serving, _, got, _ = lifecycle.restore(snap, engine.state, stream)
        lifecycle.restore_into(guarded, serving)
        t_restore = (time.perf_counter() - t0) * 1e6
        bitident = int(np.array_equal(guarded.submit(probe), before))
        emit("serving_stream/faults/restore-fallback", t_restore,
             f"fallback={int(got == 0)};bitident={bitident};"
             f"recompiles={counter['n'] - c0}")
    finally:
        shutil.rmtree(snap, ignore_errors=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
