"""Streaming serving (Section 3.2) through the state-passing engine:
steady-state QPS / p50 / p99 under live traffic, hot-swap latency,
refresh-cycle cost, and -- the redesign's whole point -- recompile counts
per swap for the state-passing engine (0) vs the closure-rebuild baseline
the serving stack used before (1 full re-jit per artifact swap). Rows land
in ``BENCH_serving_stream.json`` via ``common.write_json_results``.

CPU wall times characterize the harness; the recompile counts and the
state-swap vs re-jit latency RATIO are the architecture's signal.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BENCH_QUERIES, BENCH_N, declare, emit,
                               rerank_traffic_bound, time_fn)
from repro.core import gleanvec as gv, metrics, streaming
from repro.core import search as msearch
from repro.data import vectors
from repro.serve import faults, lifecycle
from repro.serve.engine import ServingEngine, make_search_fn

MODES = ("gleanvec-int8", "gleanvec-int8-sorted")

# Smoke-enforced ceiling on measured host<->HBM rerank traffic relative to
# the m*kappa*D*4 lower bound (rerank_traffic_bound). The pipeline gathers
# exactly kappa rows per PADDED query, so batch padding is the only slack;
# 2x leaves room for a ragged final chunk without hiding an accidental
# full-store promotion (which would be n/(m*kappa) ~ 20x+ over the bound).
HOST_RERANK_MAX_RATIO = 2.0


def _compile_count():
    """Process-wide XLA backend-compile counter via jax.monitoring."""
    counter = {"n": 0}

    def listener(event, duration, **kwargs):
        if event == "/jax/core/compile/backend_compile_duration":
            counter["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(listener)
    return counter


def run(cycles: int = 3, batch: int = 64):
    n = min(BENCH_N, 8000)
    dim, d, c = 128, 32, 8
    n0 = int(n * 0.8)
    step = max(1, (n - n0) // (cycles + 1))   # +1: warmup cycle inserts too
    ds = vectors.make_dataset("serving-stream", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 4 * batch),
                              ood=True, seed=5)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, n0, 512)] \
        + 0.1 * rng.standard_normal((512, dim)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                   c=c, d=d)
    counter = _compile_count()

    for mode in MODES:
        arts = streaming.build_streaming_artifacts(
            mode, X[:n0], model, capacity=n, sort_block=256, slack_blocks=2)
        engine = ServingEngine(msearch.make_state(arts), k=10, kappa=50,
                               batch_size=batch, dim=dim)
        stream = streaming.init_from_artifacts(arts, q_init,
                                               refresh_every=step)
        # steady-state serving (post-warmup)
        engine.submit(QT[:batch])
        engine.stats.latencies_ms.clear()
        engine.stats.n_queries = engine.stats.n_batches = 0
        engine.stats.total_s = 0.0
        t_steady = time_fn(lambda: engine.submit(QT[:4 * batch]))
        s = engine.stats
        emit(f"serving_stream/steady-{mode}", t_steady / 4,
             f"qps={s.qps:.0f};p50_ms={s.percentile_ms(50):.2f};"
             f"p99_ms={s.percentile_ms(99):.2f}")

        # streaming refresh cycles: observe -> insert -> refresh -> swap;
        # cycle 0 is the warmup (compiles the eager host-loop ops once)
        # and is excluded from the recompile count and the timers
        c0, refresh_us, inserted, swaps0 = counter["n"], [], 0, 0
        for cycle in range(cycles + 1):
            obs = QT[(cycle * batch) % len(QT):][:batch]
            engine.submit(obs)
            stream = streaming.observe_queries(stream, jnp.asarray(obs))
            rows = X[n0 + cycle * step: n0 + (cycle + 1) * step]
            t0 = time.perf_counter()
            arts2, _ = streaming.insert_rows(engine.state.artifacts, rows)
            engine.swap(engine.state._replace(artifacts=arts2))
            stream = streaming.insert(stream, rows)
            stream = streaming.refresh(stream)
            engine.swap(streaming.refresh_state(engine.state, stream,
                                                source="full"))
            jax.block_until_ready(engine.state.artifacts.scorer)
            refresh_us.append((time.perf_counter() - t0) * 1e6)
            inserted += rows.shape[0]
            if cycle == 0:      # end of warmup: start counting
                c0, refresh_us, inserted = counter["n"], [], 0
                engine.stats.swap_ms.clear()
                swaps0 = engine.n_swaps
        recompiles = counter["n"] - c0
        swap_us = float(np.median(engine.stats.swap_ms)) * 1e3
        emit(f"serving_stream/swap-{mode}", swap_us,
             f"recompiles={recompiles};cycles={cycles};"
             f"inserted={inserted};swaps={engine.n_swaps - swaps0}")
        emit(f"serving_stream/refresh_cycle-{mode}",
             float(np.median(refresh_us)),
             f"recompiles={recompiles};rows_per_cycle={step}")

        # post-stream quality on the drifted distribution
        live = streaming.live_mask(engine.state.artifacts)
        gt = np.nonzero(live)[0][vectors.exact_topk(
            QT[:128], np.asarray(engine.state.artifacts.x_full)[live], 10)]
        rec = float(metrics.recall_at_k(
            jnp.asarray(engine.submit(QT[:128])), jnp.asarray(gt)))
        emit(f"serving_stream/recall-{mode}", 0.0, f"recall10={rec:.3f}")

        # the pre-redesign baseline: every artifact swap rebuilds + re-jits
        # the closure -- measure one full re-jit + first batch per swap
        c1 = counter["n"]
        t0 = time.perf_counter()
        fn = jax.jit(make_search_fn(engine.state.artifacts, k=10, kappa=50))
        jax.block_until_ready(fn(jnp.asarray(QT[:batch])))
        rebuild_us = (time.perf_counter() - t0) * 1e6
        emit(f"serving_stream/rebuild_swap-{mode}", rebuild_us,
             f"recompiles={counter['n'] - c1};"
             f"speedup={rebuild_us / max(swap_us, 1e-9):.0f}x")

    _run_faults(counter, batch=batch)
    _run_host_rerank(counter, batch=batch)


def _run_host_rerank(counter, batch: int = 32):
    """``serving_stream/host_rerank/*``: the two-level memory hierarchy.
    The same engine serves the same traffic twice -- full-D store in HBM
    vs demoted to the host tier (double-buffered kappa-row prefetch) --
    and the section asserts the hierarchy's three contracts: exact
    (value, id) parity, zero recompiles during steady serving, and
    measured host<->HBM traffic within HOST_RERANK_MAX_RATIO of the
    m*kappa*D*4 bound. The qps_ratio is reported UNASSERTED: on CPU both
    "tiers" are the same DRAM, so wall-clock parity is a harness check,
    not the hardware signal."""
    declare("serving_stream/host_rerank/steady",
            "serving_stream/host_rerank/bytes")
    n = min(BENCH_N, 4000)
    dim, d, c = 128, 32, 8
    n0 = int(n * 0.8)
    ds = vectors.make_dataset("serving-hostrr", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 4 * batch),
                              ood=True, seed=11)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, n0, 512)] \
        + 0.1 * rng.standard_normal((512, dim)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                   c=c, d=d)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:n0], model, capacity=n, sort_block=256,
        slack_blocks=2)
    arts_host = msearch.demote_rerank_tier(arts)

    engines = {}
    for tier, a in (("hbm", arts), ("host", arts_host)):
        eng = ServingEngine(msearch.make_state(a), k=10, kappa=50,
                            batch_size=batch, dim=dim)
        eng.submit(QT[:batch])              # warmup: compile both stages
        eng.stats.latencies_ms.clear()
        eng.stats.n_queries = eng.stats.n_batches = 0
        eng.stats.total_s = 0.0
        eng.stats.host_bytes = eng.stats.host_bytes_lb = 0
        engines[tier] = eng

    # exact (value, id) parity on identical traffic, both tiers
    ids_hbm = np.asarray(engines["hbm"].submit(QT[:2 * batch]))
    ids_host = np.asarray(engines["host"].submit(QT[:2 * batch]))
    if not np.array_equal(ids_hbm, ids_host):
        raise AssertionError(
            "host-tier rerank diverged from the all-HBM engine")

    c0 = counter["n"]
    t_hbm = time_fn(lambda: engines["hbm"].submit(QT[:4 * batch]))
    t_host = time_fn(lambda: engines["host"].submit(QT[:4 * batch]))
    recompiles = counter["n"] - c0
    if recompiles:
        raise AssertionError(
            f"steady host-tier serving recompiled {recompiles}x")
    s = engines["host"].stats
    emit("serving_stream/host_rerank/steady", t_host / 4,
         f"qps={s.qps:.0f};qps_ratio={t_hbm / max(t_host, 1e-9):.2f};"
         f"p50_ms={s.percentile_ms(50):.2f};parity=1;"
         f"prefetch_p50_ms={float(np.median(s.prefetch_ms)):.2f}")

    # traffic accounting: measured bytes vs the m*kappa*D*4 bound
    bound = rerank_traffic_bound(s.n_queries, engines["host"].kappa, dim)
    ratio = s.host_bytes / max(bound, 1)
    if ratio > HOST_RERANK_MAX_RATIO:
        raise AssertionError(
            f"host<->HBM rerank traffic {s.host_bytes}B exceeds "
            f"{HOST_RERANK_MAX_RATIO}x the m*kappa*D*4 bound {bound}B")
    emit("serving_stream/host_rerank/bytes", 0.0,
         f"host_mb={s.host_bytes / 2**20:.2f};ratio={ratio:.2f};"
         f"max_ratio={HOST_RERANK_MAX_RATIO};recompiles={recompiles};"
         f"store_mb={n * dim * 4 / 2**20:.2f}")


def _recall(engine, queries, k=10):
    live = streaming.live_mask(engine.state.artifacts)
    gt = np.nonzero(live)[0][vectors.exact_topk(
        queries, np.asarray(engine.state.artifacts.x_full)[live], k)]
    return float(metrics.recall_at_k(jnp.asarray(engine.submit(queries)),
                                     jnp.asarray(gt)))


def _run_faults(counter, batch: int = 32):
    """``serving_stream/faults/*``: the fault-tolerance section -- guarded
    swap rejection latency (non-finite scan, canary battery), the
    degrade -> recover -> swap cycle with recall measured while degraded,
    and the corrupted-snapshot restore fallback with its recompile count.
    Every row is DECLARED up front so ``run.py --smoke`` fails if a
    refactor silently skips one."""
    declare("serving_stream/faults/reject-nonfinite",
            "serving_stream/faults/reject-canary",
            "serving_stream/faults/recover-nan-moments",
            "serving_stream/faults/restore-fallback")
    n = min(BENCH_N, 4000)
    dim, d, c = 128, 32, 8
    n0 = int(n * 0.8)
    ds = vectors.make_dataset("serving-faults", n=n, d=dim,
                              n_queries=max(BENCH_QUERIES, 4 * batch),
                              ood=True, seed=7)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, n0, 512)] \
        + 0.1 * rng.standard_normal((512, dim)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                   c=c, d=d)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:n0], model, capacity=n, sort_block=256,
        slack_blocks=2)
    engine = ServingEngine(msearch.make_state(arts), k=10, kappa=50,
                           batch_size=batch, dim=dim)
    guarded = lifecycle.GuardedEngine(engine, canary_queries=QT[:batch])
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0)
    stream = streaming.init_from_artifacts(arts, q_init, refresh_every=256)
    sup.note_queries(QT[: 4 * batch])
    probe = QT[: 2 * batch]
    # warm cycle: insert + supervised refresh through the guard
    arts2, _ = streaming.insert_rows(engine.state.artifacts, X[n0:])
    stream = streaming.insert(stream, X[n0:])
    guarded.swap(engine.state._replace(artifacts=arts2))
    stream, _ = sup.refresh_and_swap(stream, source="full")
    before = guarded.submit(probe)

    # guarded-swap rejection latency: non-finite scan, then canary battery
    for row, inject, _reason in (
            ("reject-nonfinite", faults.corrupt_scorer_leaf, "non-finite"),
            ("reject-canary", faults.scramble_scorer_leaf,
             "canary-overlap")):
        bad = inject(engine.state)
        t0 = time.perf_counter()
        try:
            guarded.swap(bad)
            raise AssertionError(f"{row}: corrupted state was accepted")
        except lifecycle.SwapRejected:
            t_reject = (time.perf_counter() - t0) * 1e6
        bitident = int(np.array_equal(guarded.submit(probe), before))
        emit(f"serving_stream/faults/{row}", t_reject,
             f"swaps_rejected={guarded.health.rejected};"
             f"bitident={bitident}")

    # degrade -> recover -> swap: poisoned Eq. 11 moments; the engine keeps
    # serving the stale-but-valid state (recall measured while degraded),
    # then the moments are rebuilt and the next refresh swaps clean
    stream, rep = sup.refresh_and_swap(faults.nan_moments(stream),
                                       source="stored")
    recall_degraded = _recall(engine, probe)
    t0 = time.perf_counter()
    stream = sup.recover(stream)
    stream, rep2 = sup.refresh_and_swap(stream, source="stored")
    t_recover = (time.perf_counter() - t0) * 1e6
    recall_recovered = _recall(engine, probe)
    emit("serving_stream/faults/recover-nan-moments", t_recover,
         f"degraded={sup.n_degraded};attempts={rep.attempts};"
         f"outcome={rep2.outcome};recall_degraded={recall_degraded:.3f};"
         f"recall_recovered={recall_recovered:.3f}")

    # corrupted-snapshot restore: truncate the newest step, fall back to
    # the previous one, reinstall through the guard -- zero recompiles
    before = guarded.submit(probe)
    snap = tempfile.mkdtemp(prefix="bench-snap-")
    try:
        lifecycle.snapshot(snap, engine.state, stream)
        lifecycle.snapshot(snap, engine.state, stream)
        faults.truncate_snapshot(snap, what="leaf")
        c0 = counter["n"]
        t0 = time.perf_counter()
        serving, _, got, _ = lifecycle.restore(snap, engine.state, stream)
        lifecycle.restore_into(guarded, serving)
        t_restore = (time.perf_counter() - t0) * 1e6
        bitident = int(np.array_equal(guarded.submit(probe), before))
        emit("serving_stream/faults/restore-fallback", t_restore,
             f"fallback={int(got == 0)};bitident={bitident};"
             f"recompiles={counter['n'] - c0}")
    finally:
        shutil.rmtree(snap, ignore_errors=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
