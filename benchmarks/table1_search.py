"""Paper Table 1 / throughput axis: end-to-end multi-step search QPS and
recall at the paper's operating point (10-recall@10 target ~0.9) for
full-precision vs LeanVec-Sphering vs GleanVec databases, flat and graph
indices, plus the int8-quantized variant (LVQ on top of Bx).

CPU wall times characterize relative speedups (D/d bandwidth scaling);
absolute TPU numbers come from the roofline analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_fn
from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core.quantization import quantize
from repro.core.scorer import (gleanvec_quantized_scorer,
                               sorted_gleanvec_quantized_scorer,
                               sorted_gleanvec_scorer)
from repro.index import bruteforce, graph


def run():
    ds = dataset("laion-OOD")
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    dim = X.shape[1]
    d = dim // 4
    kappa = 50
    nq = QT.shape[0]

    def finish(cand):
        vecs = X[jnp.where(cand >= 0, cand, 0)]
        full = jnp.einsum("mkd,md->mk", vecs, QT)
        top = jax.lax.top_k(jnp.where(cand >= 0, full, -3.4e38), 10)[1]
        return jnp.take_along_axis(cand, top, axis=1)

    # full-D flat (baseline search)
    us = time_fn(lambda: bruteforce.search(QT, X, 10)[1])
    _, ids = bruteforce.search(QT, X, 10)
    emit("table1/flat/fullD", us,
         f"recall10={float(metrics.recall_at_k(ids, gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    # sphering flat + rerank
    m = lvs.fit(Q, X, d)
    q_low = QT @ m.a.T
    x_low = X @ m.b.T

    def sphering_search():
        _, cand = bruteforce.search(q_low, x_low, kappa)
        return finish(cand)

    us = time_fn(sphering_search)
    emit(f"table1/flat/sphering-d{d}", us,
         f"recall10={float(metrics.recall_at_k(sphering_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    # gleanvec flat + rerank
    model = gv.fit(jax.random.PRNGKey(0), Q, X, c=48, d=d)
    tags, xg_low = gv.encode_database(model, X)
    q_views = gv.project_queries_eager(model, QT)

    def gleanvec_search():
        _, cand = bruteforce.search_gleanvec(q_views, tags, xg_low, kappa)
        return finish(cand)

    us = time_fn(gleanvec_search)
    emit(f"table1/flat/gleanvec-d{d}", us,
         f"recall10={float(metrics.recall_at_k(gleanvec_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    # int8-quantized sphering (compounded compression)
    db = quantize(x_low)

    def sq_search():
        _, cand = bruteforce.search_quantized(q_low, db.codes, db.lo,
                                              db.delta, kappa)
        return finish(cand)

    us = time_fn(sq_search)
    emit(f"table1/flat/sphering-d{d}-int8", us,
         f"recall10={float(metrics.recall_at_k(sq_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    # gleanvec + per-cluster int8 (Scorer-protocol composition: DR stacked
    # with SQ -- d bytes per vector instead of D*4)
    gq = gleanvec_quantized_scorer(model, X)

    def gq_search():
        _, cand = bruteforce.search_scorer(QT, gq, kappa)
        return finish(cand)

    us = time_fn(gq_search)
    emit(f"table1/flat/gleanvec-d{d}-int8", us,
         f"recall10={float(metrics.recall_at_k(gq_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    # tag-sorted (cluster-contiguous) layouts: one query view per block, so
    # the scan is a plain matmul (f32) / int8 matmul + offset (int8) -- the
    # Scorer protocol translates the sorted row order back to original ids.
    sgl = sorted_gleanvec_scorer(model, X, block=256)

    def sorted_search():
        _, cand = bruteforce.search_scorer(QT, sgl, kappa)
        return finish(cand)

    us = time_fn(sorted_search)
    emit(f"table1/flat/gleanvec-d{d}-sorted", us,
         f"recall10={float(metrics.recall_at_k(sorted_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    sgq = sorted_gleanvec_quantized_scorer(model, X, block=256)

    def sorted_sq_search():
        _, cand = bruteforce.search_scorer(QT, sgq, kappa)
        return finish(cand)

    us = time_fn(sorted_sq_search)
    emit(f"table1/flat/gleanvec-d{d}-int8-sorted", us,
         f"recall10="
         f"{float(metrics.recall_at_k(sorted_sq_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")

    # graph index (reduced space) + rerank
    g = graph.build(np.asarray(xg_low), r=24, n_iters=5, seed=0)

    def graph_search():
        _, cand = graph.beam_search_gleanvec(q_views, tags, xg_low, g,
                                             k=kappa, beam=96, max_hops=200)
        return finish(cand)

    us = time_fn(graph_search)
    emit(f"table1/graph/gleanvec-d{d}", us,
         f"recall10={float(metrics.recall_at_k(graph_search(), gt)):.3f};"
         f"qps={nq / (us / 1e6):.0f}")


if __name__ == "__main__":
    run()
