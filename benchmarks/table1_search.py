"""Paper Table 1 / throughput axis: end-to-end multi-step search QPS and
recall at the paper's operating point (10-recall@10 target ~0.9) for
full-precision vs LeanVec-Sphering vs GleanVec databases across the Index
protocol's traversals: flat scan, graph, IVF with the full-D vs
reduced-space coarse probe toggle, and the sharded (4-way) IVF / graph
placements. Rows land in ``BENCH_table1_search.json`` via
``common.write_json_results``.

CPU wall times characterize relative speedups (D/d bandwidth scaling);
absolute TPU numbers come from the roofline analysis. The ``probe_flops``
derived field on the IVF rows is the compiled coarse-step cost
(``normalize_cost``): the ``ivf-rprobe`` row must show ~D/d fewer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, declare, emit, time_fn
from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core.quantization import quantize
from repro.core.scorer import (gleanvec_quantized_scorer, gleanvec_scorer,
                               sorted_gleanvec_quantized_scorer,
                               sorted_gleanvec_scorer)
from repro.index import bruteforce, distributed, graph, ivf
from repro.index.protocol import replace
from repro.kernels.graph_scan import beam_step_bytes, fresh_slab_count
from repro.kernels.ivf_scan import fine_step_bytes
from repro.utils import hlo_analysis

# Regression guard (smoke-enforced): the fused beam step's cost-modelled
# per-hop HBM bytes must sit at least this far below the compiled gathered
# hop's, even at smoke shapes (n=1500 measures ~2.75x; the paper-
# proportioned >= 3x floor is asserted in tests/test_graph_scan.py).
GRAPH_FUSED_MIN_RATIO = 2.0


def _probe_flops(index, scorer, queries) -> float:
    """Compiled cost of the coarse step alone (the R^d assertion's data)."""
    qs = index.prepare_queries(scorer, queries)
    cost = hlo_analysis.normalize_cost(
        jax.jit(ivf.coarse_scores).lower(index, qs).compile()
        .cost_analysis())
    return float(cost.get("flops", 0.0))


def _fine_bytes_gathered(index, scorer, queries, kappa) -> float:
    """Compiled HBM bytes of the GATHERED fine step (``_probe_and_score``:
    posting-list gather + ``score_ids``), via ``normalize_cost``."""
    qs = index.prepare_queries(scorer, queries)
    cost = hlo_analysis.normalize_cost(
        ivf._probe_and_score.lower(qs, scorer, index, kappa).compile()
        .cost_analysis())
    return float(cost.get("bytes accessed", 0.0))


def _fine_bytes_fused(index, scorer, m: int, kappa: int) -> float:
    """HBM bytes of the FUSED range-scan fine step: the kernel's traffic
    is fixed by its BlockSpecs (``fine_step_bytes``), with the expected
    schedule occupancy = nprobe * (mean blocks per cluster) slabs/query."""
    ranges = np.asarray(scorer.list_block_ranges)
    blocks_per_cluster = (ranges >= 0).sum() / ranges.shape[0]
    visited = m * index.nprobe * blocks_per_cluster
    rows = getattr(scorer, "codes", None)
    if rows is None:
        rows = scorer.x_low
    return fine_step_bytes(m, visited, scorer.layout_block, rows.shape[1],
                           ranges.shape[0],
                           code_bytes=np.dtype(rows.dtype).itemsize,
                           k=kappa)


def _beam_step_bytes_gathered(scorer, queries, nbr_tbl, beam, e, best):
    """Compiled HBM bytes of one GATHERED hop merge (neighbor gather +
    ``score_ids`` + top_k merge), via ``normalize_cost``."""
    m = queries.shape[0]
    qs = scorer.prepare_queries(queries)
    vals = jnp.full((m, beam), -3.4e38)
    ids = jnp.full((m, beam), -1, jnp.int32)
    vis = jnp.zeros((m, beam), bool)
    ok = jnp.ones((m, e), bool)

    def hop(scorer, qs, nbr_tbl, vals, ids, vis, best, ok):
        def score_ids(cids):
            return scorer.score_ids(qs, jnp.where(cids >= 0, cids, 0))
        return graph.gathered_beam_step(score_ids, nbr_tbl, vals, ids,
                                        vis, best, ok, beam)

    cost = hlo_analysis.normalize_cost(
        jax.jit(hop).lower(scorer, qs, nbr_tbl, vals, ids, vis,
                           jnp.asarray(best), ok).compile()
        .cost_analysis())
    return float(cost.get("bytes accessed", 0.0))


def _beam_step_bytes_fused(gf, scorer, c, beam, best):
    """HBM bytes of the same hop through the fused kernel: fixed by the
    BlockSpecs + the tn-slab schedule over the hop's ACTUAL fresh-slab
    count (``beam_step_bytes``)."""
    m = best.shape[0]
    nrows = np.asarray(gf.nbr_rows)[best].reshape(m, -1)
    rows = getattr(scorer, "codes", None)
    if rows is None:
        rows = scorer.x_low
    return beam_step_bytes(m, fresh_slab_count(nrows, gf.scan_tn),
                           gf.scan_tn, rows.shape[1], c, beam,
                           nrows.shape[1],
                           code_bytes=np.dtype(rows.dtype).itemsize)


def run():
    declare("table1_search/flat/", "table1_search/ivf/",
            "table1_search/ivf-rprobe/", "table1_search/ivf-sorted-fused/",
            "table1_search/ivf-sharded/", "table1_search/graph/",
            "table1_search/graph-expand1/", "table1_search/graph-expand4/",
            "table1_search/graph-fused/", "table1_search/graph-sharded/",
            "table1_search/graph-build-numpy/",
            "table1_search/graph-build-device/")
    ds = dataset("laion-OOD")
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    dim = X.shape[1]
    d = dim // 4
    kappa = 50
    nq = QT.shape[0]

    def finish(cand):
        vecs = X[jnp.where(cand >= 0, cand, 0)]
        full = jnp.einsum("mkd,md->mk", vecs, QT)
        top = jax.lax.top_k(jnp.where(cand >= 0, full, -3.4e38), 10)[1]
        return jnp.take_along_axis(cand, top, axis=1)

    def bench(name, search, extra=""):
        us = time_fn(search)
        rec = float(metrics.recall_at_k(search(), gt))
        emit(f"table1_search/{name}", us,
             f"recall10={rec:.3f};qps={nq / (us / 1e6):.0f}" + extra)

    # full-D flat (baseline search)
    bench("flat/fullD", lambda: finish(bruteforce.search(QT, X, 10)[1]))

    # sphering flat + rerank
    m = lvs.fit(Q, X, d)
    q_low = QT @ m.a.T
    x_low = X @ m.b.T
    bench(f"flat/sphering-d{d}",
          lambda: finish(bruteforce.search(q_low, x_low, kappa)[1]))

    # gleanvec flat + rerank
    model = gv.fit(jax.random.PRNGKey(0), Q, X, c=48, d=d)
    tags, xg_low = gv.encode_database(model, X)
    q_views = gv.project_queries_eager(model, QT)
    bench(f"flat/gleanvec-d{d}",
          lambda: finish(bruteforce.search_gleanvec(q_views, tags, xg_low,
                                                    kappa)[1]))

    # int8-quantized sphering (compounded compression)
    db = quantize(x_low)
    bench(f"flat/sphering-d{d}-int8",
          lambda: finish(bruteforce.search_quantized(
              q_low, db.codes, db.lo, db.delta, kappa)[1]))

    # gleanvec + per-cluster int8 (Scorer-protocol composition: DR stacked
    # with SQ -- d bytes per vector instead of D*4)
    gq = gleanvec_quantized_scorer(model, X)
    bench(f"flat/gleanvec-d{d}-int8",
          lambda: finish(bruteforce.search_scorer(QT, gq, kappa)[1]))

    # tag-sorted (cluster-contiguous) layouts: one query view per block, so
    # the scan is a plain matmul (f32) / int8 matmul + offset (int8) -- the
    # Scorer protocol translates the sorted row order back to original ids.
    sgl = sorted_gleanvec_scorer(model, X, block=256)
    bench(f"flat/gleanvec-d{d}-sorted",
          lambda: finish(bruteforce.search_scorer(QT, sgl, kappa)[1]))

    sgq = sorted_gleanvec_quantized_scorer(model, X, block=256)
    bench(f"flat/gleanvec-d{d}-int8-sorted",
          lambda: finish(bruteforce.search_scorer(QT, sgq, kappa)[1]))

    # IVF through the Index protocol: full-D coarse probe vs the centers
    # projected into the scorer's reduced space (same nprobe, same lists;
    # probe_flops is the compiled coarse-step cost -- the rprobe row moves
    # ~D/d fewer)
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=32)
    ivr = ivf.with_reduced_centers(iv, gq, model)
    for name, index in ((f"ivf/gleanvec-d{d}-int8", iv),
                        (f"ivf-rprobe/gleanvec-d{d}-int8", ivr)):
        bench(name,
              lambda index=index: finish(
                  ivf.search_scorer(QT, gq, index, k=kappa, nprobe=8)[1]),
              extra=f";probe_flops={_probe_flops(index, gq, QT):.0f}")

    # fused sorted-IVF range scan: the coarse quantizer IS the GleanVec
    # clustering (build_aligned), so the fine step streams the probed
    # clusters' single-tag slabs (scan_lists) -- no posting-list gather,
    # no (m, nprobe*L) matrix. fine_bytes is the range-scan kernel's
    # BlockSpec-determined HBM traffic; fine_bytes_gathered is the
    # compiled gathered fine step's (normalize_cost) for the same probe.
    iva = ivf.build_aligned(model, X, nprobe=8)
    fb_fused = _fine_bytes_fused(iva, sgq, nq, kappa)
    fb_gather = _fine_bytes_gathered(replace(iva, aligned_layout=False),
                                     sgq, QT, kappa)
    bench(f"ivf-sorted-fused/gleanvec-d{d}-int8-sorted",
          lambda: finish(iva.search(QT, sgq, kappa)[1]),
          extra=f";fine_bytes={fb_fused:.0f}"
                f";fine_bytes_gathered={fb_gather:.0f}"
                f";vs_gathered_bytes={fb_gather / fb_fused:.1f}x")

    # graph index (reduced space) + rerank
    g = graph.build(np.asarray(xg_low), r=24, n_iters=5, seed=0)
    bench(f"graph/gleanvec-d{d}",
          lambda: finish(graph.beam_search_gleanvec(
              q_views, tags, xg_low, g, k=kappa, beam=96,
              max_hops=200)[1]))

    # multi-expansion beam search: expand=E pops the top-E frontier
    # vertices per hop (E x fewer while_loop iterations, E x wider MXU
    # contractions); expand=1 is the classic traversal. hops comes from
    # the traced traversal at matched beam/recall.
    gsc = gleanvec_scorer(model, X)
    for e in (1, 4):
        _, _, hops, _ = graph.beam_search_scorer(
            QT, gsc, g, k=kappa, beam=96, max_hops=200, expand=e,
            trace=True)
        bench(f"graph-expand{e}/gleanvec-d{d}",
              lambda e=e: finish(graph.beam_search_scorer(
                  QT, gsc, g, k=kappa, beam=96, max_hops=200,
                  expand=e)[1]),
              extra=f";hops={int(hops)}")

    # gather-free fused traversal: the graph bound to the tag-sorted int8
    # layout (with_fused_scan), every hop a graph_scan kernel launch --
    # no (m, expand*R) neighbor gather, no (m, beam+expand*R) merge
    # matrix in HBM. fine_bytes is the kernel's schedule-determined
    # per-hop traffic on a representative frontier; vs_gathered compares
    # the compiled gathered hop on the SAME frontier.
    gfused = graph.with_fused_scan(
        replace(g, beam=96, max_hops=200, expand=4), sgq)
    _, _, ghops, _ = graph._beam_qstate(sgq.prepare_queries(QT), sgq,
                                        gfused, kappa, 96, 200, expand=4)
    rng = np.random.default_rng(0)
    frontier = rng.integers(0, X.shape[0], size=(nq, 4)).astype(np.int32)
    hb_fused = _beam_step_bytes_fused(gfused, sgq, model.n_clusters, 96,
                                      frontier)
    hb_gather = _beam_step_bytes_gathered(sgq, QT, gfused.neighbors, 96,
                                          4, frontier)
    if hb_fused * GRAPH_FUSED_MIN_RATIO > hb_gather:
        raise RuntimeError(
            f"fused beam step regression: only {hb_gather / hb_fused:.2f}x "
            f"below the gathered hop (declared {GRAPH_FUSED_MIN_RATIO}x)")
    bench(f"graph-fused/gleanvec-d{d}-int8-sorted",
          lambda: finish(gfused.search(QT, sgq, kappa)[1]),
          extra=f";hops={int(ghops)}"
                f";fine_bytes={hb_fused:.0f}"
                f";vs_gathered={hb_gather / hb_fused:.1f}x")

    # graph construction: numpy NN-descent vs the on-device CAGRA-style
    # build (fused-kernel k-NN self-join + rank pruning) -- the default
    # at n >= 8192 via build(method="auto").
    for method in ("numpy", "device"):
        built = {}

        def build_once(method=method, built=built):
            built["g"] = graph.build(np.asarray(xg_low), r=24, n_iters=5,
                                     seed=0, method=method)
            return built["g"].neighbors

        us = time_fn(build_once, warmup=0, iters=1)
        gb = built["g"]
        rec = float(metrics.recall_at_k(
            finish(graph.beam_search_scorer(QT, gsc, gb, k=kappa, beam=96,
                                            max_hops=200)[1]), gt))
        emit(f"table1_search/graph-build-{method}/gleanvec-d{d}", us,
             f"recall10={rec:.3f};n={X.shape[0]};r=24")

    # sharded placements (4 shards; mesh-free reference path on one chip,
    # the same per-shard searches shard_map distributes on a real mesh)
    n_shards = next(s for s in (4, 2, 1) if X.shape[0] % s == 0)
    sh_iv, st_iv = distributed.build_sharded_index(
        "ivf", "gleanvec-int8", X, model, n_shards=n_shards,
        key=jax.random.PRNGKey(1), n_lists=32, nprobe=8)
    bench(f"ivf-sharded/gleanvec-d{d}-int8",
          lambda: finish(sh_iv.search(QT, st_iv, kappa)[1]))

    sh_g, st_g = distributed.build_sharded_index(
        "graph", "gleanvec", X, model, n_shards=n_shards, beam=96,
        max_hops=200, graph_kwargs={"r": 16, "n_iters": 4, "seed": 0})
    bench(f"graph-sharded/gleanvec-d{d}",
          lambda: finish(sh_g.search(QT, st_g, kappa)[1]))


if __name__ == "__main__":
    run()
