"""Quickstart: learn LeanVec-Sphering + GleanVec on synthetic OOD data and
run the multi-step search (paper Algorithms 1-5) through the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.data import vectors
from repro.index import bruteforce


def main():
    print("== GleanVec quickstart ==")
    ds = vectors.make_dataset("demo-OOD", n=20_000, d=256, n_queries=256,
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    d = 64
    print(f"database {X.shape}, queries {QT.shape}, target d={d}")

    # --- linear: LeanVec-Sphering (Algorithm 2) ---------------------------
    model = lvs.fit(Q, X, d)
    q_low, x_low = QT @ model.a.T, X @ model.b.T
    _, cand = bruteforce.search(q_low, x_low, 50)
    # rerank (Algorithm 1 line 3)
    vecs = X[cand]
    ids = jnp.take_along_axis(
        cand, jax.lax.top_k(jnp.einsum("mkd,md->mk", vecs, QT), 10)[1], 1)
    print(f"LeanVec-Sphering  recall@10 = "
          f"{float(metrics.recall_at_k(ids, gt)):.3f} "
          f"(bandwidth saved: {X.shape[1] / d:.1f}x)")

    # --- nonlinear: GleanVec (Algorithm 5) --------------------------------
    gmodel = gv.fit(jax.random.PRNGKey(0), Q, X, c=16, d=d)
    tags, xg_low = gv.encode_database(gmodel, X)
    q_views = gv.project_queries_eager(gmodel, QT)      # Algorithm 4
    _, cand = bruteforce.search_gleanvec(q_views, tags, xg_low, 50)
    vecs = X[cand]
    ids = jnp.take_along_axis(
        cand, jax.lax.top_k(jnp.einsum("mkd,md->mk", vecs, QT), 10)[1], 1)
    print(f"GleanVec (C=16)   recall@10 = "
          f"{float(metrics.recall_at_k(ids, gt)):.3f} "
          f"(+1 tag byte/vector)")

    # --- flexible d at runtime (Section 3.1) ------------------------------
    full = lvs.full_rotation_model(Q, X)
    x_store = X @ full.b.T
    for d_run in (32, 64, 128):
        q_run = QT @ full.a[:d_run].T
        _, cand = bruteforce.search(q_run, x_store[:, :d_run], 50)
        vecs = x_store[cand]                        # rerank from SAME store
        q_rot = QT @ full.a.T
        ids = jnp.take_along_axis(
            cand, jax.lax.top_k(jnp.einsum("mkd,md->mk", vecs, q_rot),
                                10)[1], 1)
        print(f"flexible-d d={d_run:4d} recall@10 = "
              f"{float(metrics.recall_at_k(ids, gt)):.3f} "
              f"(same stored vectors)")


if __name__ == "__main__":
    main()
