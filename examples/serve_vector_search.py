"""End-to-end serving driver (the paper's deployment scenario): build a
GleanVec index over a vector collection and serve batched queries through
the state-passing ServingEngine, reporting QPS / latency percentiles /
recall -- then hot-swap a refreshed state with zero recompiles.

    PYTHONPATH=src python examples/serve_vector_search.py [--n 50000]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, metrics
from repro.core import search as msearch
from repro.data import vectors
from repro.serve.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=48)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kappa", type=int, default=50)
    args = ap.parse_args()

    print(f"== building collection n={args.n} D={args.dim} ==")
    ds = vectors.make_dataset("serve-OOD", n=args.n, d=args.dim,
                              n_queries=512, ood=True, seed=0)
    X = jnp.asarray(ds.database)
    gmodel = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn), X,
                    c=args.clusters, d=args.d)
    artifacts = msearch.build_artifacts("gleanvec", X, gmodel)
    print(f"encoded: {args.dim * 4}B -> {args.d * 4 + 1}B per vector "
          f"({args.dim * 4 / (args.d * 4 + 1):.1f}x bandwidth saving)")

    print("== compiling + serving ==")
    engine = ServingEngine(msearch.make_state(artifacts), k=10,
                           kappa=args.kappa, batch_size=args.batch,
                           dim=args.dim)
    ids = engine.submit(ds.queries_test)
    rec = metrics.recall_at_k(jnp.asarray(ids),
                              jnp.asarray(ds.gt[:, :10]))
    s = engine.stats
    print(f"queries={s.n_queries} batches={s.n_batches}")
    print(f"QPS={s.qps:.0f}  p50={s.percentile_ms(50):.1f}ms  "
          f"p99={s.percentile_ms(99):.1f}ms  recall@10={float(rec):.3f}")

    # the artifacts are a pytree ARGUMENT of the compiled step, so a
    # same-treedef update (here: a refit on the served query traffic)
    # swaps in without recompiling anything
    refit = gv.fit(jax.random.PRNGKey(1), jnp.asarray(ds.queries_test), X,
                   c=args.clusters, d=args.d)
    engine.swap(engine.state._replace(
        artifacts=msearch.build_artifacts("gleanvec", X, refit)))
    engine.submit(ds.queries_test[: args.batch])
    print(f"hot-swapped refit model: version={engine.version} "
          f"swap_p50={np.median(engine.stats.swap_ms):.2f}ms "
          f"compiles={engine.n_compiles} (still the warmup executable)")


if __name__ == "__main__":
    main()
