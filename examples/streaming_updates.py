"""Streaming vector search (paper Section 3.2): inserts/deletes with moment
tracking, periodic refresh, and Eq.-12 reprojection of the stored vectors.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import linalg, metrics, streaming
from repro.data import vectors
from repro.index import bruteforce


def main():
    ds = vectors.make_dataset("stream-OOD", n=12_000, d=128, n_queries=128,
                              ood=True, seed=3)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    n0 = 8000

    st = streaming.init(linalg.second_moment(Q),
                        linalg.second_moment(X[:n0]), d=128,
                        refresh_every=1000)
    x_store = X[:n0] @ st.model.b.T
    print(f"initial store: {x_store.shape}")

    # stream in the remaining vectors; refresh + reproject at boundaries
    inserted = n0
    for start in range(n0, 12_000, 1000):
        for i in range(start, min(start + 1000, 12_000)):
            st = streaming.insert(st, X[i])
        new = X[start:start + 1000] @ st.model.b.T
        x_store = jnp.concatenate([x_store, new], axis=0)
        inserted += 1000
        if bool(streaming.needs_refresh(st)):
            st = streaming.refresh(st)
            x_store = streaming.reproject(st, x_store)   # Eq. 12
            print(f"  refreshed at n={inserted}; store reprojected")

    # search the final store (reduced d=64 prefix via Section 3.1)
    q_low = jnp.asarray(ds.queries_test) @ st.model.a[:64].T
    _, cand = bruteforce.search(q_low, x_store[:, :64], 50)
    vecs = X[cand]
    import jax
    ids = jnp.take_along_axis(
        cand, jax.lax.top_k(jnp.einsum(
            "mkd,md->mk", vecs, jnp.asarray(ds.queries_test)), 10)[1], 1)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    print(f"final recall@10 after streaming build: {float(rec):.3f}")


if __name__ == "__main__":
    main()
