"""Train a small MIND recommender for a few hundred steps, then build a
GleanVec retrieval index over the LEARNED item embeddings and serve
candidate retrieval -- the full paper-technique-in-a-training-system loop
(assignment: retrieval_cand is the paper's MIPS workload).

    PYTHONPATH=src python examples/train_recsys_retrieval.py [--steps 200]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, metrics
from repro.models import recsys
from repro.models.sharding import MeshRules
from repro.serve import retrieval
from repro.train import AdamWConfig, data, make_train_step
from repro.train.optimizer import adamw_init

RULES = MeshRules(dp=(), fsdp=(), tp=None, ep=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--items", type=int, default=20_000)
    args = ap.parse_args()

    cfg = recsys.MINDConfig(name="mind-demo", n_items=args.items,
                            seq_len=16, embed_dim=32, n_interests=4)
    params = recsys.mind.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        lambda p, b: recsys.mind.ctr_loss(p, b, cfg, RULES),
        AdamWConfig(lr=3e-3), warmup=20, total_steps=args.steps))

    print(f"== training MIND ({args.items} items) for {args.steps} steps ==")
    t0 = time.time()
    for i in range(args.steps):
        batch = data.mind_batch(0, i, 256, cfg.seq_len, cfg.n_items)
        params, opt, m = step(params, opt, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time() - t0):.0f}s)")

    # --- retrieval over learned item embeddings (the paper's MIPS) --------
    item_emb = params["item_emb"]
    batch = data.mind_batch(0, 999, 128, cfg.seq_len, cfg.n_items)
    users = recsys.mind.user_embedding(params, batch, cfg, RULES)

    idx_full = retrieval.build_retrieval_index(item_emb, "full")
    ids_full = retrieval.retrieve(idx_full, users, k=10)

    gmodel = gv.fit(jax.random.PRNGKey(1), users, item_emb, c=16, d=8)
    idx_gv = retrieval.build_retrieval_index(item_emb, "gleanvec", gmodel)
    ids_gv = retrieval.retrieve(idx_gv, users, k=10, kappa=100)

    agree = metrics.recall_at_k(jnp.asarray(ids_gv), jnp.asarray(ids_full))
    print(f"== retrieval ==\nGleanVec (32->8 dims) agreement with "
          f"full-precision retrieval: {float(agree):.3f}")
    print("bandwidth per candidate: "
          f"{32 * 4}B -> {8 * 4 + 1}B ({32 * 4 / (8 * 4 + 1):.1f}x)")


if __name__ == "__main__":
    main()
