"""repro: GleanVec/LeanVec-Sphering vector-search acceleration framework (JAX).

Layers: core (paper algorithms), index (vector-search substrate), kernels
(Pallas TPU), models (assigned architectures), train/serve (runtime),
configs (architecture registry), launch (mesh/dryrun/drivers).
"""
__version__ = "1.0.0"
