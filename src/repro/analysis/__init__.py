"""Static analysis of the search stack's own contracts.

Three rule layers over one registry (:mod:`repro.analysis.registry`):

* :mod:`repro.analysis.hlo_rules` -- declarative checks over compiled
  programs' post-opt HLO + cost analysis (forbidden dense score-matrix
  buffers, gather-free fused paths, host-transfer-free serving steps,
  donation coverage, while-trip budgets);
* :mod:`repro.analysis.protocol_rules` -- mechanical verification of the
  Scorer/Index/host-tier pytree contracts (treedef stability across
  streaming round-trips, leafless-aux host stores, -1 id padding,
  static-config-in-treedef);
* :mod:`repro.analysis.source_rules` -- repo-specific AST lint
  (isinstance dispatch on hot paths, host syncs in jitted bodies,
  ``jax.debug`` leftovers, raw version-sensitive jax APIs).

``assert_rules(compiled, rules)`` is the single entry point tests use;
``python -m repro.analysis.run audit`` sweeps the full hot-path matrix
and writes ``ANALYSIS.json``. See ``docs/static_analysis.md``.
"""
from repro.analysis.registry import (Rule, RuleResult, assert_rules,
                                     failures, results_to_json, run_rules)

__all__ = ["Rule", "RuleResult", "assert_rules", "failures",
           "results_to_json", "run_rules"]
