"""HLO-layer rules: declarative checks over ``compiled.as_text()`` +
``normalize_cost(cost_analysis())`` for any jitted program.

These promote the perf story's load-bearing assertions into reusable
rules: the fused kernels' HBM wins exist precisely because certain
buffers NEVER materialize (the dense ``(m, n)`` / ``(m, nprobe*L)`` /
``(m, beam+expand*R)`` score matrices), serving steps never bounce
through the host, donated serving state actually aliases its outputs,
and traversal loops respect their trip ceilings.

Backend note: on CPU the Pallas kernels run in interpret mode, whose
emulation lowers ``pl.load`` to real HLO gathers -- so
:class:`NoGatherOnFusedPath` is a TPU/GPU contract and self-skips
elsewhere (raw-text subjects have no backend and always check, which is
what the fixture tests use). :class:`NoDenseScoreMatrix` is
backend-independent: interpret mode preserves blocking, so the forbidden
shapes stay absent even on CPU (asserted since PR 5).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

from repro.analysis.registry import Rule, RuleResult
from repro.utils import hlo_analysis

__all__ = ["HLOProgram", "NoDenseScoreMatrix", "BufferPresent",
           "NoGatherOnFusedPath", "NoHostTransferInStep",
           "DonationCoverage", "WhileTripBudget", "donated_params"]

# input_output_alias={ {1}: (1, {}, may-alias), ... } -- the tuple's first
# field is the donated PARAMETER number (XLA prints the same syntax in
# both text dialects).
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\(\s*(\d+)\s*,")

_GATHER_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\(?\s*(\w+)\[([\d,]*)\][^=]*?"
    r"\b(gather|dynamic-gather)\(", re.M)

_HOST_MARKERS = ("infeed(", "outfeed(", "send(", "recv(", "send-done(",
                 "recv-done(", "MoveToHost", "MoveToDevice")
_HOST_SPACE_RE = re.compile(r"\bS\(5\)")


def donated_params(hlo_text: str) -> frozenset:
    """Parameter numbers the module header marks as donation sources
    (``input_output_alias``). Empty when nothing is donated. The entries
    nest braces (``{1}: (1, {}, may-alias)``), so the block is taken by
    balanced-brace scan, not regex."""
    at = hlo_text.find("input_output_alias=")
    if at < 0:
        return frozenset()
    seg, depth = "", 0
    for ch in hlo_text[hlo_text.find("{", at):]:
        seg += ch
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                break
    return frozenset(int(e) for e in _ALIAS_ENTRY_RE.findall(seg))


class HLOProgram:
    """One compiled program as the HLO rules see it: post-opt text, the
    normalized cost dict, parsed trip/byte stats, the defined-buffer
    shape set, and the backend it was compiled for (None for raw text)."""

    def __init__(self, hlo_text: str, cost: Optional[dict] = None,
                 backend: Optional[str] = None, label: str = ""):
        self.text = hlo_text
        self.cost = cost or {}
        self.backend = backend
        self.label = label
        self._shapes = None
        self._stats = None

    @classmethod
    def of(cls, subject, label: str = "") -> "HLOProgram":
        """Wrap a ``Compiled`` object / ``Lowered`` / raw HLO text."""
        if isinstance(subject, HLOProgram):
            return subject
        if isinstance(subject, str):
            return cls(subject, label=label)
        if hasattr(subject, "compile") and not hasattr(subject, "as_text"):
            subject = subject.compile()
        import jax
        cost = {}
        try:
            cost = hlo_analysis.normalize_cost(subject.cost_analysis())
        except Exception:   # cost analysis is best-effort on some backends
            pass
        return cls(subject.as_text(), cost=cost,
                   backend=jax.default_backend(), label=label)

    @property
    def buffer_shapes(self):
        if self._shapes is None:
            self._shapes = hlo_analysis.buffer_shapes(self.text)
        return self._shapes

    @property
    def stats(self):
        if self._stats is None:
            self._stats = hlo_analysis.analyze_hlo(self.text)
        return self._stats

    @property
    def donated(self):
        return donated_params(self.text)


def _shape_key(dims: Sequence[int], dtype: str) -> str:
    return f"{dtype}[{','.join(str(int(d)) for d in dims)}]"


class _ShapeRule(Rule):
    family = "hlo"

    def __init__(self, *dims: int, dtypes: Sequence[str] = ("f32", "s32")):
        self.dims = tuple(int(d) for d in dims)
        self.keys = tuple(_shape_key(self.dims, dt) for dt in dtypes)

    def _present(self, program: HLOProgram):
        return sorted(k for k in self.keys if k in program.buffer_shapes)


class NoDenseScoreMatrix(_ShapeRule):
    """FORBIDDEN buffer shapes: the fused paths' HBM win is that no
    buffer of the dense score-matrix shape exists anywhere in the module
    (any dtype of interest -- scores f32, ids s32)."""

    name = "NoDenseScoreMatrix"
    contract = ("no fused-path module defines a dense score-matrix "
                "buffer of the forbidden (rows, cols) shape")

    def check(self, program: HLOProgram) -> RuleResult:
        hit = self._present(program)
        if hit:
            return self._fail(f"forbidden dense buffer(s) present: {hit}")
        return self._pass(f"none of {list(self.keys)} defined")


class BufferPresent(_ShapeRule):
    """The positive twin (gathered baselines DO materialize the dense
    matrix): at least one of the shapes must exist. Keeps the old
    ``assert shape in hlo`` tests honest about what they compare."""

    name = "BufferPresent"
    contract = ("the gathered baseline really materializes the dense "
                "buffer the fused path is measured against")

    def check(self, program: HLOProgram) -> RuleResult:
        hit = self._present(program)
        if hit:
            return self._pass(f"present: {hit}")
        return self._fail(f"expected one of {list(self.keys)}; "
                          "module defines none")


class NoGatherOnFusedPath(Rule):
    """No gather whose result exceeds ``max_bytes`` on a fused path: the
    scalar-prefetch schedule streams slabs instead of gathering rows.
    Skips on CPU-compiled programs (Pallas interpret emulation gathers)."""

    name = "NoGatherOnFusedPath"
    family = "hlo"
    contract = ("fused kernel paths stream slabs via the scalar-prefetch "
                "schedule; no large row-gather appears in the module")

    def __init__(self, max_bytes: int = 0,
                 backends: Sequence[str] = ("tpu", "gpu")):
        self.max_bytes = int(max_bytes)
        self.backends = tuple(backends)

    def check(self, program: HLOProgram) -> RuleResult:
        if program.backend is not None \
                and program.backend not in self.backends:
            return self._skip(
                f"backend {program.backend!r}: Pallas interpret mode "
                "emulates loads as gathers; contract holds on "
                f"{list(self.backends)} only")
        big = []
        for m in _GATHER_RE.finditer(program.text):
            dtype, dims = m.group(1), m.group(2)
            nbytes = hlo_analysis._shape_bytes(dtype, dims)
            if nbytes > self.max_bytes:
                big.append(f"{dtype}[{dims}]={nbytes}B")
        if big:
            return self._fail(
                f"gather result(s) over {self.max_bytes}B: {big}")
        return self._pass("no gather above budget")


class NoHostTransferInStep(Rule):
    """Serving steps (``state_search`` / ``state_candidates`` bodies)
    never move data host<->device: no infeed/outfeed/send/recv, no
    host-memory-space (``S(5)``) buffers, no MoveToHost/MoveToDevice
    custom calls. The host rerank tier runs OUTSIDE the compiled step."""

    name = "NoHostTransferInStep"
    family = "hlo"
    contract = ("compiled serving steps contain no host<->device "
                "transfer; the rerank tier's host gather stays outside")

    def check(self, program: HLOProgram) -> RuleResult:
        hits = []
        for i, ln in enumerate(program.text.splitlines()):
            s = ln.strip()
            if "=" not in s:
                continue
            if any(mk in s for mk in _HOST_MARKERS) \
                    or _HOST_SPACE_RE.search(s):
                hits.append(f"line {i + 1}: {s[:90]}")
        if hits:
            return self._fail("host transfer markers: " + "; ".join(hits))
        return self._pass("no host-transfer instruction")


class DonationCoverage(Rule):
    """Every parameter the caller donates is an ``input_output_alias``
    source in the compiled module -- i.e. donation actually took, and a
    swap does not silently double the state's memory footprint."""

    name = "DonationCoverage"
    family = "hlo"
    contract = ("donated ServingState leaves are input_output_alias "
                "sources in the compiled step (no double-buffered state)")

    def __init__(self, params: Sequence[int]):
        self.params = frozenset(int(p) for p in params)

    def check(self, program: HLOProgram) -> RuleResult:
        donated = program.donated
        missing = sorted(self.params - donated)
        if missing:
            return self._fail(
                f"parameters {missing} not aliased "
                f"(aliased: {sorted(donated)})")
        return self._pass(f"all {len(self.params)} donated params aliased")


class WhileTripBudget(Rule):
    """Every while loop's resolved trip count stays within budget --
    beam hops and blocked scans have static ceilings; a runaway trip
    count means a schedule/layout regression."""

    name = "WhileTripBudget"
    family = "hlo"
    contract = ("every while loop in the compiled step runs at most "
                "max_trips iterations (beam-hop / scan ceilings)")

    def __init__(self, max_trips: int):
        self.max_trips = int(max_trips)

    def check(self, program: HLOProgram) -> RuleResult:
        trips = program.stats["while_trips"]
        over = {b: t for b, t in trips.items() if t > self.max_trips}
        if over:
            return self._fail(
                f"loops over budget {self.max_trips}: {over}")
        return self._pass(f"{len(trips)} loop(s) within {self.max_trips}")
