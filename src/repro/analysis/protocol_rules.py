"""Protocol-layer rules: mechanical verification of the Scorer / Index /
host-tier pytree contracts the serving stack depends on.

The zero-recompile swap story (PR 4 onward) is a structural claim:
``state_search`` specializes on the ServingState TREEDEF + leaf avals
only, so every streaming mutation -- ``insert_rows`` / ``remove_rows`` /
``refresh_artifacts`` / ``index.refreshed`` -- must return SAME-treedef,
same-aval pytrees; the host rerank tier must flatten to ZERO leaves; id
translation must keep ``-1`` padding inert; index configuration must be
static treedef metadata, never a traced leaf. These rules check each of
those claims directly on a small :class:`ProtocolContext` fixture, for
every registered scorer mode and index kind.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import Rule, RuleResult

__all__ = ["ProtocolContext", "ScorerSurface", "IdTranslationContract",
           "TreedefStableStreaming", "TreedefStableIndexRefresh",
           "LeaflessAuxHostTier", "StaticConfigInTreedef",
           "BoundedCompileCache", "SCORER_METHODS"]

# The full Scorer protocol surface (core/scorer.py): representation,
# scanning, sharding, id translation, and the streaming row ops.
SCORER_METHODS = ("prepare_queries", "pad_rows", "score_block",
                  "score_ids", "shard_specs", "translate_ids",
                  "globalize_ids", "insert_rows", "remove_rows",
                  "refresh", "encode_centers")


def tree_signature(tree):
    """(treedef, leaf avals): exactly what jit specializes a pytree
    argument on -- the equality the zero-recompile contract needs."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, tuple((l.shape, l.dtype) for l in leaves)


class ProtocolContext:
    """Small shared fixture: one OOD dataset, both DR models, and cached
    per-mode scorers / streaming artifacts. Built once per audit/test
    session (model fits dominate; everything else is cheap)."""

    def __init__(self, n: int = 512, D: int = 32, d: int = 8, c: int = 4,
                 m: int = 16, sort_block: int = 64, seed: int = 0):
        from repro.core import gleanvec as gv, leanvec_sphering as lvs
        from repro.data import vectors

        self.n, self.D, self.d, self.c, self.m = n, D, d, c, m
        self.sort_block = sort_block
        # learning queries >= D so K_Q has full rank (the lvs.fit warning)
        self.ds = vectors.make_dataset("analysis-protocol", n=n, d=D,
                                       n_queries=max(m, 2 * D), ood=True,
                                       seed=seed)
        self.X = jnp.asarray(self.ds.database)
        self.Q = jnp.asarray(self.ds.queries_test[:m])
        self.lin = lvs.fit(jnp.asarray(self.ds.queries_learn), self.X, d)
        self.gvm = gv.fit(jax.random.PRNGKey(seed),
                          jnp.asarray(self.ds.queries_learn), self.X,
                          c=c, d=d)
        self._scorers = {}
        self._streaming = {}

    def model_for(self, mode: str):
        if mode == "full":
            return None
        return self.lin if mode.startswith("sphering") else self.gvm

    def scorer(self, mode: str):
        if mode not in self._scorers:
            from repro.core import scorer as sc
            self._scorers[mode] = sc.build_scorer(
                mode, self.X, self.model_for(mode), block=self.sort_block)
        return self._scorers[mode]

    def streaming(self, mode: str, extra_rows: int = 32):
        if mode not in self._streaming:
            from repro.core import streaming
            self._streaming[mode] = streaming.build_streaming_artifacts(
                mode, self.X, self.model_for(mode),
                capacity=self.n + extra_rows, sort_block=self.sort_block,
                slack_blocks=1)
        return self._streaming[mode]


class _ProtocolRule(Rule):
    family = "protocol"

    def __init__(self, mode: Optional[str] = None):
        self.mode = mode

    def _result(self, base: RuleResult) -> RuleResult:
        if self.mode:
            return base._replace(target=self.mode)
        return base


class ScorerSurface(_ProtocolRule):
    """Every scorer exposes the full protocol surface -- a missing method
    surfaces as an AttributeError deep inside a traversal otherwise."""

    name = "ScorerSurface"
    contract = ("every registered scorer implements the full protocol: "
                + ", ".join(SCORER_METHODS) + ", n_rows")

    def check(self, ctx: ProtocolContext) -> RuleResult:
        s = ctx.scorer(self.mode)
        missing = [m for m in SCORER_METHODS
                   if not callable(getattr(s, m, None))]
        if not isinstance(getattr(s, "n_rows", None), (int, np.integer)):
            missing.append("n_rows")
        if missing:
            return self._result(self._fail(
                f"{type(s).__name__} missing: {missing}"))
        return self._result(self._pass(type(s).__name__))


class IdTranslationContract(_ProtocolRule):
    """``translate_ids`` maps internal slots to external ids with ``-1``
    (padding / dead slot) FIXED, and ``globalize_ids`` lifts external ids
    to global ones keeping ``-1`` fixed -- the convention every merge,
    probe schedule, and rerank gather relies on."""

    name = "IdTranslationContract"
    contract = ("translate_ids / globalize_ids keep -1 padding inert and "
                "map live ids into their declared ranges")

    def check(self, ctx: ProtocolContext) -> RuleResult:
        s = ctx.scorer(self.mode)
        perm = np.asarray(s.perm) if hasattr(s, "perm") else None
        # external-id capacity: sorted layouts translate slots into the
        # ORIGINAL id space (perm values), others are the identity
        ext_n = int(perm.max()) + 1 if perm is not None else s.n_rows
        live_slot = int(np.argmax(perm >= 0)) if perm is not None else 0
        probe = jnp.asarray([[live_slot, -1]], jnp.int32)
        t = np.asarray(s.translate_ids(probe))[0]
        problems = []
        if t[1] != -1:
            problems.append(f"translate_ids(-1) -> {t[1]} (want -1)")
        if not 0 <= t[0] < ext_n:
            problems.append(
                f"translate_ids(live slot {live_slot}) -> {t[0]} "
                f"outside [0, {ext_n})")
        if perm is not None and np.any(perm < 0):
            # layouts with padding: a dead slot must translate to -1
            dead = int(np.argmax(perm < 0))
            td = int(np.asarray(
                s.translate_ids(jnp.asarray([[dead]], jnp.int32)))[0, 0])
            if td != -1:
                problems.append(
                    f"translate_ids(pad slot {dead}) -> {td} (want -1)")
        g = np.asarray(s.globalize_ids(
            jnp.asarray([[t[0], -1]], jnp.int32), jnp.int32(1)))[0]
        if g[1] != -1:
            problems.append(f"globalize_ids(-1) -> {g[1]} (want -1)")
        if g[0] < 0:
            problems.append(f"globalize_ids mapped a live id negative: "
                            f"{g[0]}")
        if problems:
            return self._result(self._fail("; ".join(problems)))
        return self._result(self._pass(
            f"slot {live_slot} -> {t[0]}, globalize(shard=1) -> {g[0]}, "
            "-1 inert"))


class TreedefStableStreaming(_ProtocolRule):
    """The zero-recompile contract, scorer side: a full streaming round
    trip (insert rows -> remove them -> model refresh) returns artifacts
    with the SAME treedef and leaf avals as the originals."""

    name = "TreedefStableStreaming"
    contract = ("insert_rows / remove_rows / refresh_artifacts preserve "
                "the artifacts treedef and every leaf's shape+dtype")

    def check(self, ctx: ProtocolContext) -> RuleResult:
        from repro.core import streaming

        art = ctx.streaming(self.mode)
        sig0 = tree_signature(art)
        rows = ctx.X[:4] + 0.01
        art2, ids = streaming.insert_rows(art, rows)
        art3 = streaming.remove_rows(art2, ids)
        if art.model is not None:
            st = streaming.init_from_artifacts(art3, ctx.Q)
            art3 = streaming.refresh_artifacts(art3, streaming.refresh(st),
                                               source="full")
        sig1 = tree_signature(art3)
        if sig0[0] != sig1[0]:
            return self._result(self._fail(
                f"treedef changed: {sig0[0]} -> {sig1[0]}"))
        if sig0[1] != sig1[1]:
            diff = [(a, b) for a, b in zip(sig0[1], sig1[1]) if a != b]
            return self._result(self._fail(f"leaf avals changed: {diff}"))
        return self._result(self._pass(
            f"{len(sig0[1])} leaves stable through insert/remove/refresh"))


class TreedefStableIndexRefresh(_ProtocolRule):
    """The zero-recompile contract, index side: ``index.refreshed(scorer,
    model)`` returns a same-treedef, same-aval index for every kind."""

    name = "TreedefStableIndexRefresh"
    contract = ("index.refreshed(scorer, model) is treedef- and "
                "aval-preserving for flat / ivf / graph / sharded")

    def __init__(self, kind: str, mode: str = "gleanvec-sorted"):
        super().__init__(mode=f"{kind}/{mode}")
        self.kind = kind
        self.scorer_mode = mode

    def _build(self, ctx: ProtocolContext):
        from repro.index import FlatIndex, distributed, graph, ivf

        s = ctx.scorer(self.scorer_mode)
        model = ctx.model_for(self.scorer_mode)
        if self.kind == "flat":
            return FlatIndex(block=ctx.sort_block), s, model
        if self.kind == "ivf":
            if self.scorer_mode.endswith("sorted"):
                idx = ivf.build_aligned(model, ctx.X, nprobe=2)
            else:
                idx = ivf.with_reduced_centers(
                    ivf.build(jax.random.PRNGKey(1), ctx.X, n_lists=8),
                    s, model)
            return idx, s, model
        if self.kind == "graph":
            idx = graph.build(np.asarray(ctx.X), r=8, seed=0)
            if self.scorer_mode.endswith("sorted"):
                idx = graph.with_fused_scan(idx, s)
            return idx, s, model
        if self.kind == "sharded":
            idx, stacked = distributed.build_sharded_index(
                "flat", self.scorer_mode, ctx.X, model, n_shards=2,
                sort_block=ctx.sort_block)
            return idx, stacked, model
        raise ValueError(f"unknown index kind {self.kind!r}")

    def check(self, ctx: ProtocolContext) -> RuleResult:
        idx, s, model = self._build(ctx)
        sig0 = tree_signature(idx)
        sig1 = tree_signature(idx.refreshed(s, model))
        if sig0[0] != sig1[0]:
            return self._result(self._fail(
                f"treedef changed: {sig0[0]} -> {sig1[0]}"))
        if sig0[1] != sig1[1]:
            diff = [(a, b) for a, b in zip(sig0[1], sig1[1]) if a != b]
            return self._result(self._fail(f"leaf avals changed: {diff}"))
        return self._result(self._pass(
            f"{type(idx).__name__}: {len(sig0[1])} leaves stable"))


class LeaflessAuxHostTier(Rule):
    """HostStore / ShardedHostStore flatten to ZERO leaves (the store is
    treedef aux data), aux equality is by (type, shape, dtype) aval --
    so a content refresh keeps the treedef while a shape change breaks
    it loudly -- and demote/promote round-trips the rows exactly."""

    name = "LeaflessAuxHostTier"
    family = "protocol"
    contract = ("the host rerank tier is a leafless pytree whose aux "
                "equality is the store AVAL, not its contents")

    def check(self, ctx: ProtocolContext) -> RuleResult:
        from repro.core import rerank_tier

        x = np.asarray(ctx.X)
        problems = []
        for shards in (0, 2):
            store = rerank_tier.demote(jnp.asarray(x), shards=shards)
            leaves, treedef = jax.tree_util.tree_flatten(store)
            if leaves:
                problems.append(
                    f"{type(store).__name__} has {len(leaves)} leaves")
            refreshed = rerank_tier.demote(jnp.asarray(x + 1.0),
                                           shards=shards)
            if jax.tree_util.tree_structure(refreshed) != treedef:
                problems.append(f"{type(store).__name__}: content "
                                "refresh changed the treedef")
            smaller = rerank_tier.demote(jnp.asarray(x[:-2]),
                                         shards=shards)
            if jax.tree_util.tree_structure(smaller) == treedef:
                problems.append(f"{type(store).__name__}: shape change "
                                "did NOT change the treedef")
            back = np.asarray(rerank_tier.promote(store))
            if not np.array_equal(back, x):
                problems.append(
                    f"{type(store).__name__}: promote != original rows")
        if problems:
            return self._fail("; ".join(problems))
        return self._pass("HostStore & ShardedHostStore leafless, "
                          "aval-keyed, round-trip exact")


class BoundedCompileCache(Rule):
    """The async frontend's bucket-shape contract: every batch the
    coalescer dispatches has a shape from the SMALL, STATIC declared
    bucket set, so the serving-step executable cache is bounded by
    ``len(buckets) <= MAX_BUCKETS`` for the life of the process. A
    dispatch outside the set -- or any cache growth past warmup -- is an
    unbounded-compile leak (each stray shape re-jits the full search),
    caught here by the audit instead of as a prod latency incident."""

    name = "BoundedCompileCache"
    family = "protocol"
    contract = ("every dispatched batch shape is a declared bucket and "
                "the compiled-step cache never grows past len(buckets)")

    def check(self, ctx: ProtocolContext) -> RuleResult:
        from repro.core import search as msearch
        from repro.serve import frontend as fe_mod
        from repro.serve.engine import ServingEngine

        arts = ctx.streaming("gleanvec-int8")
        eng = ServingEngine(msearch.make_state(arts), k=5, kappa=10,
                            batch_size=ctx.m, dim=ctx.D)
        fe = fe_mod.ServingFrontend(eng, capacity=4 * ctx.m, start=False)
        problems = []
        if len(fe.buckets) > fe_mod.MAX_BUCKETS:
            problems.append(f"{len(fe.buckets)} buckets exceed "
                            f"MAX_BUCKETS={fe_mod.MAX_BUCKETS}")
        warm = eng.n_compiles
        if warm is None:
            return self._skip("engine exposes no compile-cache size on "
                              "this jax version")
        if warm > len(fe.buckets):
            problems.append(f"warmup compiled {warm} executables for "
                            f"{len(fe.buckets)} buckets")
        Q = np.tile(np.asarray(ctx.Q), (2, 1))
        for size in (1, 3, ctx.m - 1, ctx.m):
            for q in Q[:size]:
                fe.enqueue(q)
            fe.drain_once()
        stray = fe.dispatched_shapes - set(fe.buckets)
        if stray:
            problems.append(f"dispatched shapes outside the declared "
                            f"buckets {fe.buckets}: {sorted(stray)}")
        grown = eng.n_compiles - warm
        if grown:
            problems.append(f"compile cache grew {warm} -> "
                            f"{eng.n_compiles} after warmup")
        if problems:
            return self._fail("; ".join(problems))
        return self._pass(
            f"{len(fe.dispatched_shapes)} dispatched shapes within "
            f"buckets={fe.buckets}, cache fixed at {warm} executables")


class StaticConfigInTreedef(Rule):
    """Index configuration is STATIC treedef metadata: two indices that
    differ only in a config field have different treedefs (jit re-
    specializes instead of mis-serving), and no leaf is a bare python
    scalar (which would silently become a traced constant)."""

    name = "StaticConfigInTreedef"
    family = "protocol"
    contract = ("index config (block / nprobe / beam...) lives in the "
                "treedef; array data are the only leaves")

    def __init__(self, kind, field: str):
        self.kind = kind        # "flat"/"ivf"/"graph" or builder(ctx)
        self.field = field

    def check(self, ctx: ProtocolContext) -> RuleResult:
        from repro.index import FlatIndex, graph, ivf
        from repro.index.protocol import replace

        if callable(self.kind):
            idx = self.kind(ctx)
        elif self.kind == "flat":
            idx = FlatIndex(block=ctx.sort_block)
        elif self.kind == "ivf":
            idx = ivf.build(jax.random.PRNGKey(1), ctx.X, n_lists=8)
        elif self.kind == "graph":
            idx = graph.build(np.asarray(ctx.X), r=8, n_entries=4, seed=0)
        else:
            raise ValueError(f"unknown index kind {self.kind!r}")
        base = jax.tree_util.tree_structure(idx)
        bumped = replace(idx, **{
            self.field: getattr(idx, self.field) + 1})
        problems = []
        if jax.tree_util.tree_structure(bumped) == base:
            problems.append(
                f"{type(idx).__name__}.{self.field} change kept the "
                "treedef (config leaked into leaves?)")
        scalar_leaves = [type(l).__name__
                         for l in jax.tree_util.tree_leaves(idx)
                         if not hasattr(l, "shape")]
        if scalar_leaves:
            problems.append(f"python-scalar leaves: {scalar_leaves}")
        kind = getattr(self.kind, "__name__", self.kind)
        if problems:
            return RuleResult(self.name, f"{kind}.{self.field}",
                              False, "; ".join(problems),
                              family=self.family)
        return RuleResult(self.name, f"{kind}.{self.field}", True,
                          f"{type(idx).__name__}.{self.field} is treedef "
                          "metadata", family=self.family)
