"""Rule registry: the ONE definition of every contract the stack audits.

A :class:`Rule` states one invariant (a forbidden HLO buffer shape, a
pytree treedef that must survive a refresh, a banned source construct)
and checks it against a *subject* -- an :class:`~repro.analysis.hlo_rules.
HLOProgram`, a :class:`~repro.analysis.protocol_rules.ProtocolContext`,
or a :class:`~repro.analysis.source_rules.SourceTree`. Tests and the
``analysis/run.py audit`` driver share the same rule instances, so a
contract is written exactly once and enforced everywhere.

``assert_rules(compiled, rules)`` is the test-facing entry point that
replaced the per-test HLO string assertions (test_ivf_scan /
test_graph_scan / test_index_protocol); ``run_rules`` is the driver-facing
one that collects :class:`RuleResult` rows for ``ANALYSIS.json``.
"""
from __future__ import annotations

from typing import Iterable, List, NamedTuple

__all__ = ["Rule", "RuleResult", "run_rules", "failures", "assert_rules",
           "results_to_json"]


class RuleResult(NamedTuple):
    """One rule evaluated against one subject. ``evidence`` carries the
    matched shapes / missing aliases / offending source lines -- enough
    to act on a failure without re-running the audit."""

    rule: str
    target: str
    passed: bool
    evidence: str = ""
    skipped: bool = False
    family: str = ""


class Rule:
    """Base: subclasses set ``name``/``family``/``contract`` and implement
    ``check(subject) -> RuleResult`` via the ``_pass``/``_fail``/``_skip``
    helpers. ``contract`` is the human sentence the docs table renders."""

    name: str = "Rule"
    family: str = ""
    contract: str = ""

    def check(self, subject) -> RuleResult:
        raise NotImplementedError

    def _pass(self, evidence: str = "") -> RuleResult:
        return RuleResult(self.name, "", True, evidence, False, self.family)

    def _fail(self, evidence: str) -> RuleResult:
        return RuleResult(self.name, "", False, evidence, False, self.family)

    def _skip(self, evidence: str) -> RuleResult:
        return RuleResult(self.name, "", True, evidence, True, self.family)


def run_rules(subject, rules: Iterable[Rule],
              target: str = "") -> List[RuleResult]:
    """Evaluate every rule against one subject; stamp ``target`` (the
    audit-matrix cell, e.g. ``ivf/gleanvec-sorted``) onto each result."""
    out = []
    for rule in rules:
        res = rule.check(subject)
        if target and not res.target:
            res = res._replace(target=target)
        out.append(res)
    return out


def failures(results: Iterable[RuleResult]) -> List[RuleResult]:
    return [r for r in results if not r.passed and not r.skipped]


def assert_rules(subject, rules: Iterable[Rule],
                 target: str = "") -> List[RuleResult]:
    """Run ``rules`` against ``subject`` and raise ``AssertionError``
    listing every violation. ``subject`` may be a jitted ``Compiled``
    object (or raw HLO text) -- it is wrapped in an ``HLOProgram``
    automatically -- or any rule-family subject passed through as-is."""
    from repro.analysis import hlo_rules

    if isinstance(subject, str) or hasattr(subject, "as_text"):
        subject = hlo_rules.HLOProgram.of(subject, label=target)
    results = run_rules(subject, rules, target=target)
    bad = failures(results)
    if bad:
        lines = [f"  {r.rule}[{r.target or '-'}]: {r.evidence}"
                 for r in bad]
        raise AssertionError("contract violation(s):\n" + "\n".join(lines))
    return results


def results_to_json(results: Iterable[RuleResult], **extra) -> dict:
    """The ``ANALYSIS.json`` payload (mirrors ``BENCH_<name>.json``:
    one top-level tag + a flat ``results`` list of dict rows)."""
    rows = [r._asdict() for r in results]
    n_fail = len(failures(results))
    n_skip = sum(1 for r in results if r.skipped)
    return {
        "analysis": "audit",
        "passed": n_fail == 0,
        "counts": {"passed": len(rows) - n_fail - n_skip,
                   "failed": n_fail, "skipped": n_skip},
        **extra,
        "results": rows,
    }
