"""The contract audit driver: ``python -m repro.analysis.run audit``.

Composes the three rule layers over the full hot-path matrix --
7 scorer modes x {flat, IVF-aligned, fused graph, sharded, host-rerank}
-- plus the protocol round-trips and the source lint, writes the
machine-readable ``ANALYSIS.json`` (mirroring the ``BENCH_*.json``
convention), and exits nonzero on any violation.

Per matrix cell the driver compiles the REAL serving entry point
(``state_search`` / ``state_candidates`` / ``ShardedIndex.search_local``)
over a small statistical twin of the paper's shapes and runs the HLO
rules against the post-opt module: the forbidden dense score-matrix
shapes are computed from the actual mounted scorer (sorted layouts pad
``n_rows``), the donation check compiles the engine step with
``donate_argnums=(1,)`` the way ``ServingEngine`` does on accelerators,
and trip budgets scale with the cell's own block / hop ceilings.

``python -m repro.analysis.run lint`` runs the AST layer alone (fast,
no jax compilation) -- the CI job runs it first for quick feedback.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import numpy as np

from repro.analysis import hlo_rules, protocol_rules, source_rules
from repro.analysis.registry import (failures, results_to_json, run_rules)

SRC_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Audit-matrix shapes: a scaled twin of Table 1. n is deliberately NOT a
# multiple-free power match of any scan block so a legitimate (m, block)
# tile can never collide with the forbidden (m, n) matrix.
N, D, D_LOW, C, M, K, KAPPA = 1024, 32, 8, 4, 8, 5, 20
SORT_BLOCK, FLAT_BLOCK = 64, 256
NPROBE, N_LISTS = 2, 8
# KAPPA=20 deliberately differs from EXPAND*GRAPH_R=16 and
# BEAM+EXPAND*GRAPH_R=24: the legitimate (M, KAPPA) candidate buffers
# must never collide with the fused graph hop's forbidden shapes.
BEAM, MAX_HOPS, EXPAND, GRAPH_R = 8, 16, 2, 8
GRAPH_ENTRIES = 4   # <= BEAM (the beam must hold all entry points)

TOPOLOGIES = ("flat", "ivf", "graph", "sharded", "host-rerank")


class MatrixContext(protocol_rules.ProtocolContext):
    """Protocol fixture + the compiled-program cache for the HLO cells."""

    def __init__(self):
        super().__init__(n=N, D=D, d=D_LOW, c=C, m=M,
                         sort_block=SORT_BLOCK, seed=0)
        self._graph = None

    def graph_index(self):
        if self._graph is None:
            from repro.index import graph
            self._graph = graph.build(np.asarray(self.X), r=GRAPH_R,
                                      n_entries=GRAPH_ENTRIES, seed=0)
        return self._graph

    def artifacts(self, mode):
        from repro.core import search as msearch
        return msearch.SearchArtifacts(scorer=self.scorer(mode),
                                       x_full=self.X,
                                       model=self.model_for(mode))


def _compile_state_search(state, queries):
    import jax
    from repro.core import search as msearch
    fn = jax.jit(msearch.state_search, static_argnames=("k", "kappa"))
    return fn.lower(queries, state, k=K, kappa=KAPPA).compile()


def _cell_rules(scorer, dense_dims, trip_budget, extra=()):
    rules = [hlo_rules.NoDenseScoreMatrix(*dense_dims),
             hlo_rules.NoHostTransferInStep(),
             hlo_rules.NoGatherOnFusedPath(),
             hlo_rules.WhileTripBudget(trip_budget)]
    rules.extend(extra)
    return rules


def _audit_cell(ctx, mode, topo):
    """Compile one (mode, topology) cell and return its rule results."""
    import jax
    import jax.numpy as jnp
    from repro.core import search as msearch
    from repro.index import ivf, graph
    from repro.index.protocol import replace

    target = f"{topo}/{mode}"
    scorer = ctx.scorer(mode)
    n_rows = scorer.n_rows
    fused = mode.endswith("sorted")
    art = ctx.artifacts(mode)

    if topo == "flat":
        state = msearch.make_state(art, block=FLAT_BLOCK)
        compiled = _compile_state_search(state, ctx.Q)
        block = getattr(scorer, "layout_block", FLAT_BLOCK)
        rules = _cell_rules(scorer, (M, n_rows),
                            trip_budget=n_rows // block + 16)
        # donation: the engine step the accelerator path compiles
        from repro.serve import engine as serve_engine
        step = functools.partial(serve_engine._engine_step, k=K,
                                 kappa=KAPPA)
        donated = jax.jit(step, donate_argnums=(1,)).lower(
            ctx.Q, state).compile()
        n_leaves = len(jax.tree_util.tree_leaves(state))
        res = run_rules(hlo_rules.HLOProgram.of(compiled, label=target),
                        rules, target=target)
        res += run_rules(
            hlo_rules.HLOProgram.of(donated, label=target),
            [hlo_rules.DonationCoverage(range(1, 1 + n_leaves))],
            target=target)
        return res

    if topo == "ivf":
        if fused:
            idx = ivf.build_aligned(ctx.gvm, ctx.X, nprobe=NPROBE)
        else:
            idx = ivf.with_reduced_centers(
                ivf.build(jax.random.PRNGKey(1), ctx.X,
                          n_lists=N_LISTS),
                scorer, ctx.model_for(mode))
            idx = replace(idx, nprobe=NPROBE)
        state = msearch.make_state(art, index=idx)
        compiled = _compile_state_search(state, ctx.Q)
        dense = (M, n_rows)
        rules = _cell_rules(scorer, dense, trip_budget=512)
        if fused:
            # the PR-5 contract: the fused fine step never materializes
            # the (m, nprobe*max_len) gathered score matrix
            p = idx.nprobe * idx.lists.shape[1]
            rules.append(hlo_rules.NoDenseScoreMatrix(M, p))
        return run_rules(hlo_rules.HLOProgram.of(compiled, label=target),
                         rules, target=target)

    if topo == "graph":
        idx = replace(ctx.graph_index(), beam=BEAM, max_hops=MAX_HOPS,
                      expand=EXPAND)
        if fused:
            idx = graph.with_fused_scan(idx, scorer)
        state = msearch.make_state(art, index=idx)
        compiled = _compile_state_search(state, ctx.Q)
        rules = _cell_rules(scorer, (M, n_rows), trip_budget=512)
        if fused:
            # the PR-6 contract at traversal scope: no (m, expand*R)
            # score matrix over the gathered neighbor rows. The
            # (m, beam+expand*R) shape is NOT forbidden here -- the beam
            # loop's merge of already-reduced candidate VALUES into the
            # beam is that wide by construction (O(m*beam) bytes); its
            # absence is a KERNEL-scope contract, asserted where
            # test_graph_scan compiles graph_scan_beam_step alone.
            rules.append(hlo_rules.NoDenseScoreMatrix(M, EXPAND * GRAPH_R))
        return run_rules(hlo_rules.HLOProgram.of(compiled, label=target),
                         rules, target=target)

    if topo == "sharded":
        from repro.index import distributed
        idx, stacked = distributed.build_sharded_index(
            "flat", mode, ctx.X, ctx.model_for(mode), n_shards=2,
            sort_block=SORT_BLOCK)

        def local(q, index, sc_):
            return index.search_local(q, sc_, K, KAPPA)

        compiled = jax.jit(local).lower(ctx.Q, idx, stacked).compile()
        per = distributed._take_shard(stacked, 0).n_rows
        rules = _cell_rules(scorer, (M, n_rows), trip_budget=512,
                            extra=[hlo_rules.NoDenseScoreMatrix(M, per)])
        return run_rules(hlo_rules.HLOProgram.of(compiled, label=target),
                         rules, target=target)

    if topo == "host-rerank":
        demoted = msearch.demote_rerank_tier(art)
        state = msearch.make_state(demoted, block=FLAT_BLOCK)
        fn = jax.jit(msearch.state_candidates, static_argnames=("kappa",))
        compiled = fn.lower(ctx.Q, state, kappa=KAPPA).compile()
        block = getattr(scorer, "layout_block", FLAT_BLOCK)
        rules = _cell_rules(scorer, (M, n_rows),
                            trip_budget=n_rows // block + 16)
        if mode != "full":
            # the PR-8 contract: the demoted (n, D) store never enters
            # the candidates trace ("full" legitimately scores in R^D)
            rules.append(hlo_rules.NoDenseScoreMatrix(
                N, D, dtypes=("f32",)))
        return run_rules(hlo_rules.HLOProgram.of(compiled, label=target),
                         rules, target=target)

    raise ValueError(f"unknown topology {topo!r}")


def source_rule_set():
    return [source_rules.NoJaxDebug(),
            source_rules.NoIsinstanceDispatch(),
            source_rules.NoHostSyncInJit(),
            source_rules.NoRawCompatAPIs()]


def protocol_rule_set(modes):
    rules = []
    for mode in modes:
        rules += [protocol_rules.ScorerSurface(mode),
                  protocol_rules.IdTranslationContract(mode),
                  protocol_rules.TreedefStableStreaming(mode)]
    rules += [protocol_rules.TreedefStableIndexRefresh("flat"),
              protocol_rules.TreedefStableIndexRefresh("ivf"),
              protocol_rules.TreedefStableIndexRefresh(
                  "ivf", mode="gleanvec"),
              protocol_rules.TreedefStableIndexRefresh("graph"),
              protocol_rules.TreedefStableIndexRefresh("sharded"),
              protocol_rules.LeaflessAuxHostTier(),
              protocol_rules.BoundedCompileCache(),
              protocol_rules.StaticConfigInTreedef("flat", "block"),
              protocol_rules.StaticConfigInTreedef("ivf", "nprobe"),
              protocol_rules.StaticConfigInTreedef("graph", "beam")]
    return rules


def run_lint():
    tree = source_rules.SourceTree(SRC_ROOT)
    return run_rules(tree, source_rule_set(), target="src/repro")


def run_audit(out: str = "ANALYSIS.json", skip_hlo: bool = False):
    import jax
    from repro.core.scorer import MODES

    results = list(run_lint())
    print(f"[audit] source lint: {len(results)} rules", flush=True)

    ctx = MatrixContext()
    results += run_rules(ctx, protocol_rule_set(MODES))
    print(f"[audit] protocol rules done ({len(results)} total)",
          flush=True)

    if not skip_hlo:
        for mode in MODES:
            for topo in TOPOLOGIES:
                cell = _audit_cell(ctx, mode, topo)
                bad = failures(cell)
                mark = "FAIL" if bad else "ok"
                print(f"[audit] {topo}/{mode}: {mark}", flush=True)
                results += cell

    payload = results_to_json(
        results, jax_version=jax.__version__,
        backend=jax.default_backend(),
        matrix={"modes": list(MODES),
                "topologies": [] if skip_hlo else list(TOPOLOGIES)})
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    bad = failures(results)
    counts = payload["counts"]
    print(f"[audit] {counts['passed']} passed, {counts['failed']} failed,"
          f" {counts['skipped']} skipped -> {out}", flush=True)
    for r in bad:
        print(f"[audit] FAIL {r.rule}[{r.target}]: {r.evidence}",
              flush=True)
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.analysis.run",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_audit = sub.add_parser("audit", help="full three-layer audit")
    ap_audit.add_argument("--out", default="ANALYSIS.json")
    ap_audit.add_argument("--skip-hlo", action="store_true",
                          help="protocol + source layers only (no "
                               "compilation; quick local check)")
    sub.add_parser("lint", help="AST source lint only (no jax)")
    args = ap.parse_args(argv)

    if args.cmd == "lint":
        results = run_lint()
        bad = failures(results)
        for r in results:
            mark = "FAIL" if (not r.passed and not r.skipped) else "ok"
            print(f"[lint] {mark} {r.rule}: {r.evidence}")
        return 1 if bad else 0
    return run_audit(out=args.out, skip_hlo=args.skip_hlo)


if __name__ == "__main__":
    sys.exit(main())
