"""Source-layer rules: repo-specific AST lint over ``src/repro``.

These encode hygiene rules the protocols were built to make possible:
the search path dispatches on protocol methods, never ``isinstance`` over
scorer/index classes (PR 1's whole point); jit-traced functions never
host-sync (``.item()`` / ``np.*`` on traced values forces a blocking
device->host copy per call); ``jax.debug.*`` never ships; version-
sensitive jax APIs route through ``utils/jax_compat.py`` so one shim
owns the 0.4-vs-0.6 differences.

Each rule walks pre-parsed ASTs from a shared :class:`SourceTree`.
A violation can be waived for a specific line with a trailing
``# analysis: allow-<rule-tag>`` comment -- the waiver is greppable and
reviewed, unlike an allowlist buried here.
"""
from __future__ import annotations

import ast
import os
from typing import List, Tuple

from repro.analysis.registry import Rule, RuleResult

__all__ = ["SourceTree", "NoJaxDebug", "NoIsinstanceDispatch",
           "NoHostSyncInJit", "NoRawCompatAPIs", "DISPATCH_CLASSES"]

# Scorer / Index protocol classes: isinstance over any of these in hot-
# path modules is type dispatch the protocols exist to remove.
DISPATCH_CLASSES = frozenset({
    "LinearScorer", "GleanVecScorer", "QuantizedScorer",
    "GleanVecQuantizedScorer", "SortedGleanVecScorer",
    "SortedGleanVecQuantizedScorer", "FlatIndex", "IVFIndex",
    "GraphIndex", "ShardedIndex",
})

# Hot-path module prefixes (repo-relative, '/'-separated) where protocol
# dispatch is the law. ``kernels/__init__.py`` is deliberately NOT here:
# it is the one sanctioned scorer->kernel lowering boundary ("Index code
# never mentions kernels; it talks to scorers, and scorers lower here").
HOT_PATHS = ("core/search.py", "core/scorer.py", "index/", "serve/")

# jax.* attribute chains that must go through utils/jax_compat.py.
RAW_COMPAT_APIS = frozenset({
    "jax.make_mesh", "jax.set_mesh", "jax.shard_map",
    "jax.experimental.shard_map",
})
COMPAT_MODULE = "utils/jax_compat.py"


class SourceTree:
    """``src/repro`` parsed once: (relpath, source lines, ast) per file,
    shared by every source rule."""

    def __init__(self, root: str):
        self.root = root
        self.files: List[Tuple[str, List[str], ast.AST]] = []
        for dirpath, dirnames, filenames in sorted(os.walk(root)):
            dirnames.sort()
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path) as f:
                    src = f.read()
                try:
                    tree = ast.parse(src, filename=rel)
                except SyntaxError:
                    continue        # not this layer's problem
                self.files.append((rel, src.splitlines(), tree))

    @classmethod
    def of(cls, subject) -> "SourceTree":
        return subject if isinstance(subject, cls) else cls(subject)


def _attr_chain(node) -> str:
    """Dotted name of an attribute chain (``jax.debug.print`` ->
    "jax.debug.print"), or "" for non-name roots."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _waived(lines: List[str], lineno: int, tag: str) -> bool:
    ln = lines[lineno - 1] if 0 < lineno <= len(lines) else ""
    return f"# analysis: allow-{tag}" in ln


class _SourceRule(Rule):
    family = "source"
    tag = ""            # the allow-comment suffix

    def check(self, tree) -> RuleResult:
        tree = SourceTree.of(tree)
        findings = []
        for rel, lines, mod in tree.files:
            for lineno, msg in self.visit_file(rel, mod):
                if not _waived(lines, lineno, self.tag):
                    findings.append(f"{rel}:{lineno}: {msg}")
        if findings:
            return self._fail("; ".join(findings))
        return self._pass(f"{len(tree.files)} files clean")

    def visit_file(self, rel: str, mod: ast.AST):
        raise NotImplementedError


class NoJaxDebug(_SourceRule):
    """No ``jax.debug.*`` (print/breakpoint/callback) ships: they force
    host callbacks on every call of a compiled function."""

    name = "NoJaxDebug"
    tag = "jax-debug"
    contract = "no jax.debug.* call ships in src/repro"

    def visit_file(self, rel, mod):
        for node in ast.walk(mod):
            if isinstance(node, ast.Attribute):
                chain = _attr_chain(node)
                if chain.startswith("jax.debug."):
                    yield node.lineno, f"{chain} leftover"


class NoIsinstanceDispatch(_SourceRule):
    """No ``isinstance`` over Scorer/Index protocol classes in hot-path
    modules: dispatch goes through protocol methods, so index x scorer x
    placement stay orthogonal axes."""

    name = "NoIsinstanceDispatch"
    tag = "isinstance"
    contract = ("hot paths (core/search, core/scorer, index/, serve/, "
                "kernels/) never isinstance-dispatch on protocol classes")

    def visit_file(self, rel, mod):
        if not any(rel.startswith(p) for p in HOT_PATHS):
            return
        for node in ast.walk(mod):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "isinstance"
                    and len(node.args) == 2):
                continue
            t = node.args[1]
            names = [e for e in (t.elts if isinstance(t, ast.Tuple)
                                 else [t])]
            for e in names:
                nm = e.id if isinstance(e, ast.Name) else \
                    (e.attr if isinstance(e, ast.Attribute) else "")
                if nm in DISPATCH_CLASSES:
                    yield node.lineno, f"isinstance dispatch on {nm}"


class NoHostSyncInJit(_SourceRule):
    """Inside functions decorated with ``jax.jit`` (bare or through
    ``functools.partial``): no ``.item()``, no ``np.*`` / ``numpy.*``
    calls, no ``jax.device_get`` -- each forces a trace-time constant or
    a host sync. (Conservative by design: python ``float(...)`` over
    static shape arithmetic is legal and stays out of scope.)"""

    name = "NoHostSyncInJit"
    tag = "host-sync"
    contract = ("jit-traced function bodies never call .item(), np.*, "
                "or jax.device_get")

    @staticmethod
    def _is_jit_decorated(fn: ast.AST) -> bool:
        for dec in fn.decorator_list:
            chain = _attr_chain(dec)
            if chain in ("jax.jit", "jit"):
                return True
            if isinstance(dec, ast.Call):
                chain = _attr_chain(dec.func)
                if chain in ("jax.jit", "jit"):
                    return True
                if chain in ("functools.partial", "partial") and \
                        dec.args and _attr_chain(dec.args[0]) in (
                            "jax.jit", "jit"):
                    return True
        return False

    def visit_file(self, rel, mod):
        for fn in ast.walk(mod):
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                continue
            if not self._is_jit_decorated(fn):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func)
                if chain.endswith(".item") and "." in chain:
                    yield node.lineno, \
                        f"{chain}() host sync in jitted {fn.name}"
                elif chain.startswith(("np.", "numpy.")):
                    yield node.lineno, \
                        f"{chain}() in jitted {fn.name}"
                elif chain == "jax.device_get":
                    yield node.lineno, \
                        f"jax.device_get in jitted {fn.name}"


class NoRawCompatAPIs(_SourceRule):
    """Version-sensitive jax APIs (mesh construction, shard_map) are
    used only through ``utils/jax_compat.py`` -- one module owns the
    jax 0.4/0.6 differences."""

    name = "NoRawCompatAPIs"
    tag = "raw-compat"
    contract = ("jax.make_mesh / jax.set_mesh / jax.shard_map / "
                "jax.experimental.shard_map only inside utils/jax_compat")

    def visit_file(self, rel, mod):
        if rel == COMPAT_MODULE:
            return
        for node in ast.walk(mod):
            if isinstance(node, ast.Attribute):
                chain = _attr_chain(node)
                if chain in RAW_COMPAT_APIS:
                    yield node.lineno, \
                        f"{chain} bypasses utils/jax_compat"
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                names = []
                if isinstance(node, ast.ImportFrom) and node.module:
                    names = [f"{node.module}.{a.name}"
                             for a in node.names]
                else:
                    names = [a.name for a in node.names]
                for nm in names:
                    if nm in RAW_COMPAT_APIS or \
                            nm.startswith("jax.experimental.shard_map"):
                        yield node.lineno, \
                            f"import {nm} bypasses utils/jax_compat"
