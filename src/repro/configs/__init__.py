"""Per-architecture configuration modules (assignment + paper's own)."""
from repro.configs import registry

__all__ = ["registry"]
