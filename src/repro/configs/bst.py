"""bst [recsys]: Behavior Sequence Transformer (Alibaba): embed_dim=32
seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256.
[arXiv:1905.06874; paper]"""
from repro.configs.recsys_common import RECSYS_SHAPES
from repro.models.recsys import BSTConfig

ARCH_ID = "bst"
FAMILY = "recsys"
MODEL = "bst"
SHAPES = dict(RECSYS_SHAPES)
SKIPS = {}


def make_config(smoke: bool = False) -> BSTConfig:
    if smoke:
        return BSTConfig(name=ARCH_ID + "-smoke", n_items=1000, seq_len=8,
                         mlp=(64, 32, 1))
    return BSTConfig(name=ARCH_ID, n_items=4_000_000, seq_len=20,
                     embed_dim=32, n_heads=8, n_blocks=1,
                     mlp=(1024, 512, 256, 1))
