"""dlrm-mlperf [recsys]: MLPerf DLRM benchmark config (Criteo 1TB):
n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1 interaction=dot. [arXiv:1906.00091; paper]"""
from repro.configs.recsys_common import RECSYS_SHAPES
from repro.models.recsys import DLRMConfig

ARCH_ID = "dlrm-mlperf"
FAMILY = "recsys"
MODEL = "dlrm"
SHAPES = dict(RECSYS_SHAPES)
SKIPS = {}


def make_config(smoke: bool = False) -> DLRMConfig:
    if smoke:
        return DLRMConfig(name=ARCH_ID + "-smoke",
                          vocab_sizes=(1000, 200, 50, 3000), embed_dim=16,
                          bot_mlp=(32, 16), top_mlp=(64, 32, 1))
    return DLRMConfig(name=ARCH_ID)   # exact MLPerf defaults
