"""fm [recsys]: Factorization Machine, n_sparse=39 embed_dim=10,
pairwise <v_i, v_j> x_i x_j via the O(nk) sum-square trick.
[ICDM'10 (Rendle); paper]"""
from repro.configs.recsys_common import RECSYS_SHAPES
from repro.models.recsys import FMConfig

ARCH_ID = "fm"
FAMILY = "recsys"
MODEL = "fm"
SHAPES = dict(RECSYS_SHAPES)
SKIPS = {}


def make_config(smoke: bool = False) -> FMConfig:
    if smoke:
        return FMConfig(name=ARCH_ID + "-smoke", n_sparse=5,
                        vocab_per_field=1000, embed_dim=10)
    return FMConfig(name=ARCH_ID)   # 39 fields x 100k hashed, k=10
