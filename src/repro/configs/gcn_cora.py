"""gcn-cora [gnn]: n_layers=2 d_hidden=16 aggregator=mean norm=sym.
[arXiv:1609.02907; paper]

Shape-specific graph stats come from the assignment (Cora, Reddit-like
minibatch, ogbn-products, batched molecules); feature widths / class counts
follow the public datasets.
"""
from repro.models.gnn import GCNConfig

ARCH_ID = "gcn-cora"
FAMILY = "gnn"
SHAPES = {
    "full_graph_sm": {"kind": "gnn_full", "n_nodes": 2708,
                      "n_edges": 10556, "d_feat": 1433, "n_classes": 7},
    "minibatch_lg": {"kind": "gnn_minibatch", "n_nodes": 232965,
                     "n_edges": 114615892, "batch_nodes": 1024,
                     "fanouts": (15, 10), "d_feat": 602, "n_classes": 41},
    "ogb_products": {"kind": "gnn_full", "n_nodes": 2449029,
                     "n_edges": 61859140, "d_feat": 100, "n_classes": 47},
    "molecule": {"kind": "gnn_batched", "n_nodes": 30, "n_edges": 64,
                 "batch": 128, "d_feat": 16, "n_classes": 1},
}
SKIPS = {}


def make_config(smoke: bool = False, d_feat: int = 1433,
                n_classes: int = 7) -> GCNConfig:
    if smoke:
        return GCNConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16,
                         d_feat=min(d_feat, 64), n_classes=n_classes)
    return GCNConfig(name=ARCH_ID, n_layers=2, d_hidden=16, d_feat=d_feat,
                     n_classes=n_classes)
