"""The paper's own workload: GleanVec learning + multi-step search over the
Table-1 scale datasets (OI-13M / RQA-10M / T2I-10M shapes).

learn  -- the data-touching inner loop of Algorithm 5 (k-means EM step +
          query moment + per-cluster moments), database sharded over every
          mesh axis.
search -- Algorithm 1 with eager GleanVec scoring (Algorithm 4): per-shard
          reduced scan + all-gather candidates + full-precision rerank.
"""
ARCH_ID = "gleanvec-paper"
FAMILY = "vectorsearch"
SHAPES = {
    "learn_oi13m": {"kind": "vs_learn", "n": 13_000_000, "D": 512,
                    "d": 160, "C": 48, "m_queries": 10_000},
    "search_oi13m": {"kind": "vs_search", "n": 13_000_000, "D": 512,
                     "d": 160, "C": 48, "batch": 1024, "k": 10,
                     "kappa": 100},
    "search_oi13m_sorted": {"kind": "vs_search_sorted", "n": 13_000_000,
                            "D": 512, "d": 160, "C": 48, "batch": 1024,
                            "k": 10, "kappa": 100},
    "search_rqa10m": {"kind": "vs_search", "n": 10_000_000, "D": 768,
                      "d": 160, "C": 48, "batch": 1024, "k": 10,
                      "kappa": 100},
    "search_t2i10m": {"kind": "vs_search", "n": 10_000_000, "D": 200,
                      "d": 192, "C": 48, "batch": 1024, "k": 10,
                      "kappa": 100},
}
SKIPS = {}


def make_config(smoke: bool = False):
    return {"smoke": smoke}
