"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. Experts do not divide the 16-way model
axis -> tp-sharded experts (d_ff tensor-parallel) + FSDP.
[hf:xai-org/grok-1; unverified]"""
import jax.numpy as jnp

from repro.configs.lm_common import FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "grok-1-314b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items() if k != "long_500k"}
TRAIN_ACCUM = 16
OPTIMIZER = "adafactor"
ACCUM_DTYPE = "bfloat16"
SKIPS = dict(FULL_ATTN_LONG_SKIP)


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
            moe=MoEConfig(n_experts=4, top_k=2, group_size=32,
                          sharding="tp"),
            q_chunk=32, loss_chunks=2, remat_policy="dots")
    return TransformerConfig(
        name=ARCH_ID, n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_head=128, d_ff=32768, vocab=131072,
        moe=MoEConfig(n_experts=8, top_k=2, group_size=256, sharding="tp"),
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        q_chunk=512, loss_chunks=16, remat_policy="nothing",
        remat_block=8)
