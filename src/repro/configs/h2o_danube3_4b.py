"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 -- llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
import jax.numpy as jnp

from repro.configs.lm_common import LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH_ID = "h2o-danube-3-4b"
FAMILY = "lm"
SHAPES = dict(LM_SHAPES)   # SWA => long_500k runs (windowed KV cache)
TRAIN_ACCUM = 4
SKIPS = {}


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, d_head=16, d_ff=128, vocab=256, swa_window=16,
            q_chunk=32, loss_chunks=2, remat_policy="dots")
    return TransformerConfig(
        name=ARCH_ID, n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_head=120, d_ff=10240, vocab=32000, swa_window=4096,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        q_chunk=512, loss_chunks=8, remat_policy="nothing",
        remat_block=0)
