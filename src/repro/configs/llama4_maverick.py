"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 -> ep-sharded experts
(128 % 16 == 0). Modality frontend (early fusion) is out of scope for the
LM backbone per the assignment. [hf:meta-llama/Llama-4; unverified]"""
import jax.numpy as jnp

from repro.configs.lm_common import FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "llama4-maverick-400b-a17b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items() if k != "long_500k"}
TRAIN_ACCUM = 16
OPTIMIZER = "adafactor"
ACCUM_DTYPE = "bfloat16"
SKIPS = dict(FULL_ATTN_LONG_SKIP)


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
            moe=MoEConfig(n_experts=8, top_k=1, group_size=32,
                          sharding="ep"),
            q_chunk=32, loss_chunks=2, remat_policy="dots")
    return TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_head=128, d_ff=8192, vocab=202048,
        moe=MoEConfig(n_experts=128, top_k=1, group_size=1024,
                      sharding="ep"),
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        q_chunk=512, loss_chunks=16, remat_policy="nothing",
        remat_block=8)
