"""Shared shape set for the LM-family architectures (assignment spec)."""
from __future__ import annotations

# kind: "train" lowers train_step; "prefill" lowers the forward pass;
# "decode" lowers serve_step (1 new token against a seq_len KV cache).
LM_SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# Pure full-attention archs skip long_500k (sub-quadratic attention needed;
# see DESIGN.md section 5): only h2o-danube3 (SWA) runs it.
FULL_ATTN_LONG_SKIP = {
    "long_500k": ("pure full attention: 500k-context decode exceeds the "
                  "per-chip KV-cache HBM budget and 500k prefill is "
                  "quadratic; run only for the SWA arch (h2o-danube3), "
                  "per assignment note"),
}
