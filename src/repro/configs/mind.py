"""mind [recsys]: Multi-Interest Network with Dynamic routing: embed_dim=64
n_interests=4 capsule_iters=3. [arXiv:1904.08030; unverified]"""
from repro.configs.recsys_common import RECSYS_SHAPES
from repro.models.recsys import MINDConfig

ARCH_ID = "mind"
FAMILY = "recsys"
MODEL = "mind"
SHAPES = dict(RECSYS_SHAPES)
SKIPS = {}


def make_config(smoke: bool = False) -> MINDConfig:
    if smoke:
        return MINDConfig(name=ARCH_ID + "-smoke", n_items=1000, seq_len=8,
                          embed_dim=16)
    return MINDConfig(name=ARCH_ID, n_items=4_000_000, seq_len=50,
                      embed_dim=64, n_interests=4, capsule_iters=3)
