"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 -- GQA + squared-ReLU MLP (no GLU). [arXiv:2402.16819;
unverified]"""
import jax.numpy as jnp

from repro.configs.lm_common import FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH_ID = "nemotron-4-15b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items() if k != "long_500k"}
TRAIN_ACCUM = 8
SKIPS = dict(FULL_ATTN_LONG_SKIP)


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
            act="squared_relu", glu=False, q_chunk=32, loss_chunks=2,
            remat_policy="dots")
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_head=128, d_ff=24576, vocab=256000, act="squared_relu", glu=False,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        q_chunk=512, loss_chunks=16, remat_policy="nothing",
        remat_block=0)
