"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- GQA with QKV bias. [arXiv:2407.10671; hf]"""
import jax.numpy as jnp

from repro.configs.lm_common import FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen2-72b"
FAMILY = "lm"
SHAPES = {k: v for k, v in LM_SHAPES.items() if k != "long_500k"}
TRAIN_ACCUM = 8
OPTIMIZER = "adafactor"
SKIPS = dict(FULL_ATTN_LONG_SKIP)


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_head=8, d_ff=128, vocab=512, qkv_bias=True,
            q_chunk=32, loss_chunks=2, remat_policy="dots")
    return TransformerConfig(
        name=ARCH_ID, n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_head=128, d_ff=29568, vocab=152064, qkv_bias=True,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        q_chunk=512, loss_chunks=16, remat_policy="nothing",
        remat_block=10)
