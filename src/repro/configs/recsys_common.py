"""Shared shape set for the recsys-family architectures (assignment spec)."""
RECSYS_SHAPES = {
    "train_batch": {"kind": "recsys_train", "batch": 65536},
    "serve_p99": {"kind": "recsys_serve", "batch": 512},
    "serve_bulk": {"kind": "recsys_serve", "batch": 262144},
    "retrieval_cand": {"kind": "recsys_retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}
