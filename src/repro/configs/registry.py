"""Architecture registry: --arch <id> -> config module."""
from repro.configs import (bst, dlrm_mlperf, fm, gcn_cora, gleanvec_paper,
                           grok1_314b, h2o_danube3_4b, llama4_maverick,
                           mind, nemotron4_15b, qwen2_72b)

ARCHS = {m.ARCH_ID: m for m in (
    h2o_danube3_4b, qwen2_72b, nemotron4_15b, grok1_314b, llama4_maverick,
    gcn_cora, bst, mind, dlrm_mlperf, fm, gleanvec_paper)}

ASSIGNED = [m.ARCH_ID for m in (
    h2o_danube3_4b, qwen2_72b, nemotron4_15b, grok1_314b, llama4_maverick,
    gcn_cora, bst, mind, dlrm_mlperf, fm)]


def get(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]
