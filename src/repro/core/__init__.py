"""Core paper contribution: LeanVec-Sphering + GleanVec and their baselines.

Public API re-exports; see DESIGN.md for the paper-to-module map.
"""
from repro.core import (baselines, gleanvec, leanvec_sphering, linalg,
                        metrics, quantization, scorer, search,
                        spherical_kmeans, streaming)
from repro.core.baselines import (LinearDR, leanvec_es, leanvec_es_fw,
                                  leanvec_fw, svd_fit)
from repro.core.gleanvec import GleanVecModel
from repro.core.leanvec_sphering import SpheringModel

__all__ = [
    "baselines", "gleanvec", "leanvec_sphering", "linalg", "metrics",
    "quantization", "scorer", "search", "spherical_kmeans", "streaming",
    "LinearDR", "SpheringModel", "GleanVecModel",
    "svd_fit", "leanvec_fw", "leanvec_es", "leanvec_es_fw",
]
