"""Baselines the paper compares against (Section 5.1, Figures 4-5).

* ``svd_fit``       -- query-agnostic SVD/PCA of the database (the "SVD" curve).
* ``leanvec_fw``    -- LeanVec-FW [61]: block-coordinate descent on Problem (3),
                       each block solved with Frank-Wolfe over the convex hull
                       of the Stiefel manifold (the unit spectral-norm ball,
                       whose LMO is the polar factor of the gradient).
* ``leanvec_es``    -- LeanVec-ES [61]: eigensearch -- search over alpha for the
                       top-d eigenbasis of the convex combination
                       (1-a) K_X/tr(K_X) + a K_Q/tr(K_Q), used for both A and B.
* ``leanvec_es_fw`` -- ES initialization refined by FW.

All operate on second moments (K_Q, K_X), making them sharding-agnostic: the
moments are computed once with a distributed einsum, the optimization is
replicated O(D^3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.leanvec_sphering import SpheringModel

__all__ = ["LinearDR", "svd_fit", "leanvec_fw", "leanvec_es", "leanvec_es_fw",
           "leanvec_loss_from_moments"]


class LinearDR(NamedTuple):
    """A generic linear query/database projection pair (d x D each)."""

    a: jax.Array
    b: jax.Array

    @property
    def dim(self) -> int:
        return self.a.shape[0]


def leanvec_loss_from_moments(a, b, k_q, k_x):
    """Problem (3) loss via moments:

    L(A,B) = sum_q sum_x (<Aq, Bx> - <q, x>)^2
           = tr( (A^T B - I)^T K_Q (A^T B - I) K_X ).
    """
    m = a.T @ b - jnp.eye(a.shape[1], dtype=a.dtype)
    return jnp.trace(m.T @ k_q @ m @ k_x)


def svd_fit(k_x: jax.Array, d: int) -> LinearDR:
    """Query-agnostic PCA: A = B = top-d eigvecs of K_X."""
    p = linalg.topk_eigvecs(k_x, d)
    return LinearDR(a=p, b=p)


# ---------------------------------------------------------------------------
# LeanVec-FW: BCD + Frank-Wolfe over conv(St(D, d)).
# ---------------------------------------------------------------------------


def _fw_block(loss_fn, var, n_iters):
    """Frank-Wolfe over the unit spectral-norm ball for one BCD block.

    Each block subproblem of Problem (3) is a convex quadratic, so we use the
    exact line search: along v + g*(s - v), L is a quadratic in g and
    g* = clip(-b / 2a, 0, 1) with b = <grad, s - v>, a = L(s) - L(v) - b.
    """
    value_and_grad = jax.value_and_grad(loss_fn)

    def body(_, v):
        lv, g = value_and_grad(v)
        s = -linalg.polar(g)  # LMO over {||S||_2 <= 1}
        direction = s - v
        b = jnp.sum(g * direction)
        a = loss_fn(s) - lv - b
        gamma = jnp.clip(-b / (2.0 * a + 1e-30), 0.0, 1.0)
        gamma = jnp.where(a > 0, gamma, jnp.where(b < 0, 1.0, 0.0))
        return v + gamma * direction

    return jax.lax.fori_loop(0, n_iters, body, var)


@functools.partial(jax.jit, static_argnames=("d", "n_bcd", "n_fw"))
def leanvec_fw(k_q: jax.Array, k_x: jax.Array, d: int, n_bcd: int = 8,
               n_fw: int = 10) -> LinearDR:
    """LeanVec-FW baseline. Initialized from the query-agnostic SVD."""
    p0 = linalg.topk_eigvecs(k_x, d)
    eye = jnp.eye(k_q.shape[0], dtype=jnp.float32)
    # Normalize moments so FW step sizes are scale-free.
    k_qn = k_q / jnp.trace(k_q)
    k_xn = k_x / jnp.trace(k_x)

    def loss_a(a, b):
        m = a.T @ b - eye
        return jnp.trace(m.T @ k_qn @ m @ k_xn)

    def bcd_step(_, ab):
        a, b = ab
        a = _fw_block(lambda v: loss_a(v, b), a, n_fw)
        b = _fw_block(lambda v: loss_a(a, v), b, n_fw)
        return (a, b)

    a, b = jax.lax.fori_loop(0, n_bcd, bcd_step, (p0, p0))
    # NOTE: iterates live in conv(St(D,d)) (unit spectral-norm ball). Only
    # A^T B matters for score ranking, and a final Stiefel retraction degrades
    # the converged product badly, so we return the relaxed solution directly.
    return LinearDR(a=a, b=b)


# ---------------------------------------------------------------------------
# LeanVec-ES: eigensearch over the X/Q trade-off.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("d", "n_alphas"))
def leanvec_es(k_q: jax.Array, k_x: jax.Array, d: int,
               n_alphas: int = 17) -> LinearDR:
    """LeanVec-ES baseline: pick alpha on a bisection grid minimizing the
    Problem-(3) loss of the joint subspace P(alpha); A = B = P(alpha)."""
    k_qn = k_q / jnp.trace(k_q)
    k_xn = k_x / jnp.trace(k_x)

    alphas = jnp.linspace(0.0, 1.0, n_alphas)

    def eval_alpha(alpha):
        m = (1.0 - alpha) * k_xn + alpha * k_qn
        p = linalg.topk_eigvecs(m, d)
        return leanvec_loss_from_moments(p, p, k_qn, k_xn), p

    losses, ps = jax.lax.map(eval_alpha, alphas)
    best = jnp.argmin(losses)
    p = ps[best]
    return LinearDR(a=p, b=p)


def leanvec_es_fw(k_q: jax.Array, k_x: jax.Array, d: int, n_bcd: int = 8,
                  n_fw: int = 10, n_alphas: int = 17) -> LinearDR:
    """LeanVec-ES+FW: ES solution refined with FW BCD."""
    es = leanvec_es(k_q, k_x, d, n_alphas)
    eye = jnp.eye(k_q.shape[0], dtype=jnp.float32)
    k_qn = k_q / jnp.trace(k_q)
    k_xn = k_x / jnp.trace(k_x)

    def loss_a(a, b):
        m = a.T @ b - eye
        return jnp.trace(m.T @ k_qn @ m @ k_xn)

    def bcd_step(_, ab):
        a, b = ab
        a = _fw_block(lambda v: loss_a(v, b), a, n_fw)
        b = _fw_block(lambda v: loss_a(a, v), b, n_fw)
        return (a, b)

    a, b = jax.lax.fori_loop(0, n_bcd, bcd_step, (es.a, es.b))
    return LinearDR(a=a, b=b)  # see leanvec_fw NOTE on the relaxation
