"""GleanVec (paper Section 4, Algorithm 5): piecewise-linear query-aware DR.

Learning (Algorithm 5):
  1. spherical k-means on normalized database -> landmarks {mu_c};
  2. partition X by Eq. (19);
  3. per cluster, LeanVec-Sphering (Algorithm 2) -> (A_c, B_c).

Encoding: x_i -> (c_i, B_{c_i} x_i) stored contiguously (Eq. 14-15).
Query-side: lazy (Alg. 3) or eager (Alg. 4) selection of A_{c_i} q.

The per-cluster fits share the sphering matrix W (it depends on the queries
only), so learning computes one (D,D) eigh for W plus a batched (vmapped)
eigh over the C per-cluster sphered moments W K_X^c W.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import linalg, spherical_kmeans
from repro.core.leanvec_sphering import SpheringModel

__all__ = ["GleanVecModel", "fit", "fit_from_moments", "assign_tags",
           "encode_database", "sort_by_tag", "inverse_permutation",
           "project_queries_eager", "inner_products_lazy",
           "inner_products_eager", "per_cluster_moments"]


class GleanVecModel(NamedTuple):
    """Learned GleanVec transform.

    ``centers``: (C, D) unit landmarks;  ``a``: (C, d, D);  ``b``: (C, d, D);
    ``w`` / ``w_pinv``: (D, D) shared sphering (query-side).
    """

    centers: jax.Array
    a: jax.Array
    b: jax.Array
    w: jax.Array
    w_pinv: jax.Array

    @property
    def n_clusters(self) -> int:
        return self.centers.shape[0]

    @property
    def dim(self) -> int:
        return self.a.shape[1]

    def truncate(self, d: int) -> "GleanVecModel":
        """Runtime target-d selection (Section 3.1 carries over per cluster)."""
        return GleanVecModel(self.centers, self.a[:, :d], self.b[:, :d],
                             self.w, self.w_pinv)


def per_cluster_moments(x: jax.Array, tags: jax.Array, c: int) -> jax.Array:
    """K_X^c = sum_{x in X_c} x x^T for each cluster: (C, D, D).

    One einsum; shards over rows of ``x`` under pjit (psum on output).
    """
    onehot = jax.nn.one_hot(tags, c, dtype=jnp.float32)
    return jnp.einsum("nc,nd,ne->cde", onehot, x.astype(jnp.float32),
                      x.astype(jnp.float32))


def fit_from_moments(centers: jax.Array, k_q: jax.Array,
                     k_x_per_cluster: jax.Array, d: int,
                     rel_eps: float = 1e-4) -> GleanVecModel:
    """Per-cluster LeanVec-Sphering given precomputed moments."""
    w, w_pinv = linalg.sphering_from_moment(k_q, rel_eps)

    def fit_one(k_x_c):
        m = w @ k_x_c @ w
        m = 0.5 * (m + m.T)
        p = linalg.topk_eigvecs(m, d)
        return p @ w_pinv, p @ w

    a, b = jax.vmap(fit_one)(k_x_per_cluster)
    return GleanVecModel(centers=centers, a=a, b=b, w=w, w_pinv=w_pinv)


@functools.partial(jax.jit, static_argnames=("c", "d", "kmeans_iters"))
def fit(key: jax.Array, queries: jax.Array, database: jax.Array, c: int,
        d: int, kmeans_iters: int = 25, rel_eps: float = 1e-4
        ) -> GleanVecModel:
    """Algorithm 5. ``queries: (m, D)``, ``database: (n, D)``."""
    km = spherical_kmeans.fit(key, database, c, kmeans_iters)
    x_unit = spherical_kmeans.normalize_rows(database.astype(jnp.float32))
    tags = spherical_kmeans.assign(x_unit, km.centers)
    k_q = linalg.second_moment(queries)
    k_x_c = per_cluster_moments(database, tags, c)
    return fit_from_moments(km.centers, k_q, k_x_c, d, rel_eps)


def assign_tags(model: GleanVecModel, database: jax.Array) -> jax.Array:
    """Eq. (19) cluster assignment under the model's fixed landmarks (the
    tag half of :func:`encode_database`; streaming inserts use it alone to
    route rank-1 moment updates)."""
    x_unit = spherical_kmeans.normalize_rows(
        jnp.asarray(database, jnp.float32))
    return spherical_kmeans.assign(x_unit, model.centers)


def encode_database(model: GleanVecModel, database: jax.Array):
    """Eq. (14)-(15): tags ``c_i`` and reduced vectors ``x_i_low = B_{c_i} x_i``.

    Returns ``(tags: (n,) int32, x_low: (n, d))``. The pair is what a
    deployment stores contiguously per vector.
    """
    database = jnp.asarray(database, jnp.float32)
    tags = assign_tags(model, database)
    # x_low_i = B_{tags_i} x_i: gather the (d, D) block then contract.
    x_low = jnp.einsum("ndk,nk->nd", model.b[tags], database)
    return tags, x_low


def project_queries_eager(model: GleanVecModel, queries: jax.Array):
    """Alg. 4 preprocess: all views q_c = A_c q. (m, C, d)."""
    return jnp.einsum("cdk,mk->mcd", model.a, queries.astype(jnp.float32))


def inner_products_lazy(model: GleanVecModel, query: jax.Array,
                        tags: jax.Array, x_low: jax.Array) -> jax.Array:
    """Alg. 3: per-vector on-the-fly A_{c_i} q. query: (D,) -> (n,) scores."""
    a_sel = model.a[tags]                      # (n, d, D) gather
    q_proj = jnp.einsum("ndk,k->nd", a_sel, query.astype(jnp.float32))
    return jnp.sum(q_proj * x_low, axis=-1)


def inner_products_eager(q_views: jax.Array, tags: jax.Array,
                         x_low: jax.Array) -> jax.Array:
    """Alg. 4: select precomputed view q_{c_i}. q_views: (C, d) for one query."""
    return jnp.sum(q_views[tags] * x_low, axis=-1)


def sort_by_tag(tags, x_low, x_full=None, block: int = 4096,
                slack_blocks: int = 0):
    """Cluster-contiguous layout for the sorted scorers / scans (see
    core.scorer.SortedGleanVecScorer): sorts rows by tag and pads each
    cluster to a ``block`` multiple, so every block of the sorted database
    carries exactly one tag. Works for any (n, d) row array -- f32 reduced
    vectors or u8 codes (pads with zeros of the input dtype).

    ``slack_blocks`` appends that many EXTRA all-padding blocks per
    cluster beyond the round-up -- free slots the streaming path's
    ``insert_rows`` can fill without changing the layout's shape (and
    hence without recompiling anything that closed over it).

    Returns (x_low_sorted, block_tags, perm, x_full_sorted) where
    ``perm[i_sorted] = original id`` (padding rows map to id -1 and are
    filled with zeros; sorted scorers additionally mask them to -inf).
    """
    import numpy as np
    tags_np = np.asarray(tags)
    x_low_np = np.asarray(x_low)
    n, d = x_low_np.shape
    order = np.argsort(tags_np, kind="stable")
    sorted_tags = tags_np[order]
    c = int(tags_np.max()) + 1 if n else 1
    rows, perm, blk_tags = [], [], []
    full_rows = None if x_full is None else []
    x_full_np = None if x_full is None else np.asarray(x_full)
    for ci in range(c):
        sel = order[sorted_tags == ci]
        pad = (-len(sel)) % block + slack_blocks * block
        rows.append(x_low_np[sel])
        perm.append(sel.astype(np.int64))
        if full_rows is not None:
            full_rows.append(x_full_np[sel])
        if pad:
            rows.append(np.zeros((pad, d), x_low_np.dtype))
            perm.append(np.full(pad, -1, np.int64))
            if full_rows is not None:
                full_rows.append(
                    np.zeros((pad, x_full_np.shape[1]), x_full_np.dtype))
        blk_tags.extend([ci] * ((len(sel) + pad) // block))
    x_low_sorted = jnp.asarray(np.concatenate(rows, axis=0))
    perm = jnp.asarray(np.concatenate(perm))
    block_tags = jnp.asarray(np.asarray(blk_tags, np.int32))
    x_full_sorted = (None if full_rows is None
                     else jnp.asarray(np.concatenate(full_rows, axis=0)))
    return x_low_sorted, block_tags, perm, x_full_sorted


def inverse_permutation(perm, n: int):
    """``inv[original_id] = sorted row`` for a ``sort_by_tag`` permutation.

    ``perm (n_sorted,)`` maps sorted rows to original ids (-1 = padding);
    every original id in [0, n) appears exactly once, so ``inv`` is total.
    """
    import numpy as np
    perm_np = np.asarray(perm)
    inv = np.full(n, -1, np.int32)
    valid = perm_np >= 0
    inv[perm_np[valid]] = np.nonzero(valid)[0].astype(np.int32)
    return jnp.asarray(inv)
