"""LeanVec-Sphering (paper Section 3, Algorithm 2).

Closed-form, hyperparameter-free, query-aware linear dimensionality reduction:

    Q = U S V^T            (SVD of the query matrix, D x m)
    W = U S U^T            (sphering matrix; W^2 = Q Q^T)
    P = top-d left singular vectors of W X
    A = P W^{-1}           (query projection,  f(q) = A q)
    B = P W                (database projection, g(x) = B x)

Everything is phrased in terms of the second-moment matrices
``K_Q = Q Q^T`` and ``K_X = X X^T`` so the same code serves the batch
(Algorithm 2), streaming (Section 3.2) and distributed (sharded-einsum + psum)
paths: the SVD of ``W X`` is replaced by the eigendecomposition of
``W K_X W`` (they share left singular vectors / eigenvectors).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import linalg

__all__ = ["SpheringModel", "fit", "fit_from_moments", "project_queries",
           "project_database", "full_rotation_model"]


class SpheringModel(NamedTuple):
    """Learned LeanVec-Sphering transform.

    ``a``: (d, D) query projection;  ``b``: (d, D) database projection;
    ``p``: (d, D) Stiefel factor;    ``w`` / ``w_pinv``: (D, D) sphering.

    When ``d == D`` this is the "flexible target dimensionality" model of
    Section 3.1: any row-prefix ``a[:d'], b[:d']`` is a valid reduced model and
    ``<a q, b x> == <q, x>`` exactly (Eq. 10), enabling runtime-tunable d and
    rerank-from-the-same-storage.
    """

    a: jax.Array
    b: jax.Array
    p: jax.Array
    w: jax.Array
    w_pinv: jax.Array

    @property
    def dim(self) -> int:
        return self.a.shape[0]

    def truncate(self, d: int) -> "SpheringModel":
        """Runtime selection of the target dimensionality (Section 3.1)."""
        return SpheringModel(self.a[:d], self.b[:d], self.p[:d], self.w,
                             self.w_pinv)


def fit_from_moments(k_q: jax.Array, k_x: jax.Array, d: int,
                     rel_eps: float = 1e-4) -> SpheringModel:
    """Algorithm 2 phrased on second moments (D x D inputs).

    ``k_q = sum_q q q^T``, ``k_x = sum_x x x^T``.
    """
    w, w_pinv = linalg.sphering_from_moment(k_q, rel_eps)
    # eig(W K_X W) shares eigenvectors with the left singular vectors of W X.
    m = w @ k_x @ w
    m = 0.5 * (m + m.T)  # re-symmetrize for numerical stability
    p = linalg.topk_eigvecs(m, d)
    return SpheringModel(a=p @ w_pinv, b=p @ w, p=p, w=w, w_pinv=w_pinv)


def fit(queries: jax.Array, database: jax.Array, d: int,
        rel_eps: float = 1e-4) -> SpheringModel:
    """Algorithm 2. ``queries: (m, D)``, ``database: (n, D)`` (row-major).

    REQUIREMENT (implicit in the paper, which uses 10k learning queries):
    m >~ D, else K_Q = QQ^T is rank-deficient and the pseudo-inverse W^+
    zeroes the null directions -- the query projection A = P W^+ then
    discards most of the space and recall drops BELOW plain SVD (measured
    on the laion twin at m=128, D=512). We warn rather than raise: a
    rank-deficient fit is still the paper's algorithm, just under-sampled.

    The data-touching part is two sharded einsums (lowering to matmul + psum
    under pjit); the rest is replicated O(D^3).
    """
    if queries.shape[0] < queries.shape[1]:
        import warnings
        warnings.warn(
            f"LeanVec-Sphering: {queries.shape[0]} learning queries for "
            f"D={queries.shape[1]} dims -- K_Q is rank-deficient and the "
            "sphering projection will discard directions; use m >= D "
            "queries (the paper uses 10k).", stacklevel=2)
    k_q = linalg.second_moment(queries)
    k_x = linalg.second_moment(database)
    return fit_from_moments(k_q, k_x, d, rel_eps)


def full_rotation_model(queries: jax.Array, database: jax.Array,
                        rel_eps: float = 1e-4) -> SpheringModel:
    """Section 3.1: fit with ``d = D`` so the stored vectors ``x' = P' W x``
    support every prefix dimensionality and exact reranking via Eq. (10)."""
    return fit(queries, database, d=queries.shape[1], rel_eps=rel_eps)


def project_queries(model: SpheringModel, queries: jax.Array) -> jax.Array:
    """f(q) = A q, batched: (m, D) -> (m, d)."""
    return queries @ model.a.T


def project_database(model: SpheringModel, database: jax.Array) -> jax.Array:
    """g(x) = B x, batched: (n, D) -> (n, d)."""
    return database @ model.b.T
