"""Linear-algebra primitives shared by the LeanVec/GleanVec family.

All functions are jit-safe and operate on second-moment (Gram) matrices where
possible so that the data-touching part is a single sharded einsum (psum under
GSPMD) and the O(D^3) part runs replicated on D x D matrices (D <= ~1024 for
every dataset in the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "second_moment",
    "cross_moment",
    "sphering_from_moment",
    "topk_eigvecs",
    "orthonormalize_rows",
    "polar",
    "safe_inv_sqrt_spectrum",
]


def second_moment(x: jax.Array, dtype=jnp.float32) -> jax.Array:
    """K = sum_i x_i x_i^T  for row-major data ``x: (n, D)`` -> ``(D, D)``.

    Under pjit with ``x`` row-sharded this lowers to a local einsum + psum.
    """
    x = x.astype(dtype)
    return jnp.einsum("nd,ne->de", x, x)


def cross_moment(x: jax.Array, w: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Weighted moment ``sum_i w_i x_i x_i^T`` with per-row weights ``w: (n,)``."""
    x = x.astype(dtype)
    return jnp.einsum("n,nd,ne->de", w.astype(dtype), x, x)


def safe_inv_sqrt_spectrum(s: jax.Array, rel_eps: float = 1e-4):
    """Pseudo-inverse-safe 1/s for an eigen/singular spectrum ``s >= 0``.

    Entries below ``rel_eps * max(s)`` are treated as zero (paper: "if not
    [invertible], we can use a pseudoinverse"). Default 1e-4: measured on an
    ill-conditioned query moment (cond(K_Q) ~ 7e10, low intrinsic query
    dim), 1e-6 lets W^-1 amplify noise directions (loss 0.51 -> 0.05 when
    clipped at 1e-4); 1e-2 starts discarding signal (loss 1.6).
    """
    cutoff = rel_eps * jnp.max(s)
    safe = jnp.where(s > cutoff, s, 1.0)
    return jnp.where(s > cutoff, 1.0 / safe, 0.0)


def sphering_from_moment(k_q: jax.Array, rel_eps: float = 1e-4):
    """Compute the sphering matrix ``W = U S U^T`` and its pseudo-inverse.

    ``k_q = Q Q^T = U S^2 U^T`` (eigendecomposition), so ``S = sqrt(eigvals)``.
    Returns ``(W, W_pinv)``, both ``(D, D)`` symmetric PSD.
    """
    evals, u = jnp.linalg.eigh(k_q.astype(jnp.float32))
    evals = jnp.maximum(evals, 0.0)
    s = jnp.sqrt(evals)
    w = (u * s[None, :]) @ u.T
    s_inv = safe_inv_sqrt_spectrum(s, rel_eps)
    w_pinv = (u * s_inv[None, :]) @ u.T
    return w, w_pinv


def topk_eigvecs(m: jax.Array, d: int) -> jax.Array:
    """Top-``d`` eigenvectors (largest eigenvalues) of symmetric ``m: (D, D)``.

    Returns ``P: (d, D)`` with orthonormal rows, sorted by decreasing
    eigenvalue. ``d`` may equal D (full rotation, used by the flexible-d
    storage scheme of Section 3.1).
    """
    evals, vecs = jnp.linalg.eigh(m.astype(jnp.float32))  # ascending
    order = jnp.argsort(-evals)
    return vecs[:, order[:d]].T


def orthonormalize_rows(a: jax.Array) -> jax.Array:
    """Project ``a: (d, D)`` onto the Stiefel manifold St(D, d) (row-orthonormal)
    via the polar decomposition: argmin_{U in St} ||U - a||_F."""
    u, _, vt = jnp.linalg.svd(a, full_matrices=False)
    return u @ vt


def polar(a: jax.Array) -> jax.Array:
    """Polar factor of ``a`` (same shape); the LMO direction over the unit
    spectral-norm ball (convex hull of the Stiefel manifold)."""
    u, _, vt = jnp.linalg.svd(a, full_matrices=False)
    return u @ vt
