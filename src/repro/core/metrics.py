"""Evaluation metrics (paper Section 5 "Metrics")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["recall_at_k", "leanvec_loss", "ip_relative_error",
           "captured_variance_profile"]


def recall_at_k(retrieved: jax.Array, ground_truth: jax.Array) -> jax.Array:
    """K-recall@k = |S intersect G| / K, averaged over queries.

    ``retrieved``: (nq, k) ids; ``ground_truth``: (nq, K) ids.
    """
    k_gt = ground_truth.shape[1]
    hits = (retrieved[:, :, None] == ground_truth[:, None, :])
    return jnp.mean(jnp.sum(jnp.any(hits, axis=1), axis=-1) / k_gt)


def leanvec_loss(a: jax.Array, b: jax.Array, queries: jax.Array,
                 database: jax.Array) -> jax.Array:
    """Problem (3) loss, normalized per (q, x) pair, computed via moments."""
    k_q = jnp.einsum("nd,ne->de", queries, queries)
    k_x = jnp.einsum("nd,ne->de", database, database)
    m = a.T @ b - jnp.eye(a.shape[1], dtype=a.dtype)
    val = jnp.trace(m.T @ k_q @ m @ k_x)
    return val / (queries.shape[0] * database.shape[0])


def ip_relative_error(approx: jax.Array, exact: jax.Array) -> jax.Array:
    """Mean |approx - exact| / (|exact| + eps) over a score matrix."""
    return jnp.mean(jnp.abs(approx - exact) / (jnp.abs(exact) + 1e-6))


def captured_variance_profile(k_x: jax.Array) -> jax.Array:
    """Cumulative normalized eigenvalue profile (Figure 6, right)."""
    evals = jnp.linalg.eigvalsh(k_x)
    evals = jnp.sort(evals)[::-1]
    csum = jnp.cumsum(jnp.maximum(evals, 0.0))
    return csum / jnp.maximum(csum[-1], 1e-12)
