"""Scalar quantization of reduced database vectors (paper Section 3: "we could
apply scalar quantization to the database vectors Bx ... as in LeanVec").

PER-DIMENSION affine int8: sphering-reduced vectors are strongly anisotropic
(leading principal dims carry most variance), so per-vector ranges (LVQ on
raw data) destroy the low-variance dims -- measured 10-recall@10 collapse
from 0.99 to 0.14 on the laion twin. Per-dimension scales keep every dim at
8-bit resolution AND fold into the query:

    <q, u * delta + lo> = <q * delta, u> + <q, lo>

so the fused kernel (kernels/sq_dot) is a pure int8 matmul with a
query-side pre-scale -- zero extra work per database byte.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SQDatabase", "ClusteredSQDatabase", "quantize",
           "quantize_per_cluster", "dequantize", "quantized_inner_products"]


class SQDatabase(NamedTuple):
    codes: jax.Array   # (n, d) uint8 codes
    lo: jax.Array      # (d,) per-dimension lower bound
    delta: jax.Array   # (d,) per-dimension step

    @property
    def bits(self) -> int:
        return 8


def quantize(x: jax.Array, bits: int = 8,
             valid: jax.Array = None) -> SQDatabase:
    """Per-dimension affine quantization to ``bits`` (<=8) levels.

    ``valid`` ((n,) bool, optional) restricts the (lo, hi) range fit to
    the marked rows -- streaming stores quantize fixed-capacity arrays
    whose dead/padding rows must not stretch the scales. Codes are still
    produced for every row (out-of-range rows clip)."""
    levels = (1 << bits) - 1
    if valid is None:
        lo = jnp.min(x, axis=0)
        hi = jnp.max(x, axis=0)
    else:
        v = valid[:, None]
        lo = jnp.min(jnp.where(v, x, jnp.inf), axis=0)
        hi = jnp.max(jnp.where(v, x, -jnp.inf), axis=0)
        lo = jnp.where(jnp.isfinite(lo), lo, 0.0)   # no valid rows at all
        hi = jnp.where(jnp.isfinite(hi), hi, 0.0)
    delta = jnp.maximum(hi - lo, 1e-12) / levels
    codes = jnp.clip(jnp.round((x - lo[None, :]) / delta[None, :]), 0,
                     levels).astype(jnp.uint8)
    return SQDatabase(codes=codes, lo=lo, delta=delta)


class ClusteredSQDatabase(NamedTuple):
    codes: jax.Array   # (n, d) uint8 codes
    lo: jax.Array      # (C, d) per-cluster per-dimension lower bound
    delta: jax.Array   # (C, d) per-cluster per-dimension step


def quantize_per_cluster(x: jax.Array, tags: jax.Array, n_clusters: int,
                         bits: int = 8,
                         valid: jax.Array = None) -> ClusteredSQDatabase:
    """Per-cluster per-dimension affine quantization (the GleanVec ∘ SQ
    composition): each cluster's B_c x vectors get their own (lo, delta)
    per dimension, so anisotropy WITHIN a cluster is preserved at full
    8-bit resolution and the scales still fold into the per-cluster query
    views A_c q.

    ``valid`` ((n,) bool, optional) excludes rows from the per-cluster
    range fit (dead / padding rows of streaming stores); their codes are
    still produced (clipped). A cluster with no valid rows falls into the
    existing empty-cluster guard."""
    levels = (1 << bits) - 1
    x = x.astype(jnp.float32)
    x_lo, x_hi = x, x
    if valid is not None:
        x_lo = jnp.where(valid[:, None], x, jnp.inf)
        x_hi = jnp.where(valid[:, None], x, -jnp.inf)
    lo = jax.ops.segment_min(x_lo, tags, num_segments=n_clusters)
    hi = jax.ops.segment_max(x_hi, tags, num_segments=n_clusters)
    empty = ~jnp.isfinite(lo)          # empty cluster -> +-inf sentinels
    lo = jnp.where(empty, 0.0, lo)
    hi = jnp.where(~jnp.isfinite(hi), 0.0, hi)
    delta = jnp.maximum(hi - lo, 1e-12) / levels
    codes = jnp.clip(jnp.round((x - lo[tags]) / delta[tags]), 0,
                     levels).astype(jnp.uint8)
    return ClusteredSQDatabase(codes=codes, lo=lo, delta=delta)


def dequantize(db: SQDatabase) -> jax.Array:
    return db.codes.astype(jnp.float32) * db.delta[None, :] + db.lo[None, :]


def quantized_inner_products(query: jax.Array, db: SQDatabase) -> jax.Array:
    """<q, dequant(x)> without materializing the dequantized matrix.

    ``query (d,)`` -> scores ``(n,)``.
    """
    q_scaled = query * db.delta
    return db.codes.astype(jnp.float32) @ q_scaled + query @ db.lo
