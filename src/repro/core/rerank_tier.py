"""Two-level rerank memory hierarchy: host-resident full-precision tier.

The (n, D) float32 rerank store is D/d * 4 bytes per vector larger than
the int8 codes the fine-scan kernels stream -- it dominates device memory
long before the working set does. The DiskANN/SPANN-style layout keeps the
hot reduced codes near compute and demotes the full-precision tier one
level out, moving only the per-query candidate rows (kappa << n) across
the boundary. This module maps that hierarchy onto the SearchArtifacts
contract:

* :class:`HostStore` -- an (n, D) store that lives in HOST memory (numpy)
  but rides the ``ServingState`` pytree as STATIC aux data with zero
  array leaves, so the compiled search step never materializes it in
  device memory, ``jit`` never traces it, and swap/treedef checks compare
  it by (shape, dtype) -- a refreshed store with new contents is
  treedef-equal and swaps in with zero recompiles, exactly like a device
  leaf with unchanged aval.
* :class:`ShardedHostStore` -- the spill-to-host counterpart of
  ``ShardedIndex``: equal contiguous row shards held as separate host
  buffers (one per shard's spilled rerank tier), same API, global-id
  routing in ``take``.

Both keep ``x_full``'s consumer surface: ``np.asarray`` / ``jnp.asarray``
(``__array__``), fancy row indexing, and the functional
``.at[ids].set(rows)`` update ``streaming.insert_rows`` issues -- so the
streaming bridge and the benches are tier-agnostic. The one operation a
host tier CANNOT serve is a traced gather (``rerank`` inside ``jit``);
the serving engine runs the two-stage pipeline instead (device candidates
-> host ``take`` of kappa rows -> async ``device_put`` -> compiled
rerank), see :mod:`repro.serve.engine`.

Where the runtime's memories API can express device-addressable host
memory (``memory_kind="pinned_host"``: TPU, some GPUs), ``demote`` is
still the right call -- the engine's prefetch ``device_put`` then sources
from pinned pages; :func:`supports_pinned_host` probes the capability
(False on CPU backends, whose only memory space IS host memory).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["HostStore", "ShardedHostStore", "demote", "promote",
           "host_store", "host_arrays", "from_host_arrays",
           "supports_pinned_host"]


class _At:
    """``store.at[ids].set(rows)``: the jax functional-update surface,
    copy-on-write against host memory (only the touched shard buffers are
    copied for sharded stores)."""

    def __init__(self, store):
        self._store = store

    def __getitem__(self, ids):
        store = self._store

        class _Ref:
            @staticmethod
            def set(rows):
                return store.set_rows(ids, rows)

        return _Ref()


class _HostTier:
    """Shared surface of the host-resident stores (see module docstring)."""

    @property
    def ndim(self) -> int:
        return 2

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def at(self) -> _At:
        return _At(self)

    def __len__(self) -> int:
        return self.shape[0]

    def __array__(self, dtype=None, copy=None):
        a = self._materialize()
        return a.astype(dtype) if dtype is not None else a

    def __getitem__(self, idx):
        return self._materialize()[idx] \
            if isinstance(idx, tuple) else self.gather_rows(idx)

    # Treedef/aval identity: the serving contracts (jit cache keys,
    # ``ServingEngine._check_swap_compatible``) compare states by treedef,
    # and a host store IS treedef (aux) data -- equality by (type, shape,
    # dtype) makes a refreshed store with new CONTENTS swap-compatible
    # (zero recompiles), while a reshaped/retyped one is refused, exactly
    # matching the aval rule device leaves live under.
    def _aval(self):
        return (type(self).__name__, tuple(self.shape), str(self.dtype))

    def __eq__(self, other):
        return isinstance(other, _HostTier) and self._aval() == other._aval()

    def __hash__(self):
        return hash(self._aval())

    def __repr__(self):
        n, d = self.shape
        return (f"{type(self).__name__}(n={n}, D={d}, dtype={self.dtype}, "
                f"host_bytes={self.nbytes})")


class HostStore(_HostTier):
    """Single host buffer holding the (n, D) full-precision rerank tier."""

    def __init__(self, x: np.ndarray):
        self.x = np.ascontiguousarray(np.asarray(x))
        if self.x.ndim != 2:
            raise ValueError(f"HostStore needs an (n, D) array, got shape "
                             f"{self.x.shape}")

    @property
    def shape(self) -> Tuple[int, int]:
        return self.x.shape

    @property
    def dtype(self):
        return self.x.dtype

    @property
    def nbytes(self) -> int:
        return self.x.nbytes

    def _materialize(self) -> np.ndarray:
        return self.x

    def gather_rows(self, ids) -> np.ndarray:
        """Host gather of rows by external id; -1 (padding) ids clamp to
        row 0 -- callers mask their scores, exactly like the device
        ``x_full[safe]`` gather."""
        ids = np.asarray(ids)
        return self.x[np.maximum(ids, 0)]

    # the per-query candidate fetch: the ONLY data that crosses host->HBM
    take = gather_rows

    def set_rows(self, ids, rows) -> "HostStore":
        new = self.x.copy()
        new[np.asarray(ids)] = np.asarray(rows, self.x.dtype)
        return HostStore(new)


class ShardedHostStore(_HostTier):
    """Spill-to-host rerank tier of a sharded placement: equal contiguous
    row shards as separate host buffers (shard s owns global rows
    [s * per, (s+1) * per)), mirroring ``ShardedIndex``'s row partition.
    ``take`` routes global candidate ids to their owning shard, so only
    each shard's kappa-row slice crosses the boundary."""

    def __init__(self, shards: Sequence[np.ndarray]):
        self.shards = tuple(np.ascontiguousarray(np.asarray(s))
                            for s in shards)
        if not self.shards:
            raise ValueError("ShardedHostStore needs >= 1 shard")
        per = {s.shape[0] for s in self.shards}
        dims = {s.shape[1:] for s in self.shards}
        if len(per) != 1 or len(dims) != 1:
            raise ValueError("shards must be equal contiguous row splits; "
                             f"got shapes {[s.shape for s in self.shards]}")
        self.per = self.shards[0].shape[0]

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.per * len(self.shards), self.shards[0].shape[1])

    @property
    def dtype(self):
        return self.shards[0].dtype

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shards)

    def _materialize(self) -> np.ndarray:
        return np.concatenate(self.shards, axis=0)

    def gather_rows(self, ids) -> np.ndarray:
        ids = np.maximum(np.asarray(ids), 0)
        flat = ids.reshape(-1)
        out = np.empty((flat.size, self.shape[1]), self.dtype)
        owner = np.minimum(flat // self.per, self.n_shards - 1)
        for s, buf in enumerate(self.shards):
            sel = owner == s
            if sel.any():
                out[sel] = buf[flat[sel] - s * self.per]
        return out.reshape(ids.shape + (self.shape[1],))

    take = gather_rows

    def set_rows(self, ids, rows) -> "ShardedHostStore":
        ids = np.asarray(ids).reshape(-1)
        rows = np.asarray(rows, self.dtype).reshape(ids.size, -1)
        owner = np.minimum(ids // self.per, self.n_shards - 1)
        new = list(self.shards)
        for s in np.unique(owner):
            sel = owner == s
            buf = new[s].copy()
            buf[ids[sel] - s * self.per] = rows[sel]
            new[s] = buf
        return ShardedHostStore(new)


# Aux-only pytree registration: NO children. The store never appears in
# tree_leaves, so jit can't trace it, device transfers can't touch it, and
# the non-finite swap guard skips it (an O(n * D) host scan per swap would
# defeat the tier; the canary battery is the semantic guard). One
# consequence engines must handle: unflattening a jitted function's OUTPUT
# reattaches the TRACE-TIME aux object -- reattach the live store after
# every compiled call (``ServingEngine._reattach``).
for _cls in (HostStore, ShardedHostStore):
    jax.tree_util.register_pytree_node(
        _cls, lambda s: ((), s), lambda aux, children: aux)


def host_store(x) -> Optional[_HostTier]:
    """The host tier of an ``x_full``-like object, or None if device-
    resident."""
    return x if isinstance(x, _HostTier) else None


def demote(x_full, shards: int = 0) -> Union[HostStore, ShardedHostStore]:
    """Move a full-precision store to the host tier. ``shards > 0`` splits
    it into that many equal contiguous row shards (spill-to-host for
    sharded placements); rows must divide evenly, matching
    ``build_sharded_index``'s partition."""
    if isinstance(x_full, _HostTier):
        return x_full
    x = np.asarray(x_full)
    if shards:
        n = x.shape[0]
        if n % shards:
            raise ValueError(f"n={n} not divisible by shards={shards}")
        per = n // shards
        return ShardedHostStore([x[s * per:(s + 1) * per]
                                 for s in range(shards)])
    return HostStore(x)


def promote(x_full) -> jax.Array:
    """Inverse of :func:`demote`: materialize the store as a device array
    (used by offline/refit paths that genuinely need all n rows)."""
    return jnp.asarray(np.asarray(x_full))


def host_arrays(x_full) -> Optional[dict]:
    """Snapshot form of a host tier: a flat dict of numpy leaves the
    checkpoint machinery can persist WITHOUT routing them through device
    memory (None for device-resident stores -- their leaves ride the
    ServingState pytree as usual)."""
    store = host_store(x_full)
    if store is None:
        return None
    if isinstance(store, ShardedHostStore):
        return {f"shard{s}": buf for s, buf in enumerate(store.shards)}
    return {"x": store.x}


def from_host_arrays(arrays: dict) -> _HostTier:
    """Rebuild a host tier from its :func:`host_arrays` snapshot form."""
    if set(arrays) == {"x"}:
        return HostStore(arrays["x"])
    return ShardedHostStore([arrays[k] for k in sorted(
        arrays, key=lambda k: int(k.replace("shard", "")))])


def supports_pinned_host() -> bool:
    """Whether the default device exposes a ``pinned_host`` memory space
    (the memories-API形 of this tier: host-resident, device-addressable).
    TPU/GPU runtimes generally do; CPU backends report only
    ``unpinned_host`` -- their device memory IS host memory, so the
    two-stage pipeline's ``device_put`` is already a no-copy move."""
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:       # very old jax: no memories API at all
        return False
    return "pinned_host" in kinds
