"""Unified Scorer protocol: one database representation + scoring contract
shared by every index (flat / IVF / graph / distributed) and the serving
stack.

The paper's multi-step search (Algorithm 1) is index-agnostic: any index can
run its main search in a compressed representation as long as it can score a
query against (a) a contiguous block of database rows (flat scans) or (b) an
arbitrary gathered id set (IVF posting lists, graph neighbor expansions).
A *scorer* packages a database representation together with those two
operations:

    qstate = scorer.prepare_queries(q)            # Alg. 1 line 1
    scores = scorer.score_block(qstate, start, B) # (m, B), contiguous rows
    scores = scorer.score_ids(qstate, ids)        # (m, P), gathered rows

plus the layout plumbing every consumer needs: ``pad_rows`` (blocked scans),
``shard_specs`` (row-sharding under shard_map), ``encode_centers``
(auxiliary vectors -- IVF coarse centers -- encoded into a companion
scorer that consumes THIS scorer's prepared queries, so the coarse probe
runs in R^d), and the id-translation
contract (``translate_ids`` / ``globalize_ids``): a scorer may store its
rows in a private internal layout, and consumers map the row indices a scan
produces back to the external (original database) id space by calling
``translate_ids`` at the boundary. For the four row-aligned scorers this is
the identity; the SORTED scorers carry a sort permutation and translate
through it. Scorers are NamedTuples, so they are jax pytrees: they pass
through ``jit`` / ``shard_map`` boundaries as regular arguments and their
class is part of the (static) treedef.

Concrete implementations and what they store per database vector:

    ==========================  =========================  ================
    scorer                      storage                    scoring
    ==========================  =========================  ================
    LinearScorer                f32 x_low = Bx (d dims)    <Aq, Bx>
    GleanVecScorer              f32 B_c x + tag (Alg. 4)   <A_c q, B_c x>
    QuantizedScorer             u8 codes of Bx + (d) scale <Aq*delta, u>+...
    GleanVecQuantizedScorer     u8 codes of B_c x + tag    per-cluster SQ
                                + (C, d) per-cluster scale
    SortedGleanVecScorer        f32 B_c x, TAG-SORTED      <A_c q, B_c x>,
                                + per-block tag + perm     one view/block
    SortedGleanVecQuantized-    u8 codes, TAG-SORTED       per-cluster SQ,
    Scorer                      + per-block tag + perm     one view/block
    ==========================  =========================  ================

The sorted scorers store the database cluster-contiguously (rows sorted by
tag, each cluster padded to a ``layout_block`` multiple): every block has
ONE tag, so a blocked scan degenerates to a single (m, d) x (d, block)
matmul per block -- no per-row view gather, no one-hot -- which is the 13x
HBM-write reduction the Perf log quantifies. The price is a private row
order: ``perm`` (sorted row -> original id, -1 on padding) and ``inv_perm``
(original id -> sorted row) translate at the consumer boundary, so IVF
posting lists, graph neighbors and rerank candidates keep speaking original
ids. They additionally carry ``list_block_ranges`` ((C, max_blocks) block
indices per cluster, -1-padded, derived from ``block_tags``) and expose
``scan_lists(qstate, probe, k)`` -- the gather-free IVF fine step: an
ALIGNED coarse quantizer's probed clusters stream slab-by-slab through the
``kernels/ivf_scan`` range-scan kernel instead of a posting-list gather.

``GleanVecQuantizedScorer`` is the composition the LeanVec line of work
endorses (DR stacked with scalar quantization): the per-cluster reduced
vectors are int8-quantized with per-cluster per-dimension scales, and the
affine terms fold into the prepared query views so scoring stays a pure
int8 contraction.

``LinearScorer`` with ``a=None`` doubles as the exact full-precision
scorer (identity query transform over the stored vectors) -- the "full"
serving mode and the rerank reference are the same object.

The kernel lowering lives in :mod:`repro.kernels` (``scorer_topk`` /
``scorer_scores``): on TPU a scorer lowers to its Pallas kernel
(``ip_topk`` / ``gleanvec_ip`` / ``sq_dot`` / ``gleanvec_sq``), elsewhere
to the jnp mirrors used here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import gleanvec as gv
from repro.core import quantization as quant
from repro.core.quantization import ClusteredSQDatabase

__all__ = [
    "LinearScorer", "GleanVecScorer", "QuantizedScorer",
    "GleanVecQuantizedScorer", "SortedGleanVecScorer",
    "SortedGleanVecQuantizedScorer", "QuantQueryState", "Scorer", "MODES",
    "build_scorer", "linear_scorer", "exact_scorer", "gleanvec_scorer",
    "quantized_scorer", "gleanvec_quantized_scorer",
    "sorted_gleanvec_scorer", "sorted_gleanvec_quantized_scorer",
    "batch_of",
]

# Mirrors index.topk.NEG_INF (importing it would cycle: index -> bruteforce
# -> this module). Keep the value in sync.
NEG_INF = jnp.float32(-3.4e38)


def _globalize_row_aligned(ids: jax.Array, shard_idx, n_rows: int):
    """Default ``globalize_ids``: offset local ids by the shard row count."""
    return jnp.where(ids >= 0, ids + shard_idx * n_rows, -1)


def _translate_sorted(perm: jax.Array, ids: jax.Array):
    """Sorted-layout ``translate_ids``: sorted rows -> original ids via the
    sort permutation; invalid slots and padding rows map to -1."""
    orig = perm[jnp.where(ids >= 0, ids, 0)]
    return jnp.where(ids >= 0, orig, -1)


def _list_block_ranges(block_tags, c: int) -> jax.Array:
    """(C, max_blocks) table of layout-block indices per cluster, -1-padded
    (host-side, once at build; derivable from ``block_tags`` because
    ``sort_by_tag`` keeps each cluster's blocks -- slack blocks included --
    contiguous). ``ranges[probe]`` IS the probe schedule the gather-free
    range-scan kernel consumes: one argsort/bincount pass, no per-cluster
    sweep."""
    import numpy as np
    bt = np.asarray(block_tags)
    blocks = np.nonzero(bt >= 0)[0]           # stacked shards pad with -1
    t = bt[blocks]
    counts = np.bincount(t, minlength=c) if t.size else np.zeros(c, int)
    maxb = max(1, int(counts.max()) if t.size else 1)
    starts = np.zeros(c, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    order = np.argsort(t, kind="stable")
    rank = np.arange(t.size) - starts[t[order]]
    out = np.full((c, maxb), -1, np.int32)
    out[t[order], rank] = blocks[order].astype(np.int32)
    return jnp.asarray(out)


def _center_views_scorer(centers: jax.Array, model) -> "GleanVecScorer":
    """Probe companion for the eager-view qstate family (GleanVec and its
    sorted layout): centers tagged and projected per cluster."""
    if model is None:
        raise ValueError("encode_centers on a GleanVec-family scorer "
                         "needs the GleanVec model")
    tags, low = gv.encode_database(model, jnp.asarray(centers, jnp.float32))
    return GleanVecScorer(x_low=low, tags=tags)


def _center_pseudo_scorer(centers: jax.Array, model, lo, delta,
                          a) -> "GleanVecQuantizedScorer":
    """Probe companion for the folded per-cluster int8 qstate family
    (GleanVec∘int8 and its sorted layout): projected centers stored as f32
    PSEUDO-codes ``(B_t c - lo_t) / delta_t`` under the DATABASE's scales,
    so ``q_scaled . codes + q_lo == <A_t q, B_t c>`` exactly."""
    if model is None:
        raise ValueError("encode_centers on a GleanVec-family scorer "
                         "needs the GleanVec model")
    tags, low = gv.encode_database(model, jnp.asarray(centers, jnp.float32))
    return GleanVecQuantizedScorer(codes=(low - lo[tags]) / delta[tags],
                                   tags=tags, lo=lo, delta=delta, a=a)


class QuantQueryState(NamedTuple):
    """Prepared query for int8 scorers: the affine terms folded query-side.

    ``q_scaled``: (m, d) [linear] or (m, C, d) [per-cluster] = Aq * delta;
    ``q_lo``:     (m,)               or (m, C)              = <Aq, lo>.
    """

    q_scaled: jax.Array
    q_lo: jax.Array


def batch_of(qstate) -> int:
    """Query-batch size of any prepared query state (first leaf, dim 0)."""
    return jax.tree_util.tree_leaves(qstate)[0].shape[0]


def _pad0(x: jax.Array, pad: int) -> jax.Array:
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


# ---------------------------------------------------------------------------
# Streaming-store helpers (the ``live`` mask + row-level update machinery).
#
# A scorer built by ``streaming.build_streaming_artifacts`` is a FIXED-
# CAPACITY store: its row arrays are pre-allocated and an optional ``live``
# mask ((n,) bool) marks which slots currently hold a vector. Dead slots
# score -inf and translate to id -1, so they can never reach the rerank;
# ``insert_rows`` / ``remove_rows`` flip slots without changing any leaf
# shape -- which is what lets the serving engine swap the updated scorer in
# with zero recompiles. ``live=None`` (the default everywhere) means "all
# rows live" and keeps the static path's pytree structure and HLO
# unchanged.
# ---------------------------------------------------------------------------


def _encode_rows_gleanvec(model, rows: jax.Array):
    """Tag + per-cluster projection of full-D ``rows`` -- the SAME
    Eq. 14-15 pipeline as build time, so streamed inserts can never drift
    from the original encoding."""
    return gv.encode_database(model, jnp.asarray(rows, jnp.float32))


def _mask_live_block(live, start, block: int, scores: jax.Array):
    if live is None:
        return scores
    lv = jax.lax.dynamic_slice_in_dim(live, start, block, axis=0)
    return jnp.where(lv[None, :], scores, NEG_INF)


def _mask_live_ids(live, ids: jax.Array, scores: jax.Array):
    if live is None:
        return scores
    return jnp.where(live[ids], scores, NEG_INF)


def _translate_live(live, n_rows: int, ids: jax.Array) -> jax.Array:
    """Row-aligned ``translate_ids`` under a live mask: dead (or padding)
    rows map to -1 so downstream consumers drop them like sorted-layout
    padding."""
    if live is None:
        return ids
    safe = jnp.clip(ids, 0, n_rows - 1)
    ok = (ids >= 0) & (ids < n_rows) & live[safe]
    return jnp.where(ok, ids, -1)


def _set_live(live, ids: jax.Array, value: bool, n_rows: int):
    """Functional live-mask update; materializes the mask on first remove
    (which changes the scorer's treedef -- streaming stores pre-materialize
    it at build time precisely so later updates don't)."""
    if live is None:
        if value:
            return None         # all rows already live
        live = jnp.ones((n_rows,), jnp.bool_)
    return live.at[ids].set(value)


def _sorted_claim_slots(perm, inv_perm, block_tags, layout_block: int,
                        ids, tags):
    """Host-side slot allocation for the sorted layouts: for each new row's
    cluster tag, claim the first padding slot (perm == -1) inside that
    cluster's single-tag blocks. An id that is ALREADY live releases its
    old slot first (re-insert == overwrite, matching the row-aligned
    scorers -- never two sorted rows translating to one external id).
    Returns ``(slots, freed_old_slots)``; raises when a cluster is out of
    slack."""
    import numpy as np
    perm_np = np.asarray(perm).copy()
    old = np.asarray(inv_perm)[np.asarray(ids)]
    freed = old[old >= 0]
    perm_np[freed] = -1
    row_tags = np.asarray(block_tags)[
        np.arange(perm_np.shape[0]) // layout_block]
    free = perm_np < 0
    slots = np.empty(len(tags), np.int64)
    for j, t in enumerate(np.asarray(tags)):
        cand = np.nonzero(free & (row_tags == int(t)))[0]
        if cand.size == 0:
            raise ValueError(
                f"sorted layout: cluster {int(t)} has no free slots; "
                "rebuild the layout with more slack_blocks")
        slots[j] = cand[0]
        free[cand[0]] = False
    return slots, freed


class LinearScorer(NamedTuple):
    """Linear DR scoring: <Aq, Bx>. ``a=None`` means identity (exact MIPS
    over whatever ``x_low`` stores -- including the full-precision x)."""

    x_low: jax.Array                 # (n, d)
    a: Optional[jax.Array] = None    # (d, D) query transform
    live: Optional[jax.Array] = None  # (n,) bool slot mask (None = all)

    @property
    def n_rows(self) -> int:
        return self.x_low.shape[0]

    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        q = queries.astype(jnp.float32)
        return q if self.a is None else q @ self.a.T

    def pad_rows(self, pad: int) -> "LinearScorer":
        if not pad:
            return self
        return self._replace(
            x_low=_pad0(self.x_low, pad),
            live=None if self.live is None else _pad0(self.live, pad))

    def score_block(self, qstate: jax.Array, start, block: int) -> jax.Array:
        blk = jax.lax.dynamic_slice_in_dim(self.x_low, start, block, axis=0)
        return _mask_live_block(self.live, start, block, qstate @ blk.T)

    def score_ids(self, qstate: jax.Array, ids: jax.Array) -> jax.Array:
        vecs = self.x_low[ids]                          # (m, p, d)
        return _mask_live_ids(self.live, ids,
                              jnp.einsum("mpd,md->mp", vecs, qstate))

    def shard_specs(self, axes) -> "LinearScorer":
        from jax.sharding import PartitionSpec as P
        return LinearScorer(x_low=P(tuple(axes), None),
                            a=None if self.a is None else P(),
                            live=None if self.live is None
                            else P(tuple(axes)))

    def translate_ids(self, ids: jax.Array) -> jax.Array:
        return _translate_live(self.live, self.n_rows, ids)

    def globalize_ids(self, ids: jax.Array, shard_idx) -> jax.Array:
        return _globalize_row_aligned(ids, shard_idx, self.n_rows)

    # ---- streaming row-level ops (Section 3.2) ----------------------------

    def insert_rows(self, ids: jax.Array, rows: jax.Array,
                    model=None) -> "LinearScorer":
        """Encode full-D ``rows`` into slots ``ids`` and mark them live."""
        rows = jnp.asarray(rows, jnp.float32)
        enc = rows if self.a is None else rows @ model.b.T
        return self._replace(
            x_low=self.x_low.at[ids].set(enc),
            live=_set_live(self.live, ids, True, self.n_rows))

    def remove_rows(self, ids: jax.Array) -> "LinearScorer":
        """Tombstone slots ``ids`` (their contents stop mattering)."""
        return self._replace(live=_set_live(self.live, ids, False,
                                            self.n_rows))

    def refresh(self, model, transition=None, x_full=None,
                pending=None) -> "LinearScorer":
        """Re-encode under a refreshed ``model``: via the Eq. (12)
        transition matrix over the STORED reduced vectors (default), or
        exactly from ``x_full`` when given. ``pending`` ((n,) bool)
        selects the lazy subset; unmarked rows keep their old projection."""
        if self.a is None:
            return self     # exact scorer: stores the raw vectors
        if x_full is not None:
            new_low = jnp.asarray(x_full, jnp.float32) @ model.b.T
        else:
            new_low = self.x_low @ transition.T
        if pending is not None:
            new_low = jnp.where(pending[:, None], new_low, self.x_low)
        return self._replace(x_low=new_low, a=model.a)

    def encode_centers(self, centers: jax.Array,
                       model=None) -> "LinearScorer":
        """Companion probe scorer over full-D ``centers`` (C, D): scoring
        it with THIS scorer's qstate computes <Aq, B c> in R^d. With
        ``a=None`` (exact scorer) the centers pass through unprojected."""
        c = jnp.asarray(centers, jnp.float32)
        if self.a is None:
            return LinearScorer(x_low=c)
        if model is None:
            raise ValueError("encode_centers on a reduced LinearScorer "
                             "needs the DR model (its B matrix)")
        return LinearScorer(x_low=c @ model.b.T)


class GleanVecScorer(NamedTuple):
    """Eager GleanVec scoring (Alg. 4): tag-selected per-cluster views."""

    x_low: jax.Array                 # (n, d) = B_{tag_i} x_i
    tags: jax.Array                  # (n,) int32 cluster of each vector
    a: Optional[jax.Array] = None    # (C, d, D) per-cluster query maps
    live: Optional[jax.Array] = None  # (n,) bool slot mask (None = all)

    @property
    def n_rows(self) -> int:
        return self.x_low.shape[0]

    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        if self.a is None:
            raise ValueError("GleanVecScorer without `a` cannot prepare "
                             "queries; pass precomputed (m, C, d) views")
        return jnp.einsum("cdk,mk->mcd", self.a,
                          queries.astype(jnp.float32))

    def pad_rows(self, pad: int) -> "GleanVecScorer":
        if not pad:
            return self
        return self._replace(x_low=_pad0(self.x_low, pad),
                             tags=_pad0(self.tags, pad),
                             live=None if self.live is None
                             else _pad0(self.live, pad))

    def score_block(self, qstate: jax.Array, start, block: int) -> jax.Array:
        blk = jax.lax.dynamic_slice_in_dim(self.x_low, start, block, axis=0)
        tag = jax.lax.dynamic_slice_in_dim(self.tags, start, block, axis=0)
        q_sel = qstate[:, tag, :]                       # (m, block, d)
        return _mask_live_block(self.live, start, block,
                                jnp.einsum("mbd,bd->mb", q_sel, blk))

    def score_ids(self, qstate: jax.Array, ids: jax.Array) -> jax.Array:
        vecs = self.x_low[ids]                          # (m, p, d)
        tag = self.tags[ids]                            # (m, p)
        m = qstate.shape[0]
        q_sel = qstate[jnp.arange(m)[:, None], tag]     # (m, p, d)
        return _mask_live_ids(self.live, ids,
                              jnp.sum(q_sel * vecs, axis=-1))

    def shard_specs(self, axes) -> "GleanVecScorer":
        from jax.sharding import PartitionSpec as P
        return GleanVecScorer(x_low=P(tuple(axes), None),
                              tags=P(tuple(axes)),
                              a=None if self.a is None else P(),
                              live=None if self.live is None
                              else P(tuple(axes)))

    def translate_ids(self, ids: jax.Array) -> jax.Array:
        return _translate_live(self.live, self.n_rows, ids)

    def globalize_ids(self, ids: jax.Array, shard_idx) -> jax.Array:
        return _globalize_row_aligned(ids, shard_idx, self.n_rows)

    # ---- streaming row-level ops (Section 3.2) ----------------------------

    def insert_rows(self, ids: jax.Array, rows: jax.Array,
                    model=None) -> "GleanVecScorer":
        tags_new, enc = _encode_rows_gleanvec(model, rows)
        return self._replace(
            x_low=self.x_low.at[ids].set(enc),
            tags=self.tags.at[ids].set(tags_new.astype(self.tags.dtype)),
            live=_set_live(self.live, ids, True, self.n_rows))

    def remove_rows(self, ids: jax.Array) -> "GleanVecScorer":
        return self._replace(live=_set_live(self.live, ids, False,
                                            self.n_rows))

    def refresh(self, model, transition=None, x_full=None,
                pending=None) -> "GleanVecScorer":
        """Per-cluster Eq. (12): row i maps through T_{tag_i} ((C, d, d)
        ``transition``), or re-encodes exactly from ``x_full``. Tags are
        untouched -- the k-means landmarks are fixed under streaming."""
        if x_full is not None:
            new_low = jnp.einsum("ndk,nk->nd", model.b[self.tags],
                                 jnp.asarray(x_full, jnp.float32))
        else:
            new_low = jnp.einsum("nij,nj->ni", transition[self.tags],
                                 self.x_low)
        if pending is not None:
            new_low = jnp.where(pending[:, None], new_low, self.x_low)
        return self._replace(x_low=new_low, a=model.a)

    def encode_centers(self, centers: jax.Array,
                       model=None) -> "GleanVecScorer":
        """Companion probe scorer: centers tagged and projected per cluster
        (B_{t_j} c_j), scored with this scorer's eager (m, C, d) views."""
        return _center_views_scorer(centers, model)


class QuantizedScorer(NamedTuple):
    """Int8 SQ over linearly-reduced vectors, per-dimension affine scales
    folded into the query: <q, u*delta + lo> = <q*delta, u> + <q, lo>."""

    codes: jax.Array                 # (n, d) uint8
    lo: jax.Array                    # (d,)
    delta: jax.Array                 # (d,)
    a: Optional[jax.Array] = None    # (d, D) query transform
    live: Optional[jax.Array] = None  # (n,) bool slot mask (None = all)

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    def prepare_queries(self, queries: jax.Array) -> QuantQueryState:
        q = queries.astype(jnp.float32)
        if self.a is not None:
            q = q @ self.a.T
        return QuantQueryState(q_scaled=q * self.delta[None, :],
                               q_lo=q @ self.lo)

    def pad_rows(self, pad: int) -> "QuantizedScorer":
        if not pad:
            return self
        return self._replace(
            codes=_pad0(self.codes, pad),
            live=None if self.live is None else _pad0(self.live, pad))

    def score_block(self, qstate: QuantQueryState, start,
                    block: int) -> jax.Array:
        c = jax.lax.dynamic_slice_in_dim(self.codes, start, block, axis=0)
        return _mask_live_block(self.live, start, block,
                                qstate.q_scaled @ c.astype(jnp.float32).T
                                + qstate.q_lo[:, None])

    def score_ids(self, qstate: QuantQueryState, ids: jax.Array) -> jax.Array:
        c = self.codes[ids].astype(jnp.float32)         # (m, p, d)
        return _mask_live_ids(self.live, ids,
                              jnp.einsum("mpd,md->mp", c, qstate.q_scaled)
                              + qstate.q_lo[:, None])

    def shard_specs(self, axes) -> "QuantizedScorer":
        from jax.sharding import PartitionSpec as P
        return QuantizedScorer(codes=P(tuple(axes), None), lo=P(), delta=P(),
                               a=None if self.a is None else P(),
                               live=None if self.live is None
                               else P(tuple(axes)))

    def translate_ids(self, ids: jax.Array) -> jax.Array:
        return _translate_live(self.live, self.n_rows, ids)

    def globalize_ids(self, ids: jax.Array, shard_idx) -> jax.Array:
        return _globalize_row_aligned(ids, shard_idx, self.n_rows)

    # ---- streaming row-level ops (Section 3.2) ----------------------------

    def insert_rows(self, ids: jax.Array, rows: jax.Array,
                    model=None) -> "QuantizedScorer":
        """New rows are coded under the EXISTING scales (clipped if they
        fall outside the fitted range); the next ``refresh`` refits them.
        Streaming row ops assume the serving modes' 8-bit coding (the
        scorer stores no ``bits`` field; sub-8-bit stores would need
        one)."""
        rows = jnp.asarray(rows, jnp.float32)
        low = rows if self.a is None else rows @ model.b.T
        levels = 255
        enc = jnp.clip(jnp.round((low - self.lo[None, :])
                                 / self.delta[None, :]), 0,
                       levels).astype(self.codes.dtype)
        return self._replace(
            codes=self.codes.at[ids].set(enc),
            live=_set_live(self.live, ids, True, self.n_rows))

    def remove_rows(self, ids: jax.Array) -> "QuantizedScorer":
        return self._replace(live=_set_live(self.live, ids, False,
                                            self.n_rows))

    def refresh(self, model, transition=None, x_full=None,
                pending=None) -> "QuantizedScorer":
        """Dequantize -> Eq. (12) reproject (or re-encode from ``x_full``)
        -> requantize with freshly fitted scales over the live rows."""
        old_low = self.codes.astype(jnp.float32) * self.delta[None, :] \
            + self.lo[None, :]
        if x_full is not None:
            new_low = jnp.asarray(x_full, jnp.float32) @ model.b.T
        else:
            new_low = old_low @ transition.T
        if pending is not None:
            new_low = jnp.where(pending[:, None], new_low, old_low)
        db = quant.quantize(new_low, valid=self.live)
        return self._replace(codes=db.codes, lo=db.lo, delta=db.delta,
                             a=model.a)

    def encode_centers(self, centers: jax.Array,
                       model=None) -> "QuantizedScorer":
        """Companion probe scorer consuming this scorer's folded-scale
        qstate. The C centers are stored as f32 PSEUDO-codes
        ``(Bc - lo) / delta`` (not rounded to u8), so
        ``q_scaled @ codes + q_lo == <Aq, Bc>`` exactly -- probe precision
        equals the linear scorer's at C rows of negligible HBM cost."""
        if model is None:
            raise ValueError("encode_centers on a QuantizedScorer needs "
                             "the DR model (its B matrix)")
        low = jnp.asarray(centers, jnp.float32) @ model.b.T
        return QuantizedScorer(codes=(low - self.lo[None, :])
                               / self.delta[None, :],
                               lo=self.lo, delta=self.delta)


class GleanVecQuantizedScorer(NamedTuple):
    """GleanVec ∘ int8: the per-cluster reduced vectors B_c x are scalar-
    quantized with per-cluster per-dimension scales; the affine terms fold
    into the eager query views, so scoring is tag-select + int8 dot."""

    codes: jax.Array                 # (n, d) uint8 codes of B_{tag_i} x_i
    tags: jax.Array                  # (n,) int32
    lo: jax.Array                    # (C, d) per-cluster lower bounds
    delta: jax.Array                 # (C, d) per-cluster steps
    a: jax.Array                     # (C, d, D) per-cluster query maps
    live: Optional[jax.Array] = None  # (n,) bool slot mask (None = all)

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    def prepare_queries(self, queries: jax.Array) -> QuantQueryState:
        qv = jnp.einsum("cdk,mk->mcd", self.a,
                        queries.astype(jnp.float32))    # (m, C, d)
        return QuantQueryState(q_scaled=qv * self.delta[None],
                               q_lo=jnp.einsum("mcd,cd->mc", qv, self.lo))

    def pad_rows(self, pad: int) -> "GleanVecQuantizedScorer":
        if not pad:
            return self
        return self._replace(codes=_pad0(self.codes, pad),
                             tags=_pad0(self.tags, pad),
                             live=None if self.live is None
                             else _pad0(self.live, pad))

    def score_block(self, qstate: QuantQueryState, start,
                    block: int) -> jax.Array:
        c = jax.lax.dynamic_slice_in_dim(self.codes, start, block, axis=0)
        tag = jax.lax.dynamic_slice_in_dim(self.tags, start, block, axis=0)
        q_sel = qstate.q_scaled[:, tag, :]              # (m, block, d)
        scores = jnp.einsum("mbd,bd->mb", q_sel, c.astype(jnp.float32))
        return _mask_live_block(self.live, start, block,
                                scores + qstate.q_lo[:, tag])

    def score_ids(self, qstate: QuantQueryState, ids: jax.Array) -> jax.Array:
        c = self.codes[ids].astype(jnp.float32)         # (m, p, d)
        tag = self.tags[ids]                            # (m, p)
        m = tag.shape[0]
        q_sel = qstate.q_scaled[jnp.arange(m)[:, None], tag]
        lo_sel = jnp.take_along_axis(qstate.q_lo, tag, axis=1)
        return _mask_live_ids(self.live, ids,
                              jnp.sum(q_sel * c, axis=-1) + lo_sel)

    def shard_specs(self, axes) -> "GleanVecQuantizedScorer":
        from jax.sharding import PartitionSpec as P
        return GleanVecQuantizedScorer(codes=P(tuple(axes), None),
                                       tags=P(tuple(axes)),
                                       lo=P(), delta=P(), a=P(),
                                       live=None if self.live is None
                                       else P(tuple(axes)))

    def translate_ids(self, ids: jax.Array) -> jax.Array:
        return _translate_live(self.live, self.n_rows, ids)

    def globalize_ids(self, ids: jax.Array, shard_idx) -> jax.Array:
        return _globalize_row_aligned(ids, shard_idx, self.n_rows)

    # ---- streaming row-level ops (Section 3.2) ----------------------------

    def insert_rows(self, ids: jax.Array, rows: jax.Array,
                    model=None) -> "GleanVecQuantizedScorer":
        """Tag + project + code new rows under the EXISTING per-cluster
        scales (clipped); the next ``refresh`` refits them. 8-bit coding
        assumed, as everywhere on the streaming path."""
        tags_new, low = _encode_rows_gleanvec(model, rows)
        enc = jnp.clip(jnp.round((low - self.lo[tags_new])
                                 / self.delta[tags_new]), 0,
                       255).astype(self.codes.dtype)
        return self._replace(
            codes=self.codes.at[ids].set(enc),
            tags=self.tags.at[ids].set(tags_new.astype(self.tags.dtype)),
            live=_set_live(self.live, ids, True, self.n_rows))

    def remove_rows(self, ids: jax.Array) -> "GleanVecQuantizedScorer":
        return self._replace(live=_set_live(self.live, ids, False,
                                            self.n_rows))

    def refresh(self, model, transition=None, x_full=None,
                pending=None) -> "GleanVecQuantizedScorer":
        """Per-cluster dequantize -> T_{tag} reproject (or exact re-encode
        from ``x_full``) -> per-cluster requantize over live rows."""
        old_low = self.codes.astype(jnp.float32) * self.delta[self.tags] \
            + self.lo[self.tags]
        if x_full is not None:
            new_low = jnp.einsum("ndk,nk->nd", model.b[self.tags],
                                 jnp.asarray(x_full, jnp.float32))
        else:
            new_low = jnp.einsum("nij,nj->ni", transition[self.tags],
                                 old_low)
        if pending is not None:
            new_low = jnp.where(pending[:, None], new_low, old_low)
        db = quant.quantize_per_cluster(new_low, self.tags,
                                        self.lo.shape[0], valid=self.live)
        return self._replace(codes=db.codes, lo=db.lo, delta=db.delta,
                             a=model.a)

    def encode_centers(self, centers: jax.Array,
                       model=None) -> "GleanVecQuantizedScorer":
        """Companion probe scorer: per-cluster projected centers stored as
        f32 pseudo-codes under THIS scorer's per-cluster (lo, delta), so
        the probe is exact <A_t q, B_t c> from the folded qstate."""
        return _center_pseudo_scorer(centers, model, self.lo, self.delta,
                                     self.a)


class SortedGleanVecScorer(NamedTuple):
    """Eager GleanVec over a TAG-SORTED (cluster-contiguous) database.

    Rows are sorted by cluster tag and each cluster is padded to a
    ``layout_block`` multiple (``core.gleanvec.sort_by_tag``), so every
    block is single-tag and a blocked scan is one (m, d) x (d, block)
    matmul per block -- the FLOPs and bytes of the plain LeanVec scan plus
    one tag lookup per block. ``perm`` / ``inv_perm`` implement the
    id-translation contract; ``score_ids`` accepts ORIGINAL ids.
    """

    x_low: jax.Array                 # (ns, d) sorted, cluster-padded rows
    block_tags: jax.Array            # (ns // layout_block,) int32
    perm: jax.Array                  # (ns,) sorted row -> original id (-1)
    inv_perm: jax.Array              # (n,)  original id -> sorted row
    a: Optional[jax.Array] = None    # (C, d, D) per-cluster query maps
    # (C, max_blocks) layout-block indices per cluster, -1-padded (the
    # range-scan probe schedule source; None on hand-rolled layouts)
    list_block_ranges: Optional[jax.Array] = None

    @property
    def n_rows(self) -> int:
        return self.x_low.shape[0]

    @property
    def layout_block(self) -> int:
        """Rows per single-tag block (static: derived from leaf shapes)."""
        return self.x_low.shape[0] // self.block_tags.shape[0]

    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        if self.a is None:
            raise ValueError("SortedGleanVecScorer without `a` cannot "
                             "prepare queries; pass precomputed (m, C, d) "
                             "views")
        return jnp.einsum("cdk,mk->mcd", self.a,
                          queries.astype(jnp.float32))

    def pad_rows(self, pad: int) -> "SortedGleanVecScorer":
        if pad:
            raise ValueError("sorted layout is pre-padded per cluster; "
                             "scan with block == layout_block")
        return self

    def _block_views(self, qstate, start, block):
        """(m, block, d) tag-selected views of a contiguous row range."""
        lb = self.layout_block
        if block == lb:     # single-tag fast path (static branch)
            tag = jax.lax.dynamic_index_in_dim(self.block_tags, start // lb,
                                               keepdims=False)
            return jnp.take(qstate, tag, axis=1), None
        tag = self.block_tags[(start + jnp.arange(block)) // lb]
        return None, qstate[:, tag, :]

    def score_block(self, qstate: jax.Array, start, block: int) -> jax.Array:
        blk = jax.lax.dynamic_slice_in_dim(self.x_low, start, block, axis=0)
        pm = jax.lax.dynamic_slice_in_dim(self.perm, start, block, axis=0)
        q_one, q_per_row = self._block_views(qstate, start, block)
        if q_one is not None:
            scores = q_one @ blk.T                          # (m, block)
        else:
            scores = jnp.einsum("mbd,bd->mb", q_per_row, blk)
        return jnp.where(pm[None, :] >= 0, scores, NEG_INF)

    def score_ids(self, qstate: jax.Array, ids: jax.Array) -> jax.Array:
        rows = self.inv_perm[ids]                           # (m, p)
        ok = rows >= 0                # absent / removed ids score -inf
        rows = jnp.where(ok, rows, 0)
        vecs = self.x_low[rows]                             # (m, p, d)
        tag = self.block_tags[rows // self.layout_block]    # (m, p)
        m = qstate.shape[0]
        q_sel = qstate[jnp.arange(m)[:, None], tag]         # (m, p, d)
        return jnp.where(ok, jnp.sum(q_sel * vecs, axis=-1), NEG_INF)

    def scan_lists(self, qstate: jax.Array, probe: jax.Array, k: int):
        """Gather-free IVF fine step (``kernels/ivf_scan``): stream the
        probed clusters' single-tag slabs through the range-scan kernel --
        no posting-list gather, no (m, nprobe*L) candidate or score matrix.
        ``probe (m, nprobe)`` holds cluster ids that must equal this
        layout's tags (an ALIGNED coarse quantizer: ``ivf.build_aligned``).
        Returns (vals, ids) (m, k) with ORIGINAL ids; padding slots and
        removed rows (perm == -1) score -inf and strip to id -1."""
        from repro.kernels.ivf_scan import ivf_scan_topk
        if self.list_block_ranges is None:
            raise ValueError(
                "scan_lists needs list_block_ranges; build the scorer "
                "through its factory (sorted_gleanvec_scorer)")
        sched = self.list_block_ranges[probe].reshape(probe.shape[0], -1)
        q_lo = jnp.zeros(qstate.shape[:2], jnp.float32)   # no affine term
        return ivf_scan_topk(qstate, q_lo, self.block_tags, self.perm,
                             self.x_low, sched, k,
                             layout_block=self.layout_block)

    def scan_neighbors(self, qstate: jax.Array, nbr_rows: jax.Array,
                       beam_vals: jax.Array, beam_ids: jax.Array,
                       tn: int = 8):
        """Gather-free graph hop (``kernels/graph_scan``): fold one
        neighbor expansion -- given as SORTED-ROW indices ``nbr_rows
        (m, S)``, -1 padded -- into the beam, streaming the rows' ``tn``-
        slabs of this layout instead of gathering them. Returns the merged
        ``(vals, ids) (m, beam)`` with ORIGINAL ids (slot order)."""
        from repro.kernels.graph_scan import graph_scan_beam_step
        q_lo = jnp.zeros(qstate.shape[:2], jnp.float32)   # no affine term
        return graph_scan_beam_step(qstate, q_lo, self.block_tags,
                                    self.perm, self.x_low, nbr_rows,
                                    beam_vals, beam_ids,
                                    layout_block=self.layout_block, tn=tn)

    def shard_specs(self, axes) -> "SortedGleanVecScorer":
        # Row-shard the sorted layout: the shard count must divide the
        # BLOCK count so no single-tag block straddles shards, and ``perm``
        # must hold GLOBAL original ids (build the layout before sharding).
        # ``list_block_ranges`` indexes the GLOBAL block space, so it stays
        # replicated (the row-sharded flat scan never consumes it).
        from jax.sharding import PartitionSpec as P
        return SortedGleanVecScorer(x_low=P(tuple(axes), None),
                                    block_tags=P(tuple(axes)),
                                    perm=P(tuple(axes)), inv_perm=P(),
                                    a=None if self.a is None else P(),
                                    list_block_ranges=None
                                    if self.list_block_ranges is None
                                    else P())

    def translate_ids(self, ids: jax.Array) -> jax.Array:
        return _translate_sorted(self.perm, ids)

    def globalize_ids(self, ids: jax.Array, shard_idx) -> jax.Array:
        return ids          # perm already yields global original ids

    def encode_centers(self, centers: jax.Array,
                       model=None) -> "GleanVecScorer":
        """The sorted layout prepares the SAME (m, C, d) eager views as the
        row-aligned GleanVec scorer, so its probe companion is one too."""
        return _center_views_scorer(centers, model)

    # ---- streaming row-level ops (Section 3.2) ----------------------------

    def insert_rows(self, ids: jax.Array, rows: jax.Array,
                    model=None) -> "SortedGleanVecScorer":
        """Claim free padding slots inside each new row's cluster blocks
        (host-side allocation; the layout's shape never changes).
        Already-live ids release their old slot first (re-insert ==
        overwrite)."""
        tags_new, enc = _encode_rows_gleanvec(model, rows)
        slots, freed = _sorted_claim_slots(self.perm, self.inv_perm,
                                           self.block_tags,
                                           self.layout_block, ids,
                                           tags_new)
        perm = self.perm
        if freed.size:
            perm = perm.at[jnp.asarray(freed)].set(-1)
        slots = jnp.asarray(slots)
        ids = jnp.asarray(ids)
        return self._replace(
            x_low=self.x_low.at[slots].set(enc),
            perm=perm.at[slots].set(ids.astype(self.perm.dtype)),
            inv_perm=self.inv_perm.at[ids].set(
                slots.astype(self.inv_perm.dtype)))

    def remove_rows(self, ids: jax.Array) -> "SortedGleanVecScorer":
        import numpy as np
        slots = np.asarray(self.inv_perm)[np.asarray(ids)]
        slots = jnp.asarray(slots[slots >= 0])
        return self._replace(
            perm=self.perm.at[slots].set(-1),
            inv_perm=self.inv_perm.at[jnp.asarray(ids)].set(-1))

    def refresh(self, model, transition=None, x_full=None,
                pending=None) -> "SortedGleanVecScorer":
        """Per-cluster Eq. (12) over the SORTED rows (one T per single-tag
        block); padding rows stay masked by ``perm``."""
        row_tags = self.block_tags[jnp.arange(self.n_rows)
                                   // self.layout_block]
        valid = self.perm >= 0
        if x_full is not None:
            safe = jnp.where(valid, self.perm, 0)
            full_rows = jnp.asarray(x_full, jnp.float32)[safe]
            new_low = jnp.einsum("ndk,nk->nd", model.b[row_tags], full_rows)
            new_low = jnp.where(valid[:, None], new_low, 0.0)
        else:
            new_low = jnp.einsum("nij,nj->ni", transition[row_tags],
                                 self.x_low)
        if pending is not None:
            p_rows = valid & pending[jnp.where(valid, self.perm, 0)]
            new_low = jnp.where(p_rows[:, None], new_low, self.x_low)
        return self._replace(x_low=new_low, a=model.a)


class SortedGleanVecQuantizedScorer(NamedTuple):
    """GleanVec ∘ int8 over the TAG-SORTED layout: sorted per-cluster int8
    codes, per-block tags, and the same id-translation contract as
    :class:`SortedGleanVecScorer`. A blocked scan is one int8 matmul plus
    one broadcast offset add per block (d bytes of HBM per vector)."""

    codes: jax.Array                 # (ns, d) uint8, sorted/cluster-padded
    block_tags: jax.Array            # (ns // layout_block,) int32
    perm: jax.Array                  # (ns,) sorted row -> original id (-1)
    inv_perm: jax.Array              # (n,)  original id -> sorted row
    lo: jax.Array                    # (C, d) per-cluster lower bounds
    delta: jax.Array                 # (C, d) per-cluster steps
    a: jax.Array                     # (C, d, D) per-cluster query maps
    # (C, max_blocks) layout-block indices per cluster, -1-padded (the
    # range-scan probe schedule source; None on hand-rolled layouts)
    list_block_ranges: Optional[jax.Array] = None

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    @property
    def layout_block(self) -> int:
        """Rows per single-tag block (static: derived from leaf shapes)."""
        return self.codes.shape[0] // self.block_tags.shape[0]

    def prepare_queries(self, queries: jax.Array) -> QuantQueryState:
        qv = jnp.einsum("cdk,mk->mcd", self.a,
                        queries.astype(jnp.float32))        # (m, C, d)
        return QuantQueryState(q_scaled=qv * self.delta[None],
                               q_lo=jnp.einsum("mcd,cd->mc", qv, self.lo))

    def pad_rows(self, pad: int) -> "SortedGleanVecQuantizedScorer":
        if pad:
            raise ValueError("sorted layout is pre-padded per cluster; "
                             "scan with block == layout_block")
        return self

    def score_block(self, qstate: QuantQueryState, start,
                    block: int) -> jax.Array:
        c = jax.lax.dynamic_slice_in_dim(self.codes, start, block, axis=0)
        pm = jax.lax.dynamic_slice_in_dim(self.perm, start, block, axis=0)
        lb = self.layout_block
        if block == lb:     # single-tag fast path (static branch)
            tag = jax.lax.dynamic_index_in_dim(self.block_tags, start // lb,
                                               keepdims=False)
            q_sel = jnp.take(qstate.q_scaled, tag, axis=1)  # (m, d)
            scores = q_sel @ c.astype(jnp.float32).T \
                + jnp.take(qstate.q_lo, tag, axis=1)[:, None]
        else:
            tag = self.block_tags[(start + jnp.arange(block)) // lb]
            q_sel = qstate.q_scaled[:, tag, :]              # (m, block, d)
            scores = jnp.einsum("mbd,bd->mb", q_sel,
                                c.astype(jnp.float32)) + qstate.q_lo[:, tag]
        return jnp.where(pm[None, :] >= 0, scores, NEG_INF)

    def score_ids(self, qstate: QuantQueryState, ids: jax.Array) -> jax.Array:
        rows = self.inv_perm[ids]                           # (m, p)
        ok = rows >= 0                # absent / removed ids score -inf
        rows = jnp.where(ok, rows, 0)
        c = self.codes[rows].astype(jnp.float32)            # (m, p, d)
        tag = self.block_tags[rows // self.layout_block]    # (m, p)
        m = tag.shape[0]
        q_sel = qstate.q_scaled[jnp.arange(m)[:, None], tag]
        lo_sel = jnp.take_along_axis(qstate.q_lo, tag, axis=1)
        return jnp.where(ok, jnp.sum(q_sel * c, axis=-1) + lo_sel, NEG_INF)

    def scan_lists(self, qstate: QuantQueryState, probe: jax.Array, k: int):
        """Gather-free IVF fine step over the sorted int8 codes: same
        contract as :meth:`SortedGleanVecScorer.scan_lists`, with the
        per-cluster affine terms riding the folded qstate."""
        from repro.kernels.ivf_scan import ivf_scan_topk
        if self.list_block_ranges is None:
            raise ValueError(
                "scan_lists needs list_block_ranges; build the scorer "
                "through its factory (sorted_gleanvec_quantized_scorer)")
        sched = self.list_block_ranges[probe].reshape(probe.shape[0], -1)
        return ivf_scan_topk(qstate.q_scaled, qstate.q_lo, self.block_tags,
                             self.perm, self.codes, sched, k,
                             layout_block=self.layout_block)

    def scan_neighbors(self, qstate: QuantQueryState, nbr_rows: jax.Array,
                       beam_vals: jax.Array, beam_ids: jax.Array,
                       tn: int = 8):
        """Gather-free graph hop over the sorted int8 codes: same contract
        as :meth:`SortedGleanVecScorer.scan_neighbors`, with the
        per-cluster affine terms riding the folded qstate."""
        from repro.kernels.graph_scan import graph_scan_beam_step
        return graph_scan_beam_step(qstate.q_scaled, qstate.q_lo,
                                    self.block_tags, self.perm, self.codes,
                                    nbr_rows, beam_vals, beam_ids,
                                    layout_block=self.layout_block, tn=tn)

    def shard_specs(self, axes) -> "SortedGleanVecQuantizedScorer":
        # Same sharding contract as SortedGleanVecScorer: shard count must
        # divide the block count, perm must hold global original ids.
        from jax.sharding import PartitionSpec as P
        return SortedGleanVecQuantizedScorer(
            codes=P(tuple(axes), None), block_tags=P(tuple(axes)),
            perm=P(tuple(axes)), inv_perm=P(), lo=P(), delta=P(), a=P(),
            list_block_ranges=None if self.list_block_ranges is None
            else P())

    def translate_ids(self, ids: jax.Array) -> jax.Array:
        return _translate_sorted(self.perm, ids)

    def globalize_ids(self, ids: jax.Array, shard_idx) -> jax.Array:
        return ids          # perm already yields global original ids

    def encode_centers(self, centers: jax.Array,
                       model=None) -> "GleanVecQuantizedScorer":
        """Sorted-int8 prepares the same folded qstate as the row-aligned
        int8 scorer; probe companion is the pseudo-code variant."""
        return _center_pseudo_scorer(centers, model, self.lo, self.delta,
                                     self.a)

    # ---- streaming row-level ops (Section 3.2) ----------------------------

    @property
    def _row_tags(self) -> jax.Array:
        return self.block_tags[jnp.arange(self.n_rows) // self.layout_block]

    def insert_rows(self, ids: jax.Array, rows: jax.Array,
                    model=None) -> "SortedGleanVecQuantizedScorer":
        """Claim free padding slots in the new rows' clusters; code under
        the EXISTING per-cluster scales (refit at the next refresh).
        Already-live ids release their old slot first (re-insert ==
        overwrite)."""
        tags_new, low = _encode_rows_gleanvec(model, rows)
        enc = jnp.clip(jnp.round((low - self.lo[tags_new])
                                 / self.delta[tags_new]), 0,
                       255).astype(self.codes.dtype)
        slots, freed = _sorted_claim_slots(self.perm, self.inv_perm,
                                           self.block_tags,
                                           self.layout_block, ids,
                                           tags_new)
        perm = self.perm
        if freed.size:
            perm = perm.at[jnp.asarray(freed)].set(-1)
        slots = jnp.asarray(slots)
        ids = jnp.asarray(ids)
        return self._replace(
            codes=self.codes.at[slots].set(enc),
            perm=perm.at[slots].set(ids.astype(self.perm.dtype)),
            inv_perm=self.inv_perm.at[ids].set(
                slots.astype(self.inv_perm.dtype)))

    def remove_rows(self, ids: jax.Array) -> "SortedGleanVecQuantizedScorer":
        import numpy as np
        slots = np.asarray(self.inv_perm)[np.asarray(ids)]
        slots = jnp.asarray(slots[slots >= 0])
        return self._replace(
            perm=self.perm.at[slots].set(-1),
            inv_perm=self.inv_perm.at[jnp.asarray(ids)].set(-1))

    def refresh(self, model, transition=None, x_full=None,
                pending=None) -> "SortedGleanVecQuantizedScorer":
        """Per-cluster dequantize -> T_{tag} (or exact re-encode from
        ``x_full``) -> per-cluster requantize; padding rows are excluded
        from the refitted scale ranges."""
        row_tags = self._row_tags
        valid = self.perm >= 0
        old_low = self.codes.astype(jnp.float32) * self.delta[row_tags] \
            + self.lo[row_tags]
        if x_full is not None:
            safe = jnp.where(valid, self.perm, 0)
            full_rows = jnp.asarray(x_full, jnp.float32)[safe]
            new_low = jnp.einsum("ndk,nk->nd", model.b[row_tags], full_rows)
        else:
            new_low = jnp.einsum("nij,nj->ni", transition[row_tags],
                                 old_low)
        if pending is not None:
            p_rows = valid & pending[jnp.where(valid, self.perm, 0)]
            new_low = jnp.where(p_rows[:, None], new_low, old_low)
        db = quant.quantize_per_cluster(new_low, row_tags,
                                        self.lo.shape[0], valid=valid)
        return self._replace(codes=db.codes, lo=db.lo, delta=db.delta,
                             a=model.a)


Scorer = Union[LinearScorer, GleanVecScorer, QuantizedScorer,
               GleanVecQuantizedScorer, SortedGleanVecScorer,
               SortedGleanVecQuantizedScorer]


# ---------------------------------------------------------------------------
# Factories: model + database -> scorer (the encode step of Alg. 1 line 0).
# ---------------------------------------------------------------------------


def exact_scorer(database: jax.Array) -> LinearScorer:
    """Full-precision exact MIPS (the 'full' serving mode / rerank oracle)."""
    return LinearScorer(x_low=jnp.asarray(database, jnp.float32))


def linear_scorer(model, database: jax.Array) -> LinearScorer:
    """LeanVec-Sphering: x_low = Bx, queries mapped by A."""
    x_low = jnp.asarray(database, jnp.float32) @ model.b.T
    return LinearScorer(x_low=x_low, a=model.a)


def gleanvec_scorer(model, database: jax.Array) -> GleanVecScorer:
    """GleanVec (Alg. 5 model): tags + per-cluster reduced vectors."""
    tags, x_low = gv.encode_database(model, database)
    return GleanVecScorer(x_low=x_low, tags=tags, a=model.a)


def quantized_scorer(model, database: jax.Array,
                     bits: int = 8) -> QuantizedScorer:
    """LeanVec-Sphering + int8 SQ of the reduced vectors (LeanVec paper's
    compounded compression: D*4 bytes -> d bytes per vector)."""
    x_low = jnp.asarray(database, jnp.float32) @ model.b.T
    db = quant.quantize(x_low, bits)
    return QuantizedScorer(codes=db.codes, lo=db.lo, delta=db.delta,
                           a=model.a)


def gleanvec_quantized_scorer(model, database: jax.Array,
                              bits: int = 8) -> GleanVecQuantizedScorer:
    """GleanVec + per-cluster int8 SQ of the reduced vectors."""
    tags, x_low = gv.encode_database(model, database)
    db: ClusteredSQDatabase = quant.quantize_per_cluster(
        x_low, tags, model.n_clusters, bits)
    return GleanVecQuantizedScorer(codes=db.codes, tags=tags, lo=db.lo,
                                   delta=db.delta, a=model.a)


def sorted_gleanvec_scorer(model, database: jax.Array, block: int = 4096,
                           slack_blocks: int = 0) -> SortedGleanVecScorer:
    """GleanVec in the tag-sorted (cluster-contiguous) layout: each cluster
    padded to a ``block`` multiple, one tag per block. ``slack_blocks``
    reserves extra free blocks per cluster for streaming inserts."""
    tags, x_low = gv.encode_database(model, database)
    xs, block_tags, perm, _ = gv.sort_by_tag(tags, x_low, block=block,
                                             slack_blocks=slack_blocks)
    inv = gv.inverse_permutation(perm, x_low.shape[0])
    return SortedGleanVecScorer(x_low=xs, block_tags=block_tags,
                                perm=perm.astype(jnp.int32), inv_perm=inv,
                                a=model.a,
                                list_block_ranges=_list_block_ranges(
                                    block_tags, model.n_clusters))


def sorted_gleanvec_quantized_scorer(
        model, database: jax.Array, block: int = 4096,
        bits: int = 8,
        slack_blocks: int = 0) -> SortedGleanVecQuantizedScorer:
    """GleanVec + per-cluster int8 SQ in the tag-sorted layout: the SAME
    codes/scales as :func:`gleanvec_quantized_scorer` (quantize first, then
    sort), so scores match the unsorted scorer exactly."""
    tags, x_low = gv.encode_database(model, database)
    db: ClusteredSQDatabase = quant.quantize_per_cluster(
        x_low, tags, model.n_clusters, bits)
    cs, block_tags, perm, _ = gv.sort_by_tag(tags, db.codes, block=block,
                                             slack_blocks=slack_blocks)
    inv = gv.inverse_permutation(perm, x_low.shape[0])
    return SortedGleanVecQuantizedScorer(
        codes=cs, block_tags=block_tags, perm=perm.astype(jnp.int32),
        inv_perm=inv, lo=db.lo, delta=db.delta, a=model.a,
        list_block_ranges=_list_block_ranges(block_tags, model.n_clusters))


MODES = ("full", "sphering", "gleanvec", "sphering-int8", "gleanvec-int8",
         "gleanvec-sorted", "gleanvec-int8-sorted")


def build_scorer(mode: str, database: jax.Array, model=None,
                 block: int = 4096) -> Scorer:
    """Mode-string dispatch used by the serving layer (no isinstance).

    ``block`` is the sorted layouts' per-cluster padding multiple (small
    per-shard databases want a small one); other modes ignore it."""
    if mode == "full":
        return exact_scorer(database)
    if model is None:
        raise ValueError(f"mode {mode!r} needs a DR model")
    if mode == "sphering":
        return linear_scorer(model, database)
    if mode == "gleanvec":
        return gleanvec_scorer(model, database)
    if mode == "sphering-int8":
        return quantized_scorer(model, database)
    if mode == "gleanvec-int8":
        return gleanvec_quantized_scorer(model, database)
    if mode == "gleanvec-sorted":
        return sorted_gleanvec_scorer(model, database, block=block)
    if mode == "gleanvec-int8-sorted":
        return sorted_gleanvec_quantized_scorer(model, database,
                                                block=block)
    raise ValueError(f"unknown scorer mode {mode!r}; one of {MODES}")
