"""Unified Scorer protocol: one database representation + scoring contract
shared by every index (flat / IVF / graph / distributed) and the serving
stack.

The paper's multi-step search (Algorithm 1) is index-agnostic: any index can
run its main search in a compressed representation as long as it can score a
query against (a) a contiguous block of database rows (flat scans) or (b) an
arbitrary gathered id set (IVF posting lists, graph neighbor expansions).
A *scorer* packages a database representation together with those two
operations:

    qstate = scorer.prepare_queries(q)            # Alg. 1 line 1
    scores = scorer.score_block(qstate, start, B) # (m, B), contiguous rows
    scores = scorer.score_ids(qstate, ids)        # (m, P), gathered rows

plus the layout plumbing every consumer needs: ``pad_rows`` (blocked scans),
``shard_specs`` (row-sharding under shard_map). Scorers are NamedTuples, so
they are jax pytrees: they pass through ``jit`` / ``shard_map`` boundaries
as regular arguments and their class is part of the (static) treedef.

Concrete implementations and what they store per database vector:

    ==========================  =========================  ================
    scorer                      storage                    scoring
    ==========================  =========================  ================
    LinearScorer                f32 x_low = Bx (d dims)    <Aq, Bx>
    GleanVecScorer              f32 B_c x + tag (Alg. 4)   <A_c q, B_c x>
    QuantizedScorer             u8 codes of Bx + (d) scale <Aq*delta, u>+...
    GleanVecQuantizedScorer     u8 codes of B_c x + tag    per-cluster SQ
                                + (C, d) per-cluster scale
    ==========================  =========================  ================

``GleanVecQuantizedScorer`` is the composition the LeanVec line of work
endorses (DR stacked with scalar quantization): the per-cluster reduced
vectors are int8-quantized with per-cluster per-dimension scales, and the
affine terms fold into the prepared query views so scoring stays a pure
int8 contraction.

``LinearScorer`` with ``a=None`` doubles as the exact full-precision
scorer (identity query transform over the stored vectors) -- the "full"
serving mode and the rerank reference are the same object.

The kernel lowering lives in :mod:`repro.kernels` (``scorer_topk`` /
``scorer_scores``): on TPU a scorer lowers to its Pallas kernel
(``ip_topk`` / ``gleanvec_ip`` / ``sq_dot``), elsewhere to the jnp mirrors
used here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import gleanvec as gv
from repro.core import quantization as quant
from repro.core.quantization import ClusteredSQDatabase

__all__ = [
    "LinearScorer", "GleanVecScorer", "QuantizedScorer",
    "GleanVecQuantizedScorer", "QuantQueryState", "Scorer", "MODES",
    "build_scorer", "linear_scorer", "exact_scorer", "gleanvec_scorer",
    "quantized_scorer", "gleanvec_quantized_scorer", "batch_of",
]


class QuantQueryState(NamedTuple):
    """Prepared query for int8 scorers: the affine terms folded query-side.

    ``q_scaled``: (m, d) [linear] or (m, C, d) [per-cluster] = Aq * delta;
    ``q_lo``:     (m,)               or (m, C)              = <Aq, lo>.
    """

    q_scaled: jax.Array
    q_lo: jax.Array


def batch_of(qstate) -> int:
    """Query-batch size of any prepared query state (first leaf, dim 0)."""
    return jax.tree_util.tree_leaves(qstate)[0].shape[0]


def _pad0(x: jax.Array, pad: int) -> jax.Array:
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


class LinearScorer(NamedTuple):
    """Linear DR scoring: <Aq, Bx>. ``a=None`` means identity (exact MIPS
    over whatever ``x_low`` stores -- including the full-precision x)."""

    x_low: jax.Array                 # (n, d)
    a: Optional[jax.Array] = None    # (d, D) query transform

    @property
    def n_rows(self) -> int:
        return self.x_low.shape[0]

    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        q = queries.astype(jnp.float32)
        return q if self.a is None else q @ self.a.T

    def pad_rows(self, pad: int) -> "LinearScorer":
        return self if not pad else self._replace(x_low=_pad0(self.x_low,
                                                              pad))

    def score_block(self, qstate: jax.Array, start, block: int) -> jax.Array:
        blk = jax.lax.dynamic_slice_in_dim(self.x_low, start, block, axis=0)
        return qstate @ blk.T

    def score_ids(self, qstate: jax.Array, ids: jax.Array) -> jax.Array:
        vecs = self.x_low[ids]                          # (m, p, d)
        return jnp.einsum("mpd,md->mp", vecs, qstate)

    def shard_specs(self, axes) -> "LinearScorer":
        from jax.sharding import PartitionSpec as P
        return LinearScorer(x_low=P(tuple(axes), None),
                            a=None if self.a is None else P())


class GleanVecScorer(NamedTuple):
    """Eager GleanVec scoring (Alg. 4): tag-selected per-cluster views."""

    x_low: jax.Array                 # (n, d) = B_{tag_i} x_i
    tags: jax.Array                  # (n,) int32 cluster of each vector
    a: Optional[jax.Array] = None    # (C, d, D) per-cluster query maps

    @property
    def n_rows(self) -> int:
        return self.x_low.shape[0]

    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        if self.a is None:
            raise ValueError("GleanVecScorer without `a` cannot prepare "
                             "queries; pass precomputed (m, C, d) views")
        return jnp.einsum("cdk,mk->mcd", self.a,
                          queries.astype(jnp.float32))

    def pad_rows(self, pad: int) -> "GleanVecScorer":
        if not pad:
            return self
        return self._replace(x_low=_pad0(self.x_low, pad),
                             tags=_pad0(self.tags, pad))

    def score_block(self, qstate: jax.Array, start, block: int) -> jax.Array:
        blk = jax.lax.dynamic_slice_in_dim(self.x_low, start, block, axis=0)
        tag = jax.lax.dynamic_slice_in_dim(self.tags, start, block, axis=0)
        q_sel = qstate[:, tag, :]                       # (m, block, d)
        return jnp.einsum("mbd,bd->mb", q_sel, blk)

    def score_ids(self, qstate: jax.Array, ids: jax.Array) -> jax.Array:
        vecs = self.x_low[ids]                          # (m, p, d)
        tag = self.tags[ids]                            # (m, p)
        m = qstate.shape[0]
        q_sel = qstate[jnp.arange(m)[:, None], tag]     # (m, p, d)
        return jnp.sum(q_sel * vecs, axis=-1)

    def shard_specs(self, axes) -> "GleanVecScorer":
        from jax.sharding import PartitionSpec as P
        return GleanVecScorer(x_low=P(tuple(axes), None),
                              tags=P(tuple(axes)),
                              a=None if self.a is None else P())


class QuantizedScorer(NamedTuple):
    """Int8 SQ over linearly-reduced vectors, per-dimension affine scales
    folded into the query: <q, u*delta + lo> = <q*delta, u> + <q, lo>."""

    codes: jax.Array                 # (n, d) uint8
    lo: jax.Array                    # (d,)
    delta: jax.Array                 # (d,)
    a: Optional[jax.Array] = None    # (d, D) query transform

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    def prepare_queries(self, queries: jax.Array) -> QuantQueryState:
        q = queries.astype(jnp.float32)
        if self.a is not None:
            q = q @ self.a.T
        return QuantQueryState(q_scaled=q * self.delta[None, :],
                               q_lo=q @ self.lo)

    def pad_rows(self, pad: int) -> "QuantizedScorer":
        return self if not pad else self._replace(codes=_pad0(self.codes,
                                                              pad))

    def score_block(self, qstate: QuantQueryState, start,
                    block: int) -> jax.Array:
        c = jax.lax.dynamic_slice_in_dim(self.codes, start, block, axis=0)
        return qstate.q_scaled @ c.astype(jnp.float32).T \
            + qstate.q_lo[:, None]

    def score_ids(self, qstate: QuantQueryState, ids: jax.Array) -> jax.Array:
        c = self.codes[ids].astype(jnp.float32)         # (m, p, d)
        return jnp.einsum("mpd,md->mp", c, qstate.q_scaled) \
            + qstate.q_lo[:, None]

    def shard_specs(self, axes) -> "QuantizedScorer":
        from jax.sharding import PartitionSpec as P
        return QuantizedScorer(codes=P(tuple(axes), None), lo=P(), delta=P(),
                               a=None if self.a is None else P())


class GleanVecQuantizedScorer(NamedTuple):
    """GleanVec ∘ int8: the per-cluster reduced vectors B_c x are scalar-
    quantized with per-cluster per-dimension scales; the affine terms fold
    into the eager query views, so scoring is tag-select + int8 dot."""

    codes: jax.Array                 # (n, d) uint8 codes of B_{tag_i} x_i
    tags: jax.Array                  # (n,) int32
    lo: jax.Array                    # (C, d) per-cluster lower bounds
    delta: jax.Array                 # (C, d) per-cluster steps
    a: jax.Array                     # (C, d, D) per-cluster query maps

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    def prepare_queries(self, queries: jax.Array) -> QuantQueryState:
        qv = jnp.einsum("cdk,mk->mcd", self.a,
                        queries.astype(jnp.float32))    # (m, C, d)
        return QuantQueryState(q_scaled=qv * self.delta[None],
                               q_lo=jnp.einsum("mcd,cd->mc", qv, self.lo))

    def pad_rows(self, pad: int) -> "GleanVecQuantizedScorer":
        if not pad:
            return self
        return self._replace(codes=_pad0(self.codes, pad),
                             tags=_pad0(self.tags, pad))

    def score_block(self, qstate: QuantQueryState, start,
                    block: int) -> jax.Array:
        c = jax.lax.dynamic_slice_in_dim(self.codes, start, block, axis=0)
        tag = jax.lax.dynamic_slice_in_dim(self.tags, start, block, axis=0)
        q_sel = qstate.q_scaled[:, tag, :]              # (m, block, d)
        scores = jnp.einsum("mbd,bd->mb", q_sel, c.astype(jnp.float32))
        return scores + qstate.q_lo[:, tag]

    def score_ids(self, qstate: QuantQueryState, ids: jax.Array) -> jax.Array:
        c = self.codes[ids].astype(jnp.float32)         # (m, p, d)
        tag = self.tags[ids]                            # (m, p)
        m = tag.shape[0]
        q_sel = qstate.q_scaled[jnp.arange(m)[:, None], tag]
        lo_sel = jnp.take_along_axis(qstate.q_lo, tag, axis=1)
        return jnp.sum(q_sel * c, axis=-1) + lo_sel

    def shard_specs(self, axes) -> "GleanVecQuantizedScorer":
        from jax.sharding import PartitionSpec as P
        return GleanVecQuantizedScorer(codes=P(tuple(axes), None),
                                       tags=P(tuple(axes)),
                                       lo=P(), delta=P(), a=P())


Scorer = Union[LinearScorer, GleanVecScorer, QuantizedScorer,
               GleanVecQuantizedScorer]


# ---------------------------------------------------------------------------
# Factories: model + database -> scorer (the encode step of Alg. 1 line 0).
# ---------------------------------------------------------------------------


def exact_scorer(database: jax.Array) -> LinearScorer:
    """Full-precision exact MIPS (the 'full' serving mode / rerank oracle)."""
    return LinearScorer(x_low=jnp.asarray(database, jnp.float32))


def linear_scorer(model, database: jax.Array) -> LinearScorer:
    """LeanVec-Sphering: x_low = Bx, queries mapped by A."""
    x_low = jnp.asarray(database, jnp.float32) @ model.b.T
    return LinearScorer(x_low=x_low, a=model.a)


def gleanvec_scorer(model, database: jax.Array) -> GleanVecScorer:
    """GleanVec (Alg. 5 model): tags + per-cluster reduced vectors."""
    tags, x_low = gv.encode_database(model, database)
    return GleanVecScorer(x_low=x_low, tags=tags, a=model.a)


def quantized_scorer(model, database: jax.Array,
                     bits: int = 8) -> QuantizedScorer:
    """LeanVec-Sphering + int8 SQ of the reduced vectors (LeanVec paper's
    compounded compression: D*4 bytes -> d bytes per vector)."""
    x_low = jnp.asarray(database, jnp.float32) @ model.b.T
    db = quant.quantize(x_low, bits)
    return QuantizedScorer(codes=db.codes, lo=db.lo, delta=db.delta,
                           a=model.a)


def gleanvec_quantized_scorer(model, database: jax.Array,
                              bits: int = 8) -> GleanVecQuantizedScorer:
    """GleanVec + per-cluster int8 SQ of the reduced vectors."""
    tags, x_low = gv.encode_database(model, database)
    db: ClusteredSQDatabase = quant.quantize_per_cluster(
        x_low, tags, model.n_clusters, bits)
    return GleanVecQuantizedScorer(codes=db.codes, tags=tags, lo=db.lo,
                                   delta=db.delta, a=model.a)


MODES = ("full", "sphering", "gleanvec", "sphering-int8", "gleanvec-int8")


def build_scorer(mode: str, database: jax.Array, model=None) -> Scorer:
    """Mode-string dispatch used by the serving layer (no isinstance)."""
    if mode == "full":
        return exact_scorer(database)
    if model is None:
        raise ValueError(f"mode {mode!r} needs a DR model")
    if mode == "sphering":
        return linear_scorer(model, database)
    if mode == "gleanvec":
        return gleanvec_scorer(model, database)
    if mode == "sphering-int8":
        return quantized_scorer(model, database)
    if mode == "gleanvec-int8":
        return gleanvec_quantized_scorer(model, database)
    raise ValueError(f"unknown scorer mode {mode!r}; one of {MODES}")
