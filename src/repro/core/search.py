"""Multi-step vector search (paper Algorithm 1) and the GleanVec inner-product
modes (Algorithms 3-4), index-agnostic.

The main search runs in the reduced d-dimensional space through any index
(flat scan / IVF / graph from ``repro.index``); the postprocessing step
re-ranks the kappa candidates with full-precision inner products. With the
flexible-d storage of Section 3.1 (full rotation P'), the rerank uses the
*same* stored vectors (Eq. 10) -- no secondary database.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import gleanvec as gv
from repro.core.gleanvec import GleanVecModel
from repro.core.leanvec_sphering import SpheringModel

__all__ = ["SearchArtifacts", "build_artifacts_sphering",
           "build_artifacts_gleanvec", "multi_step_search", "rerank"]


class SearchArtifacts(NamedTuple):
    """Everything the serving path needs, already reduced/encoded.

    ``x_low``: (n, d) reduced database; ``tags``: (n,) or None (linear model);
    ``x_full``: (n, D) full-precision vectors for reranking (or the (n, D)
    rotated x' of Section 3.1 -- reranking is exact either way);
    ``model``: SpheringModel | GleanVecModel.
    """

    x_low: jax.Array
    tags: Optional[jax.Array]
    x_full: jax.Array
    model: object


def build_artifacts_sphering(model: SpheringModel, database: jax.Array,
                             use_rotated_full: bool = True) -> SearchArtifacts:
    """Linear path. With ``use_rotated_full`` the full vectors are stored as
    x' = P'Wx (requires d == D model; Section 3.1) so the reduced view is a
    prefix of the stored vector."""
    x_low = database @ model.b.T
    if use_rotated_full and model.dim == database.shape[1]:
        x_full = x_low  # x' = B'x; reduced view = prefix of x'
    else:
        x_full = database
    return SearchArtifacts(x_low=x_low, tags=None, x_full=x_full, model=model)


def build_artifacts_gleanvec(model: GleanVecModel,
                             database: jax.Array) -> SearchArtifacts:
    tags, x_low = gv.encode_database(model, database)
    return SearchArtifacts(x_low=x_low, tags=tags, x_full=database,
                           model=model)


def _query_low(artifacts: SearchArtifacts, queries: jax.Array):
    """Preprocessing (Alg. 1 line 1): reduce the queries.

    For GleanVec this is the eager precompute (Alg. 4): all C views. The main
    index search then consumes per-candidate tag-selected scores.
    """
    model = artifacts.model
    if isinstance(model, GleanVecModel):
        return gv.project_queries_eager(model, queries)  # (m, C, d)
    return queries @ model.a.T                           # (m, d)


def rerank(queries: jax.Array, artifacts: SearchArtifacts,
           candidates: jax.Array, k: int):
    """Postprocessing (Alg. 1 line 3): exact top-k among candidates.

    ``candidates``: (m, kappa) ids. When x_full stores the rotated x'
    (Section 3.1), queries must be rotated too: q' = A'q = P'W^{-1}q; that is
    exactly ``model.a @ q`` for the d == D model, handled transparently.
    """
    model = artifacts.model
    if (isinstance(model, SpheringModel)
            and artifacts.x_full is artifacts.x_low):
        q_full = queries @ model.a.T        # rotated query (Eq. 10)
    else:
        q_full = queries
    cand_vecs = artifacts.x_full[candidates]             # (m, kappa, D)
    scores = jnp.einsum("mkd,md->mk", cand_vecs, q_full)
    top = jax.lax.top_k(scores, k)[1]                    # (m, k)
    return jnp.take_along_axis(candidates, top, axis=1)


def multi_step_search(queries: jax.Array, artifacts: SearchArtifacts,
                      index_search: Callable, k: int, kappa: int):
    """Algorithm 1. ``index_search(q_low, artifacts, kappa) -> (m, kappa) ids``.

    ``kappa >= k`` trades accuracy for rerank cost.
    """
    q_low = _query_low(artifacts, queries)
    candidates = index_search(q_low, artifacts, kappa)
    return rerank(queries, artifacts, candidates, k)
