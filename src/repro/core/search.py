"""Multi-step vector search (paper Algorithm 1), index-agnostic.

The main search runs in the compressed representation through any Index
protocol implementation (flat scan / IVF / graph / sharded placement from
``repro.index``, see :mod:`repro.index.protocol`) over the unified Scorer
protocol (:mod:`repro.core.scorer`); the postprocessing step re-ranks the
kappa candidates with full-precision inner products. With the flexible-d
storage of Section 3.1 (full rotation P'), the rerank uses the *same*
stored vectors (Eq. 10) -- no secondary database; the artifacts record the
query-side rotation explicitly (``rerank_a``) instead of inferring it from
model types, so no isinstance dispatch remains anywhere on the search path.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rerank_tier
from repro.core import scorer as sc
from repro.index.topk import NEG_INF

__all__ = ["SearchArtifacts", "ServingState", "build_artifacts",
           "build_artifacts_sphering", "build_artifacts_gleanvec",
           "make_state", "state_search", "state_candidates",
           "multi_step_search", "rerank", "rerank_candidates", "host_tier",
           "demote_rerank_tier", "promote_rerank_tier"]


class SearchArtifacts(NamedTuple):
    """Everything the serving path needs, already reduced/encoded.

    ``scorer``: any Scorer-protocol implementation (main-search side);
    ``x_full``: (n, D) full-precision vectors for reranking (or the (n, D)
    rotated x' of Section 3.1 -- reranking is exact either way);
    ``rerank_a``: optional (D, D) query rotation for the rerank step (set
    when ``x_full`` stores rotated vectors, Eq. 10); ``model``: the learned
    DR model, kept for encode/refresh bookkeeping only -- the search path
    never inspects its type.
    """

    scorer: Any
    x_full: jax.Array
    rerank_a: Optional[jax.Array] = None
    model: Any = None

    @property
    def x_low(self):
        """Reduced database of float scorers (None for int8 scorers)."""
        return getattr(self.scorer, "x_low", None)

    @property
    def tags(self):
        """Cluster tags of GleanVec scorers (None for linear ones)."""
        return getattr(self.scorer, "tags", None)


def build_artifacts_sphering(model, database: jax.Array,
                             use_rotated_full: bool = True
                             ) -> SearchArtifacts:
    """Linear path. With ``use_rotated_full`` the full vectors are stored as
    x' = P'Wx (requires d == D model; Section 3.1) so the reduced view is a
    prefix of the stored vector and the rerank rotates queries by A'."""
    scorer = sc.linear_scorer(model, database)
    if use_rotated_full and model.dim == database.shape[1]:
        # x' = B'x; reduced view = prefix of x'; rerank query q' = A'q.
        return SearchArtifacts(scorer=scorer, x_full=scorer.x_low,
                               rerank_a=model.a, model=model)
    return SearchArtifacts(scorer=scorer, x_full=database, model=model)


def build_artifacts_gleanvec(model, database: jax.Array) -> SearchArtifacts:
    return SearchArtifacts(scorer=sc.gleanvec_scorer(model, database),
                           x_full=database, model=model)


def build_artifacts(mode: str, database: jax.Array,
                    model=None) -> SearchArtifacts:
    """Mode-string construction covering every scorer (see ``scorer.MODES``):
    full / sphering / gleanvec / sphering-int8 / gleanvec-int8 /
    gleanvec-sorted / gleanvec-int8-sorted."""
    return SearchArtifacts(scorer=sc.build_scorer(mode, database, model),
                           x_full=jnp.asarray(database, jnp.float32),
                           model=model)


class ServingState(NamedTuple):
    """The complete runtime state of a serving search, as ONE pytree.

    This is the state-passing serving contract (Section 3.2): instead of
    closing a jitted function over the artifacts, the artifacts -- and the
    Index-protocol traversal mounted over them -- ride through the
    compiled ``state_search(queries, state)`` as a regular argument. jit
    specializes on the state's TREEDEF (scorer/index classes, static index
    config) and leaf avals only, so any weight update that preserves both
    (a streaming refresh, a row insert into pre-allocated capacity, a
    re-quantization) swaps in with ZERO recompiles.

    ``version`` is a data leaf (scalar int32), not treedef metadata, so
    bumping it never invalidates the compiled function; it exists so
    engines / logs can tell which state generation produced a result.
    """

    artifacts: SearchArtifacts
    index: Any                # Index-protocol pytree (FlatIndex & friends)
    version: jax.Array        # scalar int32 state generation counter


def make_state(artifacts: SearchArtifacts, index=None, block: int = 4096,
               version: int = 0) -> ServingState:
    """Mount ``artifacts`` behind ``index`` (None = flat blocked scan) as a
    :class:`ServingState`."""
    from repro.index.protocol import FlatIndex

    if index is None:
        index = FlatIndex(block=block)
    return ServingState(artifacts=artifacts, index=index,
                        version=jnp.asarray(version, jnp.int32))


def state_search(queries: jax.Array, state: ServingState, k: int,
                 kappa: int) -> jax.Array:
    """Algorithm 1 over a :class:`ServingState`: the single function every
    serving surface compiles. ``k`` / ``kappa`` are static; everything
    else -- scorer weights, index arrays, the full-precision store -- is a
    pytree argument, so refreshed states reuse the compiled executable."""
    return multi_step_search(queries, state.artifacts, state.index, k,
                             kappa)


def state_candidates(queries: jax.Array, state: ServingState,
                     kappa: int) -> jax.Array:
    """First stage of the two-level pipeline: the main (reduced-space)
    search only, returning (m, kappa) ORIGINAL-id candidates and never
    touching ``x_full``. Fully traceable even when the rerank tier lives
    on host (the store is aux data with zero leaves), so this is the
    function serving engines compile when ``host_tier(artifacts)`` is
    set -- the host gather + :func:`rerank_candidates` run outside."""
    scorer = state.artifacts.scorer
    qstate = state.index.prepare_queries(scorer, queries)
    _, candidates = state.index.candidates(qstate, scorer, kappa)
    return candidates


def host_tier(artifacts: SearchArtifacts):
    """The artifacts' host-resident rerank store, or None when ``x_full``
    is a regular device array (single-level hierarchy)."""
    return rerank_tier.host_store(artifacts.x_full)


def demote_rerank_tier(artifacts: SearchArtifacts,
                       shards: int = 0) -> SearchArtifacts:
    """Demote the (n, D) full-precision store to host memory (sharded when
    ``shards > 0``), keeping the reduced codes -- the fine-scan working
    set -- in device memory. See :mod:`repro.core.rerank_tier`."""
    return artifacts._replace(
        x_full=rerank_tier.demote(artifacts.x_full, shards=shards))


def promote_rerank_tier(artifacts: SearchArtifacts) -> SearchArtifacts:
    """Undo :func:`demote_rerank_tier` (materializes all n rows in HBM)."""
    if host_tier(artifacts) is None:
        return artifacts
    return artifacts._replace(x_full=rerank_tier.promote(artifacts.x_full))


def _rerank_math(q_full: jax.Array, cand_vecs: jax.Array,
                 candidates: jax.Array, k: int) -> jax.Array:
    """Tier-agnostic core of the rerank: exact top-k among the gathered
    candidate rows. -1 candidate slots score NEG_INF, and ``top_k``'s
    stable tie-break keeps real ids ahead of equal-scoring padding, so a
    row with fewer than k live candidates pads its tail with -1 (never an
    arbitrary id)."""
    scores = jnp.einsum("mkd,md->mk", cand_vecs, q_full)
    scores = jnp.where(candidates >= 0, scores, NEG_INF)
    top = jax.lax.top_k(scores, k)[1]                    # (m, k)
    return jnp.take_along_axis(candidates, top, axis=1)


# The small second-stage program of the two-level pipeline: reranks the
# kappa prefetched rows after they land on device. Compiles once per
# (m, kappa, D, k) shape family and is shared by every engine/retrieval
# surface (module-level cache).
rerank_candidates = jax.jit(_rerank_math, static_argnames=("k",))


def _rotate_queries(queries: jax.Array, artifacts: SearchArtifacts):
    return queries if artifacts.rerank_a is None \
        else queries @ artifacts.rerank_a.T


def rerank(queries: jax.Array, artifacts: SearchArtifacts,
           candidates: jax.Array, k: int):
    """Postprocessing (Alg. 1 line 3): exact top-k among candidates.

    ``candidates``: (m, kappa) ids; -1 entries (padded / unfilled slots
    from graph or sharded searches) never win. When x_full stores the
    rotated x' (Section 3.1), queries are rotated by ``rerank_a`` (Eq. 10).

    Two placements of the full-precision store:

    * device array (default): the gather happens in HBM and the whole
      rerank is traceable -- it inlines into the one compiled
      ``state_search``.
    * host tier (:func:`demote_rerank_tier`): only the kappa candidate
      rows per query cross host->device (``store.take`` then
      ``device_put``), and the top-k runs in the small compiled
      :func:`rerank_candidates` program. This path is host-driven and
      CANNOT run under a trace -- jit ``state_candidates`` instead and
      rerank outside (what :class:`repro.serve.engine.ServingEngine`'s
      pipelined submit does).
    """
    store = host_tier(artifacts)
    if store is None:
        safe = jnp.where(candidates >= 0, candidates, 0)
        cand_vecs = artifacts.x_full[safe]               # (m, kappa, D)
        return _rerank_math(_rotate_queries(queries, artifacts), cand_vecs,
                            candidates, k)
    if isinstance(candidates, jax.core.Tracer):
        raise TypeError(
            "rerank over a host-tier x_full cannot run inside jit: the "
            "host gather is not traceable. Compile state_candidates and "
            "rerank the gathered rows outside the trace (see "
            "repro.serve.engine.ServingEngine).")
    cand_ids = np.asarray(candidates)
    cand_vecs = jax.device_put(store.take(cand_ids))     # kappa rows only
    return rerank_candidates(_rotate_queries(queries, artifacts), cand_vecs,
                             jnp.asarray(cand_ids), k)


def multi_step_search(queries: jax.Array, artifacts: SearchArtifacts,
                      index_search, k: int, kappa: int):
    """Algorithm 1 over any index and any scorer.

    ``index_search`` is an Index-protocol object (``FlatIndex`` /
    ``IVFIndex`` / ``GraphIndex`` / ``ShardedIndex`` -- anything with
    ``prepare_queries`` + ``candidates``): the main search runs
    ``index.candidates(index.prepare_queries(scorer, queries), scorer,
    kappa)`` and the resulting ORIGINAL-id candidates are reranked in full
    precision. A legacy callable ``index_search(q_low, artifacts, kappa)
    -> (m, kappa) ids`` is still accepted, where ``q_low`` is the scorer's
    prepared query state.

    ``kappa >= k`` trades accuracy for rerank cost.
    """
    scorer = artifacts.scorer
    if hasattr(index_search, "candidates"):     # Index protocol
        qstate = index_search.prepare_queries(scorer, queries)
        _, candidates = index_search.candidates(qstate, scorer, kappa)
    else:                                       # legacy callable
        q_low = scorer.prepare_queries(queries)
        candidates = index_search(q_low, artifacts, kappa)
    return rerank(queries, artifacts, candidates, k)
