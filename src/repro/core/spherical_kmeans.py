"""Spherical k-means (paper Appendix A) with k-means++ initialization.

Finds unit-norm centers mu_c maximizing sum_i max_c <x_i/||x_i||, mu_c>
via the EM-like iterations (23)-(24). Fully jittable (fixed iteration count),
einsum-based so it shards cleanly under pjit (assignments: one X @ mu^T per
iteration; center update: one one-hot matmul + psum).

Empty clusters are re-seeded to the currently worst-assigned points, matching
robust practice (the paper samples 1e5 points uniformly; C < 100).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["KMeansState", "normalize_rows", "kmeanspp_init", "fit", "assign"]


class KMeansState(NamedTuple):
    centers: jax.Array  # (C, D), unit rows
    inertia: jax.Array  # scalar: mean max-cosine objective (Eq. 22)


def normalize_rows(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.maximum(jnp.sum(x * x, -1, keepdims=True),
                                         eps))


def assign(x_unit: jax.Array, centers: jax.Array) -> jax.Array:
    """Cluster tags via Eq. (14)/(23): argmax_c <x_i, mu_c>. (n,) int32."""
    return jnp.argmax(x_unit @ centers.T, axis=-1).astype(jnp.int32)


def kmeanspp_init(key: jax.Array, x_unit: jax.Array, c: int) -> jax.Array:
    """k-means++ seeding on the sphere (D^2 distance = 2 - 2 cos)."""
    n = x_unit.shape[0]
    k0, key = jax.random.split(key)
    first = x_unit[jax.random.randint(k0, (), 0, n)]

    def body(carry, key_i):
        centers, n_chosen, min_d2 = carry
        probs = min_d2 / jnp.maximum(jnp.sum(min_d2), 1e-12)
        idx = jax.random.choice(key_i, n, p=probs)
        new = x_unit[idx]
        centers = centers.at[n_chosen].set(new)
        d2 = 2.0 - 2.0 * (x_unit @ new)
        return (centers, n_chosen + 1, jnp.minimum(min_d2, d2)), None

    centers0 = jnp.zeros((c, x_unit.shape[1]), x_unit.dtype).at[0].set(first)
    d2_0 = 2.0 - 2.0 * (x_unit @ first)
    (centers, _, _), _ = jax.lax.scan(
        body, (centers0, 1, d2_0), jax.random.split(key, c - 1))
    return centers


@functools.partial(jax.jit, static_argnames=("c", "n_iters"))
def fit(key: jax.Array, x: jax.Array, c: int, n_iters: int = 25) -> KMeansState:
    """Run spherical k-means. ``x: (n, D)`` (not necessarily normalized)."""
    x_unit = normalize_rows(x.astype(jnp.float32))
    n = x_unit.shape[0]
    init_key, _ = jax.random.split(key)
    centers = kmeanspp_init(init_key, x_unit, c)

    def step(_, centers):
        sims = x_unit @ centers.T                      # (n, C)
        tags = jnp.argmax(sims, axis=-1)
        onehot = jax.nn.one_hot(tags, c, dtype=jnp.float32)
        sums = onehot.T @ x_unit                       # Eq. (24) numerator
        counts = jnp.sum(onehot, axis=0)
        # Empty clusters: re-seed at the globally worst-served points.
        worst = jnp.argsort(jnp.max(sims, axis=-1))[:c]
        reseed = x_unit[worst]
        norms = jnp.linalg.norm(sums, axis=-1, keepdims=True)
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(norms, 1e-12), reseed)
        return normalize_rows(new)

    centers = jax.lax.fori_loop(0, n_iters, step, centers)
    inertia = jnp.mean(jnp.max(x_unit @ centers.T, axis=-1))
    return KMeansState(centers=centers, inertia=inertia)
