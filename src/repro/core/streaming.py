"""Streaming vector search support (paper Section 3.2).

Maintains the D x D summary statistics

    K_Q(t) = sum_{q in Q_t} q q^T,   K_X(t) = sum_{x in X_t} x x^T

under vector insertions/removals (rank-1 updates, Eq. 11), refreshes the
projections every ``s`` updates by eigendecomposition (replacing the SVDs of
Algorithm 2), and re-projects stored database vectors with the transition
matrix  T = P_{t+1} W_{t+1} (P_t W_t)^{-1}  (Eq. 12) -- either eagerly over
the whole store or lazily on access (``pending`` mask).

Functional style: every operation returns a new state (JAX arrays are
immutable); the launcher owns the loop.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.leanvec_sphering import SpheringModel, fit_from_moments

__all__ = ["StreamingState", "init", "insert", "remove", "observe_queries",
           "needs_refresh", "refresh", "transition_matrix", "reproject"]


class StreamingState(NamedTuple):
    k_q: jax.Array           # (D, D) query second moment
    k_x: jax.Array           # (D, D) database second moment
    model: SpheringModel     # current projections (full rotation, d == D ok)
    prev_bw: jax.Array       # (d, D) B = P W at the last refresh (for Eq. 12)
    updates_since: jax.Array  # scalar int32: updates since last refresh
    refresh_every: int       # s


def init(k_q: jax.Array, k_x: jax.Array, d: int,
         refresh_every: int = 1024) -> StreamingState:
    model = fit_from_moments(k_q, k_x, d)
    return StreamingState(k_q=k_q, k_x=k_x, model=model, prev_bw=model.b,
                          updates_since=jnp.zeros((), jnp.int32),
                          refresh_every=refresh_every)


def insert(state: StreamingState, x: jax.Array) -> StreamingState:
    """X_t = X_{t-1} u {x}: rank-1 update of K_X."""
    return state._replace(k_x=state.k_x + jnp.outer(x, x),
                          updates_since=state.updates_since + 1)


def remove(state: StreamingState, x: jax.Array) -> StreamingState:
    """X_t = X_{t-1} \\ {x}: rank-1 downdate of K_X."""
    return state._replace(k_x=state.k_x - jnp.outer(x, x),
                          updates_since=state.updates_since + 1)


def observe_queries(state: StreamingState, q: jax.Array) -> StreamingState:
    """Fold a batch of observed queries into K_Q (Q_t evolves over time)."""
    return state._replace(k_q=state.k_q + linalg.second_moment(q))


def needs_refresh(state: StreamingState) -> jax.Array:
    return state.updates_since >= state.refresh_every


def refresh(state: StreamingState) -> StreamingState:
    """Recompute W, P from the current moments (s | t boundary)."""
    d = state.model.dim
    new_model = fit_from_moments(state.k_q, state.k_x, d)
    return state._replace(model=new_model, prev_bw=state.model.b,
                          updates_since=jnp.zeros((), jnp.int32))


def transition_matrix(state: StreamingState) -> jax.Array:
    """T = P_{t'} W_{t'} (P_{t-1} W_{t-1})^+  (Eq. 12), (d, d).

    Valid exactly when d == D (full rotation storage, Section 3.1); for d < D
    it is the least-squares re-projection onto the new basis.
    """
    prev = state.prev_bw
    new = state.model.b
    prev_pinv = jnp.linalg.pinv(prev)
    return new @ prev_pinv


def reproject(state: StreamingState, x_low: jax.Array,
              pending: Optional[jax.Array] = None) -> jax.Array:
    """Apply Eq. (12) to stored vectors; ``pending`` selects lazy subsets."""
    t = transition_matrix(state)
    new = x_low @ t.T
    if pending is None:
        return new
    return jnp.where(pending[:, None], new, x_low)
