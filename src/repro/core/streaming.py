"""Streaming vector search support (paper Section 3.2), bridged to the
whole scorer zoo and the state-passing serving engine.

Moment tracking (the paper's math)
----------------------------------
Maintains the D x D summary statistics

    K_Q(t) = sum_{q in Q_t} q q^T,   K_X(t) = sum_{x in X_t} x x^T

under vector insertions/removals (rank-1 updates, Eq. 11), refreshes the
projections every ``s`` updates by eigendecomposition (replacing the SVDs of
Algorithm 2), and re-projects stored database vectors with the transition
matrix  T = P_{t+1} W_{t+1} (P_t W_t)^{-1}  (Eq. 12) -- either eagerly over
the whole store or lazily on access (``pending`` mask). For the GleanVec
family the SAME machinery runs per cluster: ``k_x`` holds the (C, D, D)
per-cluster moments (the k-means landmarks stay fixed under streaming, so
inserts are tagged by the existing centers), ``refresh`` re-runs the
per-cluster fits through :func:`repro.core.gleanvec.fit_from_moments`, and
the transition matrix becomes a (C, d, d) stack applied per tag.

Serving bridge (the state-passing contract)
-------------------------------------------
:func:`build_streaming_artifacts` builds a FIXED-CAPACITY
:class:`~repro.core.search.SearchArtifacts`: row arrays pre-allocated to
``capacity`` with a ``live`` slot mask (row-aligned scorers) or free
padding slots inside each cluster's single-tag blocks (sorted scorers), so
that :func:`insert_rows` / :func:`remove_rows` and
:func:`refresh_artifacts` all preserve every leaf shape AND the pytree
treedef -- the invariants :meth:`repro.serve.engine.ServingEngine.swap`
checks before installing a new state with zero recompiles. The lifecycle
the ``--stream`` demo drives:

    observe_queries -> insert/insert_rows -> refresh -> refresh_artifacts
        -> refresh_state -> engine.swap

Functional style: every operation returns a new state (JAX arrays are
immutable); the launcher owns the loop.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv
from repro.core import linalg
from repro.core import rerank_tier
from repro.core import scorer as sc
from repro.core.gleanvec import GleanVecModel
from repro.core.leanvec_sphering import SpheringModel, fit_from_moments
from repro.core.search import SearchArtifacts, ServingState

__all__ = ["StreamingState", "init", "init_gleanvec", "init_from_artifacts",
           "insert", "remove", "observe_queries", "needs_refresh",
           "refresh", "transition_matrix", "transition_condition",
           "reproject", "build_streaming_artifacts", "live_mask",
           "free_ids", "insert_rows", "remove_rows", "refresh_artifacts",
           "refresh_state"]


class StreamingState(NamedTuple):
    """Running moments + current model. ``k_x`` is (D, D) for the linear
    (LeanVec-Sphering) family and (C, D, D) -- one moment per cluster --
    for the GleanVec family; ``model`` is the matching
    :class:`SpheringModel` / :class:`GleanVecModel` and ``prev_bw`` the
    (d, D) or (C, d, D) database projection(s) at the last refresh (the
    denominator of Eq. 12)."""

    k_q: jax.Array            # (D, D) query second moment
    k_x: jax.Array            # (D, D) or (C, D, D) database second moment
    model: Union[SpheringModel, GleanVecModel]
    prev_bw: jax.Array        # (d, D) or (C, d, D) B = P W at last refresh
    updates_since: jax.Array  # scalar int32: updates since last refresh
    refresh_every: int        # s


def _per_cluster(state: StreamingState) -> bool:
    """GleanVec streaming tracks one K_X per cluster (static branch)."""
    return state.k_x.ndim == 3


def _assign(model, rows: jax.Array) -> jax.Array:
    return gv.assign_tags(model, rows)


def init(k_q: jax.Array, k_x: jax.Array, d: int,
         refresh_every: int = 1024) -> StreamingState:
    """Linear (LeanVec-Sphering) streaming state, model fit from moments."""
    model = fit_from_moments(k_q, k_x, d)
    return StreamingState(k_q=k_q, k_x=k_x, model=model, prev_bw=model.b,
                          updates_since=jnp.zeros((), jnp.int32),
                          refresh_every=refresh_every)


def init_gleanvec(model: GleanVecModel, k_q: jax.Array,
                  k_x_per_cluster: jax.Array,
                  refresh_every: int = 1024) -> StreamingState:
    """GleanVec streaming state around an ALREADY-FIT model (the landmarks
    and per-cluster projections serving right now): the first refresh's
    transition is measured against this model's B_c."""
    return StreamingState(k_q=k_q, k_x=k_x_per_cluster, model=model,
                          prev_bw=model.b,
                          updates_since=jnp.zeros((), jnp.int32),
                          refresh_every=refresh_every)


def init_from_artifacts(artifacts: SearchArtifacts, queries: jax.Array,
                        refresh_every: int = 1024) -> StreamingState:
    """Bootstrap the moments from a serving store: K_Q from the learning /
    observed queries, K_X from the store's LIVE full-precision rows
    (per-cluster for GleanVec models), model taken as-is so the first
    Eq. 12 transition is relative to what is currently serving."""
    model = artifacts.model
    if model is None:
        raise ValueError("mode 'full' stores raw vectors; there is no DR "
                         "model to stream (refresh is the identity)")
    k_q = linalg.second_moment(jnp.asarray(queries, jnp.float32))
    rows = artifacts.x_full[np.nonzero(live_mask(artifacts))[0]]
    if isinstance(model, GleanVecModel):
        tags = _assign(model, rows)
        k_x = gv.per_cluster_moments(rows, tags, model.n_clusters)
        return init_gleanvec(model, k_q, k_x, refresh_every)
    return StreamingState(k_q=k_q, k_x=linalg.second_moment(rows),
                          model=model, prev_bw=model.b,
                          updates_since=jnp.zeros((), jnp.int32),
                          refresh_every=refresh_every)


def insert(state: StreamingState, x: jax.Array) -> StreamingState:
    """X_t = X_{t-1} u {x}: rank-1 update of K_X (Eq. 11). ``x`` may be a
    single (D,) vector or a (b, D) batch; GleanVec states route each row's
    outer product to its cluster's moment."""
    x2d = jnp.atleast_2d(jnp.asarray(x, jnp.float32))
    if _per_cluster(state):
        tags = _assign(state.model, x2d)
        delta = gv.per_cluster_moments(x2d, tags, state.k_x.shape[0])
    else:
        delta = linalg.second_moment(x2d)
    return state._replace(k_x=state.k_x + delta,
                          updates_since=state.updates_since + x2d.shape[0])


def remove(state: StreamingState, x: jax.Array) -> StreamingState:
    """X_t = X_{t-1} \\ {x}: rank-1 downdate of K_X (Eq. 11)."""
    x2d = jnp.atleast_2d(jnp.asarray(x, jnp.float32))
    if _per_cluster(state):
        tags = _assign(state.model, x2d)
        delta = gv.per_cluster_moments(x2d, tags, state.k_x.shape[0])
    else:
        delta = linalg.second_moment(x2d)
    return state._replace(k_x=state.k_x - delta,
                          updates_since=state.updates_since + x2d.shape[0])


def observe_queries(state: StreamingState, q: jax.Array) -> StreamingState:
    """Fold a batch of observed queries into K_Q (Q_t evolves over time)."""
    return state._replace(k_q=state.k_q
                          + linalg.second_moment(jnp.asarray(q,
                                                             jnp.float32)))


def needs_refresh(state: StreamingState) -> jax.Array:
    return state.updates_since >= state.refresh_every


def refresh(state: StreamingState) -> StreamingState:
    """Recompute W, P (per cluster for GleanVec) from the current moments
    (s | t boundary); the outgoing model's B becomes ``prev_bw``."""
    d = state.model.dim
    if _per_cluster(state):
        new_model = gv.fit_from_moments(state.model.centers, state.k_q,
                                        state.k_x, d)
    else:
        new_model = fit_from_moments(state.k_q, state.k_x, d)
    return state._replace(model=new_model, prev_bw=state.model.b,
                          updates_since=jnp.zeros((), jnp.int32))


def transition_matrix(state: StreamingState) -> jax.Array:
    """T = P_{t'} W_{t'} (P_{t-1} W_{t-1})^+  (Eq. 12): (d, d), or the
    (C, d, d) per-cluster stack for GleanVec states.

    Valid exactly when d == D (full rotation storage, Section 3.1); for d < D
    it is the least-squares re-projection onto the new basis.
    """
    prev = state.prev_bw
    new = state.model.b
    if prev.ndim == 3:
        return jax.vmap(lambda nw, pv: nw @ jnp.linalg.pinv(pv))(new, prev)
    return new @ jnp.linalg.pinv(prev)


def transition_condition(state: StreamingState) -> float:
    """Condition number of the Eq. 12 denominator B_prev = P_{t-1} W_{t-1}
    (max over clusters for GleanVec states): sigma_max / sigma_min of the
    (d, D) projection whose pseudo-inverse the transition solve applies.

    The ``pinv`` amplifies stored-vector noise by ~this factor, so a
    near-dead cluster (its moment collapsed onto a subspace -> a tiny
    trailing singular value) makes ``source="stored"`` reprojection
    garbage while ``source="full"`` re-encoding stays exact -- the
    escalation signal :class:`repro.serve.lifecycle.RefreshSupervisor`
    keys on. Returns ``inf`` for a singular solve and ``nan`` for
    non-finite inputs; callers should escalate unless the value is
    finite AND below their threshold.
    """
    prev = jnp.asarray(state.prev_bw, jnp.float32)
    if not bool(jnp.all(jnp.isfinite(prev))):
        return float("nan")
    s = jnp.linalg.svd(prev, compute_uv=False)       # (..., min(d, D))
    smax = jnp.max(s, axis=-1)
    smin = jnp.min(s, axis=-1)
    cond = jnp.where(smin > 0, smax / smin, jnp.inf)
    return float(jnp.max(cond))


def reproject(state: StreamingState, x_low: jax.Array,
              tags: Optional[jax.Array] = None,
              pending: Optional[jax.Array] = None) -> jax.Array:
    """Apply Eq. (12) to stored reduced vectors. GleanVec states need the
    rows' cluster ``tags`` (row i maps through T_{tags_i}); ``pending``
    selects lazy subsets -- unmarked rows keep their old projection."""
    t = transition_matrix(state)
    if t.ndim == 3:
        if tags is None:
            raise ValueError("per-cluster reprojection needs the rows' "
                             "cluster tags")
        new = jnp.einsum("nij,nj->ni", t[tags], x_low)
    else:
        new = x_low @ t.T
    if pending is None:
        return new
    return jnp.where(pending[:, None], new, x_low)


# ---------------------------------------------------------------------------
# Serving bridge: fixed-capacity stores, row-level updates, state refresh.
# ---------------------------------------------------------------------------


_SORTED_MODES = ("gleanvec-sorted", "gleanvec-int8-sorted")


def build_streaming_artifacts(mode: str, database: jax.Array, model=None,
                              capacity: Optional[int] = None,
                              sort_block: int = 4096,
                              slack_blocks: int = 1,
                              host_rerank: bool = False) -> SearchArtifacts:
    """Fixed-capacity artifacts for any serving mode (see ``scorer.MODES``).

    Row-aligned modes pre-allocate ``capacity`` rows (the spare slots are
    filled with copies of row 0 so scale fits and tags stay sane, and
    masked dead via the scorer's ``live`` mask); sorted modes build the
    layout over the live rows with ``slack_blocks`` extra free blocks per
    cluster and a capacity-sized ``inv_perm``. Either way every later
    ``insert_rows`` / ``remove_rows`` / ``refresh_artifacts`` preserves
    leaf shapes and the treedef, so the serving engine swaps the result in
    without recompiling.

    ``host_rerank`` demotes the capacity-sized full-precision store to the
    host tier (:mod:`repro.core.rerank_tier`): the reduced serving
    representation keeps its device placement, while inserts/removes/
    refreshes update the host store through the same ``.at[ids].set`` /
    indexing surface -- a host-tier streamed store swaps with zero
    recompiles exactly like a device one.
    """
    X = jnp.asarray(database, jnp.float32)
    n0, _ = X.shape
    capacity = n0 if capacity is None else capacity
    if capacity < n0:
        raise ValueError(f"capacity {capacity} < initial rows {n0}")
    fill = jnp.broadcast_to(X[0], (capacity - n0, X.shape[1]))
    x_cap = jnp.concatenate([X, fill], axis=0)
    if mode in _SORTED_MODES:
        if mode == "gleanvec-sorted":
            scorer = sc.sorted_gleanvec_scorer(model, X, block=sort_block,
                                               slack_blocks=slack_blocks)
        else:
            scorer = sc.sorted_gleanvec_quantized_scorer(
                model, X, block=sort_block, slack_blocks=slack_blocks)
        pad = jnp.full((capacity - n0,), -1, scorer.inv_perm.dtype)
        scorer = scorer._replace(
            inv_perm=jnp.concatenate([scorer.inv_perm, pad]))
    else:
        scorer = sc.build_scorer(mode, x_cap, model, block=sort_block)
        live = jnp.arange(capacity) < n0
        scorer = scorer._replace(live=live)
    x_full = rerank_tier.demote(x_cap) if host_rerank else x_cap
    return SearchArtifacts(scorer=scorer, x_full=x_full, model=model)


def live_mask(artifacts: SearchArtifacts) -> np.ndarray:
    """(capacity,) bool over EXTERNAL ids: which slots hold a live vector."""
    s = artifacts.scorer
    if hasattr(s, "inv_perm"):
        return np.asarray(s.inv_perm) >= 0
    if getattr(s, "live", None) is not None:
        return np.asarray(s.live)
    return np.ones(s.n_rows, bool)


def free_ids(artifacts: SearchArtifacts, count: int) -> np.ndarray:
    """First ``count`` free external ids of a fixed-capacity store."""
    free = np.nonzero(~live_mask(artifacts))[0]
    if free.size < count:
        raise ValueError(f"store full: {free.size} free slots < {count}")
    return free[:count].astype(np.int32)


def insert_rows(artifacts: SearchArtifacts, rows: jax.Array,
                ids: Optional[jax.Array] = None):
    """Insert full-D ``rows`` into free slots of a fixed-capacity store
    (scorer representation + full-precision rerank store together).
    Returns ``(artifacts', ids)`` -- same treedef, same leaf shapes."""
    rows = jnp.atleast_2d(jnp.asarray(rows, jnp.float32))
    if ids is None:
        ids = free_ids(artifacts, rows.shape[0])
    ids = jnp.asarray(ids, jnp.int32)
    scorer = artifacts.scorer.insert_rows(ids, rows, artifacts.model)
    return (artifacts._replace(scorer=scorer,
                               x_full=artifacts.x_full.at[ids].set(rows)),
            ids)


def remove_rows(artifacts: SearchArtifacts,
                ids: jax.Array) -> SearchArtifacts:
    """Tombstone external ``ids``: they stop scoring / serving; their
    slots become insertable again."""
    return artifacts._replace(
        scorer=artifacts.scorer.remove_rows(jnp.asarray(ids, jnp.int32)))


def refresh_artifacts(artifacts: SearchArtifacts,
                      state: Optional[StreamingState],
                      source: str = "stored",
                      pending: Optional[jax.Array] = None
                      ) -> SearchArtifacts:
    """Re-encode the serving representation under ``state``'s refreshed
    model, emitting SAME-TREEDEF artifacts the engine can swap in.

    ``source="stored"`` is the paper's streaming path: the stored reduced
    vectors (dequantized first for the int8 families) map through the
    Eq. 12 transition matrix -- per cluster for GleanVec -- and the int8 /
    sorted representations are re-coded from the result with freshly
    fitted scales over the live rows; ``pending`` restricts the
    reprojection to the marked external ids (lazy refresh). With
    ``source="full"`` the representation re-encodes exactly from the
    full-precision ``x_full`` store instead (no Eq. 12 approximation; uses
    the rerank store the serving path already holds).

    ``state=None`` (or a model-free store, mode "full") returns the
    artifacts unchanged.
    """
    if state is None or artifacts.model is None:
        return artifacts
    if source not in ("stored", "full"):
        raise ValueError(f"unknown refresh source {source!r}")
    transition = transition_matrix(state) if source == "stored" else None
    x_full = artifacts.x_full if source == "full" else None
    scorer = artifacts.scorer.refresh(state.model, transition=transition,
                                      x_full=x_full, pending=pending)
    return artifacts._replace(scorer=scorer, model=state.model)


def refresh_state(serving: ServingState, state: Optional[StreamingState],
                  source: str = "stored",
                  pending: Optional[jax.Array] = None) -> ServingState:
    """Whole-state refresh: artifacts re-encoded AND the index's derived
    representations (IVF reduced-space centers) re-projected through the
    Index protocol's ``refreshed`` hook. The result has the same treedef
    and leaf avals as ``serving`` -- hand it to ``engine.swap``."""
    artifacts = refresh_artifacts(serving.artifacts, state, source=source,
                                  pending=pending)
    index = serving.index
    if hasattr(index, "refreshed"):
        index = index.refreshed(artifacts.scorer, artifacts.model)
    return serving._replace(artifacts=artifacts, index=index)
