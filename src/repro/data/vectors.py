"""Synthetic statistical twins of the paper's datasets (Table 1).

The real corpora (GIST/DEEP/T2I/LAION/WIT/RQA) are not available offline; the
paper's claims are *relative* (method A vs method B on ID vs OOD query
distributions), so we generate data reproducing the mechanisms the paper
identifies:

* Database: a mixture of C* anisotropic Gaussians with low intrinsic
  dimensionality per component (Figure 6: per-cluster spectra decay much
  faster than the global spectrum) embedded in D dims, heterogeneous
  component orientations -> checkerboard-like per-cluster correlations.
* ID queries: fresh draws from the same mixture (+ small noise).
* OOD queries: drawn from a *different* covariance whose principal axes are
  rotated w.r.t. the database's (the Figure 1 mechanism: the query principal
  direction is nearly orthogonal to the database's), plus a mean shift --
  mimicking cross-modal (text->image) and cross-model (question->answer)
  gaps.

Ground truth is exact max-inner-product via blocked brute force.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["VectorDataset", "make_dataset", "exact_topk", "DATASETS"]


class VectorDataset(NamedTuple):
    name: str
    database: np.ndarray      # (n, D) float32
    queries_learn: np.ndarray  # (m, D)
    queries_test: np.ndarray   # (m, D)
    gt: np.ndarray             # (m_test, k_gt) exact top-k ids (IP metric)
    ood: bool


def _component_basis(rng, d_full, d_intr, decay=0.85):
    """Random orthonormal basis scaled with geometric spectrum."""
    basis = np.linalg.qr(rng.standard_normal((d_full, d_full)))[0][:, :d_intr]
    scales = decay ** np.arange(d_intr)
    return basis * scales[None, :]


def make_mixture(rng, n, d_full, n_components=8, d_intr=None, spread=4.0):
    d_intr = d_intr or max(8, d_full // 6)
    comps, assignments = [], rng.integers(0, n_components, size=n)
    means = rng.standard_normal((n_components, d_full)) * spread
    bases = [_component_basis(rng, d_full, d_intr) for _ in range(n_components)]
    out = np.empty((n, d_full), np.float32)
    for c in range(n_components):
        idx = np.where(assignments == c)[0]
        z = rng.standard_normal((idx.size, d_intr))
        out[idx] = (means[c][None, :] + z @ bases[c].T).astype(np.float32)
    return out, means, bases


def exact_topk(queries: np.ndarray, database: np.ndarray, k: int,
               block: int = 8192) -> np.ndarray:
    """Exact MIPS ground truth, blocked over the database (numpy)."""
    m = queries.shape[0]
    best_ids = np.zeros((m, k), np.int64)
    best_val = np.full((m, k), -np.inf, np.float32)
    for start in range(0, database.shape[0], block):
        blk = database[start:start + block]
        scores = queries @ blk.T                        # (m, b)
        joint_val = np.concatenate([best_val, scores], axis=1)
        joint_ids = np.concatenate(
            [best_ids, np.broadcast_to(np.arange(start, start + blk.shape[0]),
                                       (m, blk.shape[0]))], axis=1)
        sel = np.argpartition(-joint_val, k - 1, axis=1)[:, :k]
        best_val = np.take_along_axis(joint_val, sel, axis=1)
        best_ids = np.take_along_axis(joint_ids, sel, axis=1)
    order = np.argsort(-best_val, axis=1)
    return np.take_along_axis(best_ids, order, axis=1)


def make_dataset(name: str, n: int, d: int, n_queries: int = 512,
                 ood: bool = False, k_gt: int = 100, seed: int = 0,
                 n_components: int = 8) -> VectorDataset:
    rng = np.random.default_rng(seed)
    database, means, bases = make_mixture(rng, n, d,
                                          n_components=n_components)

    if not ood:
        # ID: same mixture, fresh samples, mild noise.
        q_all, _, _ = make_mixture(
            np.random.default_rng(seed + 1), 2 * n_queries, d,
            n_components=n_components)
        # Resample from the *same* components for true ID-ness:
        idx = rng.integers(0, n, size=2 * n_queries)
        q_all = database[idx] + 0.05 * rng.standard_normal(
            (2 * n_queries, d)).astype(np.float32)
    else:
        # OOD: rotated principal axes + mean shift (Fig. 1 mechanism).
        rot = np.linalg.qr(rng.standard_normal((d, d)))[0].astype(np.float32)
        d_intr = max(8, d // 8)
        q_basis = _component_basis(rng, d, d_intr, decay=0.8)
        z = rng.standard_normal((2 * n_queries, d_intr))
        shift = rng.standard_normal(d) * 2.0
        q_all = ((z @ q_basis.T) @ rot + shift[None, :]).astype(np.float32)
        # Keep queries loosely aligned with the database so neighbors are
        # meaningful (cross-modal pairs are still semantically linked):
        anchor = database[rng.integers(0, n, size=2 * n_queries)]
        q_all = (0.6 * q_all + 0.4 * anchor).astype(np.float32)

    q_learn, q_test = q_all[:n_queries], q_all[n_queries:]
    gt = exact_topk(q_test, database, k_gt)
    return VectorDataset(name=name, database=database, queries_learn=q_learn,
                         queries_test=q_test, gt=gt, ood=ood)


# Scaled-down statistical twins of Table 1 (full-size shapes are exercised by
# the dry-run; these sizes keep CPU tests/benchmarks tractable).
DATASETS = {
    "gist-ID":  dict(n=20000, d=960, ood=False),
    "deep-ID":  dict(n=20000, d=256, ood=False),
    "laion-OOD": dict(n=20000, d=512, ood=True),
    "t2i-OOD":  dict(n=20000, d=200, ood=True),
    "rqa-OOD":  dict(n=20000, d=768, ood=True),
}
