"""Vector-search substrate: flat, IVF and graph indices + distributed merge."""
from repro.index import bruteforce, distributed, graph, ivf, topk

__all__ = ["bruteforce", "distributed", "graph", "ivf", "topk"]
