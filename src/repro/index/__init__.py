"""Vector-search substrate: one Index protocol (flat / IVF / graph +
sharded placement wrapper) over the unified Scorer protocol."""
from repro.index import bruteforce, distributed, graph, ivf, protocol, topk
from repro.index.distributed import ShardedIndex, build_sharded_index
from repro.index.graph import GraphIndex
from repro.index.ivf import IVFIndex
from repro.index.protocol import FlatIndex

__all__ = ["bruteforce", "distributed", "graph", "ivf", "protocol", "topk",
           "FlatIndex", "IVFIndex", "GraphIndex", "ShardedIndex",
           "build_sharded_index"]
