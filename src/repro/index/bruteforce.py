"""Flat (exact within the reduced space) index: ONE blocked brute-force MIPS
scan over any :mod:`repro.core.scorer` implementation.

``scan_scorer`` is the single scan: it pads the scorer's rows to a block
multiple, scores (batch, block) tiles via ``scorer.score_block`` and keeps a
running top-k. The historical per-representation entry points (``search`` /
``search_gleanvec`` / ``search_quantized``) are thin wrappers that build the
corresponding scorer; they are kept because their signatures mirror the
Pallas kernels (``ip_topk`` / ``gleanvec_ip`` / ``sq_dot``) they lower to on
TPU (see ``repro.kernels.scorer_topk``).

``search_gleanvec_sorted`` is the one deliberate exception: the tag-sorted
(cluster-contiguous) layout degenerates each block to a single query view,
which is a layout property, not a scoring mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scorer import (GleanVecScorer, LinearScorer,
                               QuantizedScorer, batch_of)
from repro.index import topk

__all__ = ["scan_scorer", "search_scorer", "search", "search_gleanvec",
           "search_gleanvec_sorted", "search_quantized"]


@functools.partial(jax.jit, static_argnames=("k", "block"))
def scan_scorer(scorer, qstate, k: int, block: int = 4096):
    """Blocked top-k scan of any scorer with prepared queries ``qstate``.

    Returns (vals, ids): (m, k) each; peak memory one (m, block) tile.
    """
    n = scorer.n_rows
    m = batch_of(qstate)
    padded = scorer.pad_rows((-n) % block)

    def score_block(start):
        return padded.score_block(qstate, start, block)

    return topk.blocked_topk(score_block, n, k, block, m)


def search_scorer(queries: jax.Array, scorer, k: int, block: int = 4096):
    """Prepare + scan: ``queries (m, D or d)`` -> (vals, ids) (m, k)."""
    return scan_scorer(scorer, scorer.prepare_queries(queries), k, block)


def search(q_low: jax.Array, x_low: jax.Array, k: int, block: int = 4096):
    """Linear path: ``q_low (m, d)``, ``x_low (n, d)`` -> (vals, ids)."""
    return scan_scorer(LinearScorer(x_low=x_low), q_low, k, block)


def search_gleanvec(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                    k: int, block: int = 4096):
    """Eager GleanVec path (Alg. 4): ``q_views (m, C, d)``, ``tags (n,)``."""
    return scan_scorer(GleanVecScorer(x_low=x_low, tags=tags), q_views, k,
                       block)


def search_quantized(q_low: jax.Array, codes: jax.Array, lo: jax.Array,
                     delta: jax.Array, k: int, block: int = 4096):
    """Int8 scalar-quantized path: codes (n, d) uint8, lo/delta (d,)."""
    scorer = QuantizedScorer(codes=codes, lo=lo, delta=delta)
    return scan_scorer(scorer, scorer.prepare_queries(q_low), k, block)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def search_gleanvec_sorted(q_views: jax.Array, block_tags: jax.Array,
                           x_low: jax.Array, k: int, block: int = 4096):
    """Eager GleanVec over a TAG-SORTED (cluster-contiguous) database.

    With the database sorted by cluster tag (clusters padded to ``block``
    multiples), every block has ONE tag, so scoring degenerates to a single
    (m, d) x (d, block) matmul per block -- no per-row view gather, no
    one-hot: exactly the FLOPs and bytes of the plain LeanVec scan plus one
    tag lookup per block. This is the beyond-paper layout optimization the
    Perf log quantifies (13x lower HBM writes than the gather formulation).

    ``block_tags (n_blocks,)``: tag of each block. Returned ids live in the
    sorted space; translate through the sort permutation.
    """
    m = q_views.shape[0]
    n = x_low.shape[0]
    assert n % block == 0, "pad the sorted database to a block multiple"

    def score_block(start):
        blk = jax.lax.dynamic_slice_in_dim(x_low, start, block, axis=0)
        tag = jax.lax.dynamic_index_in_dim(block_tags, start // block,
                                           keepdims=False)
        q_sel = jax.lax.dynamic_index_in_dim(q_views, tag, axis=1,
                                             keepdims=False)  # (m, d)
        return q_sel @ blk.T

    return topk.blocked_topk(score_block, n, k, block, m)
