"""Flat (exact within the reduced space) index: blocked brute-force MIPS.

Supports three database representations:
  * plain:     scores = q_low @ x_low^T                     (linear DR)
  * gleanvec:  scores = <q_views[tags_i], x_low_i>          (Alg. 4, eager)
  * quantized: scores = delta_i <q, u_i> + lo_i sum(q)      (int8 SQ)

Blocked over the database so peak memory is (batch, block); this is the
pure-JAX mirror of the ``ip_topk`` / ``gleanvec_ip`` / ``sq_dot`` Pallas
kernels (kernels/__init__ dispatches to them on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.index import topk

__all__ = ["search", "search_gleanvec", "search_gleanvec_sorted",
           "search_quantized"]


@functools.partial(jax.jit, static_argnames=("k", "block"))
def search(q_low: jax.Array, x_low: jax.Array, k: int, block: int = 4096):
    """Linear path: ``q_low (m, d)``, ``x_low (n, d)`` -> (vals, ids) (m, k)."""
    m, _ = q_low.shape
    n = x_low.shape[0]

    def score_block(start):
        blk = jax.lax.dynamic_slice_in_dim(x_low, start, block, axis=0)
        return q_low @ blk.T

    pad = (-n) % block
    if pad:
        x_low = jnp.pad(x_low, ((0, pad), (0, 0)))
    return topk.blocked_topk(score_block, n, k, block, m)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def search_gleanvec(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                    k: int, block: int = 4096):
    """Eager GleanVec path (Alg. 4): ``q_views (m, C, d)``, ``tags (n,)``."""
    m = q_views.shape[0]
    n = x_low.shape[0]
    pad = (-n) % block
    if pad:
        x_low = jnp.pad(x_low, ((0, pad), (0, 0)))
        tags = jnp.pad(tags, (0, pad))

    def score_block(start):
        blk = jax.lax.dynamic_slice_in_dim(x_low, start, block, axis=0)
        tag_blk = jax.lax.dynamic_slice_in_dim(tags, start, block, axis=0)
        # (m, block, d) gather of the tag-selected query views, then contract.
        q_sel = q_views[:, tag_blk, :]            # (m, block, d)
        return jnp.einsum("mbd,bd->mb", q_sel, blk)

    return topk.blocked_topk(score_block, n, k, block, m)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def search_gleanvec_sorted(q_views: jax.Array, block_tags: jax.Array,
                           x_low: jax.Array, k: int, block: int = 4096):
    """Eager GleanVec over a TAG-SORTED (cluster-contiguous) database.

    With the database sorted by cluster tag (clusters padded to ``block``
    multiples), every block has ONE tag, so scoring degenerates to a single
    (m, d) x (d, block) matmul per block -- no per-row view gather, no
    one-hot: exactly the FLOPs and bytes of the plain LeanVec scan plus one
    tag lookup per block. This is the beyond-paper layout optimization the
    Perf log quantifies (13x lower HBM writes than the gather formulation).

    ``block_tags (n_blocks,)``: tag of each block. Returned ids live in the
    sorted space; translate through the sort permutation.
    """
    m = q_views.shape[0]
    n = x_low.shape[0]
    assert n % block == 0, "pad the sorted database to a block multiple"

    def score_block(start):
        blk = jax.lax.dynamic_slice_in_dim(x_low, start, block, axis=0)
        tag = jax.lax.dynamic_index_in_dim(block_tags, start // block,
                                           keepdims=False)
        q_sel = jax.lax.dynamic_index_in_dim(q_views, tag, axis=1,
                                             keepdims=False)  # (m, d)
        return q_sel @ blk.T

    return topk.blocked_topk(score_block, n, k, block, m)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def search_quantized(q_low: jax.Array, codes: jax.Array, lo: jax.Array,
                     delta: jax.Array, k: int, block: int = 4096):
    """Int8 scalar-quantized path: codes (n, d) uint8, lo/delta (d,).

    Per-dimension scales fold into the query: scores = <q*delta, u> + <q, lo>.
    """
    m = q_low.shape[0]
    n = codes.shape[0]
    qf = q_low.astype(jnp.float32)
    q_scaled = qf * delta[None, :]
    q_lo = (qf @ lo)[:, None]                        # (m, 1)
    pad = (-n) % block
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))

    def score_block(start):
        c = jax.lax.dynamic_slice_in_dim(codes, start, block, axis=0)
        return q_scaled @ c.astype(jnp.float32).T + q_lo

    return topk.blocked_topk(score_block, n, k, block, m)
