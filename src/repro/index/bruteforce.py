"""Flat (exact within the reduced space) index: ONE blocked brute-force MIPS
scan over any :mod:`repro.core.scorer` implementation.

This module is the compute substrate of
:class:`repro.index.protocol.FlatIndex` -- the Index-protocol face of the
flat scan that `core.search`, the serving layer and the sharded placement
wrapper consume; call that when you want an index object, call
``search_scorer`` when you want a function.

``scan_scorer`` is the single scan: it pads the scorer's rows to a block
multiple, scores (batch, block) tiles via ``scorer.score_block``, keeps a
running top-k, and maps the winning rows to external ids through the
protocol's ``translate_ids`` -- so scorers with a private internal layout
(the tag-sorted ones, whose ``layout_block`` also overrides the scan block
so every block stays single-tag) return original database ids like everyone
else. The historical per-representation entry points (``search`` /
``search_gleanvec`` / ``search_gleanvec_sorted`` / ``search_quantized``)
are thin wrappers that build the corresponding scorer; they are kept
because their signatures mirror the Pallas kernels (``ip_topk`` /
``gleanvec_ip`` / ``gleanvec_sq``) they lower to on TPU (see
``repro.kernels.scorer_topk``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scorer import (GleanVecScorer, LinearScorer,
                               QuantizedScorer, SortedGleanVecScorer,
                               batch_of)
from repro.index import topk

__all__ = ["scan_scorer", "search_scorer", "search", "search_gleanvec",
           "search_gleanvec_sorted", "search_quantized"]


@functools.partial(jax.jit, static_argnames=("k", "block"))
def scan_scorer(scorer, qstate, k: int, block: int = 4096):
    """Blocked top-k scan of any scorer with prepared queries ``qstate``.

    Returns (vals, ids): (m, k) each, ids in the scorer's EXTERNAL id
    space; peak memory one (m, block) tile. Scorers with a fixed internal
    layout (``layout_block`` attribute) override ``block``.
    """
    n = scorer.n_rows
    m = batch_of(qstate)
    block = getattr(scorer, "layout_block", block)
    padded = scorer.pad_rows((-n) % block)

    def score_block(start):
        return padded.score_block(qstate, start, block)

    vals, ids = topk.blocked_topk(score_block, n, k, block, m)
    return vals, scorer.translate_ids(ids)


def search_scorer(queries: jax.Array, scorer, k: int, block: int = 4096):
    """Prepare + scan: ``queries (m, D or d)`` -> (vals, ids) (m, k)."""
    return scan_scorer(scorer, scorer.prepare_queries(queries), k, block)


def search(q_low: jax.Array, x_low: jax.Array, k: int, block: int = 4096):
    """Linear path: ``q_low (m, d)``, ``x_low (n, d)`` -> (vals, ids)."""
    return scan_scorer(LinearScorer(x_low=x_low), q_low, k, block)


def search_gleanvec(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                    k: int, block: int = 4096):
    """Eager GleanVec path (Alg. 4): ``q_views (m, C, d)``, ``tags (n,)``."""
    return scan_scorer(GleanVecScorer(x_low=x_low, tags=tags), q_views, k,
                       block)


def search_quantized(q_low: jax.Array, codes: jax.Array, lo: jax.Array,
                     delta: jax.Array, k: int, block: int = 4096):
    """Int8 scalar-quantized path: codes (n, d) uint8, lo/delta (d,)."""
    scorer = QuantizedScorer(codes=codes, lo=lo, delta=delta)
    return scan_scorer(scorer, scorer.prepare_queries(q_low), k, block)


def search_gleanvec_sorted(q_views: jax.Array, block_tags: jax.Array,
                           x_low: jax.Array, k: int, block: int = 4096):
    """Eager GleanVec over a TAG-SORTED (cluster-contiguous) database: one
    query view per block, one (m, d) x (d, block) matmul per block (the
    13x-lower-HBM-write layout the Perf log quantifies).

    Thin wrapper over the same blocked scan: builds a
    :class:`~repro.core.scorer.SortedGleanVecScorer` with an IDENTITY
    permutation, so -- like the historical entry point -- the returned ids
    live in the sorted row space and callers who built the layout with
    ``gleanvec.sort_by_tag`` translate through their own permutation. New
    code should build the scorer with ``sorted_gleanvec_scorer`` instead
    and let the protocol translate ids.
    """
    n = x_low.shape[0]
    ident = jnp.arange(n, dtype=jnp.int32)
    scorer = SortedGleanVecScorer(x_low=x_low, block_tags=block_tags,
                                  perm=ident, inv_perm=ident)
    return scan_scorer(scorer, q_views, k, block)
