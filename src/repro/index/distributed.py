"""Distributed (multi-chip / multi-pod) vector search: any index x any
scorer under one shard_map wrapper.

Two placement styles, one collective schedule (a single all-gather of
(batch, shards * kappa) (value, id) pairs merged into the global top-k):

1. **Flat, global-build-then-row-shard** (the historical path,
   :func:`make_sharded_search_scorer`): the scorer's row arrays are
   row-sharded across mesh axes and each shard runs the unified blocked
   scan. Id globalization goes through the SCORER-level
   ``scorer.globalize_ids(ids, shard_idx)``: row-aligned scorers offset by
   the shard row count; sorted scorers translate through their permutation
   (which must hold GLOBAL original ids -- build the sorted layout over
   the global database, then row-shard it; the shard count must divide the
   single-tag block count).

2. **Any index, per-shard build** (:class:`ShardedIndex`): the global
   database rows are partitioned into equal contiguous shards; each shard
   gets a self-contained (sub-index, sub-scorer) pair -- flat scan, IVF
   posting lists over its rows, or its own navigable subgraph -- whose
   leaves are stacked with a leading shard axis and distributed by
   shard_map. Every sub-index emits LOCAL ids; the INDEX-level
   ``index.globalize_ids(scorer, ids, row_start)`` lifts them to global
   original ids through the shard's global row offset (see
   :mod:`repro.index.protocol` for the two-contract distinction). This is
   how sharded IVF (row-sharded posting lists) and sharded graph
   (per-shard subgraphs) compose with every scorer family, sorted layouts
   included.

Implemented with shard_map so the collective schedule is explicit and stable
for the roofline analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import scorer as sc
from repro.core.scorer import LinearScorer, Scorer
from repro.index import bruteforce, graph as graph_mod, ivf as ivf_mod
from repro.index.protocol import (FlatIndex, register_index_pytree,
                                  replace, stacked_specs)
from repro.utils.jax_compat import shard_map

__all__ = ["sharded_search", "make_sharded_search",
           "sharded_search_scorer", "make_sharded_search_scorer",
           "stack_shards", "ShardedIndex", "build_sharded_index",
           "build_sharded_artifacts"]


def _local_merge(queries, scorer, mesh: Mesh, axes, k: int, kappa: int,
                 block: int):
    """Per-shard body: local scan -> global ids -> all-gather -> top-k."""
    qstate = scorer.prepare_queries(queries)
    vals, ids = bruteforce.scan_scorer(scorer, qstate, kappa, block)
    idx = jnp.zeros((), jnp.int32)       # shard index along flattened axes
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    # Row-aligned scorers offset their local ids by the shard's row count;
    # sorted scorers already emit global ids through their permutation
    # (their shard of ``perm`` holds global original ids) -- the protocol's
    # globalize_ids encapsulates the difference.
    ids = scorer.globalize_ids(ids, idx)
    vals = jax.lax.all_gather(vals, axes, axis=1, tiled=True)
    ids = jax.lax.all_gather(ids, axes, axis=1, tiled=True)
    top_vals, sel = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(ids, sel, axis=1)


def make_sharded_search_scorer(mesh: Mesh, shard_axes: Sequence[str], k: int,
                               scorer: Scorer, kappa: Optional[int] = None,
                               block: int = 4096):
    """Build a pjit-able sharded search over ``scorer``'s representation.

    ``shard_axes``: mesh axes the scorer rows are sharded over (e.g.
    ("pod", "data", "model") to use every chip). Queries are replicated --
    each chip scans its shard for the full query batch, which is the
    throughput-optimal layout when batch << n/chips. The ``scorer``
    argument fixes the pytree structure (its ``shard_specs``); pass the
    same scorer (row-sharded) when calling the returned
    ``fn(queries, scorer) -> (vals, ids)`` with global ids.
    """
    kappa = kappa or k
    axes = tuple(shard_axes)

    def local_fn(queries, s):
        return _local_merge(queries, s, mesh, axes, k, kappa, block)

    return shard_map(local_fn, mesh=mesh,
                     in_specs=(P(), scorer.shard_specs(axes)),
                     out_specs=(P(), P()))


def make_sharded_search(mesh: Mesh, shard_axes: Sequence[str], k: int,
                        kappa: Optional[int] = None, block: int = 4096):
    """Legacy linear entry point: ``fn(q_low, x_low) -> (vals, ids)``."""
    kappa = kappa or k
    axes = tuple(shard_axes)

    def local_fn(q_low, x_shard):
        return _local_merge(q_low, LinearScorer(x_low=x_shard), mesh, axes,
                            k, kappa, block)

    return shard_map(local_fn, mesh=mesh, in_specs=(P(), P(axes)),
                     out_specs=(P(), P()))


def sharded_search(q_low: jax.Array, x_low: jax.Array, mesh: Mesh,
                   shard_axes: Sequence[str], k: int,
                   kappa: Optional[int] = None, block: int = 4096):
    """One-shot convenience wrapper around :func:`make_sharded_search`."""
    fn = make_sharded_search(mesh, shard_axes, k, kappa, block)
    return jax.jit(fn)(q_low, x_low)


def sharded_search_scorer(queries: jax.Array, scorer: Scorer, mesh: Mesh,
                          shard_axes: Sequence[str], k: int,
                          kappa: Optional[int] = None, block: int = 4096):
    """One-shot wrapper around :func:`make_sharded_search_scorer`."""
    fn = make_sharded_search_scorer(mesh, shard_axes, k, scorer, kappa,
                                    block)
    return jax.jit(fn)(queries, scorer)


# ---------------------------------------------------------------------------
# Generic sharded Index: shard_map over any (sub-index, sub-scorer) stack.
# ---------------------------------------------------------------------------


def _pad_leaf(a: jax.Array, shape) -> jax.Array:
    """Pad a leaf up to ``shape``: signed-int leaves (ids, permutations,
    posting lists, entries, block tags) pad with -1 -- every consumer
    masks negative ids -- and float/unsigned leaves pad with zeros."""
    pads = [(0, t - s) for s, t in zip(a.shape, shape)]
    if not any(p[1] for p in pads):
        return a
    val = -1 if jnp.issubdtype(a.dtype, jnp.signedinteger) else 0
    return jnp.pad(a, pads, constant_values=val)


def stack_shards(shards: Sequence[Any]):
    """Stack per-shard pytrees (same treedef) into ONE pytree whose leaves
    carry a leading shard axis, padding ragged leaves (per-shard sorted
    layouts, posting-list lengths, entry-point counts) to the maximum
    shape. The result is what shard_map distributes: spec ``P(axes)`` on
    every leaf puts shard ``s``'s slice on device ``s``."""

    def stack(*leaves):
        leaves = [jnp.asarray(x) for x in leaves]
        target = tuple(max(s) for s in zip(*[x.shape for x in leaves]))
        return jnp.stack([_pad_leaf(x, target) for x in leaves])

    return jax.tree_util.tree_map(stack, *shards)


def _take_shard(tree, s):
    """Slice shard ``s`` back out of a stacked pytree."""
    return jax.tree_util.tree_map(lambda x: x[s], tree)


@dataclass(frozen=True, eq=False)
class ShardedIndex:
    """Placement wrapper implementing the Index protocol over ANY index.

    ``sub_index`` holds the per-shard indexes stacked along a leading
    shard axis (:func:`stack_shards`); the matching per-shard scorers are
    stacked the same way and passed as the ``scorer`` argument to
    ``search`` / ``candidates``. Each shard searches its self-contained
    sub-index, lifts local ids to global through the sub-index's
    ``globalize_ids`` with the shard's global ``row_starts`` offset, and
    one tiled all-gather merges the (value, id) pairs into the global
    top-k.

    With ``mesh=None`` the same computation runs shard-by-shard on one
    device (:meth:`search_local`) -- the single-device counterpart the
    parity tests compare against, and the fallback for single-chip
    benchmarking of the sharded layouts.
    """

    sub_index: Any                        # stacked leaves: (S, ...)
    row_starts: jax.Array                 # (S,) global row offset per shard
    mesh: Optional[Mesh] = None
    axes: Tuple[str, ...] = ()

    @property
    def n_shards(self) -> int:
        return self.row_starts.shape[0]

    # ---- Index protocol ----------------------------------------------------

    def prepare_queries(self, scorer, queries: jax.Array) -> jax.Array:
        # Queries are replicated; each shard prepares its own qstate from
        # its (replicated) query maps inside the shard_map body.
        return queries.astype(jnp.float32)

    def candidates(self, queries: jax.Array, scorer, k: int,
                   kappa: Optional[int] = None):
        if self.mesh is None:
            return self.search_local(queries, scorer, k, kappa)
        kappa = kappa or k
        axes = tuple(self.axes) or tuple(self.mesh.axis_names)
        mesh = self.mesh

        def body(q, starts, s_scorer, s_index):
            s_scorer = _take_shard(s_scorer, 0)   # drop the (1,) shard dim
            s_index = _take_shard(s_index, 0)
            idx = jnp.zeros((), jnp.int32)
            for a in axes:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            qs = s_index.prepare_queries(s_scorer, q)
            vals, ids = s_index.candidates(qs, s_scorer, kappa)
            ids = s_index.globalize_ids(s_scorer, ids, starts[idx])
            vals = jax.lax.all_gather(vals, axes, axis=1, tiled=True)
            ids = jax.lax.all_gather(ids, axes, axis=1, tiled=True)
            top, sel = jax.lax.top_k(vals, k)
            return top, jnp.take_along_axis(ids, sel, axis=1)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(), P(), stacked_specs(scorer, axes),
                                 stacked_specs(self.sub_index, axes)),
                       out_specs=(P(), P()))
        return fn(queries, self.row_starts, scorer, self.sub_index)

    def search(self, queries: jax.Array, scorer, k: int,
               kappa: Optional[int] = None):
        return self.candidates(self.prepare_queries(scorer, queries),
                               scorer, k, kappa)

    def search_local(self, queries: jax.Array, scorer, k: int,
                     kappa: Optional[int] = None):
        """Mesh-free reference: the SAME per-shard searches + merge, run
        sequentially on the current device. jit-safe (the serving layer
        compiles it with the index as a pytree argument): the per-shard
        row offsets stay traced scalars."""
        kappa = kappa or k
        queries = queries.astype(jnp.float32)
        all_vals, all_ids = [], []
        for s in range(self.n_shards):
            s_scorer = _take_shard(scorer, s)
            s_index = _take_shard(self.sub_index, s)
            qs = s_index.prepare_queries(s_scorer, queries)
            vals, ids = s_index.candidates(qs, s_scorer, kappa)
            all_vals.append(vals)
            all_ids.append(s_index.globalize_ids(s_scorer, ids,
                                                 self.row_starts[s]))
        vals = jnp.concatenate(all_vals, axis=1)
        ids = jnp.concatenate(all_ids, axis=1)
        top, sel = jax.lax.top_k(vals, k)
        return top, jnp.take_along_axis(ids, sel, axis=1)

    def shard_specs(self, axes):
        return stacked_specs(self, axes)

    def globalize_ids(self, scorer, ids: jax.Array, row_start) -> jax.Array:
        return ids          # candidates are already global original ids

    def refreshed(self, scorer, model) -> "ShardedIndex":
        """Streaming-refresh hook: slice each shard's (sub-index,
        sub-scorer) pair out of the stacks, run the sub-index's own
        ``refreshed`` hook against ITS scorer shard, and restack. Every
        hook is shape-preserving (IVF re-encodes its reduced probe
        centers, a fused graph re-derives its sorted-row edge lists), and
        the shards were already padded to equal shapes at build time, so
        the restacked pytree keeps the original treedef + leaf avals --
        the zero-recompile ``ServingEngine.swap`` contract."""
        subs = []
        for s in range(self.n_shards):
            s_index = _take_shard(self.sub_index, s)
            s_scorer = _take_shard(scorer, s)
            if hasattr(s_index, "refreshed"):
                s_index = s_index.refreshed(s_scorer, model)
            subs.append(s_index)
        return replace(self, sub_index=stack_shards(subs))


register_index_pytree(ShardedIndex,
                      data_fields=("sub_index", "row_starts"),
                      static_fields=("mesh", "axes"))


def build_sharded_index(kind: str, mode: str, database, model=None, *,
                        mesh: Optional[Mesh] = None,
                        shard_axes: Sequence[str] = (),
                        n_shards: Optional[int] = None, key=None,
                        block: int = 4096, sort_block: int = 256,
                        n_lists: int = 32, nprobe: int = 8,
                        reduced_probe: bool = False, aligned: bool = False,
                        beam: int = 64, max_hops: int = 256,
                        expand: int = 1, fused_graph: bool = False,
                        graph_kwargs=None):
    """Build a :class:`ShardedIndex` + matching stacked scorer.

    ``kind`` in {"flat", "ivf", "graph"} x ``mode`` in ``scorer.MODES`` x
    (``mesh`` or mesh-free with ``n_shards``): the three orthogonal axes.
    The database rows are split into equal contiguous shards; each shard
    gets a self-contained scorer (``sc.build_scorer``) and sub-index (flat
    scan / local posting lists over one shared coarse quantizer / its own
    subgraph). With ``reduced_probe`` the IVF centers are projected into
    each shard scorer's reduced space (``ivf.with_reduced_centers``); with
    ``aligned`` (sorted modes only) the per-shard coarse quantizer is the
    GleanVec model's clustering (``ivf.build_aligned_sharded``), so each
    shard's fine step runs the gather-free range scan. ``expand`` is the
    graph traversal's multi-expansion width; ``fused_graph`` (sorted
    scorer modes only) binds each shard's subgraph to its scorer's sorted
    layout (``graph.with_fused_scan``) so every shard's hops run the
    gather-free fused beam step. Returns
    ``(sharded_index, stacked_scorer)``.
    """
    X = jnp.asarray(database, jnp.float32)
    n = X.shape[0]
    axes = tuple(shard_axes)
    if mesh is not None:
        axes = axes or tuple(mesh.axis_names)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if not n_shards:
        raise ValueError("pass a mesh or an explicit n_shards")
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    per = n // n_shards
    rows = [X[s * per:(s + 1) * per] for s in range(n_shards)]
    scorers = [sc.build_scorer(mode, r, model, block=sort_block)
               for r in rows]

    if kind == "flat":
        subs = [FlatIndex(block=block)] * n_shards
    elif kind == "ivf":
        if aligned:
            if not mode.endswith("-sorted"):
                raise ValueError("aligned IVF sharding needs a sorted "
                                 f"scorer mode, got {mode!r}")
            subs = ivf_mod.build_aligned_sharded(model, X, n_shards,
                                                 nprobe=nprobe)
        else:
            if key is None:
                key = jax.random.PRNGKey(0)
            subs = ivf_mod.build_sharded(key, X, n_lists, n_shards,
                                         nprobe=nprobe)
        if reduced_probe:
            subs = [ivf_mod.with_reduced_centers(ix, s, model)
                    for ix, s in zip(subs, scorers)]
    elif kind == "graph":
        gkw = dict(graph_kwargs or {})
        subs = [replace(graph_mod.build(np.asarray(r), **gkw), beam=beam,
                        max_hops=max_hops, expand=expand) for r in rows]
        if fused_graph:
            if not mode.endswith("-sorted"):
                raise ValueError("fused_graph needs a sorted scorer mode, "
                                 f"got {mode!r}")
            subs = [graph_mod.with_fused_scan(ix, s)
                    for ix, s in zip(subs, scorers)]
    else:
        raise ValueError(f"unknown index kind {kind!r}; "
                         "one of ('flat', 'ivf', 'graph')")

    row_starts = jnp.arange(n_shards, dtype=jnp.int32) * per
    return (ShardedIndex(sub_index=stack_shards(subs),
                         row_starts=row_starts, mesh=mesh, axes=axes),
            stack_shards(scorers))


def build_sharded_artifacts(kind: str, mode: str, database, model=None, *,
                            spill_host: bool = False, **kwargs):
    """Sharded placement with the full serving surface: builds the sharded
    index + stacked scorer (:func:`build_sharded_index`, same kwargs) and
    wraps them in :class:`~repro.core.search.SearchArtifacts` ready for
    ``make_state`` / ``ServingEngine``.

    ``spill_host=True`` is the two-level memory hierarchy applied PER
    SHARD: each shard's (per, D) full-precision rerank tier demotes to its
    own host buffer (:class:`~repro.core.rerank_tier.ShardedHostStore`,
    same contiguous row partition as the index), so device memory holds
    only the reduced codes and n scales past HBM -- the rerank gather
    routes each query's kappa global candidate ids to their owning
    shard's host buffer. Returns ``(index, artifacts)``.
    """
    # lazy: repro.core.search imports repro.index.topk, which triggers this
    # package's __init__ -- a module-level import here would be circular
    from repro.core import rerank_tier
    from repro.core.search import SearchArtifacts

    index, stacked = build_sharded_index(kind, mode, database, model,
                                         **kwargs)
    x_full = jnp.asarray(database, jnp.float32)
    if spill_host:
        x_full = rerank_tier.demote(np.asarray(x_full),
                                    shards=index.n_shards)
    return index, SearchArtifacts(scorer=stacked, x_full=x_full,
                                  model=model)
