"""Distributed (multi-chip / multi-pod) vector search.

Standard sharded-ANN pattern: the database is row-sharded across every mesh
axis; each shard produces its local top-kappa (via flat scan or its local
graph shard), then candidates are all-gathered and merged into the global
top-k. The only collective is one all-gather of (batch, shards * kappa)
(value, id) pairs -- the id space stays global because each shard offsets its
local ids.

Implemented with shard_map so the collective schedule is explicit and stable
for the roofline analysis.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.index import bruteforce
from repro.index.topk import NEG_INF, merge_topk

__all__ = ["sharded_search", "make_sharded_search"]


def _local_search(q_low, x_shard, shard_offset, k, block):
    vals, ids = bruteforce.search(q_low, x_shard, k, block)
    return vals, jnp.where(ids >= 0, ids + shard_offset, -1)


def make_sharded_search(mesh: Mesh, shard_axes: Sequence[str], k: int,
                        kappa: Optional[int] = None, block: int = 4096):
    """Build a pjit-able sharded flat search.

    ``shard_axes``: mesh axes the database rows are sharded over (e.g.
    ("pod", "data", "model") to use every chip). Queries are replicated --
    each chip scans its shard for the full query batch, which is the
    throughput-optimal layout when batch << n/chips.
    Returns ``fn(q_low, x_low) -> (vals, ids)`` with global ids.
    """
    kappa = kappa or k
    axes = tuple(shard_axes)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def local_fn(q_low, x_shard):
        # shard index along the flattened shard axes
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        rows = x_shard.shape[0]
        vals, ids = _local_search(q_low, x_shard, idx * rows, kappa, block)
        # gather candidates from every shard: (n_shards * kappa,) per query
        vals = jax.lax.all_gather(vals, axes, axis=1, tiled=True)
        ids = jax.lax.all_gather(ids, axes, axis=1, tiled=True)
        top_vals, sel = jax.lax.top_k(vals, k)
        return top_vals, jnp.take_along_axis(ids, sel, axis=1)

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,  # blocked_topk's scan carry is axis-agnostic
    )
    return fn


def sharded_search(q_low: jax.Array, x_low: jax.Array, mesh: Mesh,
                   shard_axes: Sequence[str], k: int,
                   kappa: Optional[int] = None, block: int = 4096):
    """One-shot convenience wrapper around :func:`make_sharded_search`."""
    fn = make_sharded_search(mesh, shard_axes, k, kappa, block)
    return jax.jit(fn)(q_low, x_low)
