"""Distributed (multi-chip / multi-pod) vector search over any scorer.

Standard sharded-ANN pattern: the scorer's row arrays (reduced vectors /
codes / tags) are row-sharded across every mesh axis; each shard produces
its local top-kappa via the unified blocked scan, then candidates are
all-gathered and merged into the global top-k. The only collective is one
all-gather of (batch, shards * kappa) (value, id) pairs -- the id space
stays global because each shard offsets its local ids.

Because scorers are pytrees with a ``shard_specs`` method, ONE shard_map
wrapper serves every representation: linear, eager GleanVec, int8,
GleanVec∘int8 and both tag-sorted layouts all shard with the same single
all-gather merge. Globalizing the per-shard ids goes through the
protocol's ``globalize_ids``: row-aligned scorers offset by the shard row
count; sorted scorers translate through their permutation (which must hold
GLOBAL original ids -- build the sorted layout over the global database,
then row-shard it; the shard count must divide the single-tag block
count).

Implemented with shard_map so the collective schedule is explicit and stable
for the roofline analysis.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.scorer import LinearScorer, Scorer
from repro.index import bruteforce
from repro.utils.jax_compat import shard_map

__all__ = ["sharded_search", "make_sharded_search",
           "sharded_search_scorer", "make_sharded_search_scorer"]


def _local_merge(queries, scorer, mesh: Mesh, axes, k: int, kappa: int,
                 block: int):
    """Per-shard body: local scan -> global ids -> all-gather -> top-k."""
    qstate = scorer.prepare_queries(queries)
    vals, ids = bruteforce.scan_scorer(scorer, qstate, kappa, block)
    idx = jnp.zeros((), jnp.int32)       # shard index along flattened axes
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    # Row-aligned scorers offset their local ids by the shard's row count;
    # sorted scorers already emit global ids through their permutation
    # (their shard of ``perm`` holds global original ids) -- the protocol's
    # globalize_ids encapsulates the difference.
    ids = scorer.globalize_ids(ids, idx)
    vals = jax.lax.all_gather(vals, axes, axis=1, tiled=True)
    ids = jax.lax.all_gather(ids, axes, axis=1, tiled=True)
    top_vals, sel = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(ids, sel, axis=1)


def make_sharded_search_scorer(mesh: Mesh, shard_axes: Sequence[str], k: int,
                               scorer: Scorer, kappa: Optional[int] = None,
                               block: int = 4096):
    """Build a pjit-able sharded search over ``scorer``'s representation.

    ``shard_axes``: mesh axes the scorer rows are sharded over (e.g.
    ("pod", "data", "model") to use every chip). Queries are replicated --
    each chip scans its shard for the full query batch, which is the
    throughput-optimal layout when batch << n/chips. The ``scorer``
    argument fixes the pytree structure (its ``shard_specs``); pass the
    same scorer (row-sharded) when calling the returned
    ``fn(queries, scorer) -> (vals, ids)`` with global ids.
    """
    kappa = kappa or k
    axes = tuple(shard_axes)

    def local_fn(queries, s):
        return _local_merge(queries, s, mesh, axes, k, kappa, block)

    return shard_map(local_fn, mesh=mesh,
                     in_specs=(P(), scorer.shard_specs(axes)),
                     out_specs=(P(), P()))


def make_sharded_search(mesh: Mesh, shard_axes: Sequence[str], k: int,
                        kappa: Optional[int] = None, block: int = 4096):
    """Legacy linear entry point: ``fn(q_low, x_low) -> (vals, ids)``."""
    kappa = kappa or k
    axes = tuple(shard_axes)

    def local_fn(q_low, x_shard):
        return _local_merge(q_low, LinearScorer(x_low=x_shard), mesh, axes,
                            k, kappa, block)

    return shard_map(local_fn, mesh=mesh, in_specs=(P(), P(axes)),
                     out_specs=(P(), P()))


def sharded_search(q_low: jax.Array, x_low: jax.Array, mesh: Mesh,
                   shard_axes: Sequence[str], k: int,
                   kappa: Optional[int] = None, block: int = 4096):
    """One-shot convenience wrapper around :func:`make_sharded_search`."""
    fn = make_sharded_search(mesh, shard_axes, k, kappa, block)
    return jax.jit(fn)(q_low, x_low)


def sharded_search_scorer(queries: jax.Array, scorer: Scorer, mesh: Mesh,
                          shard_axes: Sequence[str], k: int,
                          kappa: Optional[int] = None, block: int = 4096):
    """One-shot wrapper around :func:`make_sharded_search_scorer`."""
    fn = make_sharded_search_scorer(mesh, shard_axes, k, scorer, kappa,
                                    block)
    return jax.jit(fn)(queries, scorer)
