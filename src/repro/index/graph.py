"""Vamana-style graph index: vectorized NN-descent build + RobustPrune
(numpy, offline) and a batched best-first beam search (JAX, online).

TPU adaptation (DESIGN.md section 2): the paper's CPU graph traversal is
memory-latency-bound with per-vector random fetches; here beams for a whole
query batch advance in lockstep, each hop popping the top-``expand``
unvisited frontier vertices (CAGRA-style multi-expansion; ``expand=1`` is
the classic best-first loop) and scoring their gathered
(batch, expand * R) neighbors with one MXU-friendly contraction --
~expand-fold fewer sequential ``while_loop`` iterations for the same
number of vertices scored. The scoring function is
pluggable so the same traversal serves plain LeanVec (q_low . x_low), eager
GleanVec (Alg. 4: per-tag query views) and int8-quantized databases.

The scoring function is the unified Scorer protocol
(:mod:`repro.core.scorer`): ``beam_search_scorer`` accepts any scorer and
scores each hop's gathered neighbor expansion with ``scorer.score_ids``, so
the same traversal serves plain LeanVec, eager GleanVec (Alg. 4), int8,
GleanVec∘int8 and the tag-sorted layouts (graph edges store ORIGINAL ids;
sorted scorers translate internally). The legacy per-representation entry
points are thin wrappers over it.

The traversal also (optionally) records the cluster tag of every expanded
vertex -- the data behind the paper's Figure 7 (tag access pattern favoring
eager execution).

Gather-free hops (``kernels/graph_scan``): a :class:`GraphIndex` carrying
``nbr_rows`` -- its edge lists pre-translated into a tag-sorted scorer's
SORTED-ROW space (``with_fused_scan``) -- replaces the per-hop gather +
``score_ids`` + ``top_k`` merge with one fused Pallas beam step
(``scorer.scan_neighbors``): the hop's neighbor rows become a slab
schedule, and gather + dot + affine + beam dedupe + top-k update fuse in
VMEM with no ``(batch, expand*R)`` score matrix in HBM. Exact (value, id)
parity with the gathered path; the stored ``nbr_rows`` must be re-derived
(``with_fused_scan`` / ``refreshed``) if the layout's slot assignment
changes (insert after remove can REUSE a freed slot).

Builds: :func:`build` (numpy NN-descent + RobustPrune, the paper's offline
path) and :func:`build_device` (CAGRA-style: exact k-NN self-join through
the fused ``scorer_topk`` kernels + rank-based detour pruning in
vectorized JAX) -- ``build(method="auto")`` switches to the device build at
``_DEVICE_BUILD_MIN_N`` rows.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace as _dc_replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scorer import GleanVecScorer, LinearScorer, batch_of
from repro.index.protocol import (_offset_ids, register_index_pytree,
                                  stacked_specs)
from repro.index.topk import NEG_INF

__all__ = ["GraphIndex", "build", "build_device", "with_fused_scan",
           "with_capacity", "insert_ids", "beam_search_scorer",
           "beam_search", "beam_search_gleanvec", "beam_search_traced",
           "gathered_beam_step"]

# build(method="auto") switches from numpy NN-descent to the on-device
# CAGRA-style self-join at this many rows (where the O(n * iters) numpy
# path stops being interactive).
_DEVICE_BUILD_MIN_N = 8192


@dataclass(frozen=True, eq=False)
class GraphIndex:
    """Navigable graph implementing the Index protocol. ``beam`` /
    ``max_hops`` / ``expand`` are static search configuration for the
    protocol path (``candidates``); the explicit entry points accept
    overrides. ``expand`` is the CAGRA-style multi-expansion width: each
    hop pops the top-``expand`` unvisited frontier vertices and scores
    their (batch, expand*R) gathered neighbors in one contraction --
    ~expand-fold fewer ``while_loop`` iterations and expand-fold wider MXU
    work per hop; ``expand=1`` reproduces the classic best-first traversal
    exactly. Entries may be -1-padded (stacked per-shard graphs): padded
    slots are masked out of the initial beam.

    ``nbr_rows`` + ``fused`` enable the gather-free hop: ``nbr_rows`` is
    ``neighbors`` translated into a tag-sorted scorer's sorted-row space
    (``with_fused_scan``; removed ids -> -1), and ``candidates`` then
    routes hops through ``scorer.scan_neighbors`` (the fused Pallas beam
    step) whenever the scorer has one. ``scan_tn`` is the kernel's slab
    tile. The translation is layout-bound: re-derive after any slot churn
    (see ``refreshed``)."""

    neighbors: jax.Array  # (n, R) int32, -1 padded
    entries: jax.Array    # (E,) int32 entry points (medoid + per-cluster)
    # (n, R) int32 sorted-row translation of ``neighbors`` (-1 = pad or
    # removed), present only on layout-aware (fused) variants
    nbr_rows: Optional[jax.Array] = None
    beam: int = 64
    max_hops: int = 256
    expand: int = 1       # frontier vertices expanded per hop
    fused: bool = False   # route hops through scorer.scan_neighbors
    scan_tn: int = 8      # graph_scan slab tile (rows per DMA)

    # ---- Index protocol ----------------------------------------------------

    def prepare_queries(self, scorer, queries: jax.Array):
        return scorer.prepare_queries(queries)

    def candidates(self, qstate, scorer, k: int):
        top, ids, _, _ = _beam_qstate(qstate, scorer, self, k, self.beam,
                                      self.max_hops, expand=self.expand)
        # -inf winners are unfilled beam slots (or streaming-dead rows a
        # scorer masked); strip their ids like the IVF path does.
        return top, jnp.where(top > NEG_INF, ids, -1)

    def search(self, queries: jax.Array, scorer, k: int):
        return self.candidates(self.prepare_queries(scorer, queries),
                               scorer, k)

    def shard_specs(self, axes):
        return stacked_specs(self, axes)

    def globalize_ids(self, scorer, ids: jax.Array, row_start) -> jax.Array:
        return _offset_ids(ids, row_start)

    def refreshed(self, scorer, model) -> "GraphIndex":
        """Streaming-refresh hook: the edge set was built from FULL-D
        geometry, which a projection refresh does not change -- but the
        FUSED variant's ``nbr_rows`` binds edges to the scorer's slot
        assignment, so it is re-derived against the (possibly churned)
        layout here. The plain variant passes through unchanged.
        (Edge INSERTION for grown databases is :func:`insert_ids`:
        pre-allocate slots with :func:`with_capacity`, then connect each
        new row via beam-search-for-neighbors + reverse-edge fill.)"""
        if self.fused and getattr(scorer, "inv_perm", None) is not None:
            return with_fused_scan(self, scorer, tn=self.scan_tn)
        return self


register_index_pytree(GraphIndex,
                      data_fields=("neighbors", "entries", "nbr_rows"),
                      static_fields=("beam", "max_hops", "expand", "fused",
                                     "scan_tn"))


def with_fused_scan(index: GraphIndex, scorer, tn: int = 8) -> GraphIndex:
    """Layout-aware variant of ``index`` bound to a tag-sorted ``scorer``:
    edge lists are pre-translated through ``scorer.inv_perm`` into sorted-
    row space (removed ids -> -1) so each hop's DMA schedule is block-
    contiguous, and ``candidates`` routes hops through the fused
    ``scan_neighbors`` kernel. Host-side; re-run (or let ``refreshed`` do
    it) after any slot churn -- a freed slot REUSED by a later insert
    would otherwise silently alias the stored rows to the new tenant."""
    inv_perm = getattr(scorer, "inv_perm", None)
    if inv_perm is None:
        raise ValueError("with_fused_scan needs a tag-sorted scorer "
                         "(SortedGleanVec*) with an inv_perm")
    nbrs = np.asarray(index.neighbors)
    inv = np.asarray(inv_perm)
    rows = inv[np.where(nbrs >= 0, nbrs, 0)]
    rows = np.where((nbrs >= 0) & (rows >= 0), rows, -1)
    return _dc_replace(index, nbr_rows=jnp.asarray(rows.astype(np.int32)),
                       fused=True, scan_tn=tn)


# ---------------------------------------------------------------------------
# Streamed growth: pre-allocated edge slots + incremental edge insertion.
# ---------------------------------------------------------------------------


def with_capacity(index: GraphIndex, capacity: int) -> GraphIndex:
    """Pad the edge table to ``capacity`` rows (edgeless, all -1) so a
    streamed graph can GROW: :func:`insert_ids` fills a padded row's edges
    in place, preserving every leaf shape and the treedef -- the
    zero-recompile ``ServingEngine.swap`` contract, mirroring
    ``ivf.with_list_slack``. Size ``capacity`` to the streaming store's
    row capacity so external ids index the table directly."""
    n, r = index.neighbors.shape
    if capacity < n:
        raise ValueError(f"capacity {capacity} < current rows {n}")
    if capacity == n:
        return index
    pad = jnp.full((capacity - n, r), -1, index.neighbors.dtype)
    nbr_rows = index.nbr_rows
    if nbr_rows is not None:
        nbr_rows = jnp.concatenate(
            [nbr_rows, jnp.full((capacity - n, r), -1, nbr_rows.dtype)])
    return _dc_replace(index,
                       neighbors=jnp.concatenate([index.neighbors, pad]),
                       nbr_rows=nbr_rows)


def insert_ids(index: GraphIndex, rows, ids, scorer, x_full,
               kappa: Optional[int] = None) -> GraphIndex:
    """Connect newly inserted external ``ids`` (full-D ``rows``) into the
    graph (host-side; shape-preserving -- the slots must exist, see
    :func:`with_capacity`).

    The Vamana-style incremental insert, adapted to the two-level layout:

    1. OUT-edges: beam-search the current graph for each new vector's
       ``kappa`` nearest candidates (through the serving ``scorer``, so
       the traversal runs in the reduced space like every query), widen
       with the batch-mates (unreachable until this call links them), then
       re-rank candidates by FULL-D L2 distance against the rerank store
       ``x_full`` -- which may be a host tier; only the candidate rows are
       gathered -- and keep the R closest as the new row's edge list.
    2. REVERSE-edge fill: for each new vertex v and out-neighbor t, v is
       added to t's list into a free slot, or replaces t's farthest
       current edge when v is closer (full-D distances again). If every
       target row wins, v still gets >= 1 in-edge by forcing the last slot
       of its nearest target -- a vertex with no in-edges would be
       unreachable forever.

    A fused index re-derives ``nbr_rows`` against the scorer's layout
    (same re-translation ``refreshed`` runs). Entries are untouched.
    """
    ids = np.asarray(ids, np.int64).reshape(-1)
    if ids.size == 0:
        return index
    nbrs = np.asarray(index.neighbors).copy()
    cap, r = nbrs.shape
    rows_np = np.asarray(rows, np.float32).reshape(ids.size, -1)
    if np.any(ids >= cap):
        raise ValueError("insert id beyond edge-table capacity; grow with "
                         "with_capacity first")
    kappa = kappa or max(2 * r, 16)

    def _fetch(ext_ids: np.ndarray) -> np.ndarray:
        # external-id row gather that works for device arrays AND host
        # tiers (HostStore.__getitem__ gathers only the requested rows)
        return np.asarray(x_full[np.asarray(ext_ids)], np.float32)

    # 1) candidate pool: reduced-space beam search + batch-mates
    _, cand = beam_search_scorer(jnp.asarray(rows_np), scorer, index,
                                 k=kappa, beam=max(index.beam, kappa),
                                 max_hops=index.max_hops,
                                 expand=index.expand)
    cand = np.asarray(cand, np.int64)                       # (b, kappa)
    mates = np.broadcast_to(ids, (ids.size, ids.size))
    cand = np.concatenate([cand, mates], axis=1)
    cand[cand == ids[:, None]] = -1                         # no self loops
    # full-D L2 re-rank of each row's candidate pool
    cvecs = _fetch(np.where(cand >= 0, cand, 0))            # (b, K, D)
    d2 = np.sum((cvecs - rows_np[:, None, :]) ** 2, axis=2)
    d2[cand < 0] = np.inf
    # mask duplicate candidates (keep first) before taking the closest R
    srt = np.sort(cand, axis=1)
    for b in range(ids.size):
        _, first = np.unique(cand[b], return_index=True)
        dup = np.ones(cand.shape[1], bool)
        dup[first] = False
        d2[b, dup] = np.inf
    sel = np.argsort(d2, axis=1, kind="stable")[:, :r]
    out_edges = np.take_along_axis(cand, sel, axis=1)
    out_edges[np.take_along_axis(d2, sel, axis=1) == np.inf] = -1
    nbrs[ids] = out_edges

    # 2) reverse-edge fill with full-D distances + in-edge guarantee
    for b, v in enumerate(ids):
        placed = False
        targets = out_edges[b][out_edges[b] >= 0]
        t_vecs = _fetch(targets) if targets.size else None
        for j, t in enumerate(targets):
            row = nbrs[t]
            if v in row:
                placed = True
                continue
            free = np.nonzero(row < 0)[0]
            if free.size:
                nbrs[t, free[0]] = v
                placed = True
                continue
            d_edges = np.sum(
                (_fetch(row) - t_vecs[j][None, :]) ** 2, axis=1)
            far = int(np.argmax(d_edges))
            d_v = float(np.sum((rows_np[b] - t_vecs[j]) ** 2))
            if d_v < d_edges[far]:
                nbrs[t, far] = v
                placed = True
        if not placed and targets.size:
            nbrs[targets[0], r - 1] = v     # nearest target cedes a slot

    # dedupe only the touched rows (insert slots + reverse-fill targets)
    touched = np.unique(np.concatenate(
        [ids, out_edges[out_edges >= 0].ravel()]))
    nbrs[touched] = _dedupe_rows(nbrs[touched])
    new = _dc_replace(index,
                      neighbors=jnp.asarray(nbrs.astype(np.int32)))
    if index.fused and getattr(scorer, "inv_perm", None) is not None:
        new = with_fused_scan(new, scorer, tn=index.scan_tn)
    return new


# ---------------------------------------------------------------------------
# Build (offline, numpy): NN-descent for candidates + RobustPrune for edges.
# ---------------------------------------------------------------------------


def _chunked_l2(x: np.ndarray, cand: np.ndarray, chunk: int = 2048):
    """d2[i, j] = ||x_i - x_cand[i, j]||^2, chunked over rows."""
    n, k = cand.shape
    out = np.empty((n, k), np.float32)
    x_sq = np.sum(x * x, axis=1)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        c = cand[s:e]
        diff_ip = np.einsum("bkd,bd->bk", x[c], x[s:e])
        out[s:e] = x_sq[c] - 2.0 * diff_ip + x_sq[s:e, None]
    return out


def _nn_descent(x: np.ndarray, r: int, n_iters: int, rng) -> np.ndarray:
    """Approximate 2R-NN lists via neighbor-of-neighbor refinement."""
    n = x.shape[0]
    k = 2 * r
    nbrs = rng.integers(0, n, size=(n, k), dtype=np.int64)
    self_ids = np.arange(n)[:, None]
    for it in range(n_iters):
        # candidates = current + neighbors-of-neighbors (sampled) + random
        nn = nbrs[nbrs[:, rng.permutation(k)[: max(2, k // 4)]]]
        nn = nn.reshape(n, -1)
        rand = rng.integers(0, n, size=(n, r // 2), dtype=np.int64)
        cand = np.concatenate([nbrs, nn, rand], axis=1)
        # dedupe by sorting; keep first occurrence (stable unique per row)
        cand.sort(axis=1)
        dup = np.concatenate(
            [np.zeros((n, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1)
        d2 = _chunked_l2(x, cand)
        d2[dup] = np.inf
        d2[cand == self_ids] = np.inf
        sel = np.argpartition(d2, k - 1, axis=1)[:, :k]
        nbrs = np.take_along_axis(cand, sel, axis=1)
        row_d = np.take_along_axis(d2, sel, axis=1)
        order = np.argsort(row_d, axis=1)
        nbrs = np.take_along_axis(nbrs, order, axis=1)
    return nbrs


def _robust_prune(x: np.ndarray, cand: np.ndarray, r: int, alpha: float,
                  chunk: int = 1024) -> np.ndarray:
    """Vamana RobustPrune, vectorized over nodes (inner loop over K slots).

    ``cand`` (n, K) sorted by distance ascending. Keeps <= r diverse edges:
    a candidate c survives iff for every previously kept edge e,
    alpha * d(e, c) >= d(p, c).
    """
    n, k = cand.shape
    out = np.full((n, r), -1, np.int64)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        c = cand[s:e]                        # (b, K) sorted by d(p, .)
        b = c.shape[0]
        vecs = x[c]                          # (b, K, D)
        # pairwise distances among candidates: (b, K, K)
        sq = np.sum(vecs * vecs, axis=2)
        pair = sq[:, :, None] - 2 * np.einsum("bkd,bld->bkl", vecs, vecs) \
            + sq[:, None, :]
        d_p = np.sum((vecs - x[s:e][:, None, :]) ** 2, axis=2)  # (b, K)
        kept = np.zeros((b, k), bool)
        pruned = np.zeros((b, k), bool)
        n_kept = np.zeros(b, np.int32)
        for j in range(k):
            take = (~pruned[:, j]) & (n_kept < r)
            kept[:, j] = take
            n_kept += take
            # prune later candidates too close to j (relative to p)
            closer = alpha * pair[:, j, :] < d_p
            pruned |= closer & take[:, None]
        for row in range(b):
            ids = c[row][kept[row]][:r]
            out[s + row, : len(ids)] = ids
    return out


def _reverse_edge_fill_ref(nbrs: np.ndarray, r: int) -> np.ndarray:
    """Sequential reverse-edge fill (the original interpreted loop, kept
    verbatim as the parity oracle for :func:`_reverse_edge_fill`): for
    every forward edge dst -> src, append dst to src's list if a slot
    remains and the edge is neither a self-loop nor already present."""
    nbrs = nbrs.copy()
    n = nbrs.shape[0]
    slots = np.sum(nbrs >= 0, axis=1)
    rev_src = nbrs.ravel()
    rev_dst = np.repeat(np.arange(n), r)
    ok = rev_src >= 0
    for srcv, dstv in zip(rev_src[ok], rev_dst[ok]):
        s = slots[srcv]
        if s < r and dstv != srcv:
            row = nbrs[srcv]
            if dstv not in row[:s]:
                nbrs[srcv, s] = dstv
                slots[srcv] += 1
    return nbrs


def _reverse_edge_fill(nbrs: np.ndarray, r: int) -> np.ndarray:
    """Vectorized reverse-edge fill: same result as the sequential
    reference, via argsort/bincount slot assignment instead of an O(n * R)
    interpreted loop.

    Equivalence: the reference processes candidates in ravel order; a
    candidate (src, dst) is accepted iff dst is not in src's PRUNED row
    and no earlier candidate already claimed the same (src, dst); accepted
    candidates take consecutive slots after src's pruned edges, dropped
    once the row is full. Here: mask existing edges with one whole-row
    compare (the pruned matrix is front-packed, -1 tail), keep the first
    occurrence per (src, dst) key, and a STABLE argsort by src preserves
    ravel order within each src, so rank-within-src = the reference's slot
    offset -- including which overflow candidates fall off the end."""
    nbrs = nbrs.copy()
    n = nbrs.shape[0]
    slots0 = np.sum(nbrs >= 0, axis=1)
    src = nbrs.ravel()
    dst = np.repeat(np.arange(n), r)
    ok = (src >= 0) & (src != dst)
    idx = np.nonzero(ok)[0]
    exists = np.any(nbrs[src[idx]] == dst[idx, None], axis=1)
    idx = idx[~exists]
    key = src[idx].astype(np.int64) * n + dst[idx]
    _, first = np.unique(key, return_index=True)
    idx = idx[np.sort(first)]                     # ravel order restored
    order = np.argsort(src[idx], kind="stable")
    idx = idx[order]
    s_sorted = src[idx]
    counts = np.bincount(s_sorted, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(idx.size) - starts[s_sorted]
    slot = slots0[s_sorted] + rank
    keep = slot < r
    nbrs[s_sorted[keep], slot[keep]] = dst[idx][keep]
    return nbrs


def _dedupe_rows(nbrs: np.ndarray) -> np.ndarray:
    """Mask repeated ids within each row to -1 (keep the first occurrence).
    Random long-range edges can collide with pruned/reverse edges; a
    duplicate edge adds no reachability but would let the gathered
    ``expand=1`` hop insert one vertex into TWO beam slots -- the builds
    emit duplicate-free rows so the gathered and fused traversals agree on
    every built graph (the fused kernel scores each distinct neighbor
    exactly once by construction)."""
    order = np.argsort(nbrs, axis=1, kind="stable")
    snb = np.take_along_axis(nbrs, order, axis=1)
    dup_sorted = np.concatenate(
        [np.zeros((nbrs.shape[0], 1), bool),
         (snb[:, 1:] == snb[:, :-1]) & (snb[:, 1:] >= 0)], axis=1)
    dup = np.zeros(nbrs.shape, bool)
    np.put_along_axis(dup, order, dup_sorted, axis=1)
    return np.where(dup, -1, nbrs)


def _entry_points(x: np.ndarray, n_entries: int, seed: int) -> np.ndarray:
    """Medoid + the database vectors nearest to spherical k-means
    centroids (the same clustering GleanVec uses), deduplicated -- so
    every mixture component is reachable in one hop."""
    n = x.shape[0]
    entries = [int(np.argmin(
        np.sum((x - x.mean(0, keepdims=True)) ** 2, axis=1)))]
    if n_entries > 1:
        import jax.random as jrandom
        from repro.core import spherical_kmeans
        km = spherical_kmeans.fit(jrandom.PRNGKey(seed), jnp.asarray(x),
                                  min(n_entries - 1, max(2, n // 64)),
                                  n_iters=10)
        x_unit = x / np.maximum(
            np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
        sims = x_unit @ np.asarray(km.centers).T
        entries.extend(int(i) for i in np.argmax(sims, axis=0))
    return np.unique(np.asarray(entries, np.int32))


def build(x: np.ndarray, r: int = 32, alpha: float = 1.2, n_iters: int = 6,
          n_random: int = 4, n_entries: int = 16, seed: int = 0,
          method: str = "numpy") -> GraphIndex:
    """Build a degree-(R + n_random) navigable graph over ``x``.

    ``method``: "numpy" (NN-descent + RobustPrune, this function),
    "device" (delegate to :func:`build_device`), or "auto" (device at
    ``n >= _DEVICE_BUILD_MIN_N``, numpy below -- the device self-join is
    where large builds stop being numpy-bound).

    Two connectivity safeguards beyond plain NN-descent (clustered data --
    e.g. the paper's multi-modal embeddings -- yields *disconnected* kNN
    graphs, on which greedy search provably stalls):
      * ``n_random`` NSW-style long-range out-edges appended per node;
      * ``n_entries`` search entry points (:func:`_entry_points`).
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    if method == "device" or (method == "auto" and n >= _DEVICE_BUILD_MIN_N):
        return build_device(x, r=r, n_random=n_random, n_entries=n_entries,
                            seed=seed)
    if method not in ("numpy", "auto"):
        raise ValueError(f"unknown graph build method: {method!r}")
    rng = np.random.default_rng(seed)
    cand = _nn_descent(x, r, n_iters, rng)          # (n, 2R) sorted
    nbrs = _robust_prune(x, cand, r, alpha)         # (n, R), -1 padded
    # add reverse edges where slots remain (improves connectivity)
    nbrs = _reverse_edge_fill(nbrs, r)
    if n_random > 0:
        rand_edges = rng.integers(0, n, size=(n, n_random), dtype=np.int64)
        nbrs = _dedupe_rows(np.concatenate([nbrs, rand_edges], axis=1))
    entries = _entry_points(x, n_entries, seed)
    return GraphIndex(neighbors=jnp.asarray(nbrs.astype(np.int32)),
                      entries=jnp.asarray(entries))


# ---------------------------------------------------------------------------
# Build (on-device, CAGRA-style): fused-kernel k-NN self-join + rank-based
# detour pruning -- no dense (n, n) matrix, no numpy NN-descent iterations.
# ---------------------------------------------------------------------------


def _device_knn(x: np.ndarray, k: int, batch: int = 1024,
                interpret: bool = False) -> np.ndarray:
    """Exact k-NN ids (self excluded, distance ascending) via the fused
    ``scorer_topk`` kernel: the augmented-IP trick -- database rows
    ``[x, -||x||^2 / 2]``, queries ``[q, 1]`` -- makes inner-product top-k
    return exact L2 order, so the self-join is a blocked ``ip_topk`` with
    no (n, n) matrix and no host-side distance math."""
    from repro import kernels
    n = x.shape[0]
    xj = jnp.asarray(x, jnp.float32)
    xsq = jnp.sum(xj * xj, axis=1)
    scorer = LinearScorer(
        x_low=jnp.concatenate([xj, -0.5 * xsq[:, None]], axis=1))
    out = np.empty((n, k), np.int64)
    for s in range(0, n, batch):
        e = min(s + batch, n)
        q = jnp.concatenate([xj[s:e], jnp.ones((e - s, 1), jnp.float32)],
                            axis=1)
        _, ids = kernels.scorer_topk(scorer, q, k + 1, interpret=interpret)
        ids = np.asarray(ids)
        # drop self (rank 0 barring exact duplicates); stable compaction
        # keeps the remaining k in distance order
        keep = ids != np.arange(s, e)[:, None]
        sel = np.argsort(~keep, axis=1, kind="stable")[:, :k]
        out[s:e] = np.take_along_axis(ids, sel, axis=1)
    return out


@jax.jit
def _detour_mask(knn: jax.Array, nbr_c: jax.Array) -> jax.Array:
    """CAGRA rank-based pruning predicate for one chunk of nodes:
    ``nbr_c (b, k0)`` distance-ascending neighbor ids, ``knn (n, k0)`` the
    full table. Edge p -> u_j is a detour iff some closer neighbor u_i
    (i < j) reaches u_j at rank < j in ITS list -- the two-hop route
    through u_i dominates, so the direct edge adds no reachability."""
    k0 = nbr_c.shape[1]
    wn = knn[nbr_c]                                        # (b, k0, k0)
    hit = wn[:, :, None, :] == nbr_c[:, None, :, None]     # (b, i, j, slot)
    slot = jax.lax.broadcasted_iota(jnp.int32, hit.shape, 3)
    rank = jnp.min(jnp.where(hit, slot, k0), axis=3)       # (b, i, j)
    j = jnp.arange(k0)
    lower = j[:, None] < j[None, :]                        # i < j
    return jnp.any(lower[None] & (rank < j[None, None, :]), axis=1)


def build_device(x: np.ndarray, r: int = 32, k_base: Optional[int] = None,
                 n_random: int = 4, n_entries: int = 16, seed: int = 0,
                 batch: int = 1024, interpret: bool = False) -> GraphIndex:
    """CAGRA-style graph build on the search accelerator: seed a
    ``k_base``-NN graph with the fused ``scorer_topk`` self-join
    (:func:`_device_knn`), rank-prune detour edges in vectorized JAX
    (:func:`_detour_mask`, chunked -- the (b, k0, k0, k0) compare never
    exceeds a few tens of MB), then the same reverse-edge fill / random
    long-range edges / entry points as the numpy build. Replaces
    NN-descent as the default at ``n >= _DEVICE_BUILD_MIN_N`` via
    ``build(method="auto")``."""
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    k0 = k_base if k_base is not None else min(2 * r, n - 1)
    knn = _device_knn(x, k0, batch=batch, interpret=interpret)
    knn_j = jnp.asarray(knn.astype(np.int32))
    nbrs = np.full((n, r), -1, np.int64)
    chunk = max(16, 2 ** 24 // max(1, k0 ** 3))
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        detour = np.asarray(_detour_mask(knn_j, knn_j[s:e]))
        kept = ~detour                                     # (b, k0)
        pos = np.cumsum(kept, axis=1) - 1
        sel = kept & (pos < r)
        nbrs[np.nonzero(sel)[0] + s, pos[sel]] = knn[s:e][sel]
    nbrs = _reverse_edge_fill(nbrs, r)
    rng = np.random.default_rng(seed)
    if n_random > 0:
        rand_edges = rng.integers(0, n, size=(n, n_random), dtype=np.int64)
        nbrs = _dedupe_rows(np.concatenate([nbrs, rand_edges], axis=1))
    entries = _entry_points(x, n_entries, seed)
    return GraphIndex(neighbors=jnp.asarray(nbrs.astype(np.int32)),
                      entries=jnp.asarray(entries))


# ---------------------------------------------------------------------------
# Search (online, JAX): batched best-first beam search.
# ---------------------------------------------------------------------------


def _beam_member_mask(ids: jax.Array, nbrs: jax.Array) -> jax.Array:
    """(batch, P) membership of ``nbrs`` in the per-row ``ids`` beam, via a
    per-row sort + searchsorted instead of the O(beam * P * beam) equality
    broadcast (P = expand * R; the broadcast was the per-hop memory peak)."""
    beam = ids.shape[1]
    sorted_ids = jnp.sort(ids, axis=1)
    pos = jax.vmap(jnp.searchsorted)(sorted_ids, nbrs)
    pos = jnp.clip(pos, 0, beam - 1)
    return jnp.take_along_axis(sorted_ids, pos, axis=1) == nbrs


def _mask_duplicate_nbrs(nbrs: jax.Array) -> jax.Array:
    """Set repeated ids within each row of ``nbrs`` to -1 (keep the first
    occurrence in sorted order). Multi-expansion hops gather overlapping
    neighborhoods; without this a vertex could hold several beam slots."""
    order = jnp.argsort(nbrs, axis=1)
    snb = jnp.take_along_axis(nbrs, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((nbrs.shape[0], 1), bool), snb[:, 1:] == snb[:, :-1]],
        axis=1)
    rows = jnp.arange(nbrs.shape[0])[:, None]
    dup = jnp.zeros(nbrs.shape, bool).at[rows, order].set(dup_sorted)
    return jnp.where(dup, -1, nbrs)


def gathered_beam_step(score_ids, nbr_tbl: jax.Array, scores: jax.Array,
                       ids: jax.Array, visited: jax.Array,
                       best_ids: jax.Array, sel_ok: jax.Array, beam: int):
    """One GATHERED hop merge: gather the popped vertices' neighbors from
    ``nbr_tbl`` (original-id space), score via ``score_ids``, dedupe
    against the beam and ``top_k``-merge. Module-level so the benches can
    lower + cost-model exactly the per-hop work the fused kernel replaces
    (``kernels.beam_step_bytes`` is its counterpart)."""
    batch = ids.shape[0]
    e = best_ids.shape[1]
    r = nbr_tbl.shape[1]
    nbrs = nbr_tbl[jnp.where(best_ids >= 0, best_ids, 0)]  # (b, e, R)
    nbrs = jnp.where((nbrs >= 0) & sel_ok[:, :, None], nbrs, -1)
    nbrs = nbrs.reshape(batch, e * r)
    if e > 1:       # overlapping neighborhoods: drop within-hop dups
        nbrs = _mask_duplicate_nbrs(nbrs)
    nscores = score_ids(nbrs)
    nscores = jnp.where(nbrs >= 0, nscores, NEG_INF)
    # dedupe against the current beam (sort-based membership)
    present = _beam_member_mask(ids, nbrs)
    nscores = jnp.where(present, NEG_INF, nscores)
    # merge and keep top-beam
    all_scores = jnp.concatenate([scores, nscores], axis=1)
    all_ids = jnp.concatenate([ids, nbrs], axis=1)
    all_vis = jnp.concatenate(
        [visited, jnp.zeros((batch, e * r), bool)], axis=1)
    top_scores, sel = jax.lax.top_k(all_scores, beam)
    top_ids = jnp.take_along_axis(all_ids, sel, axis=1)
    top_vis = jnp.take_along_axis(all_vis, sel, axis=1)
    return top_scores, top_ids, top_vis


def _beam_loop(score_ids, graph: GraphIndex, batch: int, beam: int,
               max_hops: int, expand: int = 1,
               trace_tags: Optional[jax.Array] = None, fused_step=None):
    """Shared traversal. ``score_ids(ids) -> (batch, k) scores`` for id >= 0.

    Each hop pops the top-``expand`` unvisited frontier vertices per query
    and scores their concatenated (batch, expand*R) neighbor gather in one
    contraction; ``expand=1`` is the classic best-first loop. Returns
    (scores, ids, n_hops, tag_trace) with tag_trace (batch, max_hops) = tag
    of the BEST vertex expanded at each hop (-1 = no hop), for Figure 7.

    ``fused_step(scores, ids, visited, best_ids, sel_ok) -> (scores, ids,
    visited)`` replaces the gathered hop merge with the gather-free kernel
    (see :func:`_beam_qstate`): identical top-``beam`` multiset, but the
    beam stays in slot order (the kernel folds candidates in place) rather
    than score-sorted -- every consumer (the pop's ``top_k``, the final
    ``top_k``) is order-insensitive, so the traversal is unchanged."""
    nbr_tbl = graph.neighbors
    e = max(1, expand)
    assert e <= beam, "expand must not exceed the beam width"

    n_entry = graph.entries.shape[0]
    assert n_entry <= beam, "beam must hold all entry points"
    entry = jnp.broadcast_to(graph.entries[None, :], (batch, n_entry))
    # -1-padded entries (stacked per-shard graphs) never enter the beam
    e_scores = jnp.where(entry >= 0, score_ids(entry), NEG_INF)
    cand_ids = jnp.concatenate(
        [entry, jnp.full((batch, beam - n_entry), -1, jnp.int32)], axis=1)
    cand_scores = jnp.concatenate(
        [e_scores, jnp.full((batch, beam - n_entry), NEG_INF)], axis=1)
    visited = jnp.zeros((batch, beam), bool)
    tag_hist = jnp.full((batch, max_hops), -1, jnp.int32)

    def cond(state):
        _, scores, ids, visited, hop, _ = state
        expandable = (~visited) & (ids >= 0)
        return jnp.logical_and(hop < max_hops, jnp.any(expandable))

    def body(state):
        key_unused, scores, ids, visited, hop, tag_hist = state
        expandable = (~visited) & (ids >= 0)
        masked = jnp.where(expandable, scores, NEG_INF)
        _, best = jax.lax.top_k(masked, e)                     # (batch, e)
        rows = jnp.arange(batch)[:, None]
        # slots that actually hold expandable work (fewer than e frontier
        # vertices -> the overflow selections are no-ops)
        sel_ok = jnp.take_along_axis(expandable, best, axis=1)
        has_work = jnp.any(expandable, axis=1)
        if e == 1:      # exact classic semantics: gate on the row, not the
            sel_ok = has_work[:, None]  # slot (matches the argmax loop)
        best_ids = jnp.take_along_axis(ids, best, axis=1)      # (batch, e)
        visited = visited.at[rows, best].set(
            jnp.take_along_axis(visited, best, axis=1) | sel_ok)
        if fused_step is not None:
            top_scores, top_ids, top_vis = fused_step(scores, ids, visited,
                                                      best_ids, sel_ok)
        else:
            top_scores, top_ids, top_vis = gathered_beam_step(
                score_ids, nbr_tbl, scores, ids, visited, best_ids, sel_ok,
                beam)
        if trace_tags is not None:
            first = best_ids[:, 0]
            tag = jnp.where(first >= 0,
                            trace_tags[jnp.where(first >= 0, first, 0)],
                            -1)
            tag = jnp.where(has_work, tag, -1)
            tag_hist = tag_hist.at[:, hop].set(tag)
        return (key_unused, top_scores, top_ids, top_vis, hop + 1, tag_hist)

    state = (jnp.zeros(()), cand_scores, cand_ids, visited,
             jnp.zeros((), jnp.int32), tag_hist)
    state = jax.lax.while_loop(cond, body, state)
    _, scores, ids, _, hops, tag_hist = state
    return scores, ids, hops, tag_hist


@functools.partial(jax.jit, static_argnames=("k", "beam", "max_hops",
                                             "expand"))
def _beam_qstate(qstate, scorer, graph: GraphIndex, k: int, beam: int,
                 max_hops: int, expand: int = 1,
                 trace_tags: Optional[jax.Array] = None):
    """Traversal over any scorer with prepared queries ``qstate``.

    A fused graph (``with_fused_scan``) paired with a scorer exposing
    ``scan_neighbors`` routes each hop through the gather-free Pallas beam
    step: the popped vertices' PRE-TRANSLATED sorted rows (``nbr_rows``)
    go straight to the kernel, which scores, dedupes against the beam and
    folds in place -- the visited flag stays attached to its slot's id
    (``visited & (new == old)``), which is exactly the gathered path's
    permutation of visited flags through the merge (beam ids are
    distinct). ``graph.fused`` is static aux data, so the dispatch is
    trace-time; both paths share one cache entry structure."""
    m = batch_of(qstate)

    def score_ids(ids):
        safe = jnp.where(ids >= 0, ids, 0)
        return scorer.score_ids(qstate, safe)

    fused_step = None
    if graph.fused and graph.nbr_rows is not None \
            and hasattr(scorer, "scan_neighbors"):
        nbr_rows_tbl = graph.nbr_rows
        e = max(1, expand)

        def fused_step(scores, ids, visited, best_ids, sel_ok):
            nrows = nbr_rows_tbl[jnp.where(best_ids >= 0, best_ids, 0)]
            nrows = jnp.where((nrows >= 0) & sel_ok[:, :, None], nrows, -1)
            nrows = nrows.reshape(m, e * nbr_rows_tbl.shape[1])
            new_scores, new_ids = scorer.scan_neighbors(
                qstate, nrows, scores, ids, tn=graph.scan_tn)
            # The visited flag stays attached to its entry's ID, not its
            # slot: new candidates enter unvisited, survivors keep their
            # flag. A sort + searchsorted lookup against the PRE-hop beam
            # transfers the flags regardless of output slot order (the
            # Pallas kernel folds in place; the jnp fallback re-sorts) --
            # exactly the gathered path's permutation of visited through
            # its merge, since beam ids are distinct.
            order = jnp.argsort(ids, axis=1)
            sorted_ids = jnp.take_along_axis(ids, order, axis=1)
            sorted_vis = jnp.take_along_axis(visited, order, axis=1)
            pos = jnp.clip(jax.vmap(jnp.searchsorted)(sorted_ids, new_ids),
                           0, beam - 1)
            match = jnp.take_along_axis(sorted_ids, pos, axis=1) == new_ids
            new_vis = match & jnp.take_along_axis(sorted_vis, pos, axis=1)
            return new_scores, new_ids, new_vis

    scores, ids, hops, tag_hist = _beam_loop(score_ids, graph, m, beam,
                                             max_hops, expand=expand,
                                             trace_tags=trace_tags,
                                             fused_step=fused_step)
    if k > beam:        # kappa > beam (e.g. kappa > n): pad with -1 slots
        fill = k - beam
        scores = jnp.concatenate(
            [scores, jnp.full((m, fill), NEG_INF, scores.dtype)], axis=1)
        ids = jnp.concatenate(
            [ids, jnp.full((m, fill), -1, ids.dtype)], axis=1)
    top, sel = jax.lax.top_k(scores, k)
    return top, jnp.take_along_axis(ids, sel, axis=1), hops, tag_hist


def beam_search_scorer(queries: jax.Array, scorer, graph: GraphIndex,
                       k: int, beam: int = 64, max_hops: int = 256,
                       expand: int = 1, trace: bool = False):
    """Unified-protocol beam search: ``queries (m, D)`` full-dimension.

    ``expand`` pops that many frontier vertices per hop (multi-expansion);
    1 is the classic best-first traversal. With ``trace=True`` additionally
    returns (n_hops, (m, max_hops) tag trace) -- requires a scorer with
    ``tags`` (Figure 7 measurement).
    """
    qstate = scorer.prepare_queries(queries)
    trace_tags = getattr(scorer, "tags", None) if trace else None
    if trace and trace_tags is None:
        raise ValueError("trace=True needs a tagged scorer (GleanVec*)")
    top, ids, hops, tag_hist = _beam_qstate(qstate, scorer, graph, k, beam,
                                            max_hops, expand=expand,
                                            trace_tags=trace_tags)
    if trace:
        return top, ids, hops, tag_hist
    return top, ids


def beam_search(q_low: jax.Array, x_low: jax.Array, graph: GraphIndex,
                k: int, beam: int = 64, max_hops: int = 256):
    """Linear scoring: q_low (m, d), x_low (n, d) -> ids (m, k)."""
    top, ids, _, _ = _beam_qstate(q_low, LinearScorer(x_low=x_low), graph,
                                  k, beam, max_hops)
    return top, ids


def beam_search_gleanvec(q_views: jax.Array, tags: jax.Array,
                         x_low: jax.Array, graph: GraphIndex, k: int,
                         beam: int = 64, max_hops: int = 256):
    """Eager GleanVec scoring (Alg. 4): q_views (m, C, d), tags (n,)."""
    scorer = GleanVecScorer(x_low=x_low, tags=tags)
    top, ids, _, _ = _beam_qstate(q_views, scorer, graph, k, beam, max_hops)
    return top, ids


def beam_search_traced(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                       graph: GraphIndex, k: int, beam: int = 64,
                       max_hops: int = 256):
    """GleanVec search that also returns the per-hop expanded-vertex tag
    sequence (m, max_hops) -- the measurement behind Figure 7."""
    scorer = GleanVecScorer(x_low=x_low, tags=tags)
    return _beam_qstate(q_views, scorer, graph, k, beam, max_hops,
                        trace_tags=tags)
