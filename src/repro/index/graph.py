"""Vamana-style graph index: vectorized NN-descent build + RobustPrune
(numpy, offline) and a batched best-first beam search (JAX, online).

TPU adaptation (DESIGN.md section 2): the paper's CPU graph traversal is
memory-latency-bound with per-vector random fetches; here beams for a whole
query batch advance in lockstep, each hop popping the top-``expand``
unvisited frontier vertices (CAGRA-style multi-expansion; ``expand=1`` is
the classic best-first loop) and scoring their gathered
(batch, expand * R) neighbors with one MXU-friendly contraction --
~expand-fold fewer sequential ``while_loop`` iterations for the same
number of vertices scored. The scoring function is
pluggable so the same traversal serves plain LeanVec (q_low . x_low), eager
GleanVec (Alg. 4: per-tag query views) and int8-quantized databases.

The scoring function is the unified Scorer protocol
(:mod:`repro.core.scorer`): ``beam_search_scorer`` accepts any scorer and
scores each hop's gathered neighbor expansion with ``scorer.score_ids``, so
the same traversal serves plain LeanVec, eager GleanVec (Alg. 4), int8,
GleanVec∘int8 and the tag-sorted layouts (graph edges store ORIGINAL ids;
sorted scorers translate internally). The legacy per-representation entry
points are thin wrappers over it.

The traversal also (optionally) records the cluster tag of every expanded
vertex -- the data behind the paper's Figure 7 (tag access pattern favoring
eager execution).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scorer import GleanVecScorer, LinearScorer, batch_of
from repro.index.protocol import (_offset_ids, register_index_pytree,
                                  stacked_specs)
from repro.index.topk import NEG_INF

__all__ = ["GraphIndex", "build", "beam_search_scorer", "beam_search",
           "beam_search_gleanvec", "beam_search_traced"]


@dataclass(frozen=True, eq=False)
class GraphIndex:
    """Navigable graph implementing the Index protocol. ``beam`` /
    ``max_hops`` / ``expand`` are static search configuration for the
    protocol path (``candidates``); the explicit entry points accept
    overrides. ``expand`` is the CAGRA-style multi-expansion width: each
    hop pops the top-``expand`` unvisited frontier vertices and scores
    their (batch, expand*R) gathered neighbors in one contraction --
    ~expand-fold fewer ``while_loop`` iterations and expand-fold wider MXU
    work per hop; ``expand=1`` reproduces the classic best-first traversal
    exactly. Entries may be -1-padded (stacked per-shard graphs): padded
    slots are masked out of the initial beam."""

    neighbors: jax.Array  # (n, R) int32, -1 padded
    entries: jax.Array    # (E,) int32 entry points (medoid + per-cluster)
    beam: int = 64
    max_hops: int = 256
    expand: int = 1       # frontier vertices expanded per hop

    # ---- Index protocol ----------------------------------------------------

    def prepare_queries(self, scorer, queries: jax.Array):
        return scorer.prepare_queries(queries)

    def candidates(self, qstate, scorer, k: int):
        top, ids, _, _ = _beam_qstate(qstate, scorer, self, k, self.beam,
                                      self.max_hops, expand=self.expand)
        # -inf winners are unfilled beam slots (or streaming-dead rows a
        # scorer masked); strip their ids like the IVF path does.
        return top, jnp.where(top > NEG_INF, ids, -1)

    def search(self, queries: jax.Array, scorer, k: int):
        return self.candidates(self.prepare_queries(scorer, queries),
                               scorer, k)

    def shard_specs(self, axes):
        return stacked_specs(self, axes)

    def globalize_ids(self, scorer, ids: jax.Array, row_start) -> jax.Array:
        return _offset_ids(ids, row_start)

    def refreshed(self, scorer, model) -> "GraphIndex":
        """Streaming-refresh hook: the edge set was built from FULL-D
        geometry, which a projection refresh does not change -- the graph
        passes through unchanged. (Incremental edge insertion for grown
        databases is a ROADMAP follow-up; until then serve streams via
        flat or IVF traversals.)"""
        return self


register_index_pytree(GraphIndex, data_fields=("neighbors", "entries"),
                      static_fields=("beam", "max_hops", "expand"))


# ---------------------------------------------------------------------------
# Build (offline, numpy): NN-descent for candidates + RobustPrune for edges.
# ---------------------------------------------------------------------------


def _chunked_l2(x: np.ndarray, cand: np.ndarray, chunk: int = 2048):
    """d2[i, j] = ||x_i - x_cand[i, j]||^2, chunked over rows."""
    n, k = cand.shape
    out = np.empty((n, k), np.float32)
    x_sq = np.sum(x * x, axis=1)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        c = cand[s:e]
        diff_ip = np.einsum("bkd,bd->bk", x[c], x[s:e])
        out[s:e] = x_sq[c] - 2.0 * diff_ip + x_sq[s:e, None]
    return out


def _nn_descent(x: np.ndarray, r: int, n_iters: int, rng) -> np.ndarray:
    """Approximate 2R-NN lists via neighbor-of-neighbor refinement."""
    n = x.shape[0]
    k = 2 * r
    nbrs = rng.integers(0, n, size=(n, k), dtype=np.int64)
    self_ids = np.arange(n)[:, None]
    for it in range(n_iters):
        # candidates = current + neighbors-of-neighbors (sampled) + random
        nn = nbrs[nbrs[:, rng.permutation(k)[: max(2, k // 4)]]]
        nn = nn.reshape(n, -1)
        rand = rng.integers(0, n, size=(n, r // 2), dtype=np.int64)
        cand = np.concatenate([nbrs, nn, rand], axis=1)
        # dedupe by sorting; keep first occurrence (stable unique per row)
        cand.sort(axis=1)
        dup = np.concatenate(
            [np.zeros((n, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1)
        d2 = _chunked_l2(x, cand)
        d2[dup] = np.inf
        d2[cand == self_ids] = np.inf
        sel = np.argpartition(d2, k - 1, axis=1)[:, :k]
        nbrs = np.take_along_axis(cand, sel, axis=1)
        row_d = np.take_along_axis(d2, sel, axis=1)
        order = np.argsort(row_d, axis=1)
        nbrs = np.take_along_axis(nbrs, order, axis=1)
    return nbrs


def _robust_prune(x: np.ndarray, cand: np.ndarray, r: int, alpha: float,
                  chunk: int = 1024) -> np.ndarray:
    """Vamana RobustPrune, vectorized over nodes (inner loop over K slots).

    ``cand`` (n, K) sorted by distance ascending. Keeps <= r diverse edges:
    a candidate c survives iff for every previously kept edge e,
    alpha * d(e, c) >= d(p, c).
    """
    n, k = cand.shape
    out = np.full((n, r), -1, np.int64)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        c = cand[s:e]                        # (b, K) sorted by d(p, .)
        b = c.shape[0]
        vecs = x[c]                          # (b, K, D)
        # pairwise distances among candidates: (b, K, K)
        sq = np.sum(vecs * vecs, axis=2)
        pair = sq[:, :, None] - 2 * np.einsum("bkd,bld->bkl", vecs, vecs) \
            + sq[:, None, :]
        d_p = np.sum((vecs - x[s:e][:, None, :]) ** 2, axis=2)  # (b, K)
        kept = np.zeros((b, k), bool)
        pruned = np.zeros((b, k), bool)
        n_kept = np.zeros(b, np.int32)
        for j in range(k):
            take = (~pruned[:, j]) & (n_kept < r)
            kept[:, j] = take
            n_kept += take
            # prune later candidates too close to j (relative to p)
            closer = alpha * pair[:, j, :] < d_p
            pruned |= closer & take[:, None]
        for row in range(b):
            ids = c[row][kept[row]][:r]
            out[s + row, : len(ids)] = ids
    return out


def build(x: np.ndarray, r: int = 32, alpha: float = 1.2, n_iters: int = 6,
          n_random: int = 4, n_entries: int = 16, seed: int = 0
          ) -> GraphIndex:
    """Build a degree-(R + n_random) navigable graph over ``x``.

    Two connectivity safeguards beyond plain NN-descent (clustered data --
    e.g. the paper's multi-modal embeddings -- yields *disconnected* kNN
    graphs, on which greedy search provably stalls):
      * ``n_random`` NSW-style long-range out-edges appended per node;
      * ``n_entries`` search entry points: the medoid plus the database
        vectors nearest to spherical k-means centroids (the same clustering
        GleanVec uses), so every mixture component is reachable in one hop.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    cand = _nn_descent(x, r, n_iters, rng)          # (n, 2R) sorted
    nbrs = _robust_prune(x, cand, r, alpha)         # (n, R), -1 padded
    # add reverse edges where slots remain (improves connectivity)
    slots = np.sum(nbrs >= 0, axis=1)
    rev_src = nbrs.ravel()
    rev_dst = np.repeat(np.arange(n), r)
    ok = rev_src >= 0
    for srcv, dstv in zip(rev_src[ok], rev_dst[ok]):
        s = slots[srcv]
        if s < r and dstv != srcv:
            row = nbrs[srcv]
            if dstv not in row[:s]:
                nbrs[srcv, s] = dstv
                slots[srcv] += 1
    if n_random > 0:
        rand_edges = rng.integers(0, n, size=(n, n_random), dtype=np.int64)
        nbrs = np.concatenate([nbrs, rand_edges], axis=1)
    entries = [int(np.argmin(
        np.sum((x - x.mean(0, keepdims=True)) ** 2, axis=1)))]
    if n_entries > 1:
        import jax.random as jrandom
        from repro.core import spherical_kmeans
        km = spherical_kmeans.fit(jrandom.PRNGKey(seed), jnp.asarray(x),
                                  min(n_entries - 1, max(2, n // 64)),
                                  n_iters=10)
        x_unit = x / np.maximum(
            np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
        sims = x_unit @ np.asarray(km.centers).T
        entries.extend(int(i) for i in np.argmax(sims, axis=0))
    entries = np.unique(np.asarray(entries, np.int32))
    return GraphIndex(neighbors=jnp.asarray(nbrs.astype(np.int32)),
                      entries=jnp.asarray(entries))


# ---------------------------------------------------------------------------
# Search (online, JAX): batched best-first beam search.
# ---------------------------------------------------------------------------


def _beam_member_mask(ids: jax.Array, nbrs: jax.Array) -> jax.Array:
    """(batch, P) membership of ``nbrs`` in the per-row ``ids`` beam, via a
    per-row sort + searchsorted instead of the O(beam * P * beam) equality
    broadcast (P = expand * R; the broadcast was the per-hop memory peak)."""
    beam = ids.shape[1]
    sorted_ids = jnp.sort(ids, axis=1)
    pos = jax.vmap(jnp.searchsorted)(sorted_ids, nbrs)
    pos = jnp.clip(pos, 0, beam - 1)
    return jnp.take_along_axis(sorted_ids, pos, axis=1) == nbrs


def _mask_duplicate_nbrs(nbrs: jax.Array) -> jax.Array:
    """Set repeated ids within each row of ``nbrs`` to -1 (keep the first
    occurrence in sorted order). Multi-expansion hops gather overlapping
    neighborhoods; without this a vertex could hold several beam slots."""
    order = jnp.argsort(nbrs, axis=1)
    snb = jnp.take_along_axis(nbrs, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((nbrs.shape[0], 1), bool), snb[:, 1:] == snb[:, :-1]],
        axis=1)
    rows = jnp.arange(nbrs.shape[0])[:, None]
    dup = jnp.zeros(nbrs.shape, bool).at[rows, order].set(dup_sorted)
    return jnp.where(dup, -1, nbrs)


def _beam_loop(score_ids, graph: GraphIndex, batch: int, beam: int,
               max_hops: int, expand: int = 1,
               trace_tags: Optional[jax.Array] = None):
    """Shared traversal. ``score_ids(ids) -> (batch, k) scores`` for id >= 0.

    Each hop pops the top-``expand`` unvisited frontier vertices per query
    and scores their concatenated (batch, expand*R) neighbor gather in one
    contraction; ``expand=1`` is the classic best-first loop. Returns
    (scores, ids, n_hops, tag_trace) with tag_trace (batch, max_hops) = tag
    of the BEST vertex expanded at each hop (-1 = no hop), for Figure 7.
    """
    nbr_tbl = graph.neighbors
    r = nbr_tbl.shape[1]
    e = max(1, expand)
    assert e <= beam, "expand must not exceed the beam width"

    n_entry = graph.entries.shape[0]
    assert n_entry <= beam, "beam must hold all entry points"
    entry = jnp.broadcast_to(graph.entries[None, :], (batch, n_entry))
    # -1-padded entries (stacked per-shard graphs) never enter the beam
    e_scores = jnp.where(entry >= 0, score_ids(entry), NEG_INF)
    cand_ids = jnp.concatenate(
        [entry, jnp.full((batch, beam - n_entry), -1, jnp.int32)], axis=1)
    cand_scores = jnp.concatenate(
        [e_scores, jnp.full((batch, beam - n_entry), NEG_INF)], axis=1)
    visited = jnp.zeros((batch, beam), bool)
    tag_hist = jnp.full((batch, max_hops), -1, jnp.int32)

    def cond(state):
        _, scores, ids, visited, hop, _ = state
        expandable = (~visited) & (ids >= 0)
        return jnp.logical_and(hop < max_hops, jnp.any(expandable))

    def body(state):
        key_unused, scores, ids, visited, hop, tag_hist = state
        expandable = (~visited) & (ids >= 0)
        masked = jnp.where(expandable, scores, NEG_INF)
        _, best = jax.lax.top_k(masked, e)                     # (batch, e)
        rows = jnp.arange(batch)[:, None]
        # slots that actually hold expandable work (fewer than e frontier
        # vertices -> the overflow selections are no-ops)
        sel_ok = jnp.take_along_axis(expandable, best, axis=1)
        has_work = jnp.any(expandable, axis=1)
        if e == 1:      # exact classic semantics: gate on the row, not the
            sel_ok = has_work[:, None]  # slot (matches the argmax loop)
        best_ids = jnp.take_along_axis(ids, best, axis=1)      # (batch, e)
        visited = visited.at[rows, best].set(
            jnp.take_along_axis(visited, best, axis=1) | sel_ok)
        # expand: gather the chosen vertices' neighbors in one batch
        nbrs = nbr_tbl[jnp.where(best_ids >= 0, best_ids, 0)]  # (b, e, R)
        nbrs = jnp.where((nbrs >= 0) & sel_ok[:, :, None], nbrs, -1)
        nbrs = nbrs.reshape(batch, e * r)
        if e > 1:       # overlapping neighborhoods: drop within-hop dups
            nbrs = _mask_duplicate_nbrs(nbrs)
        nscores = score_ids(nbrs)
        nscores = jnp.where(nbrs >= 0, nscores, NEG_INF)
        # dedupe against the current beam (sort-based membership)
        present = _beam_member_mask(ids, nbrs)
        nscores = jnp.where(present, NEG_INF, nscores)
        # merge and keep top-beam
        all_scores = jnp.concatenate([scores, nscores], axis=1)
        all_ids = jnp.concatenate([ids, nbrs], axis=1)
        all_vis = jnp.concatenate(
            [visited, jnp.zeros((batch, e * r), bool)], axis=1)
        top_scores, sel = jax.lax.top_k(all_scores, beam)
        top_ids = jnp.take_along_axis(all_ids, sel, axis=1)
        top_vis = jnp.take_along_axis(all_vis, sel, axis=1)
        if trace_tags is not None:
            first = best_ids[:, 0]
            tag = jnp.where(first >= 0,
                            trace_tags[jnp.where(first >= 0, first, 0)],
                            -1)
            tag = jnp.where(has_work, tag, -1)
            tag_hist = tag_hist.at[:, hop].set(tag)
        return (key_unused, top_scores, top_ids, top_vis, hop + 1, tag_hist)

    state = (jnp.zeros(()), cand_scores, cand_ids, visited,
             jnp.zeros((), jnp.int32), tag_hist)
    state = jax.lax.while_loop(cond, body, state)
    _, scores, ids, _, hops, tag_hist = state
    return scores, ids, hops, tag_hist


@functools.partial(jax.jit, static_argnames=("k", "beam", "max_hops",
                                             "expand"))
def _beam_qstate(qstate, scorer, graph: GraphIndex, k: int, beam: int,
                 max_hops: int, expand: int = 1,
                 trace_tags: Optional[jax.Array] = None):
    """Traversal over any scorer with prepared queries ``qstate``."""
    m = batch_of(qstate)

    def score_ids(ids):
        safe = jnp.where(ids >= 0, ids, 0)
        return scorer.score_ids(qstate, safe)

    scores, ids, hops, tag_hist = _beam_loop(score_ids, graph, m, beam,
                                             max_hops, expand=expand,
                                             trace_tags=trace_tags)
    top, sel = jax.lax.top_k(scores, k)
    return top, jnp.take_along_axis(ids, sel, axis=1), hops, tag_hist


def beam_search_scorer(queries: jax.Array, scorer, graph: GraphIndex,
                       k: int, beam: int = 64, max_hops: int = 256,
                       expand: int = 1, trace: bool = False):
    """Unified-protocol beam search: ``queries (m, D)`` full-dimension.

    ``expand`` pops that many frontier vertices per hop (multi-expansion);
    1 is the classic best-first traversal. With ``trace=True`` additionally
    returns (n_hops, (m, max_hops) tag trace) -- requires a scorer with
    ``tags`` (Figure 7 measurement).
    """
    qstate = scorer.prepare_queries(queries)
    trace_tags = getattr(scorer, "tags", None) if trace else None
    if trace and trace_tags is None:
        raise ValueError("trace=True needs a tagged scorer (GleanVec*)")
    top, ids, hops, tag_hist = _beam_qstate(qstate, scorer, graph, k, beam,
                                            max_hops, expand=expand,
                                            trace_tags=trace_tags)
    if trace:
        return top, ids, hops, tag_hist
    return top, ids


def beam_search(q_low: jax.Array, x_low: jax.Array, graph: GraphIndex,
                k: int, beam: int = 64, max_hops: int = 256):
    """Linear scoring: q_low (m, d), x_low (n, d) -> ids (m, k)."""
    top, ids, _, _ = _beam_qstate(q_low, LinearScorer(x_low=x_low), graph,
                                  k, beam, max_hops)
    return top, ids


def beam_search_gleanvec(q_views: jax.Array, tags: jax.Array,
                         x_low: jax.Array, graph: GraphIndex, k: int,
                         beam: int = 64, max_hops: int = 256):
    """Eager GleanVec scoring (Alg. 4): q_views (m, C, d), tags (n,)."""
    scorer = GleanVecScorer(x_low=x_low, tags=tags)
    top, ids, _, _ = _beam_qstate(q_views, scorer, graph, k, beam, max_hops)
    return top, ids


def beam_search_traced(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                       graph: GraphIndex, k: int, beam: int = 64,
                       max_hops: int = 256):
    """GleanVec search that also returns the per-hop expanded-vertex tag
    sequence (m, max_hops) -- the measurement behind Figure 7."""
    scorer = GleanVecScorer(x_low=x_low, tags=tags)
    return _beam_qstate(q_views, scorer, graph, k, beam, max_hops,
                        trace_tags=tags)
