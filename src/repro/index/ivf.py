"""IVF (inverted-file) index with padded posting lists (JAX-friendly).

Coarse quantizer = spherical k-means centers (reused from the paper's
Appendix A implementation). Lists are stored as one permutation array plus
offsets; search gathers ``nprobe`` padded lists and scores them in one
contraction, so the whole query batch stays on the MXU.

Fine scoring goes through the unified Scorer protocol
(:mod:`repro.core.scorer`): ``search_scorer`` accepts any scorer (linear,
eager GleanVec, int8, GleanVec∘int8, and the tag-sorted layouts) and scores
the gathered posting lists with ``scorer.score_ids`` -- tag gathers,
dequant-free int8 dots and sorted-layout id translation come with the
scorer, not with this index: posting lists always store ORIGINAL ids. The
coarse probe always runs in the full dimension (the centers live in R^D).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spherical_kmeans
from repro.core.scorer import LinearScorer
from repro.index.topk import NEG_INF

__all__ = ["IVFIndex", "build", "search", "search_scorer"]


class IVFIndex(NamedTuple):
    centers: jax.Array    # (C, D) coarse centroids (unit rows)
    lists: jax.Array      # (C, max_len) int32 vector ids, -1 padded
    max_len: int


def build(key, x, n_lists: int, n_iters: int = 20) -> IVFIndex:
    """Cluster and bucket the database (host-side list packing)."""
    km = spherical_kmeans.fit(key, x, n_lists, n_iters)
    x_unit = spherical_kmeans.normalize_rows(jnp.asarray(x, jnp.float32))
    tags = np.asarray(spherical_kmeans.assign(x_unit, km.centers))
    buckets = [np.where(tags == c)[0] for c in range(n_lists)]
    max_len = max(1, max(len(b) for b in buckets))
    lists = np.full((n_lists, max_len), -1, np.int32)
    for c, b in enumerate(buckets):
        lists[c, : len(b)] = b
    return IVFIndex(centers=km.centers, lists=jnp.asarray(lists),
                    max_len=max_len)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _probe_and_score(q_coarse: jax.Array, qstate, scorer, index: IVFIndex,
                     k: int, nprobe: int):
    """Probe ``nprobe`` lists per query, score candidates via the scorer."""
    m = q_coarse.shape[0]
    coarse = q_coarse @ index.centers.T                     # (m, C)
    _, probe = jax.lax.top_k(coarse, nprobe)                # (m, nprobe)
    cand = index.lists[probe].reshape(m, -1)                # (m, nprobe*L)
    safe = jnp.where(cand >= 0, cand, 0)
    scores = scorer.score_ids(qstate, safe)                 # (m, nprobe*L)
    scores = jnp.where(cand >= 0, scores, NEG_INF)
    vals, sel = jax.lax.top_k(scores, k)
    return vals, jnp.take_along_axis(cand, sel, axis=1)


def search_scorer(queries: jax.Array, scorer, index: IVFIndex, k: int,
                  nprobe: int = 8):
    """Unified-protocol search: ``queries (m, D)`` in the FULL dimension.

    The coarse step scores ``queries`` against the R^D centers; the fine
    step scores ``scorer.prepare_queries(queries)`` against the gathered
    posting lists through any scorer. Returns (vals, ids): (m, k).
    """
    q_coarse = queries.astype(jnp.float32)
    return _probe_and_score(q_coarse, scorer.prepare_queries(queries),
                            scorer, index, k, nprobe)


def search(q_low: jax.Array, q_full: jax.Array, x_low: jax.Array,
           index: IVFIndex, k: int, nprobe: int = 8):
    """Legacy linear entry point: pre-reduced ``q_low`` + raw ``x_low``."""
    return _probe_and_score(q_full, q_low, LinearScorer(x_low=x_low), index,
                            k, nprobe)
