"""IVF (inverted-file) index with padded posting lists (JAX-friendly).

Coarse quantizer = spherical k-means centers (reused from the paper's
Appendix A implementation). Lists are stored as one permutation array plus
offsets; search gathers ``nprobe`` padded lists and scores them in one
contraction, so the whole query batch stays on the MXU.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spherical_kmeans
from repro.index.topk import NEG_INF

__all__ = ["IVFIndex", "build", "search"]


class IVFIndex(NamedTuple):
    centers: jax.Array    # (C, D) coarse centroids (unit rows)
    lists: jax.Array      # (C, max_len) int32 vector ids, -1 padded
    max_len: int


def build(key, x, n_lists: int, n_iters: int = 20) -> IVFIndex:
    """Cluster and bucket the database (host-side list packing)."""
    km = spherical_kmeans.fit(key, x, n_lists, n_iters)
    x_unit = spherical_kmeans.normalize_rows(jnp.asarray(x, jnp.float32))
    tags = np.asarray(spherical_kmeans.assign(x_unit, km.centers))
    buckets = [np.where(tags == c)[0] for c in range(n_lists)]
    max_len = max(1, max(len(b) for b in buckets))
    lists = np.full((n_lists, max_len), -1, np.int32)
    for c, b in enumerate(buckets):
        lists[c, : len(b)] = b
    return IVFIndex(centers=km.centers, lists=jnp.asarray(lists),
                    max_len=max_len)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def search(q_low: jax.Array, q_full: jax.Array, x_low: jax.Array,
           index: IVFIndex, k: int, nprobe: int = 8):
    """Probe ``nprobe`` lists per query; score candidates in reduced space.

    ``q_full`` (m, D) selects the lists (coarse step runs in full dim, as the
    coarse centers live in R^D); ``q_low`` (m, d) scores candidates against
    ``x_low`` (n, d). Returns (vals, ids): (m, k).
    """
    m = q_low.shape[0]
    coarse = q_full @ index.centers.T                       # (m, C)
    _, probe = jax.lax.top_k(coarse, nprobe)                # (m, nprobe)
    cand = index.lists[probe].reshape(m, -1)                # (m, nprobe*L)
    safe = jnp.where(cand >= 0, cand, 0)
    vecs = x_low[safe]                                      # (m, P, d)
    scores = jnp.einsum("mpd,md->mp", vecs, q_low)
    scores = jnp.where(cand >= 0, scores, NEG_INF)
    vals, sel = jax.lax.top_k(scores, k)
    return vals, jnp.take_along_axis(cand, sel, axis=1)
