"""IVF (inverted-file) index with padded posting lists (JAX-friendly).

Coarse quantizer = spherical k-means centers (reused from the paper's
Appendix A implementation). Lists are stored as one permutation array plus
offsets; search gathers ``nprobe`` padded lists and scores them in one
contraction, so the whole query batch stays on the MXU.

``IVFIndex`` implements the Index protocol (:mod:`repro.index.protocol`):
fine scoring goes through the unified Scorer protocol
(:mod:`repro.core.scorer`) -- ``candidates`` scores the gathered posting
lists with ``scorer.score_ids``, so tag gathers, dequant-free int8 dots and
sorted-layout id translation come with the scorer, not with this index:
posting lists always store ORIGINAL ids.

The coarse probe has two modes. By default the centers live in R^D and the
probe scores the raw queries against them (D*4 bytes per center per
query-batch sweep). :func:`with_reduced_centers` projects the centers into
the scorer's reduced space at build time (``scorer.encode_centers``): the
probe then consumes the scorer's ALREADY-PREPARED queries and touches d
bytes per center instead of D -- the coarse step inherits the paper's D/d
bandwidth cut and needs no full-D query anywhere in the search.

The FINE step has two modes too. The default gathers the probed posting
lists and scores them with ``scorer.score_ids`` -- per-row gathers that
work for every scorer family. When the coarse quantizer is ALIGNED with a
tag-sorted scorer's clustering (:func:`build_aligned`: the centers are the
GleanVec model's landmarks, so posting list c == cluster c == a contiguous
run of single-tag blocks), ``candidates`` instead dispatches to the
scorer's gather-free ``scan_lists`` (``kernels/ivf_scan``): the probed
clusters' slabs stream through the fused single-tag kernel with a running
top-k in VMEM, no ``(m, nprobe*L)`` candidate-id or score matrix ever
reaches HBM, and the posting lists themselves are never read (they are
kept only so streaming ``insert_ids`` / ``remove_ids`` stay available).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spherical_kmeans
from repro.core.scorer import LinearScorer
from repro.index.protocol import (_offset_ids, register_index_pytree,
                                  replace, stacked_specs)
from repro.index.topk import NEG_INF

__all__ = ["IVFIndex", "IVFQueryState", "build", "build_sharded",
           "build_aligned", "build_aligned_sharded",
           "with_reduced_centers", "with_list_slack", "insert_ids",
           "remove_ids", "coarse_scores", "search", "search_scorer"]


class IVFQueryState(NamedTuple):
    """Prepared IVF query state: the scorer's qstate for fine scoring plus
    the full-D queries for the coarse probe -- ``q_coarse`` is None when
    the index carries reduced-space centers (the probe then reuses
    ``qstate``, so the full-D queries are never needed after prepare)."""

    qstate: Any
    q_coarse: Optional[jax.Array]


@dataclass(frozen=True, eq=False)
class IVFIndex:
    """Inverted-file index. ``center_scorer`` (optional) is a companion
    scorer over the C centers in the fine scorer's reduced representation;
    ``nprobe`` is static protocol-search configuration (override per call
    via :func:`search_scorer` or ``dataclasses.replace``). With
    ``aligned_layout`` (set by :func:`build_aligned`) the coarse clusters
    ARE the scorer's GleanVec clusters and ``candidates`` takes the
    gather-free range-scan path for sorted scorers."""

    centers: jax.Array                    # (C, D) coarse centroids (unit)
    lists: jax.Array                      # (C, max_len) int32 ids, -1 pad
    center_scorer: Any = None             # reduced-space probe companion
    nprobe: int = 8
    aligned_layout: bool = False          # clusters == sorted-layout tags

    @property
    def n_lists(self) -> int:
        return self.centers.shape[0]

    @property
    def max_len(self) -> int:
        return self.lists.shape[1]

    # ---- Index protocol ----------------------------------------------------

    def prepare_queries(self, scorer, queries: jax.Array) -> IVFQueryState:
        q_coarse = (queries.astype(jnp.float32)
                    if self.center_scorer is None else None)
        return IVFQueryState(qstate=scorer.prepare_queries(queries),
                             q_coarse=q_coarse)

    def candidates(self, qstate: IVFQueryState, scorer, k: int):
        if self.aligned_layout and \
                getattr(scorer, "list_block_ranges", None) is not None:
            return _probe_and_scan(qstate, scorer, self, k)
        return _probe_and_score(qstate, scorer, self, k)

    def search(self, queries: jax.Array, scorer, k: int):
        return self.candidates(self.prepare_queries(scorer, queries),
                               scorer, k)

    def shard_specs(self, axes):
        return stacked_specs(self, axes)

    def globalize_ids(self, scorer, ids: jax.Array, row_start) -> jax.Array:
        return _offset_ids(ids, row_start)

    def refreshed(self, scorer, model) -> "IVFIndex":
        """Streaming-refresh hook: the reduced-space center companion was
        derived from the OLD model's projections, so re-encode it under
        the refreshed scorer/model (same treedef: ``encode_centers``
        returns the same companion class with the same shapes)."""
        if self.center_scorer is None:
            return self
        return replace(self,
                       center_scorer=scorer.encode_centers(self.centers,
                                                           model))


register_index_pytree(IVFIndex,
                      data_fields=("centers", "lists", "center_scorer"),
                      static_fields=("nprobe", "aligned_layout"))


# ---------------------------------------------------------------------------
# Build (host-side list packing, vectorized).
# ---------------------------------------------------------------------------


def _pack_lists(tags: np.ndarray, n_lists: int,
                min_len: int = 1) -> np.ndarray:
    """Bucket row ids by tag into a (n_lists, max_len) -1-padded table.

    One argsort + bincount pass (no per-list ``np.where`` sweep -- the
    O(C * n) packing dominated build time at C >= 4k lists)."""
    n = tags.shape[0]
    counts = np.bincount(tags, minlength=n_lists)
    max_len = max(min_len, int(counts.max()) if n else min_len)
    order = np.argsort(tags, kind="stable")
    starts = np.zeros(n_lists, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    rank = np.arange(n) - starts[tags[order]]     # within-list slot
    lists = np.full((n_lists, max_len), -1, np.int32)
    lists[tags[order], rank] = order
    return lists


def _fit_and_tag(key, x, n_lists: int, n_iters: int):
    km = spherical_kmeans.fit(key, x, n_lists, n_iters)
    x_unit = spherical_kmeans.normalize_rows(jnp.asarray(x, jnp.float32))
    tags = np.asarray(spherical_kmeans.assign(x_unit, km.centers))
    return km.centers, tags


def build(key, x, n_lists: int, n_iters: int = 20,
          nprobe: int = 8) -> IVFIndex:
    """Cluster and bucket the database (host-side list packing)."""
    centers, tags = _fit_and_tag(key, x, n_lists, n_iters)
    return IVFIndex(centers=centers,
                    lists=jnp.asarray(_pack_lists(tags, n_lists)),
                    nprobe=nprobe)


def build_sharded(key, x, n_lists: int, n_shards: int, n_iters: int = 20,
                  nprobe: int = 8):
    """Row-sharded IVF: ONE coarse quantizer fit on the full database
    (identical to :func:`build` with the same key), per-shard posting
    lists over each shard's row range in LOCAL ids.

    Because every shard replicates the centers, each shard probes exactly
    the globally-top-``nprobe`` lists; the union of per-shard candidates
    is then precisely the single-device candidate set, which makes the
    all-gather merge of :class:`repro.index.distributed.ShardedIndex`
    return identical results. Lists are padded to a common ``max_len`` so
    the per-shard tables stack. Returns a list of ``n_shards`` IVFIndex.
    """
    n = jnp.asarray(x).shape[0]
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    per = n // n_shards
    centers, tags = _fit_and_tag(key, x, n_lists, n_iters)
    packed = [_pack_lists(tags[s * per:(s + 1) * per], n_lists)
              for s in range(n_shards)]
    max_len = max(p.shape[1] for p in packed)
    packed = [np.pad(p, ((0, 0), (0, max_len - p.shape[1])),
                     constant_values=-1) for p in packed]
    return [IVFIndex(centers=centers, lists=jnp.asarray(p), nprobe=nprobe)
            for p in packed]


def build_aligned(model, database, nprobe: int = 8) -> IVFIndex:
    """IVF whose coarse quantizer IS the GleanVec model's clustering.

    The centers are the model's k-means landmarks, so posting list ``c``
    holds exactly the rows a tag-sorted scorer stores in cluster ``c``'s
    contiguous single-tag blocks -- the precondition for the gather-free
    range-scan fine step (``scorer.scan_lists``, dispatched automatically
    by ``candidates``). The packed lists are kept ONLY for streaming
    ``insert_ids`` / ``remove_ids`` and for non-sorted scorers; the fused
    serving path never reads them."""
    x_unit = spherical_kmeans.normalize_rows(
        jnp.asarray(database, jnp.float32))
    tags = np.asarray(spherical_kmeans.assign(x_unit, model.centers))
    return IVFIndex(centers=jnp.asarray(model.centers, jnp.float32),
                    lists=jnp.asarray(_pack_lists(tags, model.n_clusters)),
                    nprobe=min(nprobe, model.n_clusters),
                    aligned_layout=True)


def build_aligned_sharded(model, database, n_shards: int,
                          nprobe: int = 8):
    """Per-shard :func:`build_aligned`: one shared coarse quantizer (the
    model's landmarks), per-shard posting lists in LOCAL row ids, padded to
    a common ``max_len`` so the tables stack under ``ShardedIndex``."""
    X = jnp.asarray(database, jnp.float32)
    n = X.shape[0]
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    per = n // n_shards
    x_unit = spherical_kmeans.normalize_rows(X)
    tags = np.asarray(spherical_kmeans.assign(x_unit, model.centers))
    packed = [_pack_lists(tags[s * per:(s + 1) * per], model.n_clusters)
              for s in range(n_shards)]
    max_len = max(p.shape[1] for p in packed)
    packed = [np.pad(p, ((0, 0), (0, max_len - p.shape[1])),
                     constant_values=-1) for p in packed]
    return [IVFIndex(centers=jnp.asarray(model.centers, jnp.float32),
                     lists=jnp.asarray(p),
                     nprobe=min(nprobe, model.n_clusters),
                     aligned_layout=True) for p in packed]


def with_reduced_centers(index: IVFIndex, scorer, model=None) -> IVFIndex:
    """Project the coarse centers into ``scorer``'s reduced space: the
    probe will consume the scorer's prepared queries (R^d) instead of the
    raw full-D queries -- D/d less HBM traffic in the coarse step."""
    return replace(index,
                   center_scorer=scorer.encode_centers(index.centers,
                                                       model))


def with_list_slack(index: IVFIndex, extra: int) -> IVFIndex:
    """Widen every posting list by ``extra`` -1 slots (build-time only --
    this CHANGES the lists' shape). Streaming serving pre-allocates the
    slack here so later :func:`insert_ids` calls never reshape the index
    under a compiled engine.

    ``extra`` is PER LIST and sets the probe's gather width for the whole
    run: size it to the expected per-list fill (plus skew headroom), not
    the total insert count."""
    lists = jnp.pad(index.lists, ((0, 0), (0, extra)), constant_values=-1)
    return replace(index, lists=lists)


def insert_ids(index: IVFIndex, vecs: jax.Array, ids) -> IVFIndex:
    """Append external ``ids`` (with full-D ``vecs``) to their nearest
    centers' posting lists, filling pre-allocated -1 slots (host-side;
    shape-preserving). Raises when a list is out of slack.

    One argsort/bincount slot-assignment pass like ``_pack_lists`` -- no
    per-insert ``np.nonzero`` scan over the slot table (that loop was
    O(inserts * max_len) and dominated streaming cycles at wide slack)."""
    x_unit = spherical_kmeans.normalize_rows(jnp.asarray(vecs, jnp.float32))
    tags = np.asarray(spherical_kmeans.assign(x_unit, index.centers))
    ids_np = np.asarray(ids)
    lists = np.asarray(index.lists).copy()
    free = lists < 0                                    # (C, max_len)
    need = np.bincount(tags, minlength=lists.shape[0])
    short = np.nonzero(need > free.sum(axis=1))[0]
    if short.size:
        raise ValueError(
            f"posting list {int(short[0])} is full; pre-allocate slack "
            "with with_list_slack before serving streams")
    # slot_of_rank[t, r] = column of list t's r-th free slot; each insert's
    # within-list rank comes from the same argsort/cumsum bucketing as
    # _pack_lists, so the fill order matches the sequential reference.
    frank = np.cumsum(free, axis=1) - 1
    slot_of_rank = np.zeros_like(lists)
    rows_f, cols_f = np.nonzero(free)
    slot_of_rank[rows_f, frank[rows_f, cols_f]] = cols_f
    order = np.argsort(tags, kind="stable")
    starts = np.zeros(lists.shape[0], np.int64)
    starts[1:] = np.cumsum(need)[:-1]
    rank = np.arange(tags.size) - starts[tags[order]]
    lists[tags[order], slot_of_rank[tags[order], rank]] = \
        ids_np[order].astype(lists.dtype)
    return replace(index, lists=jnp.asarray(lists))


def remove_ids(index: IVFIndex, ids) -> IVFIndex:
    """Drop external ``ids`` from every posting list (slots return to the
    -1 free pool; shape-preserving)."""
    lists = np.asarray(index.lists).copy()
    lists[np.isin(lists, np.asarray(ids))] = -1
    return replace(index, lists=jnp.asarray(lists))


# ---------------------------------------------------------------------------
# Search.
# ---------------------------------------------------------------------------


def coarse_scores(index: IVFIndex, qstate: IVFQueryState) -> jax.Array:
    """(m, C) query-center scores: full-D when the index has no reduced
    centers, else one reduced-space ``score_block`` over all C centers
    (this is the function the probe-bandwidth assertion compiles)."""
    if index.center_scorer is None:
        return qstate.q_coarse @ index.centers.T
    return index.center_scorer.score_block(qstate.qstate, 0, index.n_lists)


@functools.partial(jax.jit, static_argnames=("k",))
def _probe_and_scan(qstate: IVFQueryState, scorer, index: IVFIndex,
                    k: int):
    """Aligned fine step: probe ``nprobe`` clusters, stream their sorted
    slabs through the scorer's gather-free ``scan_lists``. ``index.lists``
    is never read (XLA drops the unused leaf), so the posting-list HBM
    footprint vanishes from the compiled sorted serving path."""
    coarse = coarse_scores(index, qstate)                   # (m, C)
    _, probe = jax.lax.top_k(coarse, index.nprobe)          # (m, nprobe)
    return scorer.scan_lists(qstate.qstate, probe, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _probe_and_score(qstate: IVFQueryState, scorer, index: IVFIndex,
                     k: int):
    """Probe ``index.nprobe`` lists per query, score via the scorer."""
    m = jax.tree_util.tree_leaves(qstate.qstate)[0].shape[0]
    coarse = coarse_scores(index, qstate)                   # (m, C)
    _, probe = jax.lax.top_k(coarse, index.nprobe)          # (m, nprobe)
    cand = index.lists[probe].reshape(m, -1)                # (m, nprobe*L)
    safe = jnp.where(cand >= 0, cand, 0)
    scores = scorer.score_ids(qstate.qstate, safe)          # (m, nprobe*L)
    scores = jnp.where(cand >= 0, scores, NEG_INF)
    vals, sel = jax.lax.top_k(scores, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    # -inf winners are padding slots or tombstoned (dead) rows a streaming
    # store masked; strip their ids so the rerank never resurrects them.
    return vals, jnp.where(vals > NEG_INF, ids, -1)


def search_scorer(queries: jax.Array, scorer, index: IVFIndex, k: int,
                  nprobe: int = 8):
    """Unified-protocol search: ``queries (m, D)`` in the FULL dimension.

    The coarse step scores the centers in R^D (or in R^d through the
    index's reduced centers); the fine step scores the gathered posting
    lists through any scorer. Returns (vals, ids): (m, k).
    """
    return replace(index, nprobe=nprobe).search(queries, scorer, k)


def search(q_low: jax.Array, q_full: jax.Array, x_low: jax.Array,
           index: IVFIndex, k: int, nprobe: int = 8):
    """Legacy linear entry point: pre-reduced ``q_low`` + raw ``x_low``.

    Always probes in FULL dimension: a reduced-centers companion is built
    for a specific scorer family's qstate, and this signature gives no way
    to know that ``q_low`` matches it -- use :func:`search_scorer` (or the
    Index protocol) for reduced-space probing."""
    qstate = IVFQueryState(qstate=q_low,
                           q_coarse=q_full.astype(jnp.float32))
    return _probe_and_score(qstate, LinearScorer(x_low=x_low),
                            replace(index, nprobe=nprobe,
                                    center_scorer=None), k)
