"""The Index protocol: one traversal contract, every index, any scorer.

The Scorer protocol (:mod:`repro.core.scorer`) made the database
*representation* pluggable; this module does the same for the database
*traversal*. An index is a pytree (its arrays are jit/shard_map arguments;
its configuration -- scan block, nprobe, beam width -- is static treedef
metadata) implementing:

    qstate = index.prepare_queries(scorer, queries)   # index-specific state
    vals, ids = index.candidates(qstate, scorer, k)   # main-search step
    vals, ids = index.search(queries, scorer, k)      # prepare + candidates
    index.shard_specs(axes)                           # PartitionSpec tree
    index.globalize_ids(scorer, ids, row_start)       # local -> global ids
    index.refreshed(scorer, model)                    # streaming refresh

``refreshed(scorer, model)`` is the streaming-refresh hook (Section 3.2):
after the scorer's representation is re-encoded under a refreshed model,
an index re-derives whatever it computed FROM that representation (the IVF
reduced-space center companion) and returns a same-treedef copy; indexes
with no derived state return themselves. The hook keeps the serving
engine's zero-recompile swap invariant: same pytree structure, same leaf
shapes.

``prepare_queries`` wraps ``scorer.prepare_queries`` plus whatever extra
query state the traversal needs (the IVF coarse probe keeps the full-D
queries only when its centers have NOT been projected into the reduced
space). ``candidates`` returns (m, k) (score, id) pairs with ids in the
scorer's EXTERNAL (original database) id space -- every index consumes
``scorer.score_block`` / ``scorer.score_ids`` and inherits the Scorer
protocol's id-translation contract, so index choice, scorer choice and
placement compose freely with no isinstance dispatch.

The id-globalization contract (index side): when an index is one shard of
a :class:`repro.index.distributed.ShardedIndex`, its whole database is the
row range ``[row_start, row_start + n_local)`` of the global database and
every id it emits is local. ``globalize_ids(scorer, ids, row_start)``
lifts those to global original ids (uniformly ``ids + row_start``;
padding/-1 slots stay -1). This is distinct from the *scorer-level*
``scorer.globalize_ids(ids, shard_idx)`` contract used by the flat
global-build-then-row-shard path (:func:`make_sharded_search_scorer`),
where a globally-built sorted scorer already emits global ids.

Implementations: :class:`FlatIndex` (here), :class:`repro.index.ivf.IVFIndex`,
:class:`repro.index.graph.GraphIndex`, and the placement wrapper
:class:`repro.index.distributed.ShardedIndex` which shard_maps ANY of them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["register_index_pytree", "FlatIndex", "replace"]

replace = dataclasses.replace


def register_index_pytree(cls, data_fields, static_fields):
    """Register ``cls`` as a jax pytree whose ``data_fields`` are children
    (arrays / sub-pytrees) and whose ``static_fields`` are hashable aux
    data baked into the treedef -- so ints like ``nprobe`` or ``beam``
    stay static under jit instead of becoming traced leaves."""

    def flatten(obj):
        return ([getattr(obj, f) for f in data_fields],
                tuple(getattr(obj, f) for f in static_fields))

    def unflatten(aux, children):
        return cls(**dict(zip(data_fields, children)),
                   **dict(zip(static_fields, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def stacked_specs(tree, axes):
    """PartitionSpec tree sharding every array leaf of a per-shard-stacked
    pytree along its leading (shard) dimension."""
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(axes))
    return jax.tree_util.tree_map(lambda _: spec, tree)


def _offset_ids(ids: jax.Array, row_start) -> jax.Array:
    """Uniform local -> global id lift; -1 (padding / unfilled) stays -1."""
    return jnp.where(ids >= 0, ids + row_start, -1)


@dataclass(frozen=True, eq=False)
class FlatIndex:
    """Exhaustive blocked scan: the index with no structure.

    ``candidates`` is :func:`repro.index.bruteforce.scan_scorer` -- the one
    blocked top-k every scorer supports. ``block`` is static (scorers with
    a fixed internal layout override it via ``layout_block``)."""

    block: int = 4096

    def prepare_queries(self, scorer, queries):
        return scorer.prepare_queries(queries)

    def candidates(self, qstate, scorer, k: int):
        from repro.index import bruteforce
        return bruteforce.scan_scorer(scorer, qstate, k, self.block)

    def search(self, queries, scorer, k: int):
        return self.candidates(self.prepare_queries(scorer, queries),
                               scorer, k)

    def shard_specs(self, axes):
        return stacked_specs(self, axes)    # no array leaves: empty tree

    def globalize_ids(self, scorer, ids, row_start):
        return _offset_ids(ids, row_start)

    def refreshed(self, scorer, model):
        return self         # no state derived from the representation


register_index_pytree(FlatIndex, data_fields=(), static_fields=("block",))
