"""Top-k utilities: blocked scans and (value, id) merge operations.

These bound the peak memory of brute-force scoring (the paper's Algorithm 1
main search over X_low) to one (m, block) tile at a time, mirroring the VMEM
tiling of the ``ip_topk`` Pallas kernel.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["merge_topk", "blocked_topk", "NEG_INF"]

NEG_INF = jnp.float32(-3.4e38)


def merge_topk(val_a, id_a, val_b, id_b, k: int):
    """Merge two (batch, *) candidate sets into the joint top-k."""
    vals = jnp.concatenate([val_a, val_b], axis=-1)
    ids = jnp.concatenate([id_a, id_b], axis=-1)
    top_vals, sel = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(ids, sel, axis=-1)


@functools.partial(jax.jit, static_argnames=("score_block_fn", "n", "k",
                                             "block", "batch"))
def blocked_topk(score_block_fn: Callable, n: int, k: int, block: int,
                 batch: int):
    """Running top-k over ``n`` database items scored block-by-block.

    ``score_block_fn(start) -> (batch, block)`` scores for ids
    [start, start+block). Scores for ids >= n must already be -inf-masked by
    the caller (or n % block == 0).
    Returns (values, ids): (batch, k) each.
    """
    n_blocks = -(-n // block)

    def body(carry, i):
        best_v, best_i = carry
        start = i * block
        scores = score_block_fn(start)
        ids = start + jax.lax.broadcasted_iota(jnp.int32, (batch, block), 1)
        valid = ids < n
        scores = jnp.where(valid, scores, NEG_INF)
        best_v, best_i = merge_topk(best_v, best_i, scores, ids, k)
        return (best_v, best_i), None

    init = (jnp.full((batch, k), NEG_INF),
            jnp.full((batch, k), -1, jnp.int32))
    (vals, ids), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    return vals, ids
