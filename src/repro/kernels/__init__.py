"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (dispatching jit wrapper) and ref.py (pure-jnp oracle used by tests
and as the differentiable/CPU fallback).
"""
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.gleanvec_ip import gleanvec_ip, gleanvec_ip_ref
from repro.kernels.ip_topk import ip_topk, ip_topk_ref
from repro.kernels.kmeans_assign import kmeans_assign, kmeans_assign_ref
from repro.kernels.sq_dot import sq_dot, sq_dot_ref

__all__ = [
    "flash_attention", "flash_attention_ref",
    "gleanvec_ip", "gleanvec_ip_ref",
    "ip_topk", "ip_topk_ref",
    "kmeans_assign", "kmeans_assign_ref",
    "sq_dot", "sq_dot_ref",
]
