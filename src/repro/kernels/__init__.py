"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (dispatching jit wrapper) and ref.py (pure-jnp oracle used by tests
and as the differentiable/CPU fallback).

This module is also the SINGLE place where a Scorer
(:mod:`repro.core.scorer`) lowers to its kernel: ``scorer_scores`` /
``scorer_topk`` map each protocol implementation to the matching Pallas
kernel on TPU (``ip_topk`` / ``gleanvec_ip`` / ``sq_dot``) and to the jnp
mirrors elsewhere. Index code never mentions kernels; it talks to scorers,
and scorers lower here.
"""
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.gleanvec_ip import gleanvec_ip, gleanvec_ip_ref
from repro.kernels.ip_topk import ip_topk, ip_topk_ref
from repro.kernels.kmeans_assign import kmeans_assign, kmeans_assign_ref
from repro.kernels.sq_dot import sq_dot, sq_dot_ref

__all__ = [
    "flash_attention", "flash_attention_ref",
    "gleanvec_ip", "gleanvec_ip_ref",
    "ip_topk", "ip_topk_ref",
    "kmeans_assign", "kmeans_assign_ref",
    "sq_dot", "sq_dot_ref",
    "scorer_scores", "scorer_topk",
]


def scorer_scores(scorer, queries, *, use_pallas=None, interpret=False):
    """Dense (m, n) scores of ``queries`` against a scorer's database,
    lowered to the scorer's kernel (TPU) or jnp mirror (elsewhere).

    ``GleanVecQuantizedScorer`` has no fused kernel yet (tracked in
    ROADMAP open items); it runs the scorer's own jnp formulation, which
    on TPU still beats dequantize-then-gleanvec_ip on bandwidth.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import scorer as sc

    kw = dict(use_pallas=use_pallas, interpret=interpret)
    if isinstance(scorer, sc.LinearScorer):
        q_low = scorer.prepare_queries(queries)
        return q_low @ scorer.x_low.T      # plain MXU matmul; no kernel won
    if isinstance(scorer, sc.GleanVecScorer):
        q_views = scorer.prepare_queries(queries)
        return gleanvec_ip(q_views, scorer.tags, scorer.x_low, **kw)
    if isinstance(scorer, sc.QuantizedScorer):
        q = queries.astype(jnp.float32)
        q_low = q if scorer.a is None else q @ scorer.a.T
        return sq_dot(q_low, scorer.codes, scorer.lo, scorer.delta, **kw)
    if isinstance(scorer, sc.GleanVecQuantizedScorer):
        qstate = scorer.prepare_queries(queries)
        return scorer.score_block(qstate, 0, scorer.n_rows)
    raise TypeError(f"no kernel lowering for {type(scorer).__name__}")


def scorer_topk(scorer, queries, k: int, *, use_pallas=None,
                interpret=False):
    """Fused MIPS top-k of ``queries`` against a scorer's database.

    ``LinearScorer`` lowers to the fused ``ip_topk`` scan (never
    materializes (m, n)); the other scorers score densely via their kernel
    and reduce with ``top_k``. Returns (vals (m, k) f32, ids (m, k) i32).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import scorer as sc

    if isinstance(scorer, sc.LinearScorer):
        q_low = scorer.prepare_queries(queries)
        return ip_topk(q_low, scorer.x_low, k, use_pallas=use_pallas,
                       interpret=interpret)
    scores = scorer_scores(scorer, queries, use_pallas=use_pallas,
                           interpret=interpret)
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids.astype(jnp.int32)
