"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (dispatching jit wrapper) and ref.py (pure-jnp oracle used by tests
and as the differentiable/CPU fallback).

This module is also the SINGLE place where a Scorer
(:mod:`repro.core.scorer`) lowers to its kernel: ``scorer_scores`` /
``scorer_topk`` map each protocol implementation to the matching Pallas
kernel on TPU (``ip_topk`` / ``gleanvec_ip`` / ``sq_dot`` /
``gleanvec_sq``) and to the jnp mirrors elsewhere. Index code never
mentions kernels; it talks to scorers, and scorers lower here.
"""
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.gleanvec_ip import gleanvec_ip, gleanvec_ip_ref
from repro.kernels.graph_scan import (beam_step_bytes, fresh_slab_count,
                                      graph_scan_beam_step,
                                      graph_scan_beam_step_ref,
                                      graph_scan_scores_ref)
from repro.kernels.gleanvec_sq import (gleanvec_sq, gleanvec_sq_ref,
                                       gleanvec_sq_sorted_ref,
                                       gleanvec_sq_topk,
                                       gleanvec_sq_topk_ref)
from repro.kernels.ip_topk import ip_topk, ip_topk_ref
from repro.kernels.ivf_scan import (fine_step_bytes, ivf_scan_scores_ref,
                                    ivf_scan_topk, ivf_scan_topk_ref)
from repro.kernels.kmeans_assign import kmeans_assign, kmeans_assign_ref
from repro.kernels.sq_dot import sq_dot, sq_dot_ref

__all__ = [
    "flash_attention", "flash_attention_ref",
    "gleanvec_ip", "gleanvec_ip_ref",
    "gleanvec_sq", "gleanvec_sq_ref", "gleanvec_sq_sorted_ref",
    "gleanvec_sq_topk", "gleanvec_sq_topk_ref",
    "ip_topk", "ip_topk_ref",
    "graph_scan_beam_step", "graph_scan_beam_step_ref",
    "graph_scan_scores_ref", "beam_step_bytes", "fresh_slab_count",
    "ivf_scan_topk", "ivf_scan_topk_ref", "ivf_scan_scores_ref",
    "fine_step_bytes",
    "kmeans_assign", "kmeans_assign_ref",
    "sq_dot", "sq_dot_ref",
    "scorer_scores", "scorer_topk",
]


def _mask_live(scorer, scores):
    """Dead slots of a fixed-capacity streaming store score -inf; the
    ``live=None`` static path is untouched (identical HLO)."""
    import jax.numpy as jnp

    from repro.core import scorer as sc

    live = getattr(scorer, "live", None)
    if live is None:
        return scores
    return jnp.where(live[None, :], scores, sc.NEG_INF)


def scorer_scores(scorer, queries, *, use_pallas=None, interpret=False):
    """Dense (m, n) scores of ``queries`` against a scorer's database,
    lowered to the scorer's kernel (TPU) or jnp mirror (elsewhere).

    ``n`` spans the scorer's INTERNAL row space: for the sorted scorers
    column j is sorted row j (translate through ``scorer.translate_ids`` to
    reach original ids); for every other scorer it is the original id.
    Scorers carrying a streaming ``live`` mask get dead columns set to
    -inf after the kernel.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import scorer as sc

    kw = dict(use_pallas=use_pallas, interpret=interpret)
    if isinstance(scorer, sc.LinearScorer):
        q_low = scorer.prepare_queries(queries)
        return _mask_live(scorer, q_low @ scorer.x_low.T)   # plain matmul
    if isinstance(scorer, sc.GleanVecScorer):
        q_views = scorer.prepare_queries(queries)
        return _mask_live(scorer, gleanvec_ip(q_views, scorer.tags,
                                              scorer.x_low, **kw))
    if isinstance(scorer, sc.QuantizedScorer):
        q = queries.astype(jnp.float32)
        q_low = q if scorer.a is None else q @ scorer.a.T
        return _mask_live(scorer, sq_dot(q_low, scorer.codes, scorer.lo,
                                         scorer.delta, **kw))
    if isinstance(scorer, sc.GleanVecQuantizedScorer):
        qs = scorer.prepare_queries(queries)
        return _mask_live(scorer, gleanvec_sq(qs.q_scaled, qs.q_lo,
                                              scorer.tags, scorer.codes,
                                              **kw))
    if isinstance(scorer, sc.SortedGleanVecScorer):
        q_views = scorer.prepare_queries(queries)
        q_lo = jnp.zeros(q_views.shape[:2], jnp.float32)   # no affine term
        scores = gleanvec_sq(q_views, q_lo, scorer.block_tags, scorer.x_low,
                             layout_block=scorer.layout_block, **kw)
        return jnp.where(scorer.perm[None, :] >= 0, scores, sc.NEG_INF)
    if isinstance(scorer, sc.SortedGleanVecQuantizedScorer):
        qs = scorer.prepare_queries(queries)
        scores = gleanvec_sq(qs.q_scaled, qs.q_lo, scorer.block_tags,
                             scorer.codes,
                             layout_block=scorer.layout_block, **kw)
        return jnp.where(scorer.perm[None, :] >= 0, scores, sc.NEG_INF)
    raise TypeError(f"no kernel lowering for {type(scorer).__name__}")


def scorer_topk(scorer, queries, k: int, *, use_pallas=None,
                interpret=False):
    """Fused MIPS top-k of ``queries`` against a scorer's database.

    Every scorer lowers to a fused scan that never materializes the dense
    (m, n) score matrix: ``LinearScorer`` to ``ip_topk``,
    ``QuantizedScorer`` to ``ip_topk`` over the codes (the query-constant
    <Aq, lo> offset is rank-invariant and added to the returned values),
    and the GleanVec family (eager, int8 and both sorted layouts) to
    ``gleanvec_sq_topk``. Returns (vals (m, k) f32, ids (m, k) i32) with
    ids ALWAYS in the original database space (sorted scorers emit ids
    through their permutation inside the kernel).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import scorer as sc

    kw = dict(use_pallas=use_pallas, interpret=interpret)
    live = getattr(scorer, "live", None)
    live_ids = (None if live is None else
                jnp.where(live, jnp.arange(live.shape[0], dtype=jnp.int32),
                          -1))
    if isinstance(scorer, (sc.LinearScorer, sc.QuantizedScorer)) \
            and live is not None:
        # ip_topk has no row-id masking input; a live-masked linear store
        # falls back to dense scores + top_k (streaming stores are served
        # through the blocked scan anyway).
        scores = scorer_scores(scorer, queries, **kw)
        return jax.lax.top_k(scores, k)
    if isinstance(scorer, sc.LinearScorer):
        q_low = scorer.prepare_queries(queries)
        return ip_topk(q_low, scorer.x_low, k, **kw)
    if isinstance(scorer, sc.QuantizedScorer):
        qs = scorer.prepare_queries(queries)
        vals, ids = ip_topk(qs.q_scaled, scorer.codes, k, **kw)
        return vals + qs.q_lo[:, None], ids
    if isinstance(scorer, sc.GleanVecScorer):
        q_views = scorer.prepare_queries(queries)
        q_lo = jnp.zeros(q_views.shape[:2], jnp.float32)   # no affine term
        return gleanvec_sq_topk(q_views, q_lo, scorer.tags, scorer.x_low,
                                k, row_ids=live_ids, **kw)
    if isinstance(scorer, sc.GleanVecQuantizedScorer):
        qs = scorer.prepare_queries(queries)
        return gleanvec_sq_topk(qs.q_scaled, qs.q_lo, scorer.tags,
                                scorer.codes, k, row_ids=live_ids, **kw)
    if isinstance(scorer, sc.SortedGleanVecScorer):
        q_views = scorer.prepare_queries(queries)
        q_lo = jnp.zeros(q_views.shape[:2], jnp.float32)   # no affine term
        return gleanvec_sq_topk(q_views, q_lo, scorer.block_tags,
                                scorer.x_low, k, row_ids=scorer.perm,
                                layout_block=scorer.layout_block, **kw)
    if isinstance(scorer, sc.SortedGleanVecQuantizedScorer):
        qs = scorer.prepare_queries(queries)
        return gleanvec_sq_topk(qs.q_scaled, qs.q_lo, scorer.block_tags,
                                scorer.codes, k, row_ids=scorer.perm,
                                layout_block=scorer.layout_block, **kw)
    raise TypeError(f"no kernel lowering for {type(scorer).__name__}")
