"""Pallas TPU kernel: FlashAttention-2-style fused attention.

Substrate for the assigned LM architectures (GQA for all five, sliding-window
for h2o-danube3). Online-softmax accumulation in VMEM scratch across the
sequential KV grid dimension; causal and sliding-window blocks that are fully
masked are skipped via the mask check degenerating to -inf (their
contribution underflows to zero weight).

Grid: (B * H, S/bq, S/bk), KV innermost. Scratch per (bq) q-block:
m (bq, 1), l (bq, 1), acc (bq, dh) fp32. VMEM per step (bq=bk=512, dh=128):
q/k/v tiles 3 * 512*128*4 = 768 KiB + acc 256 KiB << 16 MiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -3.4e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, window: Optional[int],
                  n_kv_blocks: int, scale: float):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                  # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    safe_m = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.exp(jnp.where(mask, s - safe_m, NEG_INF))
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(jk == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """``q (B, H, S, dh)``, ``k/v (B, KV, S, dh)`` -> (B, H, S, dh).

    H % KV == 0 (GQA); S padded to tile multiples internally.
    """
    b, h, s_len, dh = q.shape
    kv = k.shape[1]
    group = h // kv
    bq = min(bq, s_len)
    bk = min(bk, s_len)
    pad = (-s_len) % max(bq, bk)
    if pad:
        # Padded keys sit at positions >= s_len; every real query has
        # q_pos < s_len, so the causal mask q_pos >= k_pos excludes them.
        # Non-causal padded attention would need an explicit kv-length mask.
        assert causal, "padding requires causal=True (pad S to a block multiple)"
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    s_pad = s_len + pad

    # fold padding into the window mask by treating it as causal+window on
    # the padded domain; for pure non-causal use an effective window.
    qr = q.reshape(b * h, s_pad, dh)
    kr = k.reshape(b * kv, s_pad, dh)
    vr = v.reshape(b * kv, s_pad, dh)
    n_kv_blocks = s_pad // bk
    grid = (b * h, s_pad // bq, n_kv_blocks)
    scale = 1.0 / float(dh) ** 0.5

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, n_kv_blocks=n_kv_blocks,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda bh, i, j, grp=group: (bh // grp, j, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda bh, i, j, grp=group: (bh // grp, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s_pad, dh)[:, :, :s_len]
