"""Public op: fused attention with Pallas kernel + differentiable fallback.

The Pallas kernel is forward-only (serving / dry-run artifact); training uses
the reference path whose VJP XLA derives (models/attention.py additionally
provides a memory-bounded chunked jnp implementation used when lowering the
assigned architectures).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention.flash_attention import (
    flash_attention as _pallas_flash_attention)
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 512, bk: int = 512,
                    use_pallas: bool | None = None, interpret: bool = False):
    """``q (B, H, S, dh)``, ``k/v (B, KV, S, dh)`` -> (B, H, S, dh)."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if use_pallas:
        return _pallas_flash_attention(q, k, v, causal=causal, window=window,
                                       bq=bq, bk=bk, interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal, window=window)
