"""Pure-jnp oracle for the flash-attention kernel (GQA + causal + SWA)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """``q (B, H, S, dh)``, ``k/v (B, KV, S, dh)`` with H % KV == 0.

    Sliding window: position i attends to j in (i - window, i]. ``window``
    None = full (causal) attention.
    """
    b, h, s, dh = q.shape
    kv = k.shape[1]
    group = h // kv
    qf = q.astype(jnp.float32) / jnp.sqrt(dh).astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    scores = jnp.where(mask[None, None], scores, -3.4e38)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vf).astype(q.dtype)
