from repro.kernels.gleanvec_ip.ops import gleanvec_ip
from repro.kernels.gleanvec_ip.ref import gleanvec_ip_ref

__all__ = ["gleanvec_ip", "gleanvec_ip_ref"]
