"""Pallas TPU kernel: eager GleanVec inner products (paper Algorithm 4).

Per database tile, the tag-selected query views are materialized with a
one-hot (TN, C) x (C, d) MXU matmul per query row (no VMEM gathers -- TPU has
no efficient in-VMEM row gather), then contracted rowwise with the database
tile on the VPU:

    onehot  = (tags_tile[:, None] == iota_C)          # (TN, C)
    q_sel_m = onehot @ q_views[m]                     # (TN, d)  MXU
    scores[m, tile] = sum_d q_sel_m * x_tile          # (TN,)    VPU

The entire eager view set q_views (C, d) per query lives in VMEM: for the
paper's largest setting (C = 48, d = 320) that is 60 KiB -- the CPU
cache-contention concern of Section 4 (Figure 7) vanishes on TPU
(DESIGN.md section 2).

HBM traffic per database vector = d * 4 bytes + 4 (tag), identical to the
plain LeanVec kernel up to the tag byte -- the bandwidth win of the paper's
DR carries over; the extra one-hot FLOPs ride on otherwise-idle MXU cycles
in this bandwidth-bound regime. With a tag-sorted (cluster-contiguous)
database layout every tile is single-tag and the kernel degenerates to one
(TM, d) x (d, TN) matmul; the layout flag is plumbed through ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gleanvec_ip_kernel(qv_ref, tags_ref, x_ref, out_ref, *, c: int):
    qv = qv_ref[...].astype(jnp.float32)      # (TM, C, d)
    tags = tags_ref[...]                      # (TN,)
    x = x_ref[...].astype(jnp.float32)        # (TN, d)
    tm = qv.shape[0]
    onehot = (tags[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (tags.shape[0], c), 1)
              ).astype(jnp.float32)           # (TN, C)

    def per_query(m, acc):
        q_sel = jax.lax.dot_general(
            onehot, qv[m], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (TN, d)
        s = jnp.sum(q_sel * x, axis=1)                   # (TN,)
        return jax.lax.dynamic_update_index_in_dim(acc, s, m, 0)

    out_ref[...] = jax.lax.fori_loop(
        0, tm, per_query, jnp.zeros_like(out_ref))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def gleanvec_ip(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                tm: int = 8, tn: int = 512, interpret: bool = False):
    """``q_views (M, C, d)``, ``tags (N,) int32``, ``x_low (N, d)`` ->
    scores ``(M, N) f32``."""
    m, c, d = q_views.shape
    n = x_low.shape[0]
    tm = min(tm, max(1, m))
    m_pad = (-m) % tm
    n_pad = (-n) % tn
    if m_pad:
        q_views = jnp.pad(q_views, ((0, m_pad), (0, 0), (0, 0)))
    if n_pad:
        x_low = jnp.pad(x_low, ((0, n_pad), (0, 0)))
        tags = jnp.pad(tags, (0, n_pad))
    grid = ((m + m_pad) // tm, (n + n_pad) // tn)

    out = pl.pallas_call(
        functools.partial(_gleanvec_ip_kernel, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, c, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + m_pad, n + n_pad), jnp.float32),
        interpret=interpret,
    )(q_views, tags, x_low)
    return out[:m, :n]
