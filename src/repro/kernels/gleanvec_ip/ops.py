"""Public op: eager GleanVec scoring with Pallas kernel + fallback."""
from __future__ import annotations

import jax

from repro.kernels.gleanvec_ip.gleanvec_ip import (gleanvec_ip
                                                   as _pallas_gleanvec_ip)
from repro.kernels.gleanvec_ip.ref import gleanvec_ip_ref


def gleanvec_ip(q_views: jax.Array, tags: jax.Array, x_low: jax.Array,
                tm: int = 8, tn: int = 512, use_pallas: bool | None = None,
                interpret: bool = False):
    """``q_views (M, C, d)``, ``tags (N,)``, ``x_low (N, d)`` -> (M, N)."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if use_pallas:
        return _pallas_gleanvec_ip(q_views, tags, x_low, tm=tm, tn=tn,
                                   interpret=interpret)
    return gleanvec_ip_ref(q_views, tags, x_low)
