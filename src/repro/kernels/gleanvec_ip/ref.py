"""Pure-jnp oracle for the eager GleanVec inner-product kernel (Alg. 4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gleanvec_ip_ref(q_views: jax.Array, tags: jax.Array, x_low: jax.Array):
    """``q_views (M, C, d)``, ``tags (N,)``, ``x_low (N, d)`` -> scores (M, N).

    scores[m, n] = <q_views[m, tags[n]], x_low[n]>   (Eq. 16, eager).
    """
    q_sel = q_views[:, tags, :]                       # (M, N, d)
    return jnp.einsum("mnd,nd->mn", q_sel.astype(jnp.float32),
                      x_low.astype(jnp.float32))
