from repro.kernels.gleanvec_sq.ops import gleanvec_sq, gleanvec_sq_topk
from repro.kernels.gleanvec_sq.ref import (gleanvec_sq_ref,
                                           gleanvec_sq_sorted_ref,
                                           gleanvec_sq_topk_ref)

__all__ = ["gleanvec_sq", "gleanvec_sq_topk", "gleanvec_sq_ref",
           "gleanvec_sq_sorted_ref", "gleanvec_sq_topk_ref"]
