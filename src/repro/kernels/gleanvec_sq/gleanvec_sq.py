"""Pallas TPU kernel: fused GleanVec ∘ int8 scoring (LeanVec composition).

One pass over the codes does all three steps of the per-cluster scalar-
quantized scoring (core/scorer.GleanVecQuantizedScorer):

    tag-select   q_sel  = q_scaled[m, tags[n]]      (one-hot MXU matmul)
    int8 dot     s      = <q_sel, codes_n>          (u8 -> f32 on load)
    affine       score  = s + q_lo[m, tags[n]]      (per-cluster offset)

The per-cluster scales/offsets are folded into the prepared queries OUTSIDE
the N loop (<q_c, u*delta_c + lo_c> = <q_c*delta_c, u> + <q_c, lo_c>), so
HBM traffic per database vector is d bytes of codes + 4 bytes of tag --
versus d*4 + 4 for the float GleanVec kernel and 9*d + 8 for
dequantize-then-``gleanvec_ip`` (codes read + f32 round-trip + second read).

Two layouts share the kernel body:

  * gathered (``sorted_layout=False``): per-row ``tags (N,)``; the
    tag-selected views are materialized with a (TN, C) x (C, d) one-hot
    matmul per query row, exactly like ``gleanvec_ip`` (TPU has no efficient
    in-VMEM row gather; the one-hot FLOPs ride on idle MXU cycles in this
    bandwidth-bound regime).
  * sorted (``sorted_layout=True``): the database is tag-sorted and
    cluster-padded so every (TN, d) tile carries ONE tag -- scoring
    degenerates to a single (TM, d) x (d, TN) matmul plus a broadcast add,
    the same FLOPs and bytes as the plain int8 scan. ``tags`` shrinks to one
    entry per layout block.

The fused top-k variants fold each score tile into a running (TM, k) top-k
held in the revisited output block across the sequential N grid dimension
(same scheme as ``ip_topk``) -- the dense (M, N) score matrix never exists.
Candidate ids come from an explicit ``row_ids (N,)`` input (-1 = masked), so
sorted layouts emit ORIGINAL database ids straight from the kernel and
padding rows can never win.

VMEM per step (TM=8, TN=512, C=48, d=160): q views 240 KiB + offsets 1.5 KiB
+ codes 80 KiB (u8) + scores 16 KiB << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.4e38  # python scalar: safe to close over inside the kernel


def _tile_scores(qs, qlo, tags, x, *, c: int, sorted_layout: bool):
    """(TM, TN) score tile. ``qs (TM, C, d)``, ``qlo (TM, C)``, ``x (TN, d)``
    codes (any dtype, cast on load), ``tags``: (TN,) row tags, or (1,) tile
    tag when ``sorted_layout``."""
    x = x.astype(jnp.float32)
    if sorted_layout:
        tag = tags[0]
        q = jax.lax.dynamic_index_in_dim(qs, tag, axis=1,
                                         keepdims=False)       # (TM, d)
        lo = jax.lax.dynamic_index_in_dim(qlo, tag, axis=1,
                                          keepdims=False)      # (TM,)
        s = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return s + lo[:, None]

    tm = qs.shape[0]
    onehot = (tags[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (tags.shape[0], c), 1)
              ).astype(jnp.float32)                            # (TN, C)

    def per_query(mi, acc):
        q_sel = jax.lax.dot_general(
            onehot, qs[mi], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (TN, d)
        lo_sel = jax.lax.dot_general(
            onehot, qlo[mi][:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (TN, 1)
        s = jnp.sum(q_sel * x, axis=1) + lo_sel[:, 0]
        return jax.lax.dynamic_update_index_in_dim(acc, s, mi, 0)

    init = jnp.zeros((tm, x.shape[0]), jnp.float32)
    return jax.lax.fori_loop(0, tm, per_query, init)


def _dense_kernel(qs_ref, qlo_ref, tags_ref, x_ref, out_ref, *, c: int,
                  sorted_layout: bool):
    out_ref[...] = _tile_scores(qs_ref[...].astype(jnp.float32),
                                qlo_ref[...].astype(jnp.float32),
                                tags_ref[...], x_ref[...], c=c,
                                sorted_layout=sorted_layout)


def _topk_kernel(qs_ref, qlo_ref, tags_ref, rid_ref, x_ref, vals_ref,
                 ids_ref, *, c: int, k: int, sorted_layout: bool):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    scores = _tile_scores(qs_ref[...].astype(jnp.float32),
                          qlo_ref[...].astype(jnp.float32),
                          tags_ref[...], x_ref[...], c=c,
                          sorted_layout=sorted_layout)
    col_ids = jnp.broadcast_to(rid_ref[...][None, :], scores.shape)
    scores = jnp.where(col_ids >= 0, scores, NEG_INF)

    # fold the tile into the running top-k: k rounds of max/mask over the
    # concatenated (TM, TN + k) candidates (same scheme as ip_topk).
    cat_v = jnp.concatenate([vals_ref[...], scores], axis=1)
    cat_i = jnp.concatenate([ids_ref[...], col_ids], axis=1)

    def fold(j, carry):
        cat_v, cat_i, out_v, out_i = carry
        best = jnp.max(cat_v, axis=1)                          # (TM,)
        arg = jnp.argmax(cat_v, axis=1)                        # (TM,)
        bid = jnp.take_along_axis(cat_i, arg[:, None], axis=1)[:, 0]
        out_v = jax.lax.dynamic_update_index_in_dim(out_v, best, j, 1)
        out_i = jax.lax.dynamic_update_index_in_dim(out_i, bid, j, 1)
        hit = (jax.lax.broadcasted_iota(jnp.int32, cat_v.shape, 1)
               == arg[:, None])
        cat_v = jnp.where(hit, NEG_INF, cat_v)
        return cat_v, cat_i, out_v, out_i

    out_v = jnp.zeros_like(vals_ref)
    out_i = jnp.zeros_like(ids_ref)
    _, _, out_v, out_i = jax.lax.fori_loop(
        0, k, fold, (cat_v, cat_i, out_v, out_i))
    vals_ref[...] = out_v
    ids_ref[...] = out_i


def _pad0(x, pad, fill=0):
    if not pad:
        return x
    widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def _tag_spec(tn: int, layout_block: int, sorted_layout: bool):
    """BlockSpec of the tags input: per-row tags for gathered tiles, one tag
    per tile (layout_block // tn tiles share a block tag) when sorted."""
    if not sorted_layout:
        return pl.BlockSpec((tn,), lambda i, j: (j,))
    bpt = layout_block // tn                   # tiles per layout block
    return pl.BlockSpec((1,), lambda i, j: (j // bpt,))


@functools.partial(jax.jit, static_argnames=("layout_block", "tm", "tn",
                                             "interpret"))
def gleanvec_sq(q_scaled: jax.Array, q_lo: jax.Array, tags: jax.Array,
                codes: jax.Array, layout_block: int = 0, tm: int = 8,
                tn: int = 512, interpret: bool = False):
    """Dense fused scores. ``q_scaled (M, C, d)``, ``q_lo (M, C)``,
    ``codes (N, d)`` u8 (or f32 for the unquantized sorted scorer) ->
    ``(M, N) f32``.

    ``layout_block == 0``: gathered layout, ``tags (N,)`` per-row.
    ``layout_block > 0``: tag-sorted layout, ``tags (N // layout_block,)``
    per-block; requires ``layout_block % tn == 0``.
    """
    m, c, d = q_scaled.shape
    n = codes.shape[0]
    srt = layout_block > 0
    if srt:
        assert n % layout_block == 0 and layout_block % tn == 0, \
            (n, layout_block, tn)
    tm = min(tm, max(1, m))
    m_pad = (-m) % tm
    n_pad = 0 if srt else (-n) % tn
    q_scaled = _pad0(q_scaled, m_pad)
    q_lo = _pad0(q_lo, m_pad)
    codes = _pad0(codes, n_pad)
    if not srt:
        tags = _pad0(tags, n_pad)
    grid = ((m + m_pad) // tm, (n + n_pad) // tn)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, c=c, sorted_layout=srt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, c, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tm, c), lambda i, j: (i, 0)),
            _tag_spec(tn, layout_block, srt),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + m_pad, n + n_pad), jnp.float32),
        interpret=interpret,
    )(q_scaled, q_lo, tags, codes)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("k", "layout_block", "tm", "tn",
                                             "interpret"))
def gleanvec_sq_topk(q_scaled: jax.Array, q_lo: jax.Array, tags: jax.Array,
                     codes: jax.Array, k: int, row_ids=None,
                     layout_block: int = 0, tm: int = 8, tn: int = 512,
                     interpret: bool = False):
    """Fused scoring + blocked top-k: the (M, N) score matrix never
    materializes. Returns (vals (M, k) f32, ids (M, k) i32).

    ``row_ids (N,)`` optional external id of each row (-1 = padding, can
    never win); defaults to ``arange(N)``. Sorted layouts pass their sort
    permutation here so the kernel emits ORIGINAL database ids.
    """
    m, c, d = q_scaled.shape
    n = codes.shape[0]
    srt = layout_block > 0
    if srt:
        assert n % layout_block == 0 and layout_block % tn == 0, \
            (n, layout_block, tn)
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    tm = min(tm, max(1, m))
    m_pad = (-m) % tm
    n_pad = 0 if srt else (-n) % tn
    q_scaled = _pad0(q_scaled, m_pad)
    q_lo = _pad0(q_lo, m_pad)
    codes = _pad0(codes, n_pad)
    row_ids = _pad0(row_ids.astype(jnp.int32), n_pad, fill=-1)
    if not srt:
        tags = _pad0(tags, n_pad)
    grid = ((m + m_pad) // tm, (n + n_pad) // tn)

    vals, ids = pl.pallas_call(
        functools.partial(_topk_kernel, c=c, k=k, sorted_layout=srt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, c, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tm, c), lambda i, j: (i, 0)),
            _tag_spec(tn, layout_block, srt),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m + m_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((m + m_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_scaled, q_lo, tags, row_ids, codes)
    return vals[:m], ids[:m]
