"""Public ops: fused GleanVec ∘ int8 scoring with Pallas kernel + fallback.

``layout_block > 0`` selects the tag-sorted (cluster-contiguous) path:
``tags`` holds ONE tag per layout block and each kernel tile is single-tag
(one matmul, no one-hot). When the tile size doesn't divide the layout
block, the dispatcher degrades gracefully: it shrinks the tile to the
layout block when possible, else expands the block tags to per-row tags and
runs the gathered kernel -- never wrong, only slower.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gleanvec_sq.gleanvec_sq import (gleanvec_sq
                                                   as _pallas_gleanvec_sq)
from repro.kernels.gleanvec_sq.gleanvec_sq import (gleanvec_sq_topk
                                                   as _pallas_sq_topk)
from repro.kernels.gleanvec_sq.ref import (gleanvec_sq_ref,
                                           gleanvec_sq_sorted_ref,
                                           gleanvec_sq_topk_ref)


def _sorted_tiling(n: int, layout_block: int, tn: int):
    """(layout_block, tn, row_tags_needed) for the sorted kernel path."""
    if layout_block % tn == 0 and n % layout_block == 0:
        return layout_block, tn, False
    if tn % layout_block == 0 and n % layout_block == 0:
        return layout_block, layout_block, False   # shrink tile to block
    return 0, tn, True                             # gathered fallback


def gleanvec_sq(q_scaled: jax.Array, q_lo: jax.Array, tags: jax.Array,
                codes: jax.Array, layout_block: int = 0, tm: int = 8,
                tn: int = 512, use_pallas: bool | None = None,
                interpret: bool = False):
    """``q_scaled (M, C, d)``, ``q_lo (M, C)``, ``codes (N, d)`` ->
    ``(M, N) f32``. ``tags``: (N,) rows, or (N // layout_block,) blocks when
    ``layout_block > 0``."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        if layout_block > 0:
            return gleanvec_sq_sorted_ref(q_scaled, q_lo, tags, codes,
                                          layout_block)
        return gleanvec_sq_ref(q_scaled, q_lo, tags, codes)
    if layout_block > 0:
        lb, tn, expand = _sorted_tiling(codes.shape[0], layout_block, tn)
        if expand:
            tags = jnp.repeat(tags, layout_block)
        layout_block = lb
    return _pallas_gleanvec_sq(q_scaled, q_lo, tags, codes,
                               layout_block=layout_block, tm=tm, tn=tn,
                               interpret=interpret)


def gleanvec_sq_topk(q_scaled: jax.Array, q_lo: jax.Array, tags: jax.Array,
                     codes: jax.Array, k: int, row_ids=None,
                     layout_block: int = 0, tm: int = 8, tn: int = 512,
                     use_pallas: bool | None = None, interpret: bool = False):
    """Fused score + top-k (never materializes (M, N)). ``row_ids (N,)``:
    external id per row (-1 = masked padding); sorted layouts pass their
    sort permutation so ids come out in the ORIGINAL space."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        return gleanvec_sq_topk_ref(q_scaled, q_lo, tags, codes, k,
                                    row_ids=row_ids,
                                    layout_block=layout_block)
    if layout_block > 0:
        lb, tn, expand = _sorted_tiling(codes.shape[0], layout_block, tn)
        if expand:
            tags = jnp.repeat(tags, layout_block)
        layout_block = lb
    return _pallas_sq_topk(q_scaled, q_lo, tags, codes, k, row_ids=row_ids,
                           layout_block=layout_block, tm=tm, tn=tn,
                           interpret=interpret)
