"""Pure-jnp oracle for the fused GleanVec ∘ int8 kernel.

score[m, n] = <q_scaled[m, tags[n]], codes[n]> + q_lo[m, tags[n]]

with the per-cluster scales/offsets already folded query-side
(q_scaled = (A_c q) * delta_c, q_lo = <A_c q, lo_c>).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -3.4e38


def gleanvec_sq_ref(q_scaled: jax.Array, q_lo: jax.Array, tags: jax.Array,
                    codes: jax.Array):
    """``q_scaled (M, C, d)``, ``q_lo (M, C)``, ``tags (N,)``,
    ``codes (N, d)`` u8/f32 -> scores ``(M, N) f32``."""
    q_sel = q_scaled[:, tags, :].astype(jnp.float32)   # (M, N, d)
    scores = jnp.einsum("mnd,nd->mn", q_sel, codes.astype(jnp.float32))
    return scores + q_lo[:, tags]


def gleanvec_sq_sorted_ref(q_scaled: jax.Array, q_lo: jax.Array,
                           block_tags: jax.Array, codes: jax.Array,
                           layout_block: int):
    """Sorted-layout oracle: expand the per-block tags to rows."""
    tags = jnp.repeat(block_tags, layout_block)
    return gleanvec_sq_ref(q_scaled, q_lo, tags, codes)


def gleanvec_sq_topk_ref(q_scaled: jax.Array, q_lo: jax.Array,
                         tags: jax.Array, codes: jax.Array, k: int,
                         row_ids=None, layout_block: int = 0):
    """Score densely, mask ``row_ids < 0`` and reduce with ``top_k``;
    returned ids come from ``row_ids`` (default ``arange(N)``)."""
    if layout_block > 0:
        scores = gleanvec_sq_sorted_ref(q_scaled, q_lo, tags, codes,
                                        layout_block)
    else:
        scores = gleanvec_sq_ref(q_scaled, q_lo, tags, codes)
    if row_ids is not None:
        row_ids = row_ids.astype(jnp.int32)
        scores = jnp.where(row_ids[None, :] >= 0, scores, NEG_INF)
    vals, idx = jax.lax.top_k(scores, k)
    ids = idx.astype(jnp.int32) if row_ids is None else row_ids[idx]
    return vals, ids
