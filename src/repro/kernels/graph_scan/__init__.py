"""Gather-free graph beam step: fused Pallas hop kernel + jnp oracle."""
from repro.kernels.graph_scan.ops import (beam_step_bytes,
                                          fresh_slab_count,
                                          graph_scan_beam_step,
                                          graph_scan_beam_step_ref,
                                          graph_scan_scores_ref)

__all__ = ["graph_scan_beam_step", "graph_scan_beam_step_ref",
           "graph_scan_scores_ref", "beam_step_bytes", "fresh_slab_count"]
