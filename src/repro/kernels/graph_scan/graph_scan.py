"""Pallas TPU kernel: gather-free graph beam step (fused hop fine step).

Graph beam search scores a ``(batch, expand * R)`` neighbor expansion every
hop. The gathered path materializes that candidate set three times over in
HBM -- a neighbor-id matrix, the gathered ``d``-dim rows and an f32 score
matrix -- before a ``top_k`` over ``(batch, beam + expand*R)`` merges it
into the beam. This kernel gives the hop the ``ivf_scan`` treatment
instead: the popped frontier vertices' neighbor lists arrive as SORTED-
LAYOUT row indices (ascending per query, -1 padded), are grouped into
``tn``-row slabs of the tag-sorted layout, and the slab indices ride in as
a scalar-prefetch schedule (``pltpu.PrefetchScalarGridSpec``). Each fresh
slab is DMAed ONCE; inside VMEM the kernel fuses

  * the single-tag dot (int8 codes or f32 rows) + per-cluster affine,
  * the neighbor-membership mask (slab rows that are not in this hop's
    neighbor set never score -- exact gathered-path candidate semantics,
    each distinct neighbor scored exactly once),
  * the beam dedupe (candidates whose ORIGINAL id -- read from the sort
    permutation ``row_ids`` -- is already in the incoming beam are
    dropped, mirroring ``graph._beam_member_mask``),
  * and the running top-``beam`` update: the output block holds the beam
    itself, initialized from the incoming (vals, ids) at ``j == 0`` and
    folded in place (strict-improvement replacement of the current min,
    the online equivalent of the gathered ``top_k`` merge).

Nothing shaped ``(batch, expand*R)`` in f32 -- neither gathered rows nor a
score matrix -- ever exists in HBM; only the int32 schedule / neighbor-row
arrays (4 bytes per candidate) ride along as scalar prefetch. HBM traffic
per fresh slab: TN * d bytes of codes + TN * 4 of ids + 4 of tag; per
query: C * d * 4 + C * 4 of prepared views plus the (beam) state in/out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -3.4e38  # python scalar: safe to close over inside the kernel


def _beam_step_kernel(sched_ref, fill_ref, qs_ref, qlo_ref, nbr_ref,
                      tag_ref, rid_ref, x_ref, bvals_ref, bids_ref,
                      vals_ref, ids_ref, *, tn: int):
    """One ``tn``-row slab of one query's hop schedule, folded into its
    running (1, beam) top-k. ``sched_ref`` holds the slab schedule (a
    negative entry marks a padding / repeated-slab slot that must not
    fold); ``fill_ref`` is its forward-filled twin the BlockSpec index
    maps read, so a padding slot revisits the PREVIOUS slab (no fresh
    DMA) instead of fetching slab 0."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = bvals_ref[...]
        ids_ref[...] = bids_ref[...]

    @pl.when(sched_ref[i, j] >= 0)
    def _fold_slab():
        tag = tag_ref[0]
        q = jax.lax.dynamic_index_in_dim(qs_ref[...], tag, axis=1,
                                         keepdims=False)       # (1, d)
        lo = jax.lax.dynamic_index_in_dim(qlo_ref[...], tag, axis=1,
                                          keepdims=False)      # (1,)
        x = x_ref[...].astype(jnp.float32)                     # (TN, d)
        scores = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32) \
            + lo[:, None]                                      # (1, TN)
        # global sorted-row index of every slab row, for the membership
        # test against this hop's (scalar-prefetched) neighbor set
        rows = fill_ref[i, j] * tn \
            + jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1)  # (1, TN)
        nbrs = nbr_ref[...]                                    # (1, S)
        member = jnp.any(rows[0, :, None] == nbrs[0, None, :],
                         axis=1)[None, :]                      # (1, TN)
        # original ids straight from the sort permutation; candidates
        # already in the incoming beam are the gathered path's
        # _beam_member_mask dedupe
        cand_ids = jnp.broadcast_to(rid_ref[...][None, :], scores.shape)
        in_beam = jnp.any(cand_ids[0, :, None] == bids_ref[...][0, None, :],
                          axis=1)[None, :]                     # (1, TN)
        ok = member & (cand_ids >= 0) & ~in_beam
        cand_v = jnp.where(ok, scores, NEG_INF)

        # fold: TN rounds of strict-improvement replacement of the running
        # beam's minimum -- the online form of top_k(concat([beam, cand])).
        def fold(t, carry):
            vals, ids = carry                                  # (1, beam)
            v = jax.lax.dynamic_index_in_dim(cand_v, t, axis=1,
                                             keepdims=True)    # (1, 1)
            ci = jax.lax.dynamic_index_in_dim(cand_ids, t, axis=1,
                                              keepdims=True)   # (1, 1)
            vmin = jnp.min(vals, axis=1, keepdims=True)        # (1, 1)
            amin = jnp.argmin(vals, axis=1)                    # (1,)
            hit = (jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
                   == amin[:, None]) & (v > vmin)
            vals = jnp.where(hit, v, vals)
            ids = jnp.where(hit, ci, ids)
            return vals, ids

        vals, ids = jax.lax.fori_loop(
            0, tn, fold, (vals_ref[...], ids_ref[...]))
        vals_ref[...] = vals
        ids_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("layout_block", "tn",
                                             "interpret"))
def graph_scan_beam_step(q_scaled: jax.Array, q_lo: jax.Array,
                         block_tags: jax.Array, row_ids: jax.Array,
                         codes: jax.Array, nbr_rows: jax.Array,
                         beam_vals: jax.Array, beam_ids: jax.Array,
                         layout_block: int, tn: int = 8,
                         interpret: bool = False):
    """Fused graph hop: merge one neighbor expansion into the beam.

    ``q_scaled (M, C, d)`` / ``q_lo (M, C)``: prepared per-cluster query
    views (``q_lo`` zeros for the unquantized sorted scorer);
    ``block_tags (N // layout_block,)``: one tag per layout block;
    ``row_ids (N,)``: external id per sorted row (-1 = padding/dead);
    ``codes (N, d)``: u8 codes or f32 rows of the tag-sorted layout;
    ``nbr_rows (M, S)``: this hop's neighbor SORTED-ROW indices per query
    (-1 = pad; need not be pre-sorted -- sorted/grouped here);
    ``beam_vals/beam_ids (M, B)``: incoming beam (ids ORIGINAL, -1 empty).

    Returns the merged ``(vals (M, B), ids (M, B))`` beam: the exact
    top-B multiset of {incoming beam} U {distinct live neighbors not
    already in the beam}, in slot order (NOT sorted -- the traversal's
    final ``top_k`` orders the winners). ``tn`` must divide
    ``layout_block`` (the dispatcher in ops.py guarantees it).
    """
    m, c, d = q_scaled.shape
    n = codes.shape[0]
    assert n % layout_block == 0 and layout_block % tn == 0, \
        (n, layout_block, tn)
    s = nbr_rows.shape[1]
    b = beam_vals.shape[1]
    bpt = layout_block // tn                  # slabs per layout block
    # group the hop's neighbor rows into slabs: ascending sort (invalid
    # rows to the sentinel end), then keep each slab's FIRST slot only --
    # one fold per distinct slab, membership picks out all its neighbors.
    sorted_rows = jnp.sort(jnp.where(nbr_rows >= 0, nbr_rows, n), axis=1)
    valid = sorted_rows < n
    slab = sorted_rows // tn
    fresh = valid & jnp.concatenate(
        [jnp.ones((m, 1), bool), slab[:, 1:] != slab[:, :-1]], axis=1)
    sched_t = jnp.where(fresh, slab, -1).astype(jnp.int32)
    nbr_sorted = jnp.where(valid, sorted_rows, -1).astype(jnp.int32)
    # forward-filled twin for the index maps: padding / repeated-slab
    # slots keep the last fresh slab index, so their grid steps revisit
    # the already-resident slab (the pipeline skips the DMA) -- matching
    # ops.beam_step_bytes.
    sched_f = jnp.maximum(jax.lax.associative_scan(
        lambda a, b: jnp.where(b >= 0, b, a), sched_t, axis=1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, s),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda i, j, sr, fr: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i, j, sr, fr: (i, 0)),
            pl.BlockSpec((1, s), lambda i, j, sr, fr: (i, 0)),
            pl.BlockSpec((1,), lambda i, j, sr, fr: (fr[i, j] // bpt,)),
            pl.BlockSpec((tn,), lambda i, j, sr, fr: (fr[i, j],)),
            pl.BlockSpec((tn, d), lambda i, j, sr, fr: (fr[i, j], 0)),
            pl.BlockSpec((1, b), lambda i, j, sr, fr: (i, 0)),
            pl.BlockSpec((1, b), lambda i, j, sr, fr: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b), lambda i, j, sr, fr: (i, 0)),
            pl.BlockSpec((1, b), lambda i, j, sr, fr: (i, 0)),
        ],
    )
    vals, ids = pl.pallas_call(
        functools.partial(_beam_step_kernel, tn=tn),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, b), jnp.float32),
            jax.ShapeDtypeStruct((m, b), jnp.int32),
        ],
        interpret=interpret,
    )(sched_t, sched_f, q_scaled, q_lo, nbr_sorted, block_tags,
      row_ids.astype(jnp.int32), codes, beam_vals.astype(jnp.float32),
      beam_ids.astype(jnp.int32))
    return vals, ids
