"""Public ops: gather-free graph beam step with Pallas kernel + jnp
fallback, plus the kernel's HBM-traffic model.

``graph_scan_beam_step`` takes the hop's neighbor SORTED-ROW indices per
query (-1-padded, any order) and folds their scores into the beam --
Pallas with the slab schedule as a scalar-prefetch operand on TPU (and in
interpret mode), the gathering jnp oracle elsewhere. The kernel leaves the
beam in slot order; the oracle returns it sorted by score -- the same
top-B multiset either way (the traversal's pop / final ``top_k`` are
order-insensitive). When the requested slab tile does not divide the
layout block, the dispatcher shrinks the tile to the layout block -- never
wrong, only coarser.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.graph_scan.graph_scan import (graph_scan_beam_step
                                                 as _pallas_beam_step)
from repro.kernels.graph_scan.ref import (graph_scan_beam_step_ref,
                                          graph_scan_scores_ref)

__all__ = ["graph_scan_beam_step", "graph_scan_beam_step_ref",
           "graph_scan_scores_ref", "beam_step_bytes", "fresh_slab_count"]


def graph_scan_beam_step(q_scaled: jax.Array, q_lo: jax.Array,
                         block_tags: jax.Array, row_ids: jax.Array,
                         codes: jax.Array, nbr_rows: jax.Array,
                         beam_vals: jax.Array, beam_ids: jax.Array,
                         layout_block: int, tn: int = 8,
                         use_pallas: bool | None = None,
                         interpret: bool = False):
    """``q_scaled (M, C, d)``, ``q_lo (M, C)``, ``block_tags (NB,)``,
    ``row_ids (N,)``, ``codes (N, d)`` u8/f32, ``nbr_rows (M, S)`` hop
    neighbor sorted-row indices (-1 = pad), ``beam_vals``/``beam_ids``
    ``(M, B)`` -> merged ``(vals, ids) (M, B)``: the top-B multiset of
    {beam} U {distinct live neighbors not already in the beam}, ids
    ORIGINAL."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        return graph_scan_beam_step_ref(q_scaled, q_lo, block_tags,
                                        row_ids, codes, nbr_rows,
                                        beam_vals, beam_ids, layout_block)
    if layout_block % tn:
        tn = layout_block             # shrink: one grid step per slab
    return _pallas_beam_step(q_scaled, q_lo, block_tags, row_ids, codes,
                             nbr_rows, beam_vals, beam_ids,
                             layout_block=layout_block, tn=tn,
                             interpret=interpret)


def beam_step_bytes(m: int, slabs_visited: float, tn: int, d: int, c: int,
                    beam: int, s: int, code_bytes: int = 1) -> float:
    """HBM bytes the fused beam-step kernel moves for one hop of one query
    batch.

    Determined by the kernel's BlockSpecs (see graph_scan.py): per fresh
    slab TN*d bytes of codes + TN*4 of ids + 4 of tag; per query C*d*4 +
    C*4 of prepared views, 3*S*4 of int32 schedule/neighbor-row arrays
    (the ONLY per-candidate HBM footprint -- no f32 score or gathered-row
    matrix exists) and 4*B*8 of beam state in/out. ``slabs_visited``
    counts the FRESH schedule entries across the batch (repeated-slab and
    padding slots DMA nothing new: their index maps clamp to the previous
    slab). This is the fused side of the >= 3x beam-step assertion; the
    gathered side comes from the compiled ``graph.gathered_beam_step``'s
    ``cost_analysis`` via ``normalize_cost``.
    """
    per_slab = tn * (d * code_bytes + 4) + 4
    per_query = c * d * 4 + c * 4 + 3 * s * 4 + 4 * beam * 8
    return float(m * per_query + slabs_visited * per_slab)


def fresh_slab_count(nbr_rows, tn: int) -> int:
    """Total fresh slabs a hop with these neighbor rows DMAs (host-side:
    the data-dependent occupancy term of :func:`beam_step_bytes`)."""
    rows = np.asarray(nbr_rows)
    total = 0
    for r in rows:
        v = r[r >= 0]
        total += int(np.unique(v // tn).size)
    return total
