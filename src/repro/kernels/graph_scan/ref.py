"""Pure-jnp oracle for the fused graph beam step.

The oracle gathers the hop's neighbor rows explicitly (it is allowed to --
it is the reference, not the fast path), scores them through the same
per-cluster affine math as the kernel, applies the same three masks
(duplicate neighbor rows, dead rows, candidates already in the beam) and
merges with ``top_k`` over the concatenated (beam + candidates) set.
Because the masks reproduce exactly what ``graph._beam_loop``'s gathered
body computes (``_mask_duplicate_nbrs`` + ``score_ids`` +
``_beam_member_mask`` + merge), this oracle is ALSO the bridge the parity
tests use between the fused hop and the gathered traversal.

Note the ORDER contract difference: the kernel folds candidates into beam
slots in place (unsorted); the oracle's ``top_k`` merge returns the beam
sorted by score descending. Both are the same top-B multiset -- consumers
(the traversal's pop and final ``top_k``) are order-insensitive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -3.4e38


def graph_scan_scores_ref(q_scaled: jax.Array, q_lo: jax.Array,
                          block_tags: jax.Array, row_ids: jax.Array,
                          codes: jax.Array, nbr_rows: jax.Array,
                          layout_block: int):
    """Dense per-candidate scores: returns ``(scores, ids)`` both
    ``(M, S)`` in ascending-sorted-row order -- duplicate rows (beyond the
    first occurrence), padding slots and dead rows score -inf with id -1.
    Beam dedupe is NOT applied here (it needs the beam; see
    :func:`graph_scan_beam_step_ref`)."""
    m, s = nbr_rows.shape
    n = codes.shape[0]
    rows = jnp.sort(jnp.where(nbr_rows >= 0, nbr_rows, n), axis=1)
    valid = rows < n
    dup = jnp.concatenate(
        [jnp.zeros((m, 1), bool), rows[:, 1:] == rows[:, :-1]], axis=1)
    safe = jnp.where(valid, rows, 0)
    x = codes[safe].astype(jnp.float32)                        # (M, S, d)
    tag = block_tags[safe // layout_block]                     # (M, S)
    q_sel = q_scaled[jnp.arange(m)[:, None], tag]              # (M, S, d)
    lo_sel = jnp.take_along_axis(q_lo, tag, axis=1)            # (M, S)
    scores = jnp.sum(q_sel * x, axis=-1) + lo_sel
    ids = jnp.where(valid, row_ids[safe].astype(jnp.int32), -1)
    ok = valid & ~dup & (ids >= 0)
    return jnp.where(ok, scores, NEG_INF), jnp.where(ok, ids, -1)


def graph_scan_beam_step_ref(q_scaled: jax.Array, q_lo: jax.Array,
                             block_tags: jax.Array, row_ids: jax.Array,
                             codes: jax.Array, nbr_rows: jax.Array,
                             beam_vals: jax.Array, beam_ids: jax.Array,
                             layout_block: int):
    """Gather + mask + ``top_k``-merge oracle of
    :func:`graph_scan_beam_step` (same top-B multiset, sorted order)."""
    scores, ids = graph_scan_scores_ref(q_scaled, q_lo, block_tags,
                                        row_ids, codes, nbr_rows,
                                        layout_block)
    present = jnp.any(ids[:, :, None] == beam_ids[:, None, :], axis=2)
    scores = jnp.where(present, NEG_INF, scores)
    ids = jnp.where(present, -1, ids)
    all_v = jnp.concatenate([beam_vals.astype(jnp.float32), scores], axis=1)
    all_i = jnp.concatenate([beam_ids.astype(jnp.int32), ids], axis=1)
    top, sel = jax.lax.top_k(all_v, beam_vals.shape[1])
    return top, jnp.take_along_axis(all_i, sel, axis=1)
