from repro.kernels.ip_topk.ops import ip_topk
from repro.kernels.ip_topk.ref import ip_topk_ref

__all__ = ["ip_topk", "ip_topk_ref"]
