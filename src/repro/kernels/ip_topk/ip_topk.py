"""Pallas TPU kernel: fused inner-product scan + running top-k.

This is the hot loop of the paper's Algorithm 1 main search on a flat index:
score every database vector against a query batch and keep the best k.
The kernel streams (TN, d) database tiles HBM -> VMEM once (the bandwidth
the paper's dimensionality reduction minimizes), computes the (TM, TN) score
tile on the MXU, and folds it into a running (TM, k) top-k held in VMEM
scratch across the sequential N grid dimension -- scores never round-trip
to HBM.

Top-k folding uses k iterations of (max, argmax, mask) on the VPU; k is small
(10..128) in every paper configuration.

VMEM budget per step (TM=128, TN=512, d=160, k=16, fp32):
  q tile 128*160*4 = 80 KiB, x tile 512*160*4 = 320 KiB,
  scores 128*512*4 = 256 KiB, scratch 2 * 128*16*4 = 16 KiB   << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.4e38  # python scalar: safe to close over inside the kernel


def _ip_topk_kernel(q_ref, x_ref, vals_ref, ids_ref, *, k: int, tn: int,
                    n_total: int):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    q = q_ref[...].astype(jnp.float32)                     # (TM, d)
    x = x_ref[...].astype(jnp.float32)                     # (TN, d)
    scores = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (TM, TN)
    base = nj * tn
    col_ids = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col_ids < n_total, scores, NEG_INF)

    run_v = vals_ref[...]
    run_i = ids_ref[...]
    # fold the tile into the running top-k: k rounds of max/mask over the
    # concatenated (TM, TN + k) candidates.
    cat_v = jnp.concatenate([run_v, scores], axis=1)
    cat_i = jnp.concatenate([run_i, col_ids], axis=1)

    def fold(j, carry):
        cat_v, cat_i, out_v, out_i = carry
        best = jnp.max(cat_v, axis=1)                       # (TM,)
        arg = jnp.argmax(cat_v, axis=1)                     # (TM,)
        bid = jnp.take_along_axis(cat_i, arg[:, None], axis=1)[:, 0]
        out_v = jax.lax.dynamic_update_index_in_dim(out_v, best, j, 1)
        out_i = jax.lax.dynamic_update_index_in_dim(out_i, bid, j, 1)
        hit = (jax.lax.broadcasted_iota(jnp.int32, cat_v.shape, 1)
               == arg[:, None])
        cat_v = jnp.where(hit, NEG_INF, cat_v)
        return cat_v, cat_i, out_v, out_i

    out_v = jnp.zeros_like(run_v)
    out_i = jnp.zeros_like(run_i)
    _, _, out_v, out_i = jax.lax.fori_loop(
        0, k, fold, (cat_v, cat_i, out_v, out_i))
    vals_ref[...] = out_v
    ids_ref[...] = out_i


@functools.partial(jax.jit,
                   static_argnames=("k", "tm", "tn", "interpret"))
def ip_topk(q: jax.Array, x: jax.Array, k: int, tm: int = 128, tn: int = 512,
            interpret: bool = False):
    """Fused MIPS top-k. ``q (M, d)``, ``x (N, d)`` -> (vals, ids) (M, k).

    M, N are padded up to tile multiples internally; d should be a multiple
    of 128 for MXU efficiency (any d is functionally correct).
    """
    m, d = q.shape
    n = x.shape[0]
    tm = min(tm, max(8, m))
    m_pad = (-m) % tm
    n_pad = (-n) % tn
    if m_pad:
        q = jnp.pad(q, ((0, m_pad), (0, 0)))
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    grid = ((m + m_pad) // tm, (n + n_pad) // tn)

    vals, ids = pl.pallas_call(
        functools.partial(_ip_topk_kernel, k=k, tn=tn, n_total=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(((m + m_pad), k), jnp.float32),
            jax.ShapeDtypeStruct(((m + m_pad), k), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)
    return vals[:m], ids[:m]
