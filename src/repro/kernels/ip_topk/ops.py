"""Public op: fused MIPS top-k with TPU Pallas kernel + portable fallback."""
from __future__ import annotations

import jax

from repro.kernels.ip_topk.ip_topk import ip_topk as _pallas_ip_topk
from repro.kernels.ip_topk.ref import ip_topk_ref


def ip_topk(q: jax.Array, x: jax.Array, k: int, tm: int = 128, tn: int = 512,
            use_pallas: bool | None = None, interpret: bool = False):
    """``q (M, d)``, ``x (N, d)`` -> (vals (M, k) f32, ids (M, k) i32).

    ``use_pallas=None`` auto-selects: Pallas on TPU backends, reference jnp
    otherwise (interpret=True forces the Pallas path in Python emulation,
    used by the test suite).
    """
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if use_pallas:
        return _pallas_ip_topk(q, x, k, tm=tm, tn=tn, interpret=interpret)
    return ip_topk_ref(q, x, k)
