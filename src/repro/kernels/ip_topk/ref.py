"""Pure-jnp oracle for the fused inner-product + top-k scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ip_topk_ref(q: jax.Array, x: jax.Array, k: int):
    """Exact MIPS top-k: ``q (M, d)``, ``x (N, d)`` -> (vals, ids) (M, k)."""
    scores = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids.astype(jnp.int32)
