from repro.kernels.ivf_scan.ops import (fine_step_bytes, ivf_scan_scores_ref,
                                        ivf_scan_topk, ivf_scan_topk_ref)

__all__ = ["ivf_scan_topk", "ivf_scan_topk_ref", "ivf_scan_scores_ref",
           "fine_step_bytes"]
