"""Pallas TPU kernel: gather-free sorted-IVF range scan (fused fine step).

The sorted scorers (core/scorer.SortedGleanVec*Scorer) store every cluster
as a contiguous run of single-tag ``layout_block`` slabs. For an IVF whose
coarse quantizer IS that clustering, the fine step therefore never needs a
posting-list gather: probing cluster ``c`` means streaming ``c``'s slabs
through the single-tag scoring path (one (1, d) x (d, TN) contraction plus
a broadcast affine per tile) while a running (1, k) top-k lives in the
revisited output block. The winning ORIGINAL ids come straight from the
sort permutation (``row_ids``), exactly like ``gleanvec_sq_topk``.

The per-query probe schedule rides in as a SCALAR-PREFETCH operand
(``pltpu.PrefetchScalarGridSpec``): ``sched (M, S)`` holds the layout-block
indices each query must visit (-1 = padding). The BlockSpec index maps read
``sched`` to pick which codes/ids/tag slab the next grid step DMAs, so the
kernel never touches an unprobed block and nothing shaped
``(M, nprobe * L)`` -- neither a candidate-id matrix nor a dense score
matrix -- ever exists in HBM. The grid is ``(M, S * tiles_per_block)``;
queries are processed one per grid row because each query owns a private
schedule (the per-query views (1, C, d) stay resident across the whole
inner dimension -- their block index does not change with ``j``).

HBM traffic per grid step: TN * d bytes of codes (u8, or f32 for the
unquantized sorted scorer) + TN * 4 bytes of ids + 4 bytes of tag; per
query: C * d * 4 + C * 4 bytes of prepared views. Nothing else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -3.4e38  # python scalar: safe to close over inside the kernel


def _range_scan_kernel(sched_ref, fill_ref, qs_ref, qlo_ref, tag_ref,
                       rid_ref, x_ref, vals_ref, ids_ref, *, k: int):
    """One (1, TN) tile of one query's schedule, folded into its running
    (1, k) top-k. ``sched_ref`` is the scalar-prefetched tile schedule (a
    negative entry marks a padding slot that must not score); ``fill_ref``
    is its forward-filled twin the BlockSpec index maps read, so a padding
    slot revisits the PREVIOUS slab (no fresh DMA) instead of fetching
    slab 0."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    tag = tag_ref[0]
    q = jax.lax.dynamic_index_in_dim(qs_ref[...], tag, axis=1,
                                     keepdims=False)       # (1, d)
    lo = jax.lax.dynamic_index_in_dim(qlo_ref[...], tag, axis=1,
                                      keepdims=False)      # (1,)
    x = x_ref[...].astype(jnp.float32)                     # (TN, d)
    scores = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) \
        + lo[:, None]                                      # (1, TN)
    col_ids = jnp.broadcast_to(rid_ref[...][None, :], scores.shape)
    ok = (col_ids >= 0) & (sched_ref[i, j] >= 0)
    scores = jnp.where(ok, scores, NEG_INF)

    # fold the tile into the running top-k: k rounds of max/mask over the
    # concatenated (1, TN + k) candidates (same scheme as gleanvec_sq_topk).
    cat_v = jnp.concatenate([vals_ref[...], scores], axis=1)
    cat_i = jnp.concatenate([ids_ref[...], col_ids], axis=1)

    def fold(r, carry):
        cat_v, cat_i, out_v, out_i = carry
        best = jnp.max(cat_v, axis=1)                      # (1,)
        arg = jnp.argmax(cat_v, axis=1)                    # (1,)
        bid = jnp.take_along_axis(cat_i, arg[:, None], axis=1)[:, 0]
        out_v = jax.lax.dynamic_update_index_in_dim(out_v, best, r, 1)
        out_i = jax.lax.dynamic_update_index_in_dim(out_i, bid, r, 1)
        hit = (jax.lax.broadcasted_iota(jnp.int32, cat_v.shape, 1)
               == arg[:, None])
        cat_v = jnp.where(hit, NEG_INF, cat_v)
        return cat_v, cat_i, out_v, out_i

    out_v = jnp.zeros_like(vals_ref)
    out_i = jnp.zeros_like(ids_ref)
    _, _, out_v, out_i = jax.lax.fori_loop(
        0, k, fold, (cat_v, cat_i, out_v, out_i))
    vals_ref[...] = out_v
    ids_ref[...] = out_i


@functools.partial(jax.jit, static_argnames=("k", "layout_block", "tn",
                                             "interpret"))
def ivf_scan_topk(q_scaled: jax.Array, q_lo: jax.Array, block_tags: jax.Array,
                  row_ids: jax.Array, codes: jax.Array, sched: jax.Array,
                  k: int, layout_block: int, tn: int = 512,
                  interpret: bool = False):
    """Fused sorted-IVF range scan + blocked top-k.

    ``q_scaled (M, C, d)`` / ``q_lo (M, C)``: prepared per-cluster query
    views (``q_lo`` zeros for the unquantized sorted scorer);
    ``block_tags (N // layout_block,)``: one tag per layout block;
    ``row_ids (N,)``: external id per sorted row (-1 = padding, never wins);
    ``codes (N, d)``: u8 codes or f32 rows of the tag-sorted layout;
    ``sched (M, S)``: per-query layout-block indices to visit (-1 = pad).

    Returns (vals (M, k) f32, ids (M, k) i32) with -inf winners' ids
    stripped to -1. ``tn`` must divide ``layout_block`` (the dispatcher in
    ops.py guarantees it).
    """
    m, c, d = q_scaled.shape
    n = codes.shape[0]
    assert n % layout_block == 0 and layout_block % tn == 0, \
        (n, layout_block, tn)
    s = sched.shape[1]
    bpt = layout_block // tn                  # tiles per layout block
    # expand the block schedule to tile indices (still -1-padded)
    sched_t = jnp.where(
        sched[:, :, None] >= 0,
        sched[:, :, None] * bpt + jnp.arange(bpt, dtype=sched.dtype),
        -1).reshape(m, s * bpt).astype(jnp.int32)
    # forward-filled twin for the index maps: a padding slot keeps the
    # last valid tile index, so its grid step revisits the already-resident
    # slab (the pipeline skips the DMA) instead of re-fetching tile 0 --
    # padding costs ~zero HBM traffic, matching ops.fine_step_bytes.
    sched_f = jnp.maximum(jax.lax.associative_scan(
        lambda a, b: jnp.where(b >= 0, b, a), sched_t, axis=1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, s * bpt),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda i, j, sr, fr: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i, j, sr, fr: (i, 0)),
            pl.BlockSpec((1,), lambda i, j, sr, fr: (fr[i, j] // bpt,)),
            pl.BlockSpec((tn,), lambda i, j, sr, fr: (fr[i, j],)),
            pl.BlockSpec((tn, d), lambda i, j, sr, fr: (fr[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j, sr, fr: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, sr, fr: (i, 0)),
        ],
    )
    vals, ids = pl.pallas_call(
        functools.partial(_range_scan_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
        ],
        interpret=interpret,
    )(sched_t, sched_f, q_scaled, q_lo, block_tags,
      row_ids.astype(jnp.int32), codes)
    # the top-k fold can recycle an already-taken slot's id once everything
    # left is -inf; strip those ids like the gathered IVF path does.
    return vals, jnp.where(vals > NEG_INF, ids, -1)
