"""Public ops: gather-free sorted-IVF range scan with Pallas kernel +
jnp fallback, plus the kernel's HBM-traffic model.

``ivf_scan_topk`` takes a per-query probe schedule of layout-block indices
(-1-padded) and streams exactly those single-tag slabs -- Pallas with the
schedule as a scalar-prefetch operand on TPU (and in interpret mode), the
gathering jnp oracle elsewhere. When the requested tile does not divide
the layout block, the dispatcher shrinks the tile to the layout block
(every slab is then one grid step) -- never wrong, only coarser.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ivf_scan.ivf_scan import (ivf_scan_topk
                                             as _pallas_ivf_scan_topk)
from repro.kernels.ivf_scan.ref import (ivf_scan_scores_ref,
                                        ivf_scan_topk_ref)

__all__ = ["ivf_scan_topk", "ivf_scan_topk_ref", "ivf_scan_scores_ref",
           "fine_step_bytes"]


def ivf_scan_topk(q_scaled: jax.Array, q_lo: jax.Array,
                  block_tags: jax.Array, row_ids: jax.Array,
                  codes: jax.Array, sched: jax.Array, k: int,
                  layout_block: int, tn: int = 512,
                  use_pallas: bool | None = None, interpret: bool = False):
    """``q_scaled (M, C, d)``, ``q_lo (M, C)``, ``block_tags (NB,)``,
    ``row_ids (N,)``, ``codes (N, d)`` u8/f32, ``sched (M, S)`` layout-block
    indices (-1 = pad) -> (vals (M, k), ids (M, k)), ids ORIGINAL (-1 for
    -inf winners)."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        return ivf_scan_topk_ref(q_scaled, q_lo, block_tags, row_ids, codes,
                                 sched, k, layout_block)
    if layout_block % tn:
        tn = layout_block                  # shrink: one grid step per slab
    return _pallas_ivf_scan_topk(q_scaled, q_lo, block_tags, row_ids, codes,
                                 sched, k, layout_block=layout_block, tn=tn,
                                 interpret=interpret)


def fine_step_bytes(m: int, blocks_visited: int, layout_block: int, d: int,
                    c: int, code_bytes: int = 1, k: int = 10) -> float:
    """HBM bytes the fused range-scan kernel moves for one query batch.

    Determined by the kernel's BlockSpecs (see ivf_scan.py): per visited
    slab TN*d bytes of codes + TN*4 of ids + 4 of tag; per query C*d*4 + C*4
    of prepared views and 8k of running top-k. ``blocks_visited`` counts the
    VALID schedule entries across the batch (padding slots DMA nothing new:
    their index maps clamp to the previous slab). This is the fused side of
    the >= 4x fine-step assertion; the gathered side comes from the
    compiled ``_probe_and_score``'s ``cost_analysis`` via ``normalize_cost``.
    """
    per_block = layout_block * (d * code_bytes + 4) + 4
    per_query = c * d * 4 + c * 4 + 2 * k * 4
    return float(m * per_query + blocks_visited * per_block)
