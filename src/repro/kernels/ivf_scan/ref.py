"""Pure-jnp oracle for the fused sorted-IVF range scan.

The oracle gathers the scheduled blocks' rows explicitly (it is allowed to
-- it is the reference, not the fast path), scores them through the same
per-cluster affine math as ``gleanvec_sq_ref``, masks padding rows /
padding schedule slots to -inf, and reduces with ``top_k``. Because the
gathers reproduce exactly what ``scorer.score_ids`` computes over a
posting list holding the same rows, this oracle is ALSO the bridge the
parity tests use between the fused path and the gathered IVF path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -3.4e38


def ivf_scan_scores_ref(q_scaled: jax.Array, q_lo: jax.Array,
                        block_tags: jax.Array, row_ids: jax.Array,
                        codes: jax.Array, sched: jax.Array,
                        layout_block: int):
    """Dense per-schedule scores: returns ``(scores, ids)`` both
    ``(M, S * layout_block)`` -- column order follows the schedule, invalid
    slots score -inf with id -1."""
    m, s = sched.shape
    safe = jnp.where(sched >= 0, sched, 0)                     # (M, S)
    rows = (safe[:, :, None] * layout_block
            + jnp.arange(layout_block)[None, None, :]).reshape(m, -1)
    x = codes[rows].astype(jnp.float32)                        # (M, P, d)
    tag = jnp.broadcast_to(block_tags[safe][:, :, None],
                           (m, s, layout_block)).reshape(m, -1)
    q_sel = q_scaled[jnp.arange(m)[:, None], tag]              # (M, P, d)
    lo_sel = jnp.take_along_axis(q_lo, tag, axis=1)            # (M, P)
    scores = jnp.sum(q_sel * x, axis=-1) + lo_sel
    ids = row_ids[rows].astype(jnp.int32)
    ok = jnp.broadcast_to(sched[:, :, None] >= 0,
                          (m, s, layout_block)).reshape(m, -1) & (ids >= 0)
    return jnp.where(ok, scores, NEG_INF), jnp.where(ok, ids, -1)


def ivf_scan_topk_ref(q_scaled: jax.Array, q_lo: jax.Array,
                      block_tags: jax.Array, row_ids: jax.Array,
                      codes: jax.Array, sched: jax.Array, k: int,
                      layout_block: int):
    """Gather + dense score + ``top_k`` oracle of :func:`ivf_scan_topk`;
    -inf winners' ids are stripped to -1 exactly like the kernel."""
    scores, ids = ivf_scan_scores_ref(q_scaled, q_lo, block_tags, row_ids,
                                      codes, sched, layout_block)
    vals, sel = jax.lax.top_k(scores, k)
    out = jnp.take_along_axis(ids, sel, axis=1)
    return vals, jnp.where(vals > NEG_INF, out, -1)
