"""Pallas TPU kernel: spherical k-means assignment scan (paper Eq. 14/23).

Used during GleanVec learning (Algorithm 5, every EM iteration touches all n
database rows) and online when inserting vectors into a streaming index. The
centroid matrix stays resident in VMEM (C <= 100 in the paper; C x D fp32 at
C=64, D=960 is 240 KiB); database tiles stream through once:

    sims = x_tile @ centers^T   (MXU)
    tag  = argmax, val = max    (VPU)

HBM traffic = N*D*4 bytes read, N*8 written -- purely bandwidth-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_assign_kernel(x_ref, c_ref, tags_ref, sims_ref):
    x = x_ref[...].astype(jnp.float32)         # (TN, D)
    cent = c_ref[...].astype(jnp.float32)      # (C, D)
    sims = jax.lax.dot_general(
        x, cent, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (TN, C)
    tags_ref[...] = jnp.argmax(sims, axis=1).astype(jnp.int32)
    sims_ref[...] = jnp.max(sims, axis=1)


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def kmeans_assign(x: jax.Array, centers: jax.Array, tn: int = 1024,
                  interpret: bool = False):
    """``x (N, D)``, ``centers (C, D)`` -> (tags (N,) i32, maxsim (N,) f32)."""
    n, d = x.shape
    c = centers.shape[0]
    n_pad = (-n) % tn
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // tn,)

    tags, sims = pl.pallas_call(
        _kmeans_assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centers)
    return tags[:n], sims[:n]
