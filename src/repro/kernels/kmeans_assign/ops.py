"""Public op: k-means assignment with Pallas kernel + fallback."""
from __future__ import annotations

import jax

from repro.kernels.kmeans_assign.kmeans_assign import (
    kmeans_assign as _pallas_kmeans_assign)
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref


def kmeans_assign(x: jax.Array, centers: jax.Array, tn: int = 1024,
                  use_pallas: bool | None = None, interpret: bool = False):
    """``x (N, D)``, ``centers (C, D)`` -> (tags, maxsim)."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if use_pallas:
        return _pallas_kmeans_assign(x, centers, tn=tn, interpret=interpret)
    return kmeans_assign_ref(x, centers)
