"""Pure-jnp oracle for the spherical k-means assignment kernel (Eq. 14/23)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jax.Array, centers: jax.Array):
    """``x (N, D)``, ``centers (C, D)`` -> (tags (N,) i32, maxsim (N,) f32)."""
    sims = x.astype(jnp.float32) @ centers.astype(jnp.float32).T
    return (jnp.argmax(sims, axis=1).astype(jnp.int32),
            jnp.max(sims, axis=1))
