from repro.kernels.sq_dot.ops import sq_dot
from repro.kernels.sq_dot.ref import sq_dot_ref

__all__ = ["sq_dot", "sq_dot_ref"]
