"""Public op: int8 scalar-quantized scoring with Pallas kernel + fallback."""
from __future__ import annotations

import jax

from repro.kernels.sq_dot.ref import sq_dot_ref
from repro.kernels.sq_dot.sq_dot import sq_dot as _pallas_sq_dot


def sq_dot(q: jax.Array, codes: jax.Array, lo: jax.Array, delta: jax.Array,
           tm: int = 128, tn: int = 512, use_pallas: bool | None = None,
           interpret: bool = False):
    """``q (M, d)``, ``codes (N, d)``, ``lo/delta (N,)`` -> scores (M, N)."""
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    if use_pallas:
        return _pallas_sq_dot(q, codes, lo, delta, tm=tm, tn=tn,
                              interpret=interpret)
    return sq_dot_ref(q, codes, lo, delta)
