"""Pure-jnp oracle for the int8 scalar-quantized dot kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sq_dot_ref(q: jax.Array, codes: jax.Array, lo: jax.Array,
               delta: jax.Array):
    """``q (M, d)``, ``codes (N, d) u8``, ``lo/delta (d,)`` -> scores (M, N).

    scores[m, n] = <q_m, codes_n * delta + lo>
                 = <q_m * delta, codes_n> + <q_m, lo>.
    """
    qf = q.astype(jnp.float32)
    q_scaled = qf * delta[None, :]
    return q_scaled @ codes.astype(jnp.float32).T \
        + (qf @ lo)[:, None]
