"""Pallas TPU kernel: int8 scalar-quantized inner products.

The paper applies scalar quantization on top of the reduced vectors Bx
(Section 3), compounding the bandwidth win: d * 1 byte per vector instead of
D * 4. Per-dimension scales fold into the query OUTSIDE the N loop
(<q, u*delta + lo> = <q*delta, u> + <q, lo>), so the kernel body is a pure
int8->f32 MXU matmul over streamed code tiles plus one broadcast add.
HBM traffic per database vector = d bytes.

VMEM per step (TM=128, TN=512, d=160): q 80 KiB + codes 80 KiB (u8)
+ scores 256 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sq_dot_kernel(qs_ref, qlo_ref, codes_ref, out_ref):
    qs = qs_ref[...].astype(jnp.float32)             # (TM, d) pre-scaled q
    u = codes_ref[...].astype(jnp.float32)           # (TN, d)
    qdotu = jax.lax.dot_general(
        qs, u, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (TM, TN)
    out_ref[...] = qdotu + qlo_ref[...]              # (TM, 1) broadcast


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def sq_dot(q: jax.Array, codes: jax.Array, lo: jax.Array, delta: jax.Array,
           tm: int = 128, tn: int = 512, interpret: bool = False):
    """``q (M, d)``, ``codes (N, d) u8``, ``lo/delta (d,)`` -> (M, N) f32."""
    m, d = q.shape
    n = codes.shape[0]
    qf = q.astype(jnp.float32)
    q_scaled = qf * delta[None, :]
    q_lo = (qf @ lo)[:, None]                        # (M, 1)
    tm = min(tm, max(8, m))
    m_pad = (-m) % tm
    n_pad = (-n) % tn
    if m_pad:
        q_scaled = jnp.pad(q_scaled, ((0, m_pad), (0, 0)))
        q_lo = jnp.pad(q_lo, ((0, m_pad), (0, 0)))
    if n_pad:
        codes = jnp.pad(codes, ((0, n_pad), (0, 0)))
    grid = ((m + m_pad) // tm, (n + n_pad) // tn)

    out = pl.pallas_call(
        _sq_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + m_pad, n + n_pad), jnp.float32),
        interpret=interpret,
    )(q_scaled, q_lo, codes)
    return out[:m, :n]
