import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, parsed collective bytes, trip-corrected
roofline terms, and the compile wall time. --all runs cells in subprocesses
(isolates XLA state; an OOM/crash in one cell cannot take down the sweep) and
skips cells whose JSON already exists (incremental; --force to redo).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def cell_path(arch: str, shape: str, mesh_kind: str) -> str:
    safe = f"{arch}__{shape}__{mesh_kind}".replace("/", "_")
    return os.path.abspath(os.path.join(RESULTS_DIR, safe + ".json"))


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    from jax.sharding import NamedSharding
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_bundle
    from repro.utils import hlo_analysis, roofline

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.size
    t0 = time.time()
    bundle = build_bundle(arch, shape, mesh)

    def to_sharding(spec_tree, arg_tree):
        return jax.tree.map(
            lambda spec, _: NamedSharding(mesh, spec), spec_tree, arg_tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    in_shardings = tuple(
        to_sharding(s, a) for s, a in zip(bundle.in_shardings, bundle.args))
    out_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), bundle.out_shardings,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    from repro.utils.jax_compat import set_mesh
    with set_mesh(mesh):
        jitted = jax.jit(bundle.fn, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = hlo_analysis.normalize_cost(compiled.cost_analysis())
    hlo_text = compiled.as_text()

    # CPU-backend bf16 legalization: XLA CPU materializes f32 twins of large
    # bf16 buffers (hoisted converts around DUS/dots/collectives) that do
    # not exist in TPU modules (bf16 dots/updates are native there).
    # Estimate their footprint: f32 shapes >= 256 MB that have an
    # identically-dimensioned bf16 buffer, counted once per DISTINCT
    # defining instruction (buffer-assignment reuse makes this an upper
    # bound on liveness, so the subtraction is capped: the estimate never
    # drops below arguments + outputs + 10% of raw temps).
    import re as _re
    f32_defs, bf16_shapes = {}, set()
    for m in _re.finditer(
            r"%([\w.\-]+)\s*=\s*(f32|bf16)\[([\d,]+)\]", hlo_text):
        name, dt, dims = m.groups()
        if dt == "bf16":
            bf16_shapes.add(dims)
        else:
            f32_defs.setdefault(dims, set()).add(name)
    twin_bytes = 0
    for dims, names in f32_defs.items():
        if dims not in bf16_shapes:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= 256e6:
            # liveness heuristic: at most 3 concurrent copies per shape
            twin_bytes += n * 4 * min(len(names), 3)
    stats = hlo_analysis.analyze_hlo(
        hlo_text, default_trips=bundle.trip_counts)

    corr = (stats["dot_flops"] / float(cost.get("flops", 1.0))
            if cost.get("flops") else 1.0)
    terms = roofline.compute_terms(cost, stats, bundle.model_flops, n_chips)

    record = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes),
            "fits_v5e_16g": (mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes
                             + mem.output_size_in_bytes
                             - mem.alias_size_in_bytes) < 16e9,
            "cpu_bf16_twin_bytes": twin_bytes,
            "peak_bytes_tpu_est": max(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes + 0.1 * mem.temp_size_in_bytes,
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes
                - twin_bytes),
            "fits_v5e_16g_tpu_est": max(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes + 0.1 * mem.temp_size_in_bytes,
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes
                - twin_bytes) < 16e9,
        },
        "cost": {k: v for k, v in cost.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": {
            "bytes": stats["collective_bytes"],
            "by_kind": stats["collective_by_kind"],
            "count": stats["n_collectives"],
            "while_trips": stats["while_trips"],
        },
        "flop_correction": corr,
        "roofline": terms.to_dict(),
        "notes": bundle.notes,
    }
    return record


def all_cells():
    from repro.configs.registry import ARCHS
    cells = []
    for arch, mod in ARCHS.items():
        for shape in mod.SHAPES:
            if shape in getattr(mod, "SKIPS", {}):
                continue
            cells.append((arch, shape))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        failures = []
        todo = [(a, s, m) for a, s in all_cells() for m in meshes]
        for i, (arch, shape, mk) in enumerate(todo):
            path = cell_path(arch, shape, mk)
            if os.path.exists(path) and not args.force:
                print(f"[{i+1}/{len(todo)}] SKIP (cached) {arch}:{shape}:{mk}")
                continue
            print(f"[{i+1}/{len(todo)}] RUN {arch}:{shape}:{mk}", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mk]
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode != 0:
                failures.append((arch, shape, mk))
                print(f"    FAILED:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
            else:
                print("    " + r.stdout.strip().splitlines()[-1])
        print(f"\ndone: {len(todo) - len(failures)}/{len(todo)} ok")
        if failures:
            print("failures:", failures)
            sys.exit(1)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    for mk in meshes:
        path = cell_path(args.arch, args.shape, mk)
        try:
            rec = run_cell(args.arch, args.shape, mk)
        except Exception as e:
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"{args.arch}:{args.shape}:{mk} FAILED: {rec['error']}")
            sys.exit(1)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        r = rec["roofline"]
        print(f"{args.arch}:{args.shape}:{mk} ok "
              f"compile={rec['compile_s']}s "
              f"peak/dev={rec['memory']['peak_bytes']/1e9:.2f}GB "
              f"terms(c/m/n)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
              f"{r['collective_s']:.2e}s bottleneck={r['bottleneck']}")


if __name__ == "__main__":
    main()
