"""Production mesh construction (see MULTI-POD DRY-RUN spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run entrypoint (dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real (1-device) platform.

Mesh creation goes through :mod:`repro.utils.jax_compat` so the same code
runs on jax versions with and without ``jax.sharding.AxisType``.
"""
from __future__ import annotations

import jax

from repro.utils.jax_compat import make_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist right now, as a 1D 'data' mesh (tests)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
