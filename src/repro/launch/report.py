"""Render the dry-run / roofline results into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def load(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return [r for r in rows if r.get("ok")]


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def dryrun_table(rows):
    out = ["| arch | shape | compile s | peak GB/dev | fits 16G | "
           "HLO GFLOP/dev | coll GB/dev | top collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        c = r["collectives"]
        kinds = sorted(c["by_kind"].items(), key=lambda kv: -kv[1])[:2]
        kinds_s = " ".join(f"{k}:{v/1e9:.1f}G" for k, v in kinds) or "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
            f"{fmt_bytes(r['memory']['peak_bytes'])} | "
            f"{'Y' if r['memory']['fits_v5e_16g'] else 'N'} | "
            f"{r['roofline']['hlo_flops'] / 1e9:.1f} | "
            f"{c['bytes'] / 1e9:.2f} | {kinds_s} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS | useful | peak frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"**{rf['bottleneck']}** | {rf['model_flops_total']:.2e} | "
            f"{rf['useful_flops_ratio']:.2f} | {rf['peak_fraction']:.4f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.table in ("dryrun", "both"):
        print(f"### Dry-run ({args.mesh} mesh, {rows[0]['n_chips'] if rows else '?'} chips)\n")
        print(dryrun_table(rows))
        print()
    if args.table in ("roofline", "both"):
        print(f"### Roofline ({args.mesh} mesh)\n")
        print(roofline_table(rows))


if __name__ == "__main__":
    main()
