"""Serving driver: batched vector-search service (Algorithm 1) over a
synthetic collection with selectable scoring mode, index and placement.

    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec --n 50000
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec-int8 \
        --index ivf --nprobe 12 --reduced-probe
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec \
        --index ivf --shards 4

The three axes are orthogonal: every scorer mode (full / sphering /
gleanvec / sphering-int8 / gleanvec-int8 / gleanvec-sorted /
gleanvec-int8-sorted) x every index (flat scan / IVF / graph) x placement
(single device, or --shards N per-shard sub-indexes merged through the
ShardedIndex wrapper) runs through the same SearchArtifacts + Scorer +
Index protocol path -- the flags are the only thing that differs between a
full-precision flat service and a sharded cluster-contiguous GleanVec+int8
IVF one. ``--reduced-probe`` projects the IVF coarse centers into the
scorer's reduced space so the probe consumes the prepared queries (R^d).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import search as msearch
from repro.core.scorer import MODES
from repro.data import vectors
from repro.index import distributed, graph, ivf
from repro.index.protocol import replace
from repro.serve.engine import ServingEngine, make_search_fn


def build_index(args, X, scorer, model):
    """The --index axis: an Index-protocol object (or None = flat scan)."""
    if args.index == "flat":
        return None
    if args.index == "ivf":
        idx = ivf.build(jax.random.PRNGKey(1), X, n_lists=args.lists,
                        nprobe=args.nprobe)
        if args.reduced_probe:
            idx = ivf.with_reduced_centers(idx, scorer, model)
        return idx
    if args.index == "graph":
        return replace(graph.build(np.asarray(X), r=args.graph_degree,
                                   n_iters=4, seed=0),
                       beam=args.beam, max_hops=args.max_hops)
    raise ValueError(f"unknown index {args.index!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gleanvec", choices=list(MODES))
    ap.add_argument("--index", default="flat",
                    choices=["flat", "ivf", "graph"])
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=48)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kappa", type=int, default=50)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--nprobe", type=int, default=12)
    ap.add_argument("--reduced-probe", action="store_true",
                    help="IVF coarse probe in the scorer's reduced space")
    ap.add_argument("--beam", type=int, default=96)
    ap.add_argument("--max-hops", type=int, default=200)
    ap.add_argument("--graph-degree", type=int, default=24)
    ap.add_argument("--shards", type=int, default=0,
                    help="N per-shard sub-indexes merged via ShardedIndex "
                         "(0 = single index)")
    args = ap.parse_args()

    ds = vectors.make_dataset("serve", n=args.n, d=args.dim, n_queries=512,
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)

    if args.mode == "full":
        model = None
    elif args.mode.startswith("sphering"):
        model = lvs.fit(Q, X, args.d)
    else:
        model = gv.fit(jax.random.PRNGKey(0), Q, X, c=args.clusters,
                       d=args.d)
    if args.shards:
        # the stacked per-shard scorer IS the serving scorer -- don't also
        # encode the whole database into a global one just to discard it
        index, stacked = distributed.build_sharded_index(
            args.index, args.mode, X, model, n_shards=args.shards,
            key=jax.random.PRNGKey(1), n_lists=args.lists,
            nprobe=args.nprobe, reduced_probe=args.reduced_probe,
            beam=args.beam, max_hops=args.max_hops,
            graph_kwargs={"r": args.graph_degree, "n_iters": 4, "seed": 0})
        artifacts = msearch.SearchArtifacts(scorer=stacked, x_full=X,
                                            model=model)
    else:
        artifacts = msearch.build_artifacts(args.mode, X, model)
        index = build_index(args, X, artifacts.scorer, model)
    kappa = 10 if args.mode == "full" else args.kappa
    search_fn = make_search_fn(artifacts, k=10, kappa=kappa, index=index)

    engine = ServingEngine(search_fn, batch_size=args.batch, dim=args.dim)
    ids = engine.submit(ds.queries_test)
    rec = metrics.recall_at_k(jnp.asarray(ids), jnp.asarray(ds.gt[:, :10]))
    s = engine.stats
    placement = f"shards={args.shards}" if args.shards else "single"
    print(f"mode={args.mode} index={args.index} {placement} "
          f"n={args.n} D={args.dim} d={args.d} "
          f"reduced_probe={args.reduced_probe}")
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms recall@10={float(rec):.3f}")


if __name__ == "__main__":
    main()
