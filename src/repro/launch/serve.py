"""Serving driver: batched vector-search service (Algorithm 1) over a
synthetic collection with selectable scoring mode, index and placement.

    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec --n 50000
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec-int8 \
        --index ivf --nprobe 12 --reduced-probe
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec \
        --index ivf --shards 4
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec-int8 \
        --stream --cycles 4

The three axes are orthogonal: every scorer mode (full / sphering /
gleanvec / sphering-int8 / gleanvec-int8 / gleanvec-sorted /
gleanvec-int8-sorted) x every index (flat scan / IVF / graph) x placement
(single device, or --shards N per-shard sub-indexes merged through the
ShardedIndex wrapper) runs through the same SearchArtifacts + Scorer +
Index protocol path -- the flags are the only thing that differs between a
full-precision flat service and a sharded cluster-contiguous GleanVec+int8
IVF one. ``--reduced-probe`` projects the IVF coarse centers into the
scorer's reduced space so the probe consumes the prepared queries (R^d).
``--fused-graph`` (sorted modes) binds the graph's edge lists to the
tag-sorted layout so every hop runs the gather-free fused beam-step kernel;
``--graph-build device`` constructs the graph on the accelerator
(CAGRA-style fused self-join) instead of numpy NN-descent.

``--stream`` drives the Section 3.2 lifecycle under live traffic: the
engine keeps serving drifted (OOD) queries while each cycle observes them
into K_Q, inserts new database rows into the fixed-capacity store, and
swaps the Eq. 11-12 refreshed state in -- zero recompiles after warmup,
asserted by the engine's compile counter.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import search as msearch
from repro.core import streaming
from repro.core.scorer import MODES
from repro.data import vectors
from repro.index import distributed, graph, ivf
from repro.index.protocol import replace
from repro.serve.engine import ServingEngine


def build_index(args, X, scorer, model):
    """The --index axis: an Index-protocol object (or None = flat scan)."""
    if args.index == "flat":
        return None
    if args.index == "ivf":
        if args.aligned:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--aligned needs a sorted scorer mode "
                                 "(gleanvec-sorted / gleanvec-int8-sorted)")
            idx = ivf.build_aligned(model, X, nprobe=args.nprobe)
        else:
            idx = ivf.build(jax.random.PRNGKey(1), X, n_lists=args.lists,
                            nprobe=args.nprobe)
        if args.reduced_probe:
            idx = ivf.with_reduced_centers(idx, scorer, model)
        return idx
    if args.index == "graph":
        idx = replace(graph.build(np.asarray(X), r=args.graph_degree,
                                  n_iters=4, seed=0,
                                  method=args.graph_build),
                      beam=args.beam, max_hops=args.max_hops,
                      expand=args.expand)
        if args.fused_graph:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--fused-graph needs a sorted scorer mode "
                                 "(gleanvec-sorted / gleanvec-int8-sorted)")
            idx = graph.with_fused_scan(idx, scorer)
        return idx
    raise ValueError(f"unknown index {args.index!r}")


def run_stream(args):
    """Section 3.2 lifecycle under live traffic: serve drifted queries,
    observe them into K_Q, insert rows, refresh, hot-swap -- one compiled
    executable throughout."""
    n0 = int(args.n * 0.7)
    step = (args.n - n0) // args.cycles
    ds = vectors.make_dataset("serve-stream", n=args.n, d=args.dim,
                              n_queries=max(512, args.batch * args.cycles),
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    # the model serving at t=0 was fit on ID (database-like) queries; the
    # live traffic below is OOD -- the drift the refreshes adapt to
    q_init = np.asarray(X)[rng.integers(0, n0, 1024)] \
        + 0.1 * rng.standard_normal((1024, args.dim)).astype(np.float32)
    if args.mode.startswith("sphering"):
        model = lvs.fit(jnp.asarray(q_init), X[:n0], args.d)
    else:
        model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                       c=args.clusters, d=args.d)
    artifacts = streaming.build_streaming_artifacts(
        args.mode, X[:n0], model, capacity=args.n, sort_block=256,
        slack_blocks=2)
    index = None
    if args.index == "ivf":
        if args.aligned:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--aligned needs a sorted scorer mode")
            index = ivf.build_aligned(model, X[:n0], nprobe=args.nprobe)
        else:
            index = ivf.build(jax.random.PRNGKey(1), X[:n0],
                              n_lists=args.lists, nprobe=args.nprobe)
        # slack is per list: expected fill + 4x skew headroom, NOT the
        # total insert count (that would inflate every probe's gather);
        # sized from the BUILT index's list count (--aligned has
        # model.n_clusters lists, not --lists)
        slack = 4 * max(1, (args.n - n0) // index.n_lists)
        index = ivf.with_list_slack(index, slack)
        if args.reduced_probe:
            index = ivf.with_reduced_centers(index, artifacts.scorer, model)
    engine = ServingEngine(msearch.make_state(artifacts, index=index),
                           k=10, kappa=args.kappa, batch_size=args.batch,
                           dim=args.dim)
    stream = streaming.init_from_artifacts(artifacts, q_init,
                                           refresh_every=step)
    print(f"stream mode={args.mode} index={args.index} n0={n0} "
          f"capacity={args.n} D={args.dim} d={args.d} "
          f"cycles={args.cycles} inserts/cycle={step}")
    for cycle in range(args.cycles):
        obs = QT[(cycle * args.batch) % len(QT):][:args.batch]
        live_idx = np.nonzero(streaming.live_mask(engine.state.artifacts))[0]
        served = engine.submit(obs)           # live traffic keeps flowing
        gt = live_idx[vectors.exact_topk(
            obs, np.asarray(engine.state.artifacts.x_full)[live_idx], 10)]
        rec = float(metrics.recall_at_k(jnp.asarray(served),
                                        jnp.asarray(gt)))
        stream = streaming.observe_queries(stream, jnp.asarray(obs))
        rows = X[n0 + cycle * step: n0 + (cycle + 1) * step]
        arts2, new_ids = streaming.insert_rows(engine.state.artifacts, rows)
        stream = streaming.insert(stream, rows)
        state2 = engine.state._replace(artifacts=arts2)
        if index is not None:
            state2 = state2._replace(
                index=ivf.insert_ids(state2.index, rows, new_ids))
        engine.swap(state2)
        stream = streaming.refresh(stream)
        engine.swap(streaming.refresh_state(engine.state, stream,
                                            source=args.refresh_source))
        print(f"  cycle {cycle}: served {served.shape[0]} queries "
              f"recall@10={rec:.3f} live_rows="
              f"{int(streaming.live_mask(engine.state.artifacts).sum())} "
              f"version={engine.version} compiles={engine.n_compiles} "
              f"swap_p50={np.median(engine.stats.swap_ms):.2f}ms")
    s = engine.stats
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms "
          f"swaps={engine.n_swaps} compiles={engine.n_compiles} "
          f"(zero recompiles after warmup: "
          f"{engine.n_compiles in (None, 1)})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gleanvec", choices=list(MODES))
    ap.add_argument("--index", default="flat",
                    choices=["flat", "ivf", "graph"])
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=48)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kappa", type=int, default=50)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--nprobe", type=int, default=12)
    ap.add_argument("--reduced-probe", action="store_true",
                    help="IVF coarse probe in the scorer's reduced space")
    ap.add_argument("--aligned", action="store_true",
                    help="IVF coarse quantizer = the GleanVec clustering "
                         "(sorted modes: gather-free range-scan fine step)")
    ap.add_argument("--beam", type=int, default=96)
    ap.add_argument("--max-hops", type=int, default=200)
    ap.add_argument("--expand", type=int, default=1,
                    help="graph frontier vertices expanded per hop "
                         "(multi-expansion beam search; 1 = classic)")
    ap.add_argument("--graph-degree", type=int, default=24)
    ap.add_argument("--graph-build", default="numpy",
                    choices=["numpy", "device", "auto"],
                    help="graph construction: numpy NN-descent, on-device "
                         "CAGRA-style self-join, or auto (device at large n)")
    ap.add_argument("--fused-graph", action="store_true",
                    help="sorted modes: bind the graph to the tag-sorted "
                         "layout (graph.with_fused_scan) so every hop runs "
                         "the gather-free fused beam-step kernel")
    ap.add_argument("--shards", type=int, default=0,
                    help="N per-shard sub-indexes merged via ShardedIndex "
                         "(0 = single index)")
    ap.add_argument("--stream", action="store_true",
                    help="drive the Section 3.2 observe -> insert -> "
                         "refresh -> swap lifecycle under live traffic")
    ap.add_argument("--cycles", type=int, default=3,
                    help="streaming refresh cycles (--stream)")
    ap.add_argument("--refresh-source", default="stored",
                    choices=["stored", "full"],
                    help="refresh via Eq. 12 over stored vectors or exact "
                         "re-encode from the rerank store")
    args = ap.parse_args()

    if args.stream:
        if args.mode == "full" or args.shards or args.index == "graph":
            raise SystemExit("--stream needs a DR mode and a flat or IVF "
                             "single-device index")
        run_stream(args)
        return

    ds = vectors.make_dataset("serve", n=args.n, d=args.dim, n_queries=512,
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)

    if args.mode == "full":
        model = None
    elif args.mode.startswith("sphering"):
        model = lvs.fit(Q, X, args.d)
    else:
        model = gv.fit(jax.random.PRNGKey(0), Q, X, c=args.clusters,
                       d=args.d)
    if args.shards:
        # the stacked per-shard scorer IS the serving scorer -- don't also
        # encode the whole database into a global one just to discard it
        index, stacked = distributed.build_sharded_index(
            args.index, args.mode, X, model, n_shards=args.shards,
            key=jax.random.PRNGKey(1), n_lists=args.lists,
            nprobe=args.nprobe, reduced_probe=args.reduced_probe,
            aligned=args.aligned, beam=args.beam, max_hops=args.max_hops,
            expand=args.expand, fused_graph=args.fused_graph,
            graph_kwargs={"r": args.graph_degree, "n_iters": 4, "seed": 0,
                          "method": args.graph_build})
        artifacts = msearch.SearchArtifacts(scorer=stacked, x_full=X,
                                            model=model)
    else:
        artifacts = msearch.build_artifacts(args.mode, X, model)
        index = build_index(args, X, artifacts.scorer, model)
    kappa = 10 if args.mode == "full" else args.kappa

    engine = ServingEngine(msearch.make_state(artifacts, index=index),
                           k=10, kappa=kappa, batch_size=args.batch,
                           dim=args.dim)
    ids = engine.submit(ds.queries_test)
    rec = metrics.recall_at_k(jnp.asarray(ids), jnp.asarray(ds.gt[:, :10]))
    s = engine.stats
    placement = f"shards={args.shards}" if args.shards else "single"
    print(f"mode={args.mode} index={args.index} {placement} "
          f"n={args.n} D={args.dim} d={args.d} "
          f"reduced_probe={args.reduced_probe}")
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms recall@10={float(rec):.3f}")


if __name__ == "__main__":
    main()
