"""Serving driver: batched vector-search service (Algorithm 1) over a
synthetic collection with selectable scoring mode, index and placement.

    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec --n 50000
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec-int8 \
        --index ivf --nprobe 12 --reduced-probe
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec \
        --index ivf --shards 4
    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec-int8 \
        --stream --cycles 4

The three axes are orthogonal: every scorer mode (full / sphering /
gleanvec / sphering-int8 / gleanvec-int8 / gleanvec-sorted /
gleanvec-int8-sorted) x every index (flat scan / IVF / graph) x placement
(single device, or --shards N per-shard sub-indexes merged through the
ShardedIndex wrapper) runs through the same SearchArtifacts + Scorer +
Index protocol path -- the flags are the only thing that differs between a
full-precision flat service and a sharded cluster-contiguous GleanVec+int8
IVF one. ``--reduced-probe`` projects the IVF coarse centers into the
scorer's reduced space so the probe consumes the prepared queries (R^d).
``--fused-graph`` (sorted modes) binds the graph's edge lists to the
tag-sorted layout so every hop runs the gather-free fused beam-step kernel;
``--graph-build device`` constructs the graph on the accelerator
(CAGRA-style fused self-join) instead of numpy NN-descent.

``--stream`` drives the Section 3.2 lifecycle under live traffic: the
engine keeps serving drifted (OOD) queries while each cycle observes them
into K_Q, inserts new database rows into the fixed-capacity store, and
swaps the Eq. 11-12 refreshed state in -- zero recompiles after warmup,
asserted by the engine's compile counter. The stream loop runs through
the fault-tolerant lifecycle layer: every swap is GUARDED (non-finite
scan + version monotonicity + canary top-k overlap, `serve/lifecycle.py`)
and every refresh SUPERVISED (retry/backoff, stored->full escalation,
graceful degradation). ``--snapshot-dir`` persists the
ServingState + StreamingState pair each cycle; ``--restore`` resumes a
killed process from the newest restorable snapshot -- template model, NO
refit -- and continues the refresh cadence; ``--inject-fault <kind>``
drills one full fail -> degrade -> recover -> swap cycle end-to-end
(exits non-zero if the stack mishandles it).

``--frontend`` runs the ASYNC serving topology (`serve/frontend.py`) --
the ``--stream`` loop's observe/refresh/swap lifecycle moved off-thread,
with concurrent clients admitted through a bounded coalescing queue::

    clients ----> enqueue(query, deadline) ---------+   Rejected(queue-full
       |              |                             |   / deadline) -> client
       |       [bounded admission queue]            |
       |              | drain: shed expired -------+   Rejected(shed)
       |        [pad to static bucket shape]
       |              v
       |      dispatcher: search_with(state)  <- atomic state read
       |              |        ^
       |   slice per-request   | GuardedEngine.swap (validated)
       v              v        |
    futures <- ids  RefreshWorker thread: observe -> refresh (supervised:
                    retry/backoff -> escalate -> degrade -> recover)

Serving never blocks on a refresh; a stuck/crashed worker leaves the
stale-but-valid state answering (staleness grows, the alertable signal).
``--frontend --inject-fault {stuck-worker, slow-refresh, poison-burst,
queue-overflow}`` drills exactly those overload/concurrency faults,
asserting the frontend keeps answering within SLO or sheds predictably
(exits non-zero otherwise).
"""
from __future__ import annotations

import argparse
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import search as msearch
from repro.core import streaming
from repro.core.scorer import MODES
from repro.data import vectors
from repro.index import distributed, graph, ivf
from repro.index.protocol import replace
from repro.serve import faults, frontend, lifecycle
from repro.serve.engine import ServingEngine
from repro.train import checkpoint


def build_index(args, X, scorer, model):
    """The --index axis: an Index-protocol object (or None = flat scan)."""
    if args.index == "flat":
        return None
    if args.index == "ivf":
        if args.aligned:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--aligned needs a sorted scorer mode "
                                 "(gleanvec-sorted / gleanvec-int8-sorted)")
            idx = ivf.build_aligned(model, X, nprobe=args.nprobe)
        else:
            idx = ivf.build(jax.random.PRNGKey(1), X, n_lists=args.lists,
                            nprobe=args.nprobe)
        if args.reduced_probe:
            idx = ivf.with_reduced_centers(idx, scorer, model)
        return idx
    if args.index == "graph":
        idx = replace(graph.build(np.asarray(X), r=args.graph_degree,
                                  n_iters=4, seed=0,
                                  method=args.graph_build),
                      beam=args.beam, max_hops=args.max_hops,
                      expand=args.expand)
        if args.fused_graph:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--fused-graph needs a sorted scorer mode "
                                 "(gleanvec-sorted / gleanvec-int8-sorted)")
            idx = graph.with_fused_scan(idx, scorer)
        return idx
    raise ValueError(f"unknown index {args.index!r}")


def _stream_model(args, q_init, X, n0, template: bool):
    """The stream's DR model: a real fit, or (restore path) a structural
    template -- same classes/treedef, placeholder weights, NO refit."""
    if template:
        return lifecycle.template_model(args.mode, args.dim, args.d,
                                        clusters=args.clusters)
    if args.mode.startswith("sphering"):
        return lvs.fit(jnp.asarray(q_init), X[:n0], args.d)
    return gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:n0],
                  c=args.clusters, d=args.d)


def _drill_fail(msg):
    print(f"  drill FAIL: {msg}")
    raise SystemExit(1)


def _fault_drill(kind, guarded, supervisor, stream, obs, snap_dir):
    """Inject one ``--inject-fault`` kind mid-stream and verify the stack
    handles it. Immediate kinds (rejected swaps, snapshot fallback, query
    hardening) are checked here; deferred kinds (poisoned moments, a
    refresh exception) hand back a poisoned stream / failing refresh_fn
    plus a check to run after the cycle's supervised refresh. Returns
    ``(stream, refresh_fn, deferred_check)``; any mishandling exits 1."""
    eng = guarded.engine
    print(f"  -- injecting fault: {kind}")
    if kind == "nan-moments":
        def check(rep):
            if rep.outcome != "degraded":
                _drill_fail("poisoned moments were not degraded "
                            f"(outcome={rep.outcome})")
            if lifecycle.nonfinite_leaves(eng.state):
                _drill_fail("engine is serving non-finite state")
            print(f"  drill: refresh degraded after {rep.attempts} attempts "
                  "(still serving last-known-good) -> recovering")
        return faults.nan_moments(stream), streaming.refresh, check
    if kind == "refresh-exception":
        fn = faults.failing(streaming.refresh, n_failures=1)

        def check(rep):
            if rep.outcome != "ok" or rep.attempts < 2:
                _drill_fail("retry did not absorb the injected exception "
                            f"(outcome={rep.outcome} attempts={rep.attempts})")
            print(f"  drill PASS: refresh-exception absorbed on attempt "
                  f"{rep.attempts} (escalated={rep.escalated})")
        return stream, fn, check
    # immediate kinds: verified against a pre-fault result set
    before = guarded.submit(obs)
    if kind in ("corrupt-scorer", "scramble-scorer"):
        bad = (faults.corrupt_scorer_leaf if kind == "corrupt-scorer"
               else faults.scramble_scorer_leaf)(eng.state)
        want = "non-finite" if kind == "corrupt-scorer" else "canary-overlap"
        v0, s0 = guarded.version, eng.n_swaps
        try:
            guarded.swap(bad)
            _drill_fail("corrupted state was accepted")
        except lifecycle.SwapRejected as e:
            if e.reason != want:
                _drill_fail(f"rejected for {e.reason!r}, expected {want!r}")
        if (guarded.version, eng.n_swaps) != (v0, s0):
            _drill_fail("rejected swap mutated the engine")
        if not np.array_equal(guarded.submit(obs), before):
            _drill_fail("results changed across a rejected swap")
        print(f"  drill PASS: {kind} rejected ({want}), "
              "results bit-identical")
    elif kind == "truncated-snapshot":
        d = snap_dir or tempfile.mkdtemp(prefix="snap-drill-")
        lifecycle.snapshot(d, eng.state, stream, meta={"drill": 0})
        lifecycle.snapshot(d, eng.state, stream, meta={"drill": 1})
        steps = checkpoint.available_steps(d)
        faults.truncate_snapshot(d, what="manifest")
        serving, _, got, meta = lifecycle.restore(d, eng.state, stream)
        if got != steps[-2] or meta.get("drill") != 0:
            _drill_fail(f"restore did not fall back (got step {got})")
        lifecycle.restore_into(guarded, serving)
        if not np.array_equal(guarded.submit(obs), before):
            _drill_fail("restored state is not bit-identical")
        print(f"  drill PASS: truncated step {steps[-1]} fell back to "
              f"step {got}, restored results bit-identical")
    elif kind == "poison-queries":
        res = guarded.submit(faults.poison_queries(obs))
        if not (res[0] == -1).all():
            _drill_fail("poisoned row returned fabricated ids")
        if not np.array_equal(res[1:], before[1:]):
            _drill_fail("poisoned row contaminated its batch")
        print("  drill PASS: poisoned row sanitized to -1, "
              "batch uncontaminated")
    elif kind == "wrong-dim-queries":
        try:
            guarded.submit(faults.wrong_dim_queries(obs))
            _drill_fail("wrong-dimensionality batch was accepted")
        except ValueError as e:
            print(f"  drill PASS: wrong-dim batch refused ({e})")
    else:
        raise SystemExit(f"unknown fault kind {kind!r}")
    return stream, streaming.refresh, None


def run_stream(args):
    """Section 3.2 lifecycle under live traffic: serve drifted queries,
    observe them into K_Q, insert rows, refresh, hot-swap -- one compiled
    executable throughout, every swap guarded and every refresh
    supervised (see module docstring)."""
    n0 = int(args.n * 0.7)
    step = (args.n - n0) // args.cycles
    ds = vectors.make_dataset("serve-stream", n=args.n, d=args.dim,
                              n_queries=max(512, args.batch * args.cycles),
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)
    rng = np.random.default_rng(0)
    # the model serving at t=0 was fit on ID (database-like) queries; the
    # live traffic below is OOD -- the drift the refreshes adapt to
    q_init = np.asarray(X)[rng.integers(0, n0, 1024)] \
        + 0.1 * rng.standard_normal((1024, args.dim)).astype(np.float32)
    restoring = False
    if args.restore:
        if not args.snapshot_dir:
            raise SystemExit("--restore needs --snapshot-dir")
        restoring = bool(checkpoint.available_steps(args.snapshot_dir))
        if not restoring:
            print(f"no snapshots under {args.snapshot_dir}; cold start")
    model = _stream_model(args, q_init, X, n0, template=restoring)
    artifacts = streaming.build_streaming_artifacts(
        args.mode, X[:n0], model, capacity=args.n, sort_block=256,
        slack_blocks=2, host_rerank=args.host_rerank)
    index = None
    if args.index == "graph":
        index = replace(graph.build(np.asarray(X[:n0]), r=args.graph_degree,
                                    n_iters=4, seed=0,
                                    method=args.graph_build),
                        beam=args.beam, max_hops=args.max_hops,
                        expand=args.expand)
        # pre-allocate edge rows for every future insert (shape-preserving
        # growth, like IVF's list slack)
        index = graph.with_capacity(index, args.n)
        if args.fused_graph:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--fused-graph needs a sorted scorer mode")
            index = graph.with_fused_scan(index, artifacts.scorer)
    elif args.index == "ivf":
        if args.aligned:
            if not args.mode.endswith("-sorted"):
                raise SystemExit("--aligned needs a sorted scorer mode")
            index = ivf.build_aligned(model, X[:n0], nprobe=args.nprobe)
        else:
            index = ivf.build(jax.random.PRNGKey(1), X[:n0],
                              n_lists=args.lists, nprobe=args.nprobe)
        # slack is per list: expected fill + 4x skew headroom, NOT the
        # total insert count (that would inflate every probe's gather);
        # sized from the BUILT index's list count (--aligned has
        # model.n_clusters lists, not --lists)
        slack = 4 * max(1, (args.n - n0) // index.n_lists)
        index = ivf.with_list_slack(index, slack)
        if args.reduced_probe:
            index = ivf.with_reduced_centers(index, artifacts.scorer, model)
    serving = msearch.make_state(artifacts, index=index)
    stream, cycle0 = None, 0
    if restoring:
        # templates above supplied STRUCTURE; leaves come from the snapshot
        serving, stream, snap_step, meta = lifecycle.restore(
            args.snapshot_dir, serving,
            lifecycle.template_stream(model, refresh_every=step))
        cycle0 = int(meta.get("cycle", -1)) + 1
        print(f"restored snapshot step {snap_step} -> resuming at cycle "
              f"{cycle0} (version {int(np.asarray(serving.version))}, "
              "no refit)")
    engine = ServingEngine(serving, k=10, kappa=args.kappa,
                           batch_size=args.batch, dim=args.dim)
    guarded = lifecycle.GuardedEngine(engine, canary_queries=QT[:args.batch],
                                      min_overlap=args.min_overlap)
    supervisor = lifecycle.RefreshSupervisor(guarded)
    if stream is None:
        stream = streaming.init_from_artifacts(artifacts, q_init,
                                               refresh_every=step)
    print(f"stream mode={args.mode} index={args.index} n0={n0} "
          f"capacity={args.n} D={args.dim} d={args.d} "
          f"cycles={args.cycles} inserts/cycle={step} "
          f"guard(min_overlap={args.min_overlap})")
    drill_cycle = -1
    if args.inject_fault:
        if args.inject_fault == "nan-moments" and args.cycles - cycle0 < 2:
            raise SystemExit("--inject-fault nan-moments needs >= 2 cycles "
                             "(degrade, then the recovered swap)")
        drill_cycle = max(cycle0, min(args.cycles // 2, args.cycles - 2))
    for cycle in range(cycle0, args.cycles):
        obs = QT[(cycle * args.batch) % len(QT):][:args.batch]
        refresh_fn, deferred = streaming.refresh, None
        if cycle == drill_cycle:
            stream, refresh_fn, deferred = _fault_drill(
                args.inject_fault, guarded, supervisor, stream, obs,
                args.snapshot_dir)
        live_idx = np.nonzero(streaming.live_mask(guarded.state.artifacts))[0]
        served = guarded.submit(obs)          # live traffic keeps flowing
        supervisor.note_queries(obs)
        gt = live_idx[vectors.exact_topk(
            obs, np.asarray(guarded.state.artifacts.x_full)[live_idx], 10)]
        rec = float(metrics.recall_at_k(jnp.asarray(served),
                                        jnp.asarray(gt)))
        stream = streaming.observe_queries(stream, jnp.asarray(obs))
        # the next unconsumed slice of X -- indexed off the LIVE count, not
        # the cycle number, so a restored run (possibly with a different
        # --cycles) continues exactly where the snapshot's store left off
        rows = X[live_idx.size: min(live_idx.size + step, args.n)]
        if rows.shape[0]:
            arts2, new_ids = streaming.insert_rows(guarded.state.artifacts,
                                                   rows)
            stream = streaming.insert(stream, rows)
            state2 = guarded.state._replace(artifacts=arts2)
            if index is not None:
                if args.index == "graph":
                    # connect the new rows: beam-search-for-neighbors +
                    # reverse-edge fill (full-D distances via the rerank
                    # tier, host or device)
                    state2 = state2._replace(index=graph.insert_ids(
                        state2.index, rows, np.asarray(new_ids),
                        arts2.scorer, arts2.x_full))
                else:
                    state2 = state2._replace(
                        index=ivf.insert_ids(state2.index, rows, new_ids))
            guarded.swap(state2)
        stream, rep = supervisor.refresh_and_swap(
            stream, source=args.refresh_source, refresh_fn=refresh_fn)
        if deferred is not None:
            deferred(rep)
        if rep.outcome == "degraded":
            # keep serving stale-but-valid; rebuild the moments from the
            # last-known-good store + retained queries for the next cycle
            stream = supervisor.recover(stream)
        bad = lifecycle.nonfinite_leaves(guarded.state)
        if bad:
            raise SystemExit(f"SERVE INVARIANT VIOLATED: non-finite leaves "
                             f"in served state: {bad[:4]}")
        print(f"  cycle {cycle}: served {served.shape[0]} queries "
              f"recall@10={rec:.3f} live_rows="
              f"{int(streaming.live_mask(guarded.state.artifacts).sum())} "
              f"version={guarded.version} compiles={guarded.n_compiles} "
              f"refresh={rep.outcome}/{rep.source} "
              f"swap_p50={np.median(engine.stats.swap_ms):.2f}ms")
        if args.snapshot_dir:
            lifecycle.snapshot(args.snapshot_dir, guarded.state, stream,
                               meta={"cycle": cycle})
    if args.inject_fault == "nan-moments":
        if supervisor.n_degraded < 1 or supervisor.n_recoveries < 1:
            _drill_fail("degrade/recover cycle did not complete")
        if supervisor.reports[-1].outcome != "ok":
            _drill_fail("post-recovery refresh did not swap")
        print("  drill PASS: nan-moments -> degraded -> recovered -> "
              "swapped")
    s = engine.stats
    h = supervisor
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms "
          f"swaps={engine.n_swaps} compiles={engine.n_compiles} "
          f"(zero recompiles after warmup: "
          f"{engine.n_compiles in (None, 1)})")
    print(f"guard: accepted={guarded.health.accepted} "
          f"rejected={guarded.health.rejected} "
          f"rollbacks={guarded.health.rollbacks} "
          f"last_overlap={guarded.health.last_overlap:.3f} | "
          f"supervisor: refreshes={h.n_refreshes} retries={h.n_retries} "
          f"escalations={h.n_escalations} degraded={h.n_degraded} "
          f"recoveries={h.n_recoveries}")


def _frontend_traffic(fe, queries, n_clients=4, deadline_ms=None,
                      timeout_s=60.0):
    """Fire ``queries`` at the frontend from ``n_clients`` concurrent
    client threads. Returns ``(results {row -> (k,) ids}, rejected
    {row -> reason})`` -- every offered request is accounted for, served
    or loudly refused."""
    results, rejected = {}, {}
    lock = threading.Lock()

    def client(rows):
        for i in rows:
            try:
                ids = fe.enqueue(queries[i],
                                 deadline_ms=deadline_ms).result(timeout_s)
                with lock:
                    results[i] = ids
            except frontend.Rejected as e:
                with lock:
                    rejected[i] = e.reason

    threads = [threading.Thread(target=client,
                                args=(range(c, len(queries), n_clients),))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results, rejected


def _await(cond, timeout_s=30.0, poll_s=0.01):
    t0 = time.monotonic()
    while not cond():
        if time.monotonic() - t0 > timeout_s:
            return False
        time.sleep(poll_s)
    return True


def _frontend_drill(args, fe, guarded, worker, release, refresh_fn, QT):
    """One ``--frontend --inject-fault`` overload/concurrency drill; any
    mishandling exits 1 through ``_drill_fail``."""
    kind = args.inject_fault
    eng = guarded.engine
    print(f"  -- injecting fault: {kind}")
    if kind == "poison-burst":
        burst = faults.burst_overflow(args.dim, args.batch * 4, seed=1,
                                      poison_frac=0.25)
        bad = ~np.isfinite(burst).all(axis=1)
        res, rej = _frontend_traffic(fe, burst)
        if rej:
            _drill_fail(f"in-capacity burst was rejected: {rej}")
        got = np.stack([res[i] for i in range(len(burst))])
        if not (got[bad] == -1).all():
            _drill_fail("poisoned rows returned fabricated ids")
        ref = eng.submit(burst)      # same sanitize gate, unbatched path
        if not np.array_equal(got, ref):
            _drill_fail("burst results diverge from direct submit")
        print(f"  drill PASS: {int(bad.sum())}/{len(burst)} poisoned rows "
              "-> -1, clean rows bit-identical to submit")
    elif kind == "queue-overflow":
        cap = 8
        fe_q = frontend.ServingFrontend(guarded, capacity=cap, start=False,
                                        warmup=False)
        burst = faults.burst_overflow(args.dim, cap + args.batch, seed=2)
        admitted, n_rej = [], 0
        for q in burst:              # no dispatcher: the queue must fill
            try:
                admitted.append(fe_q.enqueue(q))
            except frontend.Rejected as e:
                if e.reason != "queue-full":
                    _drill_fail(f"overflow rejected as {e.reason!r}")
                n_rej += 1
        if n_rej != len(burst) - cap:
            _drill_fail(f"admitted {len(admitted)}/{len(burst)} past "
                        f"capacity {cap}")
        if eng.stats.n_rejected < n_rej:
            _drill_fail("rejections not counted in ServeStats")
        while fe_q.queue_depth:
            fe_q.drain_once()
        if any((f.result(5)).shape != (eng.k,) for f in admitted):
            _drill_fail("admitted requests did not resolve after overflow")
        print(f"  drill PASS: {n_rej} overflow requests rejected loudly, "
              f"all {cap} admitted requests served")
    elif kind == "slow-refresh":
        n0 = worker.n_cycles
        worker.observe(QT[:args.batch])
        worker.request_refresh()
        # serving must proceed WHILE the slowed refresh runs
        res, rej = _frontend_traffic(fe, QT[:args.batch * 2])
        if len(res) + len(rej) != args.batch * 2:
            _drill_fail("requests lost during slow refresh")
        if not _await(lambda: worker.n_cycles > n0):
            _drill_fail("slowed refresh never completed")
        if refresh_fn.calls < 1:
            _drill_fail("slow_refresh injector never ran")
        print(f"  drill PASS: served {len(res)} requests during a "
              f"{refresh_fn.delay_s * 1e3:.0f}ms-delayed refresh "
              f"(staleness peaked, then swap landed)")
    elif kind == "stuck-worker":
        v0 = guarded.version
        worker.observe(QT[:args.batch])
        worker.request_refresh()
        if not _await(lambda: refresh_fn.calls >= 1):
            _drill_fail("stuck refresh never entered")
        time.sleep(0.05)
        if not worker.stuck(0.02):
            _drill_fail("watchdog did not flag the stuck worker")
        # the frontend must keep answering on the stale-but-valid state
        res, rej = _frontend_traffic(fe, QT[:args.batch * 2])
        if len(res) != args.batch * 2 or rej:
            _drill_fail("requests failed while the worker was stuck")
        if guarded.version != v0:
            _drill_fail("version moved while the refresh was stuck")
        release.set()
        if not _await(lambda: guarded.version > v0):
            _drill_fail("released worker never swapped")
        print(f"  drill PASS: {len(res)} requests served on the stale "
              f"state while stuck; release -> swap (version {v0} -> "
              f"{guarded.version})")
    else:
        raise SystemExit(f"unknown frontend fault kind {kind!r}")


def run_frontend(args):
    """Async serving topology: bounded-queue coalescing frontend over a
    guarded engine, refresh lifecycle on a supervised background worker,
    mixed ID/OOD traffic from concurrent clients (see module docstring
    diagram)."""
    ds = vectors.make_dataset("serve-frontend", n=args.n, d=args.dim,
                              n_queries=max(512, args.batch * 8), ood=True,
                              seed=0)
    X = jnp.asarray(ds.database)
    QT = np.asarray(ds.queries_test)              # OOD (drifted) traffic
    rng = np.random.default_rng(0)
    q_id = np.asarray(X)[rng.integers(0, args.n, 1024)] \
        + 0.1 * rng.standard_normal((1024, args.dim)).astype(np.float32)
    model = _stream_model(args, q_id, X, args.n, template=False)
    artifacts = streaming.build_streaming_artifacts(
        args.mode, X, model, capacity=args.n, sort_block=256,
        slack_blocks=2, host_rerank=args.host_rerank)
    engine = ServingEngine(msearch.make_state(artifacts), k=10,
                           kappa=args.kappa, batch_size=args.batch,
                           dim=args.dim)
    guarded = lifecycle.GuardedEngine(engine, canary_queries=QT[:args.batch],
                                      min_overlap=args.min_overlap)
    supervisor = lifecycle.RefreshSupervisor(guarded)
    stream = streaming.init_from_artifacts(artifacts, q_id,
                                           refresh_every=args.batch)
    release, refresh_fn = None, streaming.refresh
    if args.inject_fault == "slow-refresh":
        refresh_fn = faults.slow_refresh(delay_s=0.25)
    elif args.inject_fault == "stuck-worker":
        release = threading.Event()
        refresh_fn = faults.stuck_worker(release, timeout_s=60.0)
    worker = frontend.RefreshWorker(supervisor, stream,
                                    source=args.refresh_source,
                                    refresh_fn=refresh_fn).start()
    fe = frontend.ServingFrontend(guarded, capacity=args.queue_capacity,
                                  default_deadline_ms=args.deadline_ms)
    compiles0 = engine.n_compiles
    print(f"frontend mode={args.mode} n={args.n} D={args.dim} d={args.d} "
          f"buckets={fe.buckets} capacity={args.queue_capacity} "
          f"deadline={args.deadline_ms}ms slo={args.slo_ms}ms "
          f"compiles(warm)={compiles0}")

    # warm wave: mixed ID/OOD traffic with a background refresh mid-wave
    mixed = np.empty((args.batch * 4, args.dim), np.float32)
    mixed[0::2] = q_id[: args.batch * 2]
    mixed[1::2] = QT[: args.batch * 2]
    worker.observe(mixed[: args.batch])
    if args.inject_fault not in ("stuck-worker", "slow-refresh"):
        worker.request_refresh()
    res, rej = _frontend_traffic(fe, mixed,
                                 deadline_ms=args.deadline_ms)
    if len(res) + len(rej) != len(mixed):
        raise SystemExit("TRAFFIC INVARIANT VIOLATED: requests lost "
                         f"({len(res)} served + {len(rej)} refused "
                         f"!= {len(mixed)} offered)")
    if args.inject_fault not in ("stuck-worker", "slow-refresh"):
        if not _await(lambda: worker.n_cycles >= 1):
            raise SystemExit("background refresh never completed")

    if args.inject_fault:
        _frontend_drill(args, fe, guarded, worker, release, refresh_fn, QT)

    # end-state invariants: ALWAYS a valid serving state, zero recompiles
    bad = lifecycle.nonfinite_leaves(guarded.state)
    if bad:
        raise SystemExit(f"SERVE INVARIANT VIOLATED: non-finite leaves "
                         f"in served state: {bad[:4]}")
    final = guarded.submit(QT[: args.batch])
    if final.shape != (args.batch, engine.k):
        raise SystemExit("engine not serving after the run")
    if engine.n_compiles != compiles0:
        raise SystemExit(f"RECOMPILED while serving: {compiles0} -> "
                         f"{engine.n_compiles} executables")
    fe.close()
    stopped = worker.stop(timeout=1.0)
    s = engine.stats
    print(f"QPS={s.qps:.0f} request_p50={s.request_percentile_ms(50):.1f}ms "
          f"request_p99={s.request_percentile_ms(99):.1f}ms "
          f"(slo={args.slo_ms}ms) shed_rate={s.shed_rate:.3f} "
          f"rejected={s.n_rejected} shed={s.n_shed} "
          f"deadline_miss={s.n_deadline_miss} sanitized={s.n_sanitized}")
    print(f"worker: cycles={worker.n_cycles} degraded={worker.degraded} "
          f"staleness={worker.staleness_s:.2f}s stopped={stopped} | "
          f"swaps={engine.n_swaps} compiles={engine.n_compiles} "
          f"(zero recompiles after warmup: True)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gleanvec", choices=list(MODES))
    ap.add_argument("--index", default="flat",
                    choices=["flat", "ivf", "graph"])
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=48)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kappa", type=int, default=50)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--nprobe", type=int, default=12)
    ap.add_argument("--reduced-probe", action="store_true",
                    help="IVF coarse probe in the scorer's reduced space")
    ap.add_argument("--aligned", action="store_true",
                    help="IVF coarse quantizer = the GleanVec clustering "
                         "(sorted modes: gather-free range-scan fine step)")
    ap.add_argument("--beam", type=int, default=96)
    ap.add_argument("--max-hops", type=int, default=200)
    ap.add_argument("--expand", type=int, default=1,
                    help="graph frontier vertices expanded per hop "
                         "(multi-expansion beam search; 1 = classic)")
    ap.add_argument("--graph-degree", type=int, default=24)
    ap.add_argument("--graph-build", default="numpy",
                    choices=["numpy", "device", "auto"],
                    help="graph construction: numpy NN-descent, on-device "
                         "CAGRA-style self-join, or auto (device at large n)")
    ap.add_argument("--fused-graph", action="store_true",
                    help="sorted modes: bind the graph to the tag-sorted "
                         "layout (graph.with_fused_scan) so every hop runs "
                         "the gather-free fused beam-step kernel")
    ap.add_argument("--shards", type=int, default=0,
                    help="N per-shard sub-indexes merged via ShardedIndex "
                         "(0 = single index)")
    ap.add_argument("--host-rerank", action="store_true",
                    help="two-level memory hierarchy: demote the (n, D) "
                         "full-precision rerank tier to host memory (only "
                         "the kappa candidate rows per query cross "
                         "host->device); with --shards, each shard's tier "
                         "spills to its own host buffer")
    ap.add_argument("--stream", action="store_true",
                    help="drive the Section 3.2 observe -> insert -> "
                         "refresh -> swap lifecycle under live traffic")
    ap.add_argument("--frontend", action="store_true",
                    help="async serving topology: bounded-queue coalescing "
                         "frontend + supervised background refresh worker "
                         "(serve/frontend.py; see module docstring diagram)")
    ap.add_argument("--queue-capacity", type=int, default=256,
                    help="--frontend: admission-queue bound; a full queue "
                         "REJECTS new requests (backpressure, not a drop)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="--frontend: per-request latency budget; "
                         "unmeetable budgets are rejected at enqueue, "
                         "expired ones shed at dispatch (default: none)")
    ap.add_argument("--slo-ms", type=float, default=250.0,
                    help="--frontend: declared SLO the request p50/p99 "
                         "summary is reported against")
    ap.add_argument("--cycles", type=int, default=3,
                    help="streaming refresh cycles (--stream)")
    ap.add_argument("--refresh-source", default="stored",
                    choices=["stored", "full"],
                    help="refresh via Eq. 12 over stored vectors or exact "
                         "re-encode from the rerank store")
    ap.add_argument("--snapshot-dir", default=None,
                    help="--stream: persist ServingState + StreamingState "
                         "here after every cycle (atomic manifest steps)")
    ap.add_argument("--restore", action="store_true",
                    help="--stream: resume from the newest restorable "
                         "snapshot in --snapshot-dir (template model, no "
                         "refit); corrupted steps fall back to older ones")
    ap.add_argument("--min-overlap", type=float, default=0.3,
                    help="guarded-swap canary: reject a candidate whose "
                         "pinned-battery top-k overlap drops below this "
                         "(0 disables the canary)")
    ap.add_argument("--inject-fault", default=None,
                    choices=list(faults.FAULTS) + list(faults.FRONTEND_FAULTS),
                    help="drill one fault kind and verify the stack "
                         "handles it (exits non-zero on mishandling). "
                         "Lifecycle kinds need --stream; concurrency kinds "
                         "(stuck-worker / slow-refresh / poison-burst / "
                         "queue-overflow) need --frontend")
    args = ap.parse_args()

    if args.inject_fault in faults.FRONTEND_FAULTS and not args.frontend:
        raise SystemExit(f"--inject-fault {args.inject_fault} is a "
                         "concurrency drill: it needs --frontend")
    if args.inject_fault in faults.FAULTS and not args.stream:
        raise SystemExit(f"--inject-fault {args.inject_fault} is a "
                         "lifecycle drill: it needs --stream")
    if args.frontend:
        if args.stream:
            raise SystemExit("--frontend IS the async stream topology; "
                             "drop --stream")
        if args.mode == "full" or args.shards:
            raise SystemExit("--frontend needs a DR mode and a "
                             "single-device index")
        if args.index != "flat":
            raise SystemExit("--frontend serves the flat streaming store "
                             "(index slack/insert rides --stream)")
        run_frontend(args)
        return
    if args.stream:
        if args.mode == "full" or args.shards:
            raise SystemExit("--stream needs a DR mode and a "
                             "single-device index")
        run_stream(args)
        return
    if args.snapshot_dir or args.restore or args.inject_fault:
        raise SystemExit("--snapshot-dir/--restore/--inject-fault are "
                         "lifecycle flags: they need --stream")

    ds = vectors.make_dataset("serve", n=args.n, d=args.dim, n_queries=512,
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)

    if args.mode == "full":
        model = None
    elif args.mode.startswith("sphering"):
        model = lvs.fit(Q, X, args.d)
    else:
        model = gv.fit(jax.random.PRNGKey(0), Q, X, c=args.clusters,
                       d=args.d)
    if args.shards:
        # the stacked per-shard scorer IS the serving scorer -- don't also
        # encode the whole database into a global one just to discard it
        index, stacked = distributed.build_sharded_index(
            args.index, args.mode, X, model, n_shards=args.shards,
            key=jax.random.PRNGKey(1), n_lists=args.lists,
            nprobe=args.nprobe, reduced_probe=args.reduced_probe,
            aligned=args.aligned, beam=args.beam, max_hops=args.max_hops,
            expand=args.expand, fused_graph=args.fused_graph,
            graph_kwargs={"r": args.graph_degree, "n_iters": 4, "seed": 0,
                          "method": args.graph_build})
        artifacts = msearch.SearchArtifacts(scorer=stacked, x_full=X,
                                            model=model)
        if args.host_rerank:
            # spill-to-host: per-shard rerank tiers demote to host buffers
            artifacts = msearch.demote_rerank_tier(artifacts,
                                                   shards=args.shards)
    else:
        artifacts = msearch.build_artifacts(args.mode, X, model)
        index = build_index(args, X, artifacts.scorer, model)
        if args.host_rerank:
            artifacts = msearch.demote_rerank_tier(artifacts)
    kappa = 10 if args.mode == "full" else args.kappa

    engine = ServingEngine(msearch.make_state(artifacts, index=index),
                           k=10, kappa=kappa, batch_size=args.batch,
                           dim=args.dim)
    ids = engine.submit(ds.queries_test)
    rec = metrics.recall_at_k(jnp.asarray(ids), jnp.asarray(ds.gt[:, :10]))
    s = engine.stats
    placement = f"shards={args.shards}" if args.shards else "single"
    print(f"mode={args.mode} index={args.index} {placement} "
          f"n={args.n} D={args.dim} d={args.d} "
          f"reduced_probe={args.reduced_probe}")
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms recall@10={float(rec):.3f}")


if __name__ == "__main__":
    main()
