"""Serving driver: batched vector-search service (Algorithm 1) over a
synthetic collection with selectable scoring mode.

    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec --n 50000

Every mode (full / sphering / gleanvec / sphering-int8 / gleanvec-int8 /
gleanvec-sorted / gleanvec-int8-sorted) runs through the same
SearchArtifacts + Scorer path -- the mode string is the only thing that
differs between a full-precision service and a cluster-contiguous
GleanVec+int8 one.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import search as msearch
from repro.core.scorer import MODES
from repro.data import vectors
from repro.serve.engine import ServingEngine, make_search_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gleanvec", choices=list(MODES))
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=48)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kappa", type=int, default=50)
    args = ap.parse_args()

    ds = vectors.make_dataset("serve", n=args.n, d=args.dim, n_queries=512,
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)

    if args.mode == "full":
        model = None
    elif args.mode.startswith("sphering"):
        model = lvs.fit(Q, X, args.d)
    else:
        model = gv.fit(jax.random.PRNGKey(0), Q, X, c=args.clusters,
                       d=args.d)
    artifacts = msearch.build_artifacts(args.mode, X, model)
    kappa = 10 if args.mode == "full" else args.kappa
    search_fn = make_search_fn(artifacts, k=10, kappa=kappa)

    engine = ServingEngine(search_fn, batch_size=args.batch, dim=args.dim)
    ids = engine.submit(ds.queries_test)
    rec = metrics.recall_at_k(jnp.asarray(ids), jnp.asarray(ds.gt[:, :10]))
    s = engine.stats
    print(f"mode={args.mode} n={args.n} D={args.dim} d={args.d}")
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms recall@10={float(rec):.3f}")


if __name__ == "__main__":
    main()
