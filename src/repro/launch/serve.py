"""Serving driver: batched vector-search service (Algorithm 1) over a
synthetic collection with selectable scoring mode.

    PYTHONPATH=src python -m repro.launch.serve --mode gleanvec --n 50000
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.data import vectors
from repro.index import bruteforce
from repro.serve.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gleanvec",
                    choices=["full", "sphering", "gleanvec"])
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--clusters", type=int, default=48)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kappa", type=int, default=50)
    args = ap.parse_args()

    ds = vectors.make_dataset("serve", n=args.n, d=args.dim, n_queries=512,
                              ood=True, seed=0)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)

    def rerank(cand, queries):
        vecs = X[jnp.where(cand >= 0, cand, 0)]
        full = jnp.einsum("mkd,md->mk", vecs, queries)
        top = jax.lax.top_k(jnp.where(cand >= 0, full, -3.4e38), 10)[1]
        return jnp.take_along_axis(cand, top, axis=1)

    if args.mode == "full":
        def search_fn(q):
            return bruteforce.search(q, X, 10)[1]
    elif args.mode == "sphering":
        model = lvs.fit(Q, X, args.d)
        x_low = X @ model.b.T

        def search_fn(q):
            _, cand = bruteforce.search(q @ model.a.T, x_low, args.kappa)
            return rerank(cand, q)
    else:
        model = gv.fit(jax.random.PRNGKey(0), Q, X, c=args.clusters,
                       d=args.d)
        tags, x_low = gv.encode_database(model, X)

        def search_fn(q):
            q_views = gv.project_queries_eager(model, q)
            _, cand = bruteforce.search_gleanvec(q_views, tags, x_low,
                                                 args.kappa)
            return rerank(cand, q)

    engine = ServingEngine(search_fn, batch_size=args.batch, dim=args.dim)
    ids = engine.submit(ds.queries_test)
    rec = metrics.recall_at_k(jnp.asarray(ids), jnp.asarray(ds.gt[:, :10]))
    s = engine.stats
    print(f"mode={args.mode} n={args.n} D={args.dim} d={args.d}")
    print(f"QPS={s.qps:.0f} p50={s.percentile_ms(50):.1f}ms "
          f"p99={s.percentile_ms(99):.1f}ms recall@10={float(rec):.3f}")


if __name__ == "__main__":
    main()
