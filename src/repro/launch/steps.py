"""Step bundles: (architecture x input-shape) -> lowerable jitted step.

Every dry-run cell, training driver and smoke test goes through
``build_bundle(arch_id, shape_name, mesh, smoke)`` which returns the step
function, abstract arguments (ShapeDtypeStructs -- no allocation), the
in/out PartitionSpecs for pjit, per-scan trip counts for the roofline
correction, and the analytic MODEL_FLOPS of the step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core import gleanvec as gv_mod
from repro.core import linalg, spherical_kmeans
from repro.models import gnn, recsys, transformer as tfm
from repro.models.sharding import MeshRules, logical_to_spec
from repro.train import data as data_mod
from repro.train.optimizer import (AdafactorConfig, AdafactorState,
                                   AdamWConfig, AdamWState, adafactor_init,
                                   adamw_init)
from repro.train.trainstep import make_train_step

__all__ = ["StepBundle", "build_bundle"]

SDS = jax.ShapeDtypeStruct


@dataclass
class StepBundle:
    name: str
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    trip_counts: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0
    notes: str = ""


def _dp_spec(rules: MeshRules, *rest):
    return P(rules.dp if rules.dp else None, *rest)


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _pad_up(n: int, mult: int) -> int:
    return -(-n // max(mult, 1)) * max(mult, 1)


def _opt_specs(param_specs):
    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


def _spec_tuple(spec, ndim):
    t = tuple(spec) if spec is not None else ()
    return t + (None,) * (ndim - len(t))


def _adafactor_specs(p_specs, p_shapes, momentum: bool):
    def vr(spec, p):
        t = _spec_tuple(spec, p.ndim)
        return P(*t[:-1]) if p.ndim >= 2 else P(*t)

    def vc(spec, p):
        t = _spec_tuple(spec, p.ndim)
        return P(*(t[:-2] + t[-1:])) if p.ndim >= 2 else P(None)

    def mu(spec, p):
        return spec if momentum else P(None)

    is_spec = lambda x: isinstance(x, P)  # noqa: E731
    return AdafactorState(
        step=P(),
        vr=jax.tree.map(vr, p_specs, p_shapes, is_leaf=is_spec),
        vc=jax.tree.map(vc, p_specs, p_shapes, is_leaf=is_spec),
        mu=jax.tree.map(mu, p_specs, p_shapes, is_leaf=is_spec))


def _opt_setup(module, p_shapes, p_specs, smoke: bool):
    """(opt_shapes, opt_specs, opt_cfg, accum_dtype) per config module."""
    name = getattr(module, "OPTIMIZER", "adamw") if not smoke else "adamw"
    accum_dtype = jnp.bfloat16 if (
        getattr(module, "ACCUM_DTYPE", "") == "bfloat16" and not smoke) \
        else jnp.float32
    if name == "adafactor":
        cfg = AdafactorConfig(lr=1e-2)
        shapes = jax.eval_shape(lambda p: adafactor_init(p, cfg), p_shapes)
        specs = _adafactor_specs(p_specs, p_shapes,
                                 cfg.momentum is not None)
        return shapes, specs, cfg, accum_dtype
    return (jax.eval_shape(adamw_init, p_shapes), _opt_specs(p_specs),
            AdamWConfig(), accum_dtype)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_active_params(cfg: tfm.TransformerConfig) -> Tuple[float, float]:
    """(active_params, total_params) excluding embeddings, including head."""
    dq, dkv = cfg.qkv_dims
    attn = cfg.d_model * dq * 2 + cfg.d_model * dkv * 2
    n_mats = 3 if cfg.glu else 2
    if cfg.moe is not None:
        router = cfg.d_model * cfg.moe.n_experts
        expert = n_mats * cfg.d_model * cfg.d_ff
        mlp_total = router + cfg.moe.n_experts * expert
        mlp_active = router + cfg.moe.top_k * expert
    else:
        mlp_total = mlp_active = n_mats * cfg.d_model * cfg.d_ff
    head = cfg.d_model * cfg.vocab
    total = cfg.n_layers * (attn + mlp_total) + head
    active = cfg.n_layers * (attn + mlp_active) + head
    return float(active), float(total)


def _lm_attn_flops_train(cfg, batch, seq) -> float:
    kv_avg = seq / 2 if cfg.swa_window is None else min(cfg.swa_window, seq)
    # qk + pv = 2 matmuls x 2 flops; fwd + bwd = 3x
    return 3.0 * 2 * 2 * batch * seq * kv_avg * cfg.n_heads * cfg.d_head


def _lm_bundle(module, shape_name: str, mesh: Mesh, rules: MeshRules,
               smoke: bool) -> StepBundle:
    cfg = module.make_config(smoke)
    shape = dict(module.SHAPES[shape_name])
    if smoke:
        shape["seq"] = min(shape["seq"], 64)
        shape["batch"] = min(shape["batch"], 4)
    b, s = shape["batch"], shape["seq"]
    kind = shape["kind"]
    active, _ = _lm_active_params(cfg)
    p_shapes = jax.eval_shape(
        lambda: tfm.init(jax.random.PRNGKey(0), cfg))
    p_specs = tfm.param_specs(cfg, rules)

    if kind == "train":
        opt_shapes, o_specs, opt_cfg, accum_dtype = _opt_setup(
            module, p_shapes, p_specs, smoke)
        batch_shapes = {"tokens": SDS((b, s), jnp.int32),
                        "labels": SDS((b, s), jnp.int32)}
        b_specs = {"tokens": _dp_spec(rules, None),
                   "labels": _dp_spec(rules, None)}
        accum = 1 if smoke else getattr(module, "TRAIN_ACCUM", 1)
        # microbatch must stay divisible by the data-parallel degree
        dp_size = max(_axes_size(mesh, rules.dp), 1)
        while accum > 1 and (b // accum) % dp_size != 0:
            accum //= 2
        step = make_train_step(
            lambda p, bt: tfm.train_loss(p, bt, cfg, rules),
            opt_cfg, accum_steps=accum, accum_dtype=accum_dtype)
        flops = 6.0 * active * b * s + _lm_attn_flops_train(cfg, b, s)
        return StepBundle(
            name=f"{module.ARCH_ID}:{shape_name}", fn=step,
            args=(p_shapes, opt_shapes, batch_shapes),
            in_shardings=(p_specs, o_specs, b_specs),
            out_shardings=(p_specs, o_specs, P()),
            trip_counts={"layers": cfg.n_layers,
                         "loss_chunks": cfg.loss_chunks,
                         "q_chunks": max(1, s // cfg.q_chunk)},
            model_flops=flops)

    if kind == "prefill":
        # serving uses the flat layer layout (params are re-laid-out once at
        # serving load time; the blocked layout exists for training remat)
        import dataclasses
        cfg = dataclasses.replace(cfg, remat_block=0)
        p_shapes = jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0),
                                                   cfg))
        p_specs = tfm.param_specs(cfg, rules)
        batch_shapes = SDS((b, s), jnp.int32)
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_cache(cfg, b, s))
        step = lambda p, t: tfm.prefill_step(p, t, cfg, rules)  # noqa: E731
        flops = 2.0 * active * b * s \
            + _lm_attn_flops_train(cfg, b, s) / 3.0
        return StepBundle(
            name=f"{module.ARCH_ID}:{shape_name}", fn=step,
            args=(p_shapes, batch_shapes),
            in_shardings=(p_specs, _dp_spec(rules, None)),
            out_shardings=(logical_to_spec(rules, ("batch", "vocab")),
                           tfm.cache_specs(cfg, rules)),
            trip_counts={"layers": cfg.n_layers,
                         "q_chunks": max(1, s // cfg.q_chunk)},
            model_flops=flops)

    # decode: 1 new token against a seq-long cache (flat layer layout --
    # see the prefill note)
    import dataclasses
    cfg = dataclasses.replace(cfg, remat_block=0)
    p_shapes = jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0), cfg))
    dp_size = _axes_size(mesh, rules.dp)
    dp_eff = rules.dp if (b % max(dp_size, 1) == 0) else ()
    decode_rules = MeshRules(
        dp=dp_eff, fsdp=(rules.fsdp if cfg.moe is not None else ()),
        tp=rules.tp, ep=rules.ep)
    p_specs_d = tfm.param_specs(cfg, decode_rules)
    cache_shapes = jax.eval_shape(lambda: tfm.init_cache(cfg, b, s))
    c_specs = tfm.cache_specs(cfg, decode_rules)
    tok = SDS((b,), jnp.int32)
    pos = SDS((), jnp.int32)
    step = (lambda p, c, t, q:
            tfm.decode_step(p, c, t, q, cfg, decode_rules))
    kv_len = tfm.cache_len(cfg, s)
    flops = 2.0 * active * b \
        + 2 * 2 * b * kv_len * cfg.n_heads * cfg.d_head
    return StepBundle(
        name=f"{module.ARCH_ID}:{shape_name}", fn=step,
        args=(p_shapes, cache_shapes, tok, pos),
        in_shardings=(p_specs_d, c_specs, _dp_spec(decode_rules), P()),
        out_shardings=(logical_to_spec(decode_rules, ("batch", "vocab")),
                       c_specs),
        trip_counts={"layers": cfg.n_layers},
        model_flops=flops,
        notes="serve_step (decode)")


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def _gnn_bundle(module, shape_name: str, mesh: Mesh, rules: MeshRules,
                smoke: bool) -> StepBundle:
    shape = dict(module.SHAPES[shape_name])
    if smoke:
        for k_ in ("n_nodes", "n_edges"):
            if k_ in shape:
                shape[k_] = min(shape[k_], 512)
        shape["batch_nodes"] = min(shape.get("batch_nodes", 64), 64)
        shape["batch"] = min(shape.get("batch", 8), 8)
        shape["d_feat"] = min(shape["d_feat"], 32)
    cfg = module.make_config(smoke=False, d_feat=shape["d_feat"],
                             n_classes=shape["n_classes"])
    kind = shape["kind"]
    p_shapes = jax.eval_shape(lambda: gnn.init(jax.random.PRNGKey(0), cfg))
    p_specs = jax.tree.map(lambda _: P(), p_shapes)
    opt_shapes = jax.eval_shape(adamw_init, p_shapes)
    o_specs = _opt_specs(p_specs)
    h = cfg.d_hidden

    if kind == "gnn_full":
        n, e = shape["n_nodes"], shape["n_edges"]
        e = _pad_up(e, _axes_size(mesh, rules.dp))  # pjit-divisible edges
        batch_shapes = {"feats": SDS((n, shape["d_feat"]), jnp.float32),
                        "edges": SDS((2, e), jnp.int32),
                        "labels": SDS((n,), jnp.int32),
                        "mask": SDS((n,), jnp.float32)}
        b_specs = {"feats": P(), "edges": P(None, rules.dp or None),
                   "labels": P(), "mask": P()}
        loss_fn = lambda p, bt: gnn.full_graph_loss(p, bt, cfg, rules)  # noqa
        flops = 3.0 * (2 * n * shape["d_feat"] * h + 2 * n * h
                       * shape["n_classes"] + 2 * e * (h + shape["n_classes"]))
    elif kind == "gnn_minibatch":
        n, e, bn = shape["n_nodes"], shape["n_edges"], shape["batch_nodes"]
        f1, f2 = shape["fanouts"]
        batch_shapes = {"feats": SDS((n, shape["d_feat"]), jnp.float32),
                        "indptr": SDS((n + 1,), jnp.int32),
                        "indices": SDS((e,), jnp.int32),
                        "seeds": SDS((bn,), jnp.int32),
                        "labels": SDS((bn,), jnp.int32),
                        "rng": SDS((2,), jnp.uint32)}
        b_specs = {"feats": P(), "indptr": P(), "indices": P(),
                   "seeds": _dp_spec(rules), "labels": _dp_spec(rules),
                   "rng": P()}

        def loss_fn(p, bt):
            bt = dict(bt)
            bt["rng"] = jax.random.wrap_key_data(bt["rng"])
            return gnn.minibatch_loss(p, bt, cfg, rules)

        flops = 3.0 * 2 * bn * (f1 * f2 + 2 * f1 + 2) * shape["d_feat"] * h
    else:  # gnn_batched (molecule)
        g_, nn_, ee = shape["batch"], shape["n_nodes"], shape["n_edges"]
        batch_shapes = {"feats": SDS((g_, nn_, shape["d_feat"]), jnp.float32),
                        "edges": SDS((g_, ee, 2), jnp.int32),
                        "labels": SDS((g_,), jnp.int32)}
        b_specs = {"feats": _dp_spec(rules, None, None),
                   "edges": _dp_spec(rules, None, None),
                   "labels": _dp_spec(rules)}
        loss_fn = lambda p, bt: gnn.batched_graphs_loss(p, bt, cfg, rules)  # noqa
        flops = 3.0 * 2 * g_ * (nn_ * shape["d_feat"] * h
                                + nn_ * h * shape["n_classes"] + ee * h)

    step = make_train_step(loss_fn, AdamWConfig(lr=1e-2))
    return StepBundle(
        name=f"{module.ARCH_ID}:{shape_name}", fn=step,
        args=(p_shapes, opt_shapes, batch_shapes),
        in_shardings=(p_specs, o_specs, b_specs),
        out_shardings=(p_specs, o_specs, P()),
        trip_counts={}, model_flops=flops)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

_RECSYS_MODELS = {"dlrm": recsys.dlrm, "fm": recsys.fm, "bst": recsys.bst,
                  "mind": recsys.mind}


def _mlp_flops(dims) -> float:
    return float(sum(2 * a * b_ for a, b_ in zip(dims[:-1], dims[1:])))


def _recsys_batch(model_name: str, cfg, b: int):
    if model_name == "dlrm":
        shapes = {"dense": SDS((b, cfg.n_dense), jnp.float32),
                  "sparse": SDS((b, cfg.n_sparse), jnp.int32),
                  "label": SDS((b,), jnp.int32)}
    elif model_name == "fm":
        shapes = {"sparse": SDS((b, cfg.n_sparse), jnp.int32),
                  "label": SDS((b,), jnp.int32)}
    elif model_name == "bst":
        shapes = {"seq": SDS((b, cfg.seq_len), jnp.int32),
                  "target": SDS((b,), jnp.int32),
                  "label": SDS((b,), jnp.int32)}
    else:  # mind
        shapes = {"seq": SDS((b, cfg.seq_len), jnp.int32),
                  "target": SDS((b,), jnp.int32)}
    return shapes


def _recsys_flops(model_name: str, cfg, b: int) -> float:
    if model_name == "dlrm":
        d = cfg.embed_dim
        f = cfg.n_sparse + 1
        return 3.0 * b * (_mlp_flops((cfg.n_dense,) + cfg.bot_mlp)
                          + 2 * f * f * d
                          + _mlp_flops((f * (f - 1) // 2 + cfg.bot_mlp[-1],)
                                       + cfg.top_mlp))
    if model_name == "fm":
        return 3.0 * b * (2 * cfg.n_sparse * cfg.embed_dim)
    if model_name == "bst":
        d, s = cfg.embed_dim, cfg.seq_len + 1
        blk = 4 * 2 * s * d * d + 2 * 2 * s * s * d \
            + 2 * s * d * cfg.ff_dim * 2
        return 3.0 * b * (cfg.n_blocks * blk
                          + _mlp_flops((s * d,) + cfg.mlp))
    d, s, k_ = cfg.embed_dim, cfg.seq_len, cfg.n_interests
    return 3.0 * b * cfg.capsule_iters * (2 * 2 * s * k_ * d + 2 * d * d)


def _recsys_param_specs(model_name: str, p_shapes, rules: MeshRules):
    tp = rules.tp
    dp = rules.dp if rules.dp else None

    def spec_for(path, leaf):
        name = jax.tree_util.keystr(path)
        if "table" in name and model_name == "dlrm":
            return P(tp, dp)          # 2D: rows x model, dim x data
        if "item_emb" in name or ("'v'" in name) or ("'w'" in name
                                                     and leaf.ndim == 1):
            return P(tp) if leaf.ndim == 1 else P(tp, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, p_shapes)


def _recsys_bundle(module, shape_name: str, mesh: Mesh, rules: MeshRules,
                   smoke: bool) -> StepBundle:
    model_name = module.MODEL
    model = _RECSYS_MODELS[model_name]
    cfg = module.make_config(smoke)
    shape = dict(module.SHAPES[shape_name])
    if smoke:
        shape["batch"] = min(shape["batch"], 32)
        shape["n_candidates"] = min(shape.get("n_candidates", 4096), 4096)
    b = shape["batch"]
    kind = shape["kind"]
    p_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), cfg))
    p_specs = _recsys_param_specs(model_name, p_shapes, rules)
    batch_shapes = _recsys_batch(model_name, cfg, b)
    b_specs = {k_: _dp_spec(rules, *([None] * (len(v.shape) - 1)))
               for k_, v in batch_shapes.items()}
    flops = _recsys_flops(model_name, cfg, b)

    lookup_fn = None
    if model_name == "dlrm" and rules.tp is not None and not smoke:
        from repro.models.embedding import make_sharded_lookup
        lookup_fn = make_sharded_lookup(mesh, cfg.padded_total_vocab,
                                        cfg.embed_dim)

    if kind == "recsys_train":
        opt_shapes = jax.eval_shape(adamw_init, p_shapes)
        o_specs = _opt_specs(p_specs)
        if model_name == "dlrm":
            loss_fn = (lambda p, bt: model.ctr_loss(p, bt, cfg, rules,
                                                    lookup_fn=lookup_fn))
        else:
            loss_fn = lambda p, bt: model.ctr_loss(p, bt, cfg, rules)  # noqa
        step = make_train_step(loss_fn, AdamWConfig(lr=1e-3))
        return StepBundle(
            name=f"{module.ARCH_ID}:{shape_name}", fn=step,
            args=(p_shapes, opt_shapes, batch_shapes),
            in_shardings=(p_specs, o_specs, b_specs),
            out_shardings=(p_specs, o_specs, P()),
            trip_counts={}, model_flops=flops)

    if kind == "recsys_serve":
        if model_name == "dlrm":
            def serve(p, bt):
                from repro.models import embedding as emb_mod
                idx = bt["sparse"] + jnp.asarray(
                    recsys.dlrm.offsets(cfg))[None, :]
                emb = (emb_mod.embedding_lookup(p["table"], idx)
                       if lookup_fn is None else lookup_fn(p["table"], idx))
                return model.forward(p, bt["dense"], emb, cfg, rules)
        elif model_name == "mind":
            def serve(p, bt):
                caps = model.interests(p, bt["seq"], cfg, rules)
                t_emb = jnp.take(p["item_emb"], bt["target"],
                                 axis=0).astype(jnp.float32)
                return model.score_against(caps, t_emb, cfg.pow_p)
        else:
            def serve(p, bt):
                bt = dict(bt)
                lbl = bt.pop("label", None)
                del lbl
                if model_name == "fm":
                    return model.logits(p, bt["sparse"], cfg, rules)
                h = model._encode(p, bt["seq"], bt["target"], cfg, rules)
                from repro.models import layers as lyr
                return lyr.mlp_apply(p["mlp"], h.reshape(h.shape[0], -1),
                                     act="relu",
                                     compute_dtype=cfg.compute_dtype)[:, 0]
        return StepBundle(
            name=f"{module.ARCH_ID}:{shape_name}", fn=serve,
            args=(p_shapes, batch_shapes),
            in_shardings=(p_specs, b_specs),
            out_shardings=_dp_spec(rules),
            trip_counts={}, model_flops=flops / 3.0)

    # retrieval_cand: 1 user vs n_candidates item vectors (the paper's MIPS)
    n_cand = shape["n_candidates"]
    user_dim = (cfg.bot_mlp[-1] if model_name == "dlrm"
                else cfg.embed_dim)
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    n_cand = _pad_up(n_cand, _axes_size(mesh, all_axes))
    cand_shapes = SDS((n_cand, user_dim), jnp.float32)
    cand_spec = P(all_axes or None, None)
    if b % max(_axes_size(mesh, rules.dp), 1) != 0:
        b_specs = {k_: P(*([None] * len(v.shape)))
                   for k_, v in batch_shapes.items()}

    def retrieval(p, bt, candidates):
        user = model.user_embedding(p, bt, cfg, rules)     # (B, d)
        scores = jnp.einsum("nd,bd->bn", candidates, user)
        _, ids = jax.lax.top_k(scores, 10)
        return ids

    return StepBundle(
        name=f"{module.ARCH_ID}:{shape_name}", fn=retrieval,
        args=(p_shapes, batch_shapes, cand_shapes),
        in_shardings=(p_specs, b_specs, cand_spec),
        out_shardings=P(),
        trip_counts={},
        model_flops=flops / 3.0 + 2.0 * b * n_cand * user_dim,
        notes="baseline full-D retrieval; GleanVec variant in serve/")


# ---------------------------------------------------------------------------
# Vector-search family (the paper's own workload)
# ---------------------------------------------------------------------------


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _vs_bundle(module, shape_name: str, mesh: Mesh, rules: MeshRules,
               smoke: bool) -> StepBundle:
    shape = dict(module.SHAPES[shape_name])
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in all_axes])) \
        if all_axes else 1
    if smoke:
        shape["n"] = min(shape["n"], 2048)
        shape["m_queries"] = min(shape.get("m_queries", 256), 256)
        shape["batch"] = min(shape.get("batch", 32), 32)
    dim, d_low, c = shape["D"], shape["d"], shape["C"]
    rows_spec = P(all_axes or None, None)

    if shape["kind"] == "vs_learn":
        n = _pad_to(min(shape["n"], 1_000_000), max(n_shards, 1) * 512)
        m = _pad_to(shape["m_queries"], max(n_shards, 1))
        x_sds = SDS((n, dim), jnp.float32)
        q_sds = SDS((m, dim), jnp.float32)
        cent_sds = SDS((c, dim), jnp.float32)

        def learn_step(x, q, centers):
            """One full Algorithm-5 data pass: EM update + moments + fits."""
            x_unit = spherical_kmeans.normalize_rows(x)
            sims = x_unit @ centers.T
            tags = jnp.argmax(sims, axis=-1)
            onehot = jax.nn.one_hot(tags, c, dtype=jnp.float32)
            sums = onehot.T @ x_unit
            new_centers = spherical_kmeans.normalize_rows(sums)
            k_q = linalg.second_moment(q)
            # per-cluster moments via a scan over clusters (bounded memory)
            def one_cluster(c_idx):
                mask = (tags == c_idx).astype(jnp.float32)
                xm = x * mask[:, None]
                return xm.T @ x
            k_x_c = jax.lax.map(one_cluster, jnp.arange(c))
            model = gv_mod.fit_from_moments(new_centers, k_q, k_x_c, d_low)
            return new_centers, model.a, model.b

        flops = (2.0 * n * c * dim            # assignment
                 + 2.0 * m * dim * dim        # K_Q
                 + 2.0 * c * n * dim * dim    # per-cluster moments
                 + 2.0 * n * dim)             # masks/normalize
        return StepBundle(
            name=f"{module.ARCH_ID}:{shape_name}", fn=learn_step,
            args=(x_sds, q_sds, cent_sds),
            in_shardings=(rows_spec, _dp_spec(rules, None), P()),
            out_shardings=(P(), P(), P()),
            trip_counts={"clusters": c}, model_flops=flops,
            notes="Algorithm 5 data pass (train_step analogue)")

    # vs_search: Algorithm 1 with eager GleanVec scoring + local rerank;
    # "vs_search_sorted" uses the cluster-contiguous layout (one tag per
    # 4096-row block -> plain matmul scan, no per-row view gather).
    sorted_layout = shape["kind"] == "vs_search_sorted"
    n = _pad_to(shape["n"], max(n_shards, 1) * 4096)
    b, k_, kappa = shape["batch"], shape["k"], shape["kappa"]
    q_sds = SDS((b, dim), jnp.float32)
    tags_sds = SDS((n // 4096,) if sorted_layout else (n,), jnp.int32)
    xlow_sds = SDS((n, d_low), jnp.float32)
    xfull_sds = SDS((n, dim), jnp.float32)
    a_sds = SDS((c, d_low, dim), jnp.float32)

    from repro.index import bruteforce

    def search_step(q, tags, x_low, x_full, a_mats):
        q_views = jnp.einsum("cdk,mk->mcd", a_mats, q)     # (B, C, d)

        def local(q_, qv, tg, xl, xf):
            if sorted_layout:
                vals, ids = bruteforce.search_gleanvec_sorted(
                    qv, tg, xl, kappa, block=4096)
            else:
                vals, ids = bruteforce.search_gleanvec(qv, tg, xl, kappa,
                                                       block=4096)
            # local full-precision rerank (Alg. 1 line 3, shard-local part)
            safe = jnp.where(ids >= 0, ids, 0)
            cand = xf[safe]                                # (B, kappa, D)
            full = jnp.einsum("bkd,bd->bk", cand, q_)
            full = jnp.where(ids >= 0, full, -3.4e38)
            if all_axes:
                idx = jnp.zeros((), jnp.int32)
                for ax in all_axes:
                    idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
                gids = jnp.where(ids >= 0, ids + idx * xl.shape[0], -1)
                full = jax.lax.all_gather(full, all_axes, axis=1, tiled=True)
                gids = jax.lax.all_gather(gids, all_axes, axis=1, tiled=True)
            else:
                gids = ids
            top, sel = jax.lax.top_k(full, k_)
            return top, jnp.take_along_axis(gids, sel, axis=1)

        if all_axes:
            from repro.utils.jax_compat import shard_map
            fn = shard_map(
                local, mesh=mesh,
                in_specs=(P(), P(), P(all_axes), P(all_axes, None),
                          P(all_axes, None)),
                out_specs=(P(), P()))
            # tags spec covers both layouts (rows or blocks -- both shard
            # over all axes)
        else:
            fn = local
        return fn(q, q_views, tags, x_low, x_full)

    flops = (2.0 * b * c * d_low * dim        # eager views
             + 2.0 * b * n * d_low            # reduced scan
             + 2.0 * b * kappa * n_shards * dim)  # rerank
    return StepBundle(
        name=f"{module.ARCH_ID}:{shape_name}", fn=search_step,
        args=(q_sds, tags_sds, xlow_sds, xfull_sds, a_sds),
        in_shardings=(P(), P(all_axes or None), rows_spec, rows_spec, P()),
        out_shardings=(P(), P()),
        trip_counts={"db_blocks": n // max(n_shards, 1) // 4096},
        model_flops=flops,
        notes="Algorithm 1 multi-step search (serve_step analogue)")


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def build_bundle(arch_id: str, shape_name: str, mesh: Mesh,
                 smoke: bool = False) -> StepBundle:
    module = registry.get(arch_id)
    if shape_name in getattr(module, "SKIPS", {}):
        raise ValueError(
            f"{arch_id}:{shape_name} skipped: {module.SKIPS[shape_name]}")
    rules = MeshRules.for_mesh(mesh)
    if module.FAMILY == "lm":
        return _lm_bundle(module, shape_name, mesh, rules, smoke)
    if module.FAMILY == "gnn":
        return _gnn_bundle(module, shape_name, mesh, rules, smoke)
    if module.FAMILY == "recsys":
        return _recsys_bundle(module, shape_name, mesh, rules, smoke)
    if module.FAMILY == "vectorsearch":
        return _vs_bundle(module, shape_name, mesh, rules, smoke)
    raise ValueError(f"unknown family {module.FAMILY}")
