"""Training driver with fault tolerance:

  * periodic atomic checkpoints (params + optimizer + step);
  * crash recovery: --resume restores the latest checkpoint and replays the
    deterministic data stream from the restored step (bit-exact restart);
  * failure injection for drills: REPRO_FAIL_AT_STEP=<n> aborts mid-run;
  * straggler watchdog: per-step wall-clock deadline (midpoint of recent
    median x --straggler-factor); breaches are logged and counted -- on a
    real cluster this signal feeds the scheduler's replace/despecle path;
  * elastic restart: checkpoints are mesh-agnostic (host arrays +
    reshard-on-load), so resuming on a different device count re-shards
    automatically (tests/test_distributed.py::test_elastic_reshard_restore).

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-3-4b \
        --shape train_4k --smoke --steps 20 --ckpt-dir /tmp/ck [--resume]
"""
from __future__ import annotations

import argparse
import os
import statistics
import sys
import time

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_bundle
from repro.train import checkpoint
from repro.train import data as data_mod
from repro.train.optimizer import adamw_init


def make_batch(module, shape_name: str, bundle, step: int, seed: int = 0):
    """Deterministic batch matching the bundle's abstract batch shapes."""
    import jax.numpy as jnp
    shapes = bundle.args[2]
    kind = module.SHAPES[shape_name]["kind"]
    if kind == "train":
        b, s = shapes["tokens"].shape
        vocab = module.make_config(True).vocab
        return data_mod.lm_batch(seed, step, b, s, vocab)
    # generic: random fill honoring dtypes (gnn/recsys smoke streams)
    def fill(path, sds):
        name = jax.tree_util.keystr(path)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), step),
            abs(hash(name)) % (1 << 31))
        if np.issubdtype(sds.dtype, np.integer) or sds.dtype == jnp.uint32:
            hi = 2 if "label" in name else max(2, min(1 << 15, 1 << 30))
            return jax.random.randint(key, sds.shape, 0, hi).astype(sds.dtype)
        return jax.random.normal(key, sds.shape, sds.dtype)
    return jax.tree_util.tree_map_with_path(
        fill, shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args()

    module = registry.get(args.arch)
    mesh = make_host_mesh()
    bundle = build_bundle(args.arch, args.shape, mesh, smoke=args.smoke)
    fail_at = int(os.environ.get("REPRO_FAIL_AT_STEP", -1))

    # init or resume
    import jax.numpy as jnp

    def materialize(sds_tree):
        def mk(path, sds):
            name = jax.tree_util.keystr(path)
            key = jax.random.PRNGKey(abs(hash(name)) % (1 << 31))
            if np.issubdtype(sds.dtype, np.integer):
                return jnp.zeros(sds.shape, sds.dtype)
            return (jax.random.normal(key, sds.shape, jnp.float32) * 0.02
                    ).astype(sds.dtype)
        return jax.tree_util.tree_map_with_path(
            mk, sds_tree, is_leaf=lambda x: isinstance(x,
                                                       jax.ShapeDtypeStruct))

    params = materialize(bundle.args[0])
    opt = adamw_init(params)
    start_step = 0
    if args.resume and args.ckpt_dir and checkpoint.latest_step(
            args.ckpt_dir) is not None:
        restored, start_step, _ = checkpoint.restore(
            args.ckpt_dir, {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    step_fn = jax.jit(bundle.fn)
    durations = []
    stragglers = 0
    for i in range(start_step, args.steps):
        if i == fail_at:
            print(f"[drill] injected failure at step {i}; "
                  f"restart with --resume")
            sys.exit(42)
        batch = make_batch(module, args.shape, bundle, i, args.seed)
        t0 = time.time()
        params, opt, metrics = jax.block_until_ready(
            step_fn(params, opt, batch))
        dt = time.time() - t0
        if len(durations) >= 5:
            deadline = statistics.median(durations) * args.straggler_factor
            if dt > deadline:
                stragglers += 1
                print(f"[straggler] step {i} took {dt:.2f}s "
                      f"(deadline {deadline:.2f}s) -- flagged")
        durations.append(dt)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, i + 1,
                            {"params": params, "opt": opt},
                            meta={"arch": args.arch, "shape": args.shape})
            print(f"[ckpt] step {i + 1} -> {args.ckpt_dir}")
    print(f"done: {args.steps - start_step} steps, "
          f"{stragglers} straggler events, "
          f"median step {statistics.median(durations):.2f}s")


if __name__ == "__main__":
    main()
