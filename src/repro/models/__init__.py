"""Assigned-architecture model zoo + shared layers and sharding rules."""
from repro.models import (attention, embedding, gnn, layers, moe, recsys,
                          sharding, transformer)

__all__ = ["attention", "embedding", "gnn", "layers", "moe", "recsys",
           "sharding", "transformer"]
