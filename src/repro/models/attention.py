"""Attention substrate for the LM architectures.

Three paths, one semantics (see kernels/flash_attention/ref.py oracle):

* ``chunked_attention`` -- differentiable, memory-bounded (scans over query
  chunks; peak temp = B*H*qc*S scores). Used when lowering ``train_step`` and
  prefill: at 32k sequence a full score tensor would not fit HBM, matching
  what the fused kernel achieves on real TPUs.
* ``kernels.flash_attention`` -- the Pallas TPU kernel (serving/forward).
* ``decode_attention`` -- one-token attention against a KV cache whose
  sequence dimension may be sharded over the ``model`` axis (flash-decoding
  style: XLA turns the max/sum reductions over the sharded axis into small
  (B, H) all-reduces -- the collective-light layout for long-context decode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -3.4e38

__all__ = ["chunked_attention", "decode_attention"]


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: Optional[int] = None,
                      q_chunk: int = 512, constrain_fn=None) -> jax.Array:
    """``q (B, S, H, dh)``, ``k/v (B, S, KV, dh)`` -> (B, S, H, dh).

    GQA by broadcasting K/V up to H heads (K/V are computed replicated over
    the tensor-parallel axis -- Megatron-style KV replication for
    n_kv < tp_degree -- so the repeat is a local slice, never a collective,
    and every attention tensor carries a clean (batch, heads) sharding).
    Query chunks are dynamic-sliced in a scan so only one (B, H, qc, S)
    score tile is live at a time; ``constrain_fn(x)`` (optional) pins its
    sharding to (dp, tp, None, None).
    """
    b, s, h, dh = q.shape
    kv = k.shape[2]
    group = h // kv
    scale = 1.0 / float(dh) ** 0.5
    q_chunk = min(q_chunk, s)
    pad = (-s) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (s + pad) // q_chunk

    if group > 1:
        k = jnp.repeat(k, group, axis=2)               # (B, S, H, dh)
        v = jnp.repeat(v, group, axis=2)
    k_pos = jnp.arange(s)

    def body(_, ci):
        q_c = jax.lax.dynamic_slice_in_dim(q, ci * q_chunk, q_chunk,
                                           axis=1)     # (B, qc, H, dh)
        scores = jnp.einsum("bqhd,bshd->bhqs",
                            q_c.astype(jnp.float32) * scale,
                            k.astype(jnp.float32))
        if constrain_fn is not None:
            scores = constrain_fn(scores)
        q_pos = ci * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, s), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        # softmax in f32, PV matmul in the compute dtype: halves the HBM
        # traffic of the dominant (B, H, qc, S) tensor (section Perf)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(q.dtype))
        return None, out

    _, outs = jax.lax.scan(body, None,
                           jnp.arange(n_chunks))       # (C, B, qc, H, dh)
    out = outs.swapaxes(0, 1).reshape(b, s + pad, h, dh)
    return out[:, :s]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array) -> jax.Array:
    """One-step attention: ``q (B, H, dh)``, caches ``(B, S, KV, dh)``.

    ``length``: number of valid cache entries (scalar or (B,)). The softmax
    reduction runs over the cache sequence axis; when that axis is sharded
    over "model", XLA emits (B, H)-sized all-reduces only.
    """
    b, h, dh = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    group = h // kv
    scale = 1.0 / float(dh) ** 0.5
    qr = q.reshape(b, kv, group, dh).astype(jnp.float32) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qr,
                        k_cache.astype(jnp.float32))      # (B, KV, G, S)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(length, (-1, 1))   # (B or 1, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)
