"""Sharded embedding tables and EmbeddingBag, built from scratch.

JAX has no ``nn.EmbeddingBag`` and no CSR sparse; the lookup pipeline here is
``jnp.take`` + ``jax.ops.segment_sum`` (bag reduction) and, for
production-scale tables (DLRM's 26 Criteo tables, ~880M rows), an explicit
shard_map implementation of the classic DLRM model-parallel lookup:

  table rows   sharded over "model"   (each chip owns a vocab slice)
  table dim    sharded over "data"    (each data-row owns an embed-dim slice)
  batch        sharded over "data"

  1. all-gather the (local-batch) indices over "data"  -> global batch ids
  2. masked local take + psum over "model"             -> (B_global, F, D/dp)
  3. all_to_all over "data" swapping batch <-> dim     -> (B_local, F, D)

Collective bytes per step = B*F*D/dp (psum) + B*F*D/dp (a2a) -- the canonical
DLRM all-to-all pattern. Differentiable (gather/psum/all_to_all all have
transposes), so the same path serves training.

Multiple tables with different vocab sizes are packed into ONE (sum V_i, D)
array with per-feature row offsets.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

__all__ = ["pack_table_offsets", "embedding_lookup", "embedding_bag",
           "make_sharded_lookup"]


def pack_table_offsets(vocab_sizes: Sequence[int]) -> np.ndarray:
    """Row offsets for packing len(vocab_sizes) tables into one array."""
    return np.concatenate([[0], np.cumsum(np.asarray(vocab_sizes))[:-1]]
                          ).astype(np.int32)


def embedding_lookup(table: jax.Array, idx: jax.Array,
                     offsets: Optional[jax.Array] = None) -> jax.Array:
    """Plain lookup. ``idx (B, F)`` + per-feature ``offsets (F,)`` ->
    (B, F, D). Single-device / GSPMD-auto path."""
    if offsets is not None:
        idx = idx + offsets[None, :]
    return jnp.take(table, idx, axis=0)


def embedding_bag(table: jax.Array, idx: jax.Array, segment_ids: jax.Array,
                  n_bags: int, combiner: str = "mean",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """EmbeddingBag: ragged multi-hot lookup reduced per bag.

    ``idx (L,)`` flat ids, ``segment_ids (L,)`` bag assignment (sorted or
    not), -> (n_bags, D). This is the take+segment_sum construction the
    kernel-taxonomy mandates.
    """
    emb = jnp.take(table, idx, axis=0)                    # (L, D)
    if weights is not None:
        emb = emb * weights[:, None]
    summed = jax.ops.segment_sum(emb, segment_ids, n_bags)
    if combiner == "sum":
        return summed
    counts = jax.ops.segment_sum(jnp.ones_like(idx, jnp.float32),
                                 segment_ids, n_bags)
    if combiner == "mean":
        return summed / jnp.maximum(counts, 1.0)[:, None]
    raise ValueError(f"unknown combiner {combiner!r}")


def make_sharded_lookup(mesh: Mesh, total_vocab: int, dim: int):
    """Build the 2D-sharded DLRM lookup for the production mesh.

    Returns ``lookup(table, flat_idx) -> (B_local..., D)`` to be called under
    jit with:
      table sharded P("model", ("pod", "data")) -- rows x dim;
      flat_idx (B, F) sharded P(("pod", "data"), None).
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model"
    n_tp = mesh.shape[tp]
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    rows_per_shard = -(-total_vocab // n_tp)
    dim_per_shard = dim // n_dp

    def local_fn(table, idx):
        # table: (rows_per_shard, dim_per_shard); idx: (B_local, F)
        b_local, f = idx.shape
        idx_g = jax.lax.all_gather(idx, dp_axes, axis=0, tiled=True)
        row0 = jax.lax.axis_index(tp) * rows_per_shard
        loc = idx_g - row0
        hit = (loc >= 0) & (loc < rows_per_shard)
        emb = jnp.take(table, jnp.clip(loc, 0, rows_per_shard - 1), axis=0)
        emb = jnp.where(hit[..., None], emb, 0.0)     # (B, F, D/dp)
        emb = jax.lax.psum(emb, tp)
        # batch <-> dim exchange: every data shard keeps its batch slice but
        # gains the full dim.
        if dp_axes:
            emb = jax.lax.all_to_all(emb, dp_axes, split_axis=0,
                                     concat_axis=2, tiled=True)
        return emb                                     # (B_local, F, D)

    from repro.utils.jax_compat import shard_map
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(tp, dp_axes if dp_axes else None),
                  P(dp_axes if dp_axes else None, None)),
        out_specs=P(dp_axes if dp_axes else None, None, None),
    )
