"""GCN (Kipf & Welling) in three execution regimes matching the assigned
shapes for ``gcn-cora``:

  * full-graph (full_graph_sm / ogb_products): sym-normalized message
    passing over a global edge list via ``jax.ops.segment_sum`` -- JAX has no
    CSR SpMM, so the gather(src) -> scale -> scatter-add(dst) pipeline IS the
    SpMM (DESIGN.md). Edges shard over the data axes; per-shard partial
    segment sums are combined by the psum XLA inserts for the replicated
    output.
  * minibatch (minibatch_lg): GraphSAGE-style two-hop uniform neighbor
    sampling (fanout 15, 10) from CSR on-device, then a dense batched
    aggregation -- the sampler is part of the system, not a stub.
  * batched small graphs (molecule): vmapped per-graph message passing +
    mean-pool readout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding import MeshRules, constrain

__all__ = ["GCNConfig", "init", "full_graph_loss", "minibatch_loss",
           "batched_graphs_loss", "sample_neighbors"]


@dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 16
    d_feat: int = 1433
    n_classes: int = 7
    aggregator: str = "mean"   # paper config: mean
    norm: str = "sym"          # symmetric D^-1/2 (A+I) D^-1/2
    fanouts: tuple = (15, 10)
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.float32


def init(key, cfg: GCNConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims) - 1)
    return {"w": [layers.dense_init(k, dims[i], dims[i + 1], cfg.param_dtype,
                                    with_bias=True)
                  for i, k in enumerate(keys)]}


# ---------------------------------------------------------------------------
# Full-graph path
# ---------------------------------------------------------------------------


def _gcn_propagate(h: jax.Array, edges: jax.Array, n_nodes: int,
                   norm: str, rules: MeshRules) -> jax.Array:
    """One A-hat @ H product. ``edges (2, E)`` = (src, dst) with implicit
    self-loops added analytically."""
    src, dst = edges[0], edges[1]
    ones = jnp.ones(src.shape, jnp.float32)
    deg = jax.ops.segment_sum(ones, dst, n_nodes) + 1.0  # +1 self loop
    if norm == "sym":
        coef = jax.lax.rsqrt(deg[src]) * jax.lax.rsqrt(deg[dst])
        self_coef = 1.0 / deg
    else:  # mean / rw normalization
        coef = 1.0 / deg[dst]
        self_coef = 1.0 / deg
    msg = h[src] * coef[:, None]
    msg = constrain(msg, rules, ("batch", None))
    agg = jax.ops.segment_sum(msg, dst, n_nodes)
    return agg + h * self_coef[:, None]


def full_graph_logits(params, feats: jax.Array, edges: jax.Array,
                      cfg: GCNConfig, rules: MeshRules) -> jax.Array:
    n = feats.shape[0]
    h = feats.astype(cfg.compute_dtype)
    for i, w in enumerate(params["w"]):
        h = layers.dense(w, h, cfg.compute_dtype)
        h = _gcn_propagate(h, edges, n, cfg.norm, rules)
        if i < len(params["w"]) - 1:
            h = jax.nn.relu(h)
    return h


def full_graph_loss(params, batch: Dict[str, jax.Array], cfg: GCNConfig,
                    rules: MeshRules) -> jax.Array:
    logits = full_graph_logits(params, batch["feats"], batch["edges"], cfg,
                               rules)
    labels = batch["labels"]
    mask = batch.get("mask")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Minibatch path (neighbor sampling)
# ---------------------------------------------------------------------------


def sample_neighbors(key, indptr: jax.Array, indices: jax.Array,
                     nodes: jax.Array, fanout: int) -> jax.Array:
    """Uniform-with-replacement neighbor sampling from CSR.

    ``nodes (...,)`` -> ``(..., fanout)`` neighbor ids; isolated nodes
    self-loop.
    """
    start = indptr[nodes]
    deg = indptr[nodes + 1] - start
    r = jax.random.randint(key, nodes.shape + (fanout,), 0, 1 << 30)
    offset = r % jnp.maximum(deg[..., None], 1)
    nbr = indices[start[..., None] + offset]
    return jnp.where(deg[..., None] > 0, nbr, nodes[..., None])


def minibatch_logits(params, key, feats, indptr, indices, seeds,
                     cfg: GCNConfig, rules: MeshRules):
    """Two-hop sampled GCN forward for ``seeds (B,)``."""
    f1, f2 = cfg.fanouts
    k1, k2 = jax.random.split(key)
    hop1 = sample_neighbors(k1, indptr, indices, seeds, f1)      # (B, f1)
    hop2 = sample_neighbors(k2, indptr, indices, hop1, f2)       # (B, f1, f2)

    x_seed = feats[seeds].astype(cfg.compute_dtype)              # (B, F)
    x1 = feats[hop1].astype(cfg.compute_dtype)                   # (B, f1, F)
    x1 = constrain(x1, rules, ("batch", None, None))
    x2 = feats[hop2].astype(cfg.compute_dtype)                   # (B, f1, f2, F)
    x2 = constrain(x2, rules, ("batch", None, None, None))

    w1 = params["w"][0]
    # layer 1 for hop-1 nodes: mean over their sampled neighbors + self
    h1_nbrs = layers.dense(w1, jnp.mean(x2, axis=2), cfg.compute_dtype)
    h1_self = layers.dense(w1, x1, cfg.compute_dtype)
    h1 = jax.nn.relu(0.5 * (h1_nbrs + h1_self))                  # (B, f1, H)
    # layer 1 for seeds: mean over hop-1 + self
    h1s = jax.nn.relu(0.5 * (
        layers.dense(w1, jnp.mean(x1, axis=1), cfg.compute_dtype)
        + layers.dense(w1, x_seed, cfg.compute_dtype)))          # (B, H)
    # layer 2 for seeds
    w2 = params["w"][1]
    out = 0.5 * (layers.dense(w2, jnp.mean(h1, axis=1), cfg.compute_dtype)
                 + layers.dense(w2, h1s, cfg.compute_dtype))
    return out                                                   # (B, C)


def minibatch_loss(params, batch, cfg: GCNConfig, rules: MeshRules):
    logits = minibatch_logits(params, batch["rng"], batch["feats"],
                              batch["indptr"], batch["indices"],
                              batch["seeds"], cfg, rules)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(
        jnp.take_along_axis(logp, batch["labels"][:, None], axis=1))


# ---------------------------------------------------------------------------
# Batched small graphs (molecule)
# ---------------------------------------------------------------------------


def batched_graphs_logits(params, feats, edges, cfg: GCNConfig,
                          rules: MeshRules):
    """``feats (G, N, F)``, ``edges (G, E, 2)`` -> (G,) graph logits."""
    n = feats.shape[1]

    def one_graph(x, e):
        h = x.astype(cfg.compute_dtype)
        for i, w in enumerate(params["w"]):
            h = layers.dense(w, h, cfg.compute_dtype)
            src, dst = e[:, 0], e[:, 1]
            deg = jax.ops.segment_sum(jnp.ones(src.shape, jnp.float32), dst,
                                      n) + 1.0
            coef = jax.lax.rsqrt(deg[src]) * jax.lax.rsqrt(deg[dst])
            h = jax.ops.segment_sum(h[src] * coef[:, None], dst, n) \
                + h / deg[:, None]
            if i < len(params["w"]) - 1:
                h = jax.nn.relu(h)
        return jnp.mean(h, axis=0)                         # node mean-pool

    pooled = jax.vmap(one_graph)(feats, edges)             # (G, C)
    return pooled


def batched_graphs_loss(params, batch, cfg: GCNConfig, rules: MeshRules):
    out = batched_graphs_logits(params, batch["feats"], batch["edges"], cfg,
                                rules)
    # graph-level binary target in n_classes=1 regime, else multi-class
    if out.shape[-1] == 1:
        logit = out[:, 0].astype(jnp.float32)
        y = batch["labels"].astype(jnp.float32)
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    return -jnp.mean(
        jnp.take_along_axis(logp, batch["labels"][:, None], axis=1))
