"""Shared neural-net building blocks (pure JAX, dict params).

Initializers return nested dicts of arrays; apply functions are pure. All
matmuls go through ``dense``/einsum so dtype policy (params fp32 or bf16,
compute bf16, accum fp32) is uniform.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["dense_init", "dense", "rmsnorm_init", "rmsnorm", "rope",
           "activation", "mlp_init", "mlp_apply", "embed_init"]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               with_bias: bool = False):
    scale = 1.0 / math.sqrt(d_in)
    w = jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)
    if with_bias:
        return {"w": w, "b": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def dense(params, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                   params["w"].astype(compute_dtype))
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embeddings. ``x (..., S, H, dh)``, ``positions (..., S)``."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "squared_relu":  # Primer / nemotron-4
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


def mlp_init(key, dims, dtype=jnp.float32, with_bias: bool = True):
    """Plain MLP tower: dims = (d_in, h1, ..., d_out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {"layers": [dense_init(k, dims[i], dims[i + 1], dtype, with_bias)
                       for i, k in enumerate(keys)]}


def mlp_apply(params, x: jax.Array, act: str = "relu",
              final_act: Optional[str] = None,
              compute_dtype=jnp.bfloat16) -> jax.Array:
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        x = dense(layer, x, compute_dtype)
        if i < n - 1:
            x = activation(act, x)
        elif final_act is not None:
            x = activation(final_act, x)
    return x


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}
