"""Mixture-of-Experts FFN (GShard-style grouped einsum dispatch).

Supports the two assigned MoE architectures:
  * grok-1-314b:   8 experts, top-2  -> "tp" sharding (8 experts do not divide
                   the 16-way model axis; experts stay stacked, d_ff is
                   tensor-parallel, params additionally FSDP over data)
  * llama4-maverick: 128 experts, top-1 -> "ep" sharding (experts sharded over
                   the model axis; XLA materializes the token all-to-alls)

Tokens are processed in fixed-size groups (GShard): per group of T_g tokens,
each expert has capacity C = ceil(T_g * top_k * capacity_factor / E) rounded
up to a multiple of 4; overflow tokens are dropped (standard GShard
semantics, the residual stream carries them unchanged). The load-balancing
auxiliary loss follows Switch/GShard: E * sum_e f_e * p_e.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding import MeshRules, constrain

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 256
    sharding: str = "ep"          # "ep" | "tp"
    aux_loss_weight: float = 0.01


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig, glu: bool,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e = cfg.n_experts
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d_model, e), dtype) * scale_in,
        "w_up": jax.random.normal(ks[1], (e, d_model, d_ff), dtype) * scale_in,
        "w_down": jax.random.normal(ks[2], (e, d_ff, d_model), dtype) * scale_out,
    }
    if glu:
        p["w_gate"] = jax.random.normal(ks[3], (e, d_model, d_ff),
                                        dtype) * scale_in
    return p


def _capacity(tg: int, cfg: MoEConfig) -> int:
    c = int(tg * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(4, -(-c // 4) * 4)


def moe_apply(params, x: jax.Array, cfg: MoEConfig, act: str, glu: bool,
              rules: MeshRules, compute_dtype=jnp.bfloat16):
    """``x (..., T, D)`` -> (y, aux_loss). Leading dims flattened to tokens."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    tg = min(cfg.group_size, t)
    assert t % tg == 0, f"token count {t} not divisible by group {tg}"
    g = t // tg
    e = cfg.n_experts
    cap = _capacity(tg, cfg)

    xg = xt.reshape(g, tg, d)
    xg = constrain(xg, rules, ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (G, Tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)     # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # per-expert positions with capacity (GShard): process the K choices in
    # priority order so primary assignments win slots.
    dispatch = jnp.zeros((g, tg, e, cap), compute_dtype)
    combine = jnp.zeros((g, tg, e, cap), jnp.float32)
    counts = jnp.zeros((g, e), jnp.int32)
    for slot in range(cfg.top_k):
        idx_s = gate_idx[..., slot]                           # (G, Tg)
        onehot = jax.nn.one_hot(idx_s, e, dtype=jnp.int32)    # (G, Tg, E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        pos_tok = jnp.sum(pos * onehot, axis=-1)              # (G, Tg)
        keep = pos_tok < cap
        cap_oh = jax.nn.one_hot(pos_tok, cap, dtype=compute_dtype)
        d_s = (onehot.astype(compute_dtype)[..., None] * cap_oh[:, :, None, :]
               * keep.astype(compute_dtype)[:, :, None, None])
        dispatch = dispatch + d_s
        combine = combine + d_s.astype(jnp.float32) * \
            gate_vals[..., slot][:, :, None, None]
        counts = counts + jnp.sum(onehot * keep[..., None].astype(jnp.int32),
                                  axis=1)

    ep_axis = "ep" if cfg.sharding == "ep" else None
    x_e = jnp.einsum("gtec,gtd->gecd", dispatch,
                     xg.astype(compute_dtype))                # (G, E, C, D)
    x_e = constrain(x_e, rules, ("batch", ep_axis, None, None))

    w_up = params["w_up"].astype(compute_dtype)
    h = jnp.einsum("gecd,edf->gecf", x_e, w_up)
    if glu:
        gate_h = jnp.einsum("gecd,edf->gecf", x_e,
                            params["w_gate"].astype(compute_dtype))
        h = layers.activation(act, gate_h) * h
    else:
        h = layers.activation(act, h)
    tp_axis = "tp" if cfg.sharding == "tp" else None
    h = constrain(h, rules, ("batch", ep_axis, None, tp_axis))
    y_e = jnp.einsum("gecf,efd->gecd", h,
                     params["w_down"].astype(compute_dtype))
    y_e = constrain(y_e, rules, ("batch", ep_axis, None, None))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(compute_dtype), y_e)
    y = constrain(y, rules, ("batch", None, None))

    # Switch-style load-balance loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(frac_tokens * mean_probs)
    return y.reshape(orig_shape).astype(x.dtype), aux
