"""The four assigned recommender architectures.

  dlrm-mlperf  MLPerf DLRM (Criteo 1TB): 13 dense, 26 sparse tables
               (exact MLPerf cardinalities, ~880M rows), dot interaction,
               bottom 13-512-256-128, top 1024-1024-512-256-1.
  fm           Factorization Machine (Rendle '10): 39 sparse fields,
               k=10, pairwise term via the O(nk) sum-square identity.
  bst          Behavior Sequence Transformer (Alibaba): 20-item behavior
               sequence, 1 transformer block (8 heads, d=32), MLP
               1024-512-256.
  mind         Multi-Interest Network with Dynamic routing: 4 interest
               capsules, 3 routing iterations, label-aware attention.

All expose ``init(key, cfg)``, ``ctr_loss(params, batch, cfg, rules)`` and a
``user_embedding`` tower used by the retrieval path (serve/retrieval.py),
where the paper's GleanVec accelerates candidate scoring.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.sharding import MeshRules, constrain

__all__ = ["DLRMConfig", "FMConfig", "BSTConfig", "MINDConfig",
           "MLPERF_CRITEO_VOCAB_SIZES", "dlrm", "fm", "bst", "mind"]

# MLPerf DLRM (Criteo Terabyte) per-table cardinalities -- the standard list.
MLPERF_CRITEO_VOCAB_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36)


def _bce(logit: jax.Array, y: jax.Array) -> jax.Array:
    logit = logit.astype(jnp.float32)
    y = y.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    vocab_sizes: Tuple[int, ...] = MLPERF_CRITEO_VOCAB_SIZES
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def padded_total_vocab(self) -> int:
        """Rows padded to 512 so the table shards evenly on any production
        mesh axis combination (16 / 32 / 256 / 512); pad rows are unused."""
        return -(-self.total_vocab // 512) * 512


class dlrm:
    Config = DLRMConfig

    @staticmethod
    def init(key, cfg: DLRMConfig):
        k_emb, k_bot, k_top = jax.random.split(key, 3)
        return {
            "table": jax.random.normal(
                k_emb, (cfg.padded_total_vocab, cfg.embed_dim),
                cfg.param_dtype) * (cfg.embed_dim ** -0.5),
            "bot": layers.mlp_init(k_bot, (cfg.n_dense,) + cfg.bot_mlp,
                                   cfg.param_dtype),
            "top": layers.mlp_init(
                k_top,
                (cfg.n_sparse * (cfg.n_sparse + 1) // 2 + cfg.bot_mlp[-1],)
                + cfg.top_mlp, cfg.param_dtype),
        }

    @staticmethod
    def offsets(cfg: DLRMConfig) -> np.ndarray:
        from repro.models.embedding import pack_table_offsets
        return pack_table_offsets(cfg.vocab_sizes)

    @staticmethod
    def forward(params, dense: jax.Array, emb: jax.Array, cfg: DLRMConfig,
                rules: MeshRules) -> jax.Array:
        """``dense (B, 13)``, ``emb (B, 26, D)`` (already looked up)."""
        cd = cfg.compute_dtype
        bot = layers.mlp_apply(params["bot"], dense.astype(cd), act="relu",
                               final_act="relu", compute_dtype=cd)  # (B, D)
        z = jnp.concatenate([bot[:, None, :], emb.astype(cd)], axis=1)
        z = constrain(z, rules, ("batch", None, None))
        inter = jnp.einsum("bid,bjd->bij", z, z)            # (B, 27, 27)
        f = z.shape[1]
        iu, ju = jnp.triu_indices(f, k=1)
        flat = inter[:, iu, ju]                             # (B, 351)
        top_in = jnp.concatenate([bot, flat], axis=1)
        logit = layers.mlp_apply(params["top"], top_in, act="relu",
                                 compute_dtype=cd)[:, 0]
        return logit

    @staticmethod
    def ctr_loss(params, batch: Dict[str, jax.Array], cfg: DLRMConfig,
                 rules: MeshRules, lookup_fn=None) -> jax.Array:
        from repro.models import embedding as emb_mod
        idx = batch["sparse"] + jnp.asarray(dlrm.offsets(cfg))[None, :]
        if lookup_fn is None:
            emb = emb_mod.embedding_lookup(params["table"], idx)
        else:
            emb = lookup_fn(params["table"], idx)
        emb = constrain(emb, rules, ("batch", None, None))
        logit = dlrm.forward(params, batch["dense"], emb, cfg, rules)
        return _bce(logit, batch["label"])

    @staticmethod
    def user_embedding(params, batch, cfg: DLRMConfig,
                       rules: MeshRules) -> jax.Array:
        """Bottom-MLP output as the retrieval query vector (B, D)."""
        cd = cfg.compute_dtype
        return layers.mlp_apply(params["bot"], batch["dense"].astype(cd),
                                act="relu", final_act="relu",
                                compute_dtype=cd).astype(jnp.float32)


# ---------------------------------------------------------------------------
# FM
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    vocab_per_field: int = 100_000
    embed_dim: int = 10
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.float32

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field


class fm:
    Config = FMConfig

    @staticmethod
    def init(key, cfg: FMConfig):
        k_v, k_w = jax.random.split(key)
        return {
            "v": jax.random.normal(k_v, (cfg.total_vocab, cfg.embed_dim),
                                   cfg.param_dtype) * 0.01,
            "w": jnp.zeros((cfg.total_vocab,), cfg.param_dtype),
            "w0": jnp.zeros((), cfg.param_dtype),
        }

    @staticmethod
    def logits(params, sparse: jax.Array, cfg: FMConfig,
               rules: MeshRules) -> jax.Array:
        """``sparse (B, F)`` field-local ids -> (B,) logits.

        Pairwise term via the Rendle identity:
        sum_{i<j} <v_i, v_j> = 0.5 * (||sum_i v_i||^2 - sum_i ||v_i||^2).
        """
        offs = (jnp.arange(cfg.n_sparse) * cfg.vocab_per_field)[None, :]
        idx = sparse + offs
        v = jnp.take(params["v"], idx, axis=0)             # (B, F, k)
        v = constrain(v, rules, ("batch", None, None))
        w = jnp.take(params["w"], idx, axis=0)             # (B, F)
        sum_v = jnp.sum(v, axis=1)
        pair = 0.5 * (jnp.sum(sum_v * sum_v, axis=-1)
                      - jnp.sum(v * v, axis=(1, 2)))
        return params["w0"] + jnp.sum(w, axis=1) + pair

    @staticmethod
    def ctr_loss(params, batch, cfg: FMConfig, rules: MeshRules):
        return _bce(fm.logits(params, batch["sparse"], cfg, rules),
                    batch["label"])

    @staticmethod
    def user_embedding(params, batch, cfg: FMConfig,
                       rules: MeshRules) -> jax.Array:
        offs = (jnp.arange(cfg.n_sparse) * cfg.vocab_per_field)[None, :]
        v = jnp.take(params["v"], batch["sparse"] + offs, axis=0)
        return jnp.sum(v, axis=1).astype(jnp.float32)      # (B, k)


# ---------------------------------------------------------------------------
# BST
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 4_000_000
    seq_len: int = 20
    embed_dim: int = 32
    n_heads: int = 8
    n_blocks: int = 1
    ff_dim: int = 128
    mlp: Tuple[int, ...] = (1024, 512, 256, 1)
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.float32


class bst:
    Config = BSTConfig

    @staticmethod
    def init(key, cfg: BSTConfig):
        ks = jax.random.split(key, 8)
        d = cfg.embed_dim
        blocks = []
        for i in range(cfg.n_blocks):
            kb = jax.random.split(ks[2 + i], 6)
            blocks.append({
                "wq": jax.random.normal(kb[0], (d, d), cfg.param_dtype) * d ** -0.5,
                "wk": jax.random.normal(kb[1], (d, d), cfg.param_dtype) * d ** -0.5,
                "wv": jax.random.normal(kb[2], (d, d), cfg.param_dtype) * d ** -0.5,
                "wo": jax.random.normal(kb[3], (d, d), cfg.param_dtype) * d ** -0.5,
                "ln1": layers.rmsnorm_init(d, cfg.param_dtype),
                "ln2": layers.rmsnorm_init(d, cfg.param_dtype),
                "w_up": jax.random.normal(kb[4], (d, cfg.ff_dim),
                                          cfg.param_dtype) * d ** -0.5,
                "w_down": jax.random.normal(kb[5], (cfg.ff_dim, d),
                                            cfg.param_dtype) * cfg.ff_dim ** -0.5,
            })
        seq_plus_target = cfg.seq_len + 1
        return {
            "item_emb": jax.random.normal(
                ks[0], (cfg.n_items, d), cfg.param_dtype) * 0.02,
            "pos_emb": jax.random.normal(
                ks[1], (seq_plus_target, d), cfg.param_dtype) * 0.02,
            "blocks": blocks,
            "mlp": layers.mlp_init(ks[7], (seq_plus_target * d,) + cfg.mlp,
                                   cfg.param_dtype),
        }

    @staticmethod
    def _encode(params, seq_items: jax.Array, target_item: jax.Array,
                cfg: BSTConfig, rules: MeshRules) -> jax.Array:
        """seq (B, S), target (B,) -> transformer output (B, S+1, d)."""
        cd = cfg.compute_dtype
        items = jnp.concatenate([seq_items, target_item[:, None]], axis=1)
        h = jnp.take(params["item_emb"], items, axis=0).astype(cd)
        h = h + params["pos_emb"].astype(cd)[None]
        h = constrain(h, rules, ("batch", None, None))
        b, s, d = h.shape
        nh = cfg.n_heads
        dh = d // nh
        for blk in params["blocks"]:
            hn = layers.rmsnorm(blk["ln1"], h)
            q = (hn @ blk["wq"].astype(cd)).reshape(b, s, nh, dh)
            k = (hn @ blk["wk"].astype(cd)).reshape(b, s, nh, dh)
            v = (hn @ blk["wv"].astype(cd)).reshape(b, s, nh, dh)
            scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / dh ** 0.5
            probs = jax.nn.softmax(scores.astype(jnp.float32),
                                   axis=-1).astype(cd)
            attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, d)
            h = h + attn @ blk["wo"].astype(cd)
            hn = layers.rmsnorm(blk["ln2"], h)
            ff = jax.nn.relu(hn @ blk["w_up"].astype(cd))
            h = h + ff @ blk["w_down"].astype(cd)
        return h

    @staticmethod
    def ctr_loss(params, batch, cfg: BSTConfig, rules: MeshRules):
        h = bst._encode(params, batch["seq"], batch["target"], cfg, rules)
        flat = h.reshape(h.shape[0], -1)
        logit = layers.mlp_apply(params["mlp"], flat, act="relu",
                                 compute_dtype=cfg.compute_dtype)[:, 0]
        return _bce(logit, batch["label"])

    @staticmethod
    def user_embedding(params, batch, cfg: BSTConfig,
                       rules: MeshRules) -> jax.Array:
        """Mean-pooled sequence representation (target slot excluded)."""
        dummy_target = batch["seq"][:, -1]
        h = bst._encode(params, batch["seq"], dummy_target, cfg, rules)
        return jnp.mean(h[:, :-1], axis=1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# MIND
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 4_000_000
    seq_len: int = 50
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    pow_p: float = 2.0    # label-aware attention sharpness
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.float32


class mind:
    Config = MINDConfig

    @staticmethod
    def init(key, cfg: MINDConfig):
        k_emb, k_s = jax.random.split(key)
        d = cfg.embed_dim
        return {
            "item_emb": jax.random.normal(
                k_emb, (cfg.n_items, d), cfg.param_dtype) * 0.02,
            # shared bilinear map S for B2I routing
            "s": jax.random.normal(k_s, (d, d), cfg.param_dtype) * d ** -0.5,
        }

    @staticmethod
    def interests(params, seq: jax.Array, cfg: MINDConfig,
                  rules: MeshRules) -> jax.Array:
        """Behavior-to-Interest dynamic routing -> (B, K, d) capsules."""
        cd = cfg.compute_dtype
        e = jnp.take(params["item_emb"], seq, axis=0).astype(cd)  # (B,S,d)
        e = constrain(e, rules, ("batch", None, None))
        eh = e @ params["s"].astype(cd)                           # (B,S,d)
        b_logits = jnp.zeros(e.shape[:2] + (cfg.n_interests,), jnp.float32)

        def squash(x):
            n2 = jnp.sum(x * x, axis=-1, keepdims=True)
            return (n2 / (1.0 + n2)) * x * jax.lax.rsqrt(n2 + 1e-9)

        caps = None
        for _ in range(cfg.capsule_iters):
            c = jax.nn.softmax(b_logits, axis=-1)                 # (B,S,K)
            caps = squash(jnp.einsum("bsk,bsd->bkd",
                                     c.astype(cd), eh).astype(jnp.float32))
            b_logits = b_logits + jnp.einsum(
                "bkd,bsd->bsk", caps, eh.astype(jnp.float32))
        return caps                                               # (B,K,d)

    @staticmethod
    def score_against(caps: jax.Array, target_emb: jax.Array,
                      pow_p: float) -> jax.Array:
        """Label-aware attention: softmax(p * <cap, e>) weighting, (B,)."""
        sims = jnp.einsum("bkd,bd->bk", caps, target_emb)
        w = jax.nn.softmax(pow_p * sims, axis=-1)
        user = jnp.einsum("bk,bkd->bd", w, caps)
        return jnp.sum(user * target_emb, axis=-1)

    @staticmethod
    def ctr_loss(params, batch, cfg: MINDConfig, rules: MeshRules):
        """In-batch sampled softmax over targets."""
        caps = mind.interests(params, batch["seq"], cfg, rules)
        t_emb = jnp.take(params["item_emb"], batch["target"],
                         axis=0).astype(jnp.float32)              # (B,d)
        # scores of every user against every in-batch target
        sims = jnp.einsum("bkd,cd->bck", caps, t_emb)
        w = jax.nn.softmax(cfg.pow_p * sims, axis=-1)
        scores = jnp.sum(w * sims, axis=-1)                       # (B,C)
        logp = jax.nn.log_softmax(scores, axis=-1)
        return -jnp.mean(jnp.diagonal(logp))

    @staticmethod
    def user_embedding(params, batch, cfg: MINDConfig,
                       rules: MeshRules) -> jax.Array:
        """Max-sim retrieval uses all K interests; export mean capsule."""
        caps = mind.interests(params, batch["seq"], cfg, rules)
        return jnp.mean(caps, axis=1)
