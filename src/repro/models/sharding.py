"""Sharding rules: one place mapping logical tensor roles -> PartitionSpecs.

Axes (production mesh, launch/mesh.py):
  * ``data``  -- batch / tokens / database rows (+ composed with ``pod``)
  * ``model`` -- tensor-parallel: attention heads, FFN hidden, vocab, experts
  * ``pod``   -- outermost data parallelism across pods (multi-pod mesh only)

``MeshRules`` resolves the axis names present in the current mesh, so the
same model code lowers on the single-pod (data, model) and the multi-pod
(pod, data, model) meshes. On a 1-device CPU mesh every spec degenerates to
fully-replicated, which is how the smoke tests run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisSel = Union[None, str, Tuple[str, ...]]

__all__ = ["MeshRules", "logical_to_spec", "constrain"]


@dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axis mapping.

    ``dp``: pure data parallel axes (batch dim);
    ``fsdp``: axes that additionally shard parameters/optimizer state
              (ZeRO-3); subset of dp in this design;
    ``tp``: tensor-parallel axis;
    ``ep``: expert-parallel axis (MoE; usually == tp).
    """

    dp: Tuple[str, ...] = ("data",)
    fsdp: Tuple[str, ...] = ("data",)
    tp: Optional[str] = "model"
    ep: Optional[str] = "model"

    @classmethod
    def for_mesh(cls, mesh: jax.sharding.Mesh, fsdp: bool = True
                 ) -> "MeshRules":
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names)
        tp = "model" if "model" in names else None
        # ZeRO-3 spans every data-parallel axis: on the multi-pod mesh the
        # param/grad/optimizer shards halve again (pod x data = 32-way).
        return cls(dp=dp or (), fsdp=(dp if fsdp else ()), tp=tp, ep=tp)

    # -- common specs --------------------------------------------------
    def batch(self, *rest: AxisSel) -> P:
        return P(self.dp if self.dp else None, *rest)

    def replicated(self) -> P:
        return P()


def logical_to_spec(rules: MeshRules, logical: Sequence[Optional[str]]) -> P:
    """Map per-dim logical names to a PartitionSpec.

    Recognized names: "batch", "fsdp", "tp", "ep", "vocab"(=tp),
    "seq_tp" (decode KV-cache sequence dim over tp), None (replicated).
    """
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        elif name == "batch":
            out.append(rules.dp if rules.dp else None)
        elif name == "fsdp":
            out.append(rules.fsdp if rules.fsdp else None)
        elif name in ("tp", "vocab", "seq_tp"):
            out.append(rules.tp)
        elif name == "ep":
            out.append(rules.ep)
        else:
            raise ValueError(f"unknown logical axis {name!r}")
    return P(*out)


def constrain(x: jax.Array, rules: MeshRules,
              logical: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    try:
        spec = logical_to_spec(rules, logical)
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
