"""Config-driven decoder-only LM covering the five assigned architectures.

Features exercised per arch (configs/):
  h2o-danube-3-4b   GQA + sliding-window attention (SWA), SwiGLU
  qwen2-72b         GQA + QKV bias, SwiGLU, 152k vocab
  nemotron-4-15b    GQA + squared-ReLU (no GLU), 256k vocab
  grok-1-314b       GQA + MoE 8e top-2 (tp-sharded experts)
  llama4-maverick   GQA + MoE 128e top-1 (ep-sharded experts)

Implementation notes (these are the load-bearing scaling decisions):
  * scan-over-layers with stacked (L, ...) params: keeps the HLO one layer
    big (fast 512-way SPMD compiles) and gives FSDP its layer-granular
    all-gather cadence for free.
  * activation remat per layer, policy configurable (``nothing`` for the
    72B/314B trainings, ``dots`` for small models).
  * chunked attention (models/attention.py) and chunked cross-entropy: no
    (S, S) score or (T, V) logit tensor is ever materialized.
  * GQA with n_kv < tp_degree: K/V projections are computed replicated over
    the model axis (Megatron-style KV replication); Q/O are head-sharded.
  * vocab-parallel embedding + LM head: mask+psum lookup (shard_map-free,
    einsum-based one-hot on the label side only), logits stay vocab-sharded
    through the loss.
  * decode: KV cache sequence axis sharded over "model" (flash-decoding);
    SWA archs keep a ring-buffer cache of window size.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention, layers
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.sharding import MeshRules, constrain, logical_to_spec

__all__ = ["TransformerConfig", "init", "train_loss", "decode_step",
           "param_logical_axes", "param_specs", "init_cache",
           "cache_specs"]


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "silu"
    glu: bool = True
    qkv_bias: bool = False
    swa_window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    rope_theta: float = 1e4
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    q_chunk: int = 512
    loss_chunks: int = 8
    remat_policy: str = "nothing"    # "nothing" | "dots" | "none"
    remat_block: int = 0             # >0: hierarchical (sqrt) remat -- scan
                                     # over L/remat_block blocks of layers;
                                     # only block inputs are saved

    @property
    def qkv_dims(self) -> Tuple[int, int]:
        return self.n_heads * self.d_head, self.n_kv_heads * self.d_head


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: TransformerConfig):
    ks = jax.random.split(key, 8)
    dq, dkv = cfg.qkv_dims
    dt = cfg.param_dtype
    s = cfg.d_model ** -0.5
    p = {
        "ln1": layers.rmsnorm_init(cfg.d_model, dt),
        "wq": jax.random.normal(ks[0], (cfg.d_model, dq), dt) * s,
        "wk": jax.random.normal(ks[1], (cfg.d_model, dkv), dt) * s,
        "wv": jax.random.normal(ks[2], (cfg.d_model, dkv), dt) * s,
        "wo": jax.random.normal(ks[3], (dq, cfg.d_model), dt) * (dq ** -0.5),
        "ln2": layers.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((dq,), dt)
        p["bk"] = jnp.zeros((dkv,), dt)
        p["bv"] = jnp.zeros((dkv,), dt)
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[4], cfg.d_model, cfg.d_ff, cfg.moe, cfg.glu,
                            dt)
    else:
        p["w_up"] = jax.random.normal(ks[5], (cfg.d_model, cfg.d_ff), dt) * s
        p["w_down"] = jax.random.normal(
            ks[6], (cfg.d_ff, cfg.d_model), dt) * (cfg.d_ff ** -0.5)
        if cfg.glu:
            p["w_gate"] = jax.random.normal(
                ks[7], (cfg.d_model, cfg.d_ff), dt) * s
    return p


def blocked_layout(cfg: TransformerConfig) -> bool:
    """Stacked layer params live as (n_blocks, block, ...) when hierarchical
    remat is on -- natively, so no (bitcast-defeating, sharded) reshapes ever
    appear inside the compiled step (measured multi-GB copies otherwise)."""
    return (cfg.remat_block > 0 and cfg.n_layers % cfg.remat_block == 0
            and cfg.n_layers > cfg.remat_block)


def init(key, cfg: TransformerConfig):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    if blocked_layout(cfg):
        nb = cfg.n_layers // cfg.remat_block
        stacked = jax.tree.map(
            lambda x: x.reshape((nb, cfg.remat_block) + x.shape[1:]),
            stacked)
    return {
        "embed": jax.random.normal(
            k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype) * 0.02,
        "layers": stacked,
        "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "lm_head": jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab),
            cfg.param_dtype) * (cfg.d_model ** -0.5),
    }


def param_logical_axes(cfg: TransformerConfig):
    """Logical per-dim axis names mirroring ``init``'s tree."""
    lax_ = {
        "ln1": {"scale": (None,)},
        "wq": (None, "fsdp", "tp"),
        "wk": (None, "fsdp", None),   # KV replicated over tp (n_kv < tp)
        "wv": (None, "fsdp", None),
        "wo": (None, "tp", "fsdp"),
        "ln2": {"scale": (None,)},
    }
    if cfg.qkv_bias:
        lax_["bq"] = (None, "tp")
        lax_["bk"] = (None, None)
        lax_["bv"] = (None, None)
    if cfg.moe is not None:
        ep = cfg.moe.sharding == "ep"
        lax_["moe"] = {
            "router": (None, "fsdp", None),
            "w_up": (None, "ep", "fsdp", None) if ep
            else (None, None, "fsdp", "tp"),
            "w_down": (None, "ep", None, "fsdp") if ep
            else (None, None, "tp", "fsdp"),
        }
        if cfg.glu:
            lax_["moe"]["w_gate"] = lax_["moe"]["w_up"]
    else:
        lax_["w_up"] = (None, "fsdp", "tp")
        lax_["w_down"] = (None, "tp", "fsdp")
        if cfg.glu:
            lax_["w_gate"] = (None, "fsdp", "tp")
    if blocked_layout(cfg):
        def add_axis(t):
            return (None,) + t
        lax_ = jax.tree.map(add_axis, lax_,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("vocab", None),
        "layers": lax_,
        "final_norm": {"scale": (None,)},
        "lm_head": (None, "vocab"),
    }


def param_specs(cfg: TransformerConfig, rules: MeshRules):
    logical = param_logical_axes(cfg)

    def to_spec(x):
        return logical_to_spec(rules, x) if isinstance(x, tuple) else x

    return jax.tree.map(to_spec, logical,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Embedding / loss (vocab-parallel, chunked)
# ---------------------------------------------------------------------------


def _embed_lookup(table: jax.Array, tokens: jax.Array, rules: MeshRules,
                  compute_dtype) -> jax.Array:
    """Vocab-parallel lookup: explicit mask+psum under shard_map.

    XLA's partitioned gather from a vocab-sharded table falls back to full
    table rematerialization (verified on the 512-way dry-run); the manual
    formulation keeps the table sharded and emits exactly one psum over the
    model axis of the (B, S, D) activations."""
    if rules.tp is None:
        out = jnp.take(table, tokens, axis=0).astype(compute_dtype)
        return constrain(out, rules, ("batch", None, None))
    dp = rules.dp if rules.dp else None

    def local(tbl, tok):
        rows = tbl.shape[0]
        row0 = jax.lax.axis_index(rules.tp) * rows
        loc = tok - row0
        hit = (loc >= 0) & (loc < rows)
        emb = jnp.take(tbl, jnp.clip(loc, 0, rows - 1), axis=0)
        emb = jnp.where(hit[..., None], emb.astype(compute_dtype), 0)
        return jax.lax.psum(emb, rules.tp)

    from repro.utils.jax_compat import shard_map
    fn = shard_map(local,
                   in_specs=(P(rules.tp, None), P(dp, None)),
                   out_specs=P(dp, None, None))
    return fn(table, tokens)


def _chunked_xent(h: jax.Array, w_head: jax.Array, labels: jax.Array,
                  n_chunks: int, rules: MeshRules) -> jax.Array:
    """Cross entropy without materializing (T, V) logits: scan over
    sequence chunks; vocab stays sharded (lse reductions -> psum)."""
    b, s, d = h.shape
    n_chunks = min(n_chunks, s)
    assert s % n_chunks == 0
    sc = s // n_chunks
    hs = h.reshape(b, n_chunks, sc, d).swapaxes(0, 1)      # (C, B, sc, D)
    ls = labels.reshape(b, n_chunks, sc).swapaxes(0, 1)
    vocab = w_head.shape[1]

    @jax.checkpoint
    def body(carry, inp):
        h_c, l_c = inp
        logits = jnp.einsum("bsd,dv->bsv", h_c.astype(jnp.bfloat16),
                            w_head.astype(jnp.bfloat16)).astype(jnp.float32)
        logits = constrain(logits, rules, ("batch", None, "vocab"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(l_c, vocab, dtype=jnp.float32)
        onehot = constrain(onehot, rules, ("batch", None, "vocab"))
        label_logit = jnp.sum(logits * onehot, axis=-1)
        return carry + jnp.sum(lse - label_logit), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


# ---------------------------------------------------------------------------
# Layer body (shared by train fwd and decode)
# ---------------------------------------------------------------------------


def _qkv(p, cfg: TransformerConfig, h: jax.Array):
    cd = cfg.compute_dtype
    q = jnp.einsum("...d,dk->...k", h, p["wq"].astype(cd))
    k = jnp.einsum("...d,dk->...k", h, p["wk"].astype(cd))
    v = jnp.einsum("...d,dk->...k", h, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _mlp(p, cfg: TransformerConfig, h: jax.Array, rules: MeshRules):
    cd = cfg.compute_dtype
    if cfg.moe is not None:
        return moe_apply(p["moe"], h, cfg.moe, cfg.act, cfg.glu, rules, cd)
    up = jnp.einsum("...d,df->...f", h, p["w_up"].astype(cd))
    if cfg.glu:
        gate = jnp.einsum("...d,df->...f", h, p["w_gate"].astype(cd))
        act = layers.activation(cfg.act, gate) * up
    else:
        act = layers.activation(cfg.act, up)
    act = constrain(act, rules, ("batch", None, "tp"))
    out = jnp.einsum("...f,fd->...d", act, p["w_down"].astype(cd))
    return out, jnp.zeros((), jnp.float32)


def _layer_fwd(p, cfg: TransformerConfig, rules: MeshRules, h: jax.Array,
               positions: jax.Array):
    """One decoder layer, training/prefill form. ``h (B, S, D)``."""
    b, s, _ = h.shape
    hn = layers.rmsnorm(p["ln1"], h)
    q, k, v = _qkv(p, cfg, hn)
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    q = constrain(q, rules, ("batch", None, "tp", None))
    attn = attention.chunked_attention(
        q, k, v, causal=True, window=cfg.swa_window, q_chunk=cfg.q_chunk,
        constrain_fn=lambda x: constrain(x, rules,
                                         ("batch", "tp", None, None)))
    attn = constrain(attn, rules, ("batch", None, "tp", None))
    attn_flat = attn.reshape(b, s, cfg.n_heads * cfg.d_head)
    h = h + jnp.einsum("...k,kd->...d", attn_flat,
                       p["wo"].astype(cfg.compute_dtype))
    h = constrain(h, rules, ("batch", None, None))
    hn = layers.rmsnorm(p["ln2"], h)
    mlp_out, aux = _mlp(p, cfg, hn, rules)
    h = h + mlp_out
    h = constrain(h, rules, ("batch", None, None))
    return h, aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "nothing": save nothing, recompute all


# ---------------------------------------------------------------------------
# Training forward/loss
# ---------------------------------------------------------------------------


def train_loss(params, batch: Dict[str, jax.Array], cfg: TransformerConfig,
               rules: MeshRules) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    h = _embed_lookup(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, layer_params):
        h, aux = carry
        h2, aux2 = _layer_fwd(layer_params, cfg, rules, h, positions)
        return (h2, aux + aux2), None

    body_r = _remat(body, cfg.remat_policy)
    carry0 = (h, jnp.zeros((), jnp.float32))
    if blocked_layout(cfg):
        # hierarchical (sqrt) remat: outer scan over blocks saves only the
        # nb block inputs; each block recomputes its inner layer scan.
        # params["layers"] is already (nb, block, ...) -- see init().
        @jax.checkpoint
        def block_body(carry, block_params):
            out, _ = jax.lax.scan(body_r, carry, block_params)
            return out, None

        (h, aux), _ = jax.lax.scan(block_body, carry0, params["layers"])
    else:
        (h, aux), _ = jax.lax.scan(body_r, carry0, params["layers"])
    h = layers.rmsnorm(params["final_norm"], h)
    loss = _chunked_xent(h, params["lm_head"], labels, cfg.loss_chunks,
                         rules)
    return loss + aux / cfg.n_layers


# ---------------------------------------------------------------------------
# Prefill (forward pass + KV cache build)
# ---------------------------------------------------------------------------


def prefill_step(params, tokens: jax.Array, cfg: TransformerConfig,
                 rules: MeshRules):
    """Inference prefill: forward over the prompt, returning the last-token
    logits and the populated KV cache (scan ys give the (L, ...) stacking).
    For SWA archs the cache keeps only the trailing window."""
    b, s = tokens.shape
    h = _embed_lookup(params["embed"], tokens, rules, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    keep = cache_len(cfg, s)

    def body(h, layer_params):
        p = layer_params
        hn = layers.rmsnorm(p["ln1"], h)
        q, k, v = _qkv(p, cfg, hn)
        q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        q = constrain(q, rules, ("batch", None, "tp", None))
        attn = attention.chunked_attention(
            q, k, v, causal=True, window=cfg.swa_window,
            q_chunk=cfg.q_chunk,
            constrain_fn=lambda x: constrain(x, rules,
                                             ("batch", "tp", None, None)))
        attn_flat = attn.reshape(b, s, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("...k,kd->...d", attn_flat,
                           p["wo"].astype(cfg.compute_dtype))
        hn = layers.rmsnorm(p["ln2"], h)
        mlp_out, _ = _mlp(p, cfg, hn, rules)
        h = constrain(h + mlp_out, rules, ("batch", None, None))
        return h, (k[:, s - keep:], v[:, s - keep:])

    if blocked_layout(cfg):
        def block_body(hh, block_params):
            return jax.lax.scan(body, hh, block_params)
        h, (k_cache, v_cache) = jax.lax.scan(block_body, h,
                                             params["layers"])
    else:
        h, (k_cache, v_cache) = jax.lax.scan(body, h, params["layers"])
    h = layers.rmsnorm(params["final_norm"], h[:, -1:])[:, 0]
    logits = jnp.einsum("bd,dv->bv", h.astype(jnp.bfloat16),
                        params["lm_head"].astype(jnp.bfloat16))
    logits = constrain(logits, rules, ("batch", "vocab"))
    return logits.astype(jnp.float32), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Decode (one token, KV cache)
# ---------------------------------------------------------------------------


def cache_len(cfg: TransformerConfig, max_seq: int) -> int:
    if cfg.swa_window is not None:
        return min(cfg.swa_window, max_seq)
    return max_seq


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None):
    s = cache_len(cfg, max_seq)
    dtype = dtype or cfg.compute_dtype
    if blocked_layout(cfg):
        shape = (cfg.n_layers // cfg.remat_block, cfg.remat_block, batch,
                 s, cfg.n_kv_heads, cfg.d_head)
    else:
        shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg: TransformerConfig, rules: MeshRules):
    logical = (None, "batch", "seq_tp", None, None)
    if blocked_layout(cfg):
        logical = (None,) + logical
    spec = logical_to_spec(rules, logical)
    return {"k": spec, "v": spec}


def decode_step(params, cache, tokens: jax.Array, pos: jax.Array,
                cfg: TransformerConfig, rules: MeshRules):
    """One decode step: ``tokens (B,)`` at absolute position ``pos``
    (scalar). Returns (logits (B, V), new_cache)."""
    b = tokens.shape[0]
    h = _embed_lookup(params["embed"], tokens[:, None], rules,
                      cfg.compute_dtype)                     # (B, 1, D)
    h = h[:, 0]
    s_cache = cache["k"].shape[2]
    # ring-buffer slot for SWA; plain slot otherwise
    slot = pos % s_cache if cfg.swa_window is not None else pos
    length = jnp.minimum(pos + 1, s_cache)

    def body(h, xs):
        p, k_c, v_c = xs
        hn = layers.rmsnorm(p["ln1"], h[:, None])[:, 0]
        q, k, v = _qkv(p, cfg, hn)
        q = q.reshape(b, cfg.n_heads, cfg.d_head)
        k = k.reshape(b, cfg.n_kv_heads, cfg.d_head)
        v = v.reshape(b, cfg.n_kv_heads, cfg.d_head)
        pos_b = jnp.broadcast_to(pos, (b, 1))
        q = layers.rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
        k = layers.rope(k[:, None], pos_b, cfg.rope_theta)[:, 0]
        # flash-decoding: the cache keeps its seq dim sharded over "model";
        # q must be REPLICATED over that axis or XLA resolves the contraction
        # conflict by all-gathering the (huge) cache instead (measured
        # ~1 GB/layer at 32k). The psum of the (B, H, dh) partials is tiny.
        q = constrain(q, rules, ("batch", None, None))
        k_c = jax.lax.dynamic_update_slice_in_dim(
            k_c, k.astype(k_c.dtype)[:, None], slot, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            v_c, v.astype(v_c.dtype)[:, None], slot, axis=1)
        attn = attention.decode_attention(q, k_c, v_c, length)
        h = h + jnp.einsum("bk,kd->bd",
                           attn.reshape(b, cfg.n_heads * cfg.d_head),
                           p["wo"].astype(cfg.compute_dtype))
        hn = layers.rmsnorm(p["ln2"], h[:, None])[:, 0]
        mlp_out, _ = _mlp(p, cfg, hn[:, None], rules)
        h = h + mlp_out[:, 0]
        return h, (k_c, v_c)

    if blocked_layout(cfg):
        def block_body(hh, xs):
            return jax.lax.scan(body, hh, xs)
        h, (new_k, new_v) = jax.lax.scan(
            block_body, h, (params["layers"], cache["k"], cache["v"]))
    else:
        h, (new_k, new_v) = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"]))
    h = layers.rmsnorm(params["final_norm"], h[:, None])[:, 0]
    logits = jnp.einsum("bd,dv->bv", h.astype(jnp.bfloat16),
                        params["lm_head"].astype(jnp.bfloat16))
    logits = constrain(logits, rules, ("batch", "vocab"))
    return logits.astype(jnp.float32), {"k": new_k, "v": new_v}
