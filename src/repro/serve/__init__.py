"""Serving layer: batched search engine + fault-tolerant lifecycle
(guarded swaps / snapshot-restore / refresh supervision) + fault
injectors + recsys retrieval + LM decode."""
from repro.serve import decode, engine, faults, lifecycle, retrieval

__all__ = ["decode", "engine", "faults", "lifecycle", "retrieval"]
