"""Serving layer: batched search engine + async coalescing frontend +
fault-tolerant lifecycle (guarded swaps / snapshot-restore / refresh
supervision) + fault injectors + recsys retrieval + LM decode."""
from repro.serve import (decode, engine, faults, frontend, lifecycle,
                         retrieval)

__all__ = ["decode", "engine", "faults", "frontend", "lifecycle",
           "retrieval"]
