"""Serving layer: batched search engine + recsys retrieval + LM decode."""
from repro.serve import decode, engine, retrieval

__all__ = ["decode", "engine", "retrieval"]
