"""LM generation loop: prefill once, then jitted decode steps with the KV
cache (the serve_step the decode_32k / long_500k dry-run shapes exercise).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.sharding import MeshRules

__all__ = ["generate"]


def generate(params, prompt: jax.Array, n_new: int,
             cfg: tfm.TransformerConfig, rules: Optional[MeshRules] = None,
             temperature: float = 0.0, rng: Optional[jax.Array] = None):
    """``prompt (B, S0)`` -> generated tokens ``(B, S0 + n_new)``.

    Greedy when temperature == 0, else categorical sampling. The cache is
    sized for the full output (SWA archs keep only their window).
    """
    rules = rules or MeshRules(dp=(), fsdp=(), tp=None, ep=None)
    b, s0 = prompt.shape
    max_seq = s0 + n_new
    logits, cache = tfm.prefill_step(params, prompt, cfg, rules)
    # re-home the prefill cache into a max_seq-sized cache
    full = tfm.init_cache(cfg, b, max_seq, dtype=cache["k"].dtype)
    keep = cache["k"].shape[-3]
    full = {
        kk: jax.lax.dynamic_update_slice_in_dim(
            full[kk], cache[kk], max(0, min(s0, tfm.cache_len(cfg, max_seq))
                                     - keep), axis=full[kk].ndim - 3)
        for kk in ("k", "v")
    }

    step_fn = jax.jit(lambda p, c, t, q: tfm.decode_step(p, c, t, q, cfg,
                                                         rules))
    tokens = prompt
    last = jnp.argmax(logits, axis=-1).astype(prompt.dtype)
    key = rng if rng is not None else jax.random.PRNGKey(0)
    for i in range(n_new):
        tokens = jnp.concatenate([tokens, last[:, None]], axis=1)
        if i == n_new - 1:
            break
        logits, full = step_fn(params, full, last,
                               jnp.asarray(s0 + i, jnp.int32))
        if temperature > 0:
            key, sub = jax.random.split(key)
            last = jax.random.categorical(
                sub, logits / temperature, axis=-1).astype(prompt.dtype)
        else:
            last = jnp.argmax(logits, axis=-1).astype(prompt.dtype)
    return tokens
