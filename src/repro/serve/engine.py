"""Batched vector-search serving engine (Algorithm 1 as a service).

Pulls requests from a host-side queue, pads to the compiled batch size,
executes the jitted multi-step search, and reports per-batch latency / QPS.
This is the measurement harness behind the paper's throughput axis; on CPU
the numbers characterize the harness, on TPU the system.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeStats", "ServingEngine", "make_search_fn"]


def make_search_fn(artifacts, k: int, kappa: int, block: int = 4096,
                   index=None):
    """Close Algorithm 1 over ``artifacts`` for any scorer and any Index
    protocol implementation: a jit-able ``queries (B, D) -> ids (B, k)``
    with a main search + rerank.

    ``index`` defaults to the flat blocked scan (``FlatIndex(block)``);
    pass an ``IVFIndex`` / ``GraphIndex`` / ``ShardedIndex`` to serve the
    same artifacts through a different traversal -- the engine neither
    knows nor cares which representation is scanned nor how it is
    traversed or placed.
    """
    from repro.core import search as msearch
    from repro.index.protocol import FlatIndex

    if index is None:
        index = FlatIndex(block=block)

    def search_fn(queries):
        return msearch.multi_step_search(queries, artifacts, index, k,
                                         kappa)

    return search_fn


@dataclass
class ServeStats:
    n_queries: int = 0
    n_batches: int = 0
    total_s: float = 0.0
    latencies_ms: List[float] = field(default_factory=list)

    @property
    def qps(self) -> float:
        return self.n_queries / self.total_s if self.total_s else 0.0

    def percentile_ms(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) \
            if self.latencies_ms else 0.0


class ServingEngine:
    """search_fn(queries (B, D)) -> ids (B, k); fixed compiled batch B."""

    def __init__(self, search_fn: Callable, batch_size: int, dim: int):
        self.search_fn = jax.jit(search_fn)
        self.batch_size = batch_size
        self.dim = dim
        self.stats = ServeStats()
        # warmup/compile with a dummy batch
        dummy = jnp.zeros((batch_size, dim), jnp.float32)
        jax.block_until_ready(self.search_fn(dummy))

    def submit(self, queries: np.ndarray) -> np.ndarray:
        """Run all queries through fixed-size batches (pad the tail)."""
        out = []
        n = queries.shape[0]
        for s in range(0, n, self.batch_size):
            chunk = queries[s:s + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            t0 = time.perf_counter()
            ids = jax.block_until_ready(self.search_fn(jnp.asarray(chunk)))
            dt = time.perf_counter() - t0
            self.stats.n_batches += 1
            self.stats.n_queries += min(self.batch_size, n - s)
            self.stats.total_s += dt
            self.stats.latencies_ms.append(dt * 1e3)
            out.append(np.asarray(ids)[: self.batch_size - pad])
        return np.concatenate(out, axis=0)
