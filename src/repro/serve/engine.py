"""Batched vector-search serving engine (Algorithm 1 as a service), built
around the state-passing contract of :class:`repro.core.search.ServingState`.

The engine compiles ONE ``(queries, state) -> (ids, state)`` step and
carries the state through every call (the classic jax state-passing loop:
with donation the runtime aliases the state buffers input -> output, so the
pass-through is free). Because the artifacts are an argument rather than a
closure constant, ``swap(state)`` installs a refreshed scorer / index /
database with ZERO recompilations -- the swap is a treedef + aval check and
a pointer move, asserted by the compile counter the engine exposes
(``n_compiles``) and by the ``compile_counter`` test fixture.

Pulls requests from a host-side queue, pads to the compiled batch size,
executes the jitted multi-step search, and reports per-batch latency / QPS
plus swap latency. On CPU the numbers characterize the harness, on TPU the
system.
"""
from __future__ import annotations

import collections
import functools
import time
from dataclasses import dataclass
from typing import Deque, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as msearch

__all__ = ["ServeStats", "ServingEngine", "make_search_fn",
           "sanitize_queries"]


def sanitize_queries(queries: np.ndarray, dim: int
                     ) -> "tuple[np.ndarray, np.ndarray]":
    """The ONE input-hardening gate every serving surface shares
    (``ServingEngine.submit`` and the coalescing frontend's ``enqueue``).

    Validates shape/dtype -- a wrong-dimensionality or non-numeric batch
    raises a clear ``ValueError`` instead of surfacing as an XLA shape
    error from inside the compiled step -- and zeroes rows containing
    non-finite values so one poisoned row can never contaminate the rows
    sharing its padded batch. Returns ``(clean (n, dim) float32,
    bad_rows (n,) bool)``; callers report the flagged rows as all ``-1``
    ids and count them in ``ServeStats.n_sanitized``.
    """
    queries = np.asarray(queries)
    if queries.ndim != 2 or queries.shape[1] != dim:
        raise ValueError(
            f"queries must be a (n, {dim}) array; got shape "
            f"{queries.shape}")
    if not (np.issubdtype(queries.dtype, np.floating)
            or np.issubdtype(queries.dtype, np.integer)):
        raise ValueError(
            f"queries must be real-valued (float or int), got dtype "
            f"{queries.dtype}")
    queries = queries.astype(np.float32, copy=False)
    bad_rows = ~np.isfinite(queries).all(axis=1)
    if bad_rows.any():
        queries = np.where(bad_rows[:, None], np.float32(0), queries)
    return queries, bad_rows


def _engine_step(queries, state: msearch.ServingState, *, k: int,
                 kappa: int):
    """The one compiled serving step: search + state pass-through.

    Returning the (donated) state unchanged lets XLA alias its buffers
    input -> output, so carrying multi-GB artifacts through the call costs
    nothing and the caller's next step uses the same executable.
    """
    ids = msearch.state_search(queries, state, k, kappa)
    return ids, state


def _candidates_step(queries, state: msearch.ServingState, *, kappa: int):
    """First stage of the two-level serving pipeline (host rerank tier):
    the compiled reduced-space search only -- ``x_full`` is host-resident
    aux data and never enters the trace. The host gather of the kappa
    candidate rows, the prefetch ``device_put``, and the small compiled
    ``rerank_candidates`` program run outside, overlapped with the next
    batch's fine scan by ``ServingEngine.submit``."""
    cand = msearch.state_candidates(queries, state, kappa)
    return cand, state


def make_search_fn(artifacts, k: int, kappa: int, block: int = 4096,
                   index=None):
    """One-shot convenience: bind ``artifacts`` (+ optional Index-protocol
    ``index``) into a jit-able ``queries (B, D) -> ids (B, k)``.

    This is a thin wrapper over the state-passing path -- it builds a
    :class:`~repro.core.search.ServingState` and partially applies it. For
    anything long-lived (or refreshable) use :class:`ServingEngine`, which
    keeps the state an argument so it can be hot-swapped.
    """
    state = msearch.make_state(artifacts, index=index, block=block)

    def search_fn(queries):
        return msearch.state_search(queries, state, k, kappa)

    return search_fn


@dataclass
class ServeStats:
    """Serving counters. ``latencies_ms`` / ``swap_ms`` are RING BUFFERS
    (``deque(maxlen=window)``): a long-running engine sees millions of
    batches, and an unbounded list would both grow without limit and
    freeze the percentiles on ancient history -- the window keeps memory
    flat and the p50/p99 a moving view of the recent ``window`` batches.
    The scalar counters (``n_queries``/``n_batches``/``total_s``) remain
    lifetime totals."""

    n_queries: int = 0
    n_batches: int = 0
    n_sanitized: int = 0          # non-finite query rows zeroed out
    total_s: float = 0.0
    # Overload accounting (async frontend, :mod:`repro.serve.frontend`):
    # ``n_rejected`` counts requests refused AT ENQUEUE (bounded queue at
    # capacity, or a deadline the admission estimate says cannot be met);
    # ``n_shed`` counts requests the dispatcher dropped from the queue
    # because their deadline expired while waiting; ``n_deadline_miss``
    # counts requests that were served but completed past their deadline
    # (the SLO-miss tail the shed policy exists to bound). Rejection and
    # shedding are LOUD (a backpressure error to the client), never a
    # silent drop.
    n_rejected: int = 0
    n_shed: int = 0
    n_deadline_miss: int = 0
    # Host-tier traffic accounting (two-level rerank hierarchy only):
    # ``host_bytes`` is the measured host->device rerank-row traffic,
    # ``host_bytes_lb`` the m*kappa*D*4 lower bound per batch -- the bench
    # layer smoke-enforces measured <= 2x bound, pinning the tier's whole
    # point (per-query traffic scales with kappa, not n).
    host_bytes: int = 0
    host_bytes_lb: int = 0
    window: int = 8192
    latencies_ms: Optional[Deque[float]] = None
    swap_ms: Optional[Deque[float]] = None
    prefetch_ms: Optional[Deque[float]] = None    # host gather + H2D + rerank
    request_ms: Optional[Deque[float]] = None     # frontend enqueue->resolve

    def __post_init__(self):
        if self.latencies_ms is None:
            self.latencies_ms = collections.deque(maxlen=self.window)
        if self.swap_ms is None:
            self.swap_ms = collections.deque(maxlen=self.window)
        if self.prefetch_ms is None:
            self.prefetch_ms = collections.deque(maxlen=self.window)
        if self.request_ms is None:
            self.request_ms = collections.deque(maxlen=self.window)

    @property
    def qps(self) -> float:
        return self.n_queries / self.total_s if self.total_s else 0.0

    @property
    def host_bytes_ratio(self) -> float:
        """Measured host->device rerank traffic over the kappa-row lower
        bound (1.0 = every transferred byte is a candidate row)."""
        return self.host_bytes / self.host_bytes_lb \
            if self.host_bytes_lb else 0.0

    def percentile_ms(self, p: float) -> float:
        return float(np.percentile(np.asarray(self.latencies_ms,
                                              np.float64), p)) \
            if self.latencies_ms else 0.0

    def request_percentile_ms(self, p: float) -> float:
        """Percentile over per-REQUEST latency (enqueue -> resolved), the
        number an SLO is stated against -- queue wait included, unlike the
        per-batch compute window ``percentile_ms`` reads."""
        return float(np.percentile(np.asarray(self.request_ms,
                                              np.float64), p)) \
            if self.request_ms else 0.0

    @property
    def shed_rate(self) -> float:
        """Fraction of offered requests rejected or shed (0.0 when
        nothing was offered): the overload pressure-relief observable."""
        offered = self.n_queries + self.n_rejected + self.n_shed
        return (self.n_rejected + self.n_shed) / offered if offered else 0.0


class ServingEngine:
    """Serves ``state_search(queries (B, D), state) -> ids (B, k)`` at a
    fixed compiled batch size, with hot-swappable state.

    ``state`` is the versioned :class:`~repro.core.search.ServingState`
    pytree; ``swap`` installs a new state with the SAME treedef and leaf
    avals and refuses anything that would trigger a recompile; the engine
    bumps the state's version counter on every swap.

    ``donate=True`` additionally donates the state argument so XLA aliases
    its buffers input -> output (zero-copy carry of multi-GB artifacts on
    accelerators). Donation makes the engine the EXCLUSIVE owner of every
    leaf: outside references to the state passed in -- including arrays
    SHARED with it, like a StreamingState's model or the array the
    artifacts were built from -- die on the first call, so only enable it
    when the host loop reads state exclusively through ``engine.state``.
    It is off by default (and pointless on CPU, where jax does not
    implement donation and would warn on every call).
    """

    def __init__(self, state: msearch.ServingState, k: int, kappa: int,
                 batch_size: int, dim: int, donate: bool = False,
                 stats_window: int = 8192):
        if donate and jax.default_backend() == "cpu":
            donate = False      # not implemented on CPU; avoid the warning
        self.k = k
        self.kappa = kappa
        self.batch_size = batch_size
        self.dim = dim
        self.donate = donate
        self.stats = ServeStats(window=stats_window)
        self.state = state
        self.n_swaps = 0
        self._version0 = int(state.version)
        # Two serving shapes, picked by where the rerank tier lives:
        # device x_full -> ONE compiled step (search + rerank inline);
        # host x_full  -> compiled candidates step + host gather + the
        # shared compiled rerank_candidates, pipelined across batches.
        self._host = msearch.host_tier(state.artifacts)
        dummy = jnp.zeros((batch_size, dim), jnp.float32)
        if self._host is None:
            self._cand_fn = None
            self._fn = jax.jit(
                functools.partial(_engine_step, k=k, kappa=kappa),
                donate_argnums=(1,) if donate else ())
            # warmup/compile with a dummy batch
            ids, self.state = self._fn(dummy, self.state)
        else:
            self._fn = None
            self._cand_fn = jax.jit(
                functools.partial(_candidates_step, kappa=kappa),
                donate_argnums=(1,) if donate else ())
            # warmup compiles BOTH stages for this shape family
            cand, new_state = self._cand_fn(dummy, self.state)
            self.state = self._reattach(new_state)
            ids = msearch.rerank(dummy, self.state.artifacts,
                                 np.asarray(cand), k)
        jax.block_until_ready(ids)

    def _reattach(self, state: msearch.ServingState) -> msearch.ServingState:
        """Re-bind the LIVE host store to a state that round-tripped the
        compiled step: unflattening a jitted output reattaches the
        trace-time aux object, which after a content-refreshing swap would
        resurrect stale rows (aux equality is by shape/dtype only)."""
        if self._host is None:
            return state
        return state._replace(
            artifacts=state.artifacts._replace(x_full=self._host))

    @property
    def version(self) -> int:
        return int(self.state.version)

    @property
    def n_compiles(self) -> Optional[int]:
        """Executables compiled for the serving step (1 after warmup; still
        1 after any number of well-formed swaps). On the host-rerank path
        this counts the candidates stage -- the rerank stage is the
        module-level shared ``rerank_candidates`` cache."""
        fn = self._fn if self._fn is not None else self._cand_fn
        cache_size = getattr(fn, "_cache_size", None)
        return cache_size() if cache_size is not None else None

    def search_with(self, queries, state: msearch.ServingState):
        """One full search against an arbitrary (treedef-compatible) state
        WITHOUT installing it or touching engine stats -- the lifecycle
        layer's canary hook. Runs whichever pipeline shape the engine
        serves, so a canary over a host-tier state exercises the candidate
        state's own host store."""
        queries = jnp.asarray(queries, jnp.float32)
        if self._host is None:
            ids, _ = self._fn(queries, state)
            return ids
        cand, _ = self._cand_fn(queries, state)
        return msearch.rerank(queries, state.artifacts, np.asarray(cand),
                              self.k)

    def _check_swap_compatible(self, state: msearch.ServingState) -> None:
        """Raise ``ValueError`` unless ``state`` would reuse the compiled
        step (same treedef, same leaf shapes/dtypes). Pure check -- never
        mutates the engine; ``swap`` and the lifecycle layer's guarded
        swap both run it before touching anything."""
        old_def = jax.tree_util.tree_structure(self.state)
        new_def = jax.tree_util.tree_structure(state)
        if old_def != new_def:
            raise ValueError(
                "swap would recompile: state treedef changed\n"
                f"  installed: {old_def}\n  offered:   {new_def}")
        old_leaves = jax.tree_util.tree_leaves(self.state)
        new_leaves = jax.tree_util.tree_leaves(state)
        for i, (o, n) in enumerate(zip(old_leaves, new_leaves)):
            o_aval = (jnp.shape(o), jnp.result_type(o))
            n_aval = (jnp.shape(n), jnp.result_type(n))
            if o_aval != n_aval:
                raise ValueError(
                    f"swap would recompile: leaf {i} changed aval "
                    f"{o_aval} -> {n_aval}")

    def swap(self, state: msearch.ServingState) -> None:
        """Hot-swap the serving state: zero recompiles, by construction.

        The new state must match the installed one's treedef (same scorer /
        index classes, same static index config) and leaf shapes/dtypes --
        exactly the invariants ``streaming.refresh_state`` preserves. A
        mismatch raises BEFORE any engine field changes (``state`` /
        ``n_swaps`` are untouched on every rejection path) instead of
        silently recompiling. For semantic validation on top of the
        structural contract -- non-finite scans, canary batteries,
        rollback -- wrap the engine in
        :class:`repro.serve.lifecycle.GuardedEngine`.
        """
        self._check_swap_compatible(state)
        t0 = time.perf_counter()
        # host-side generation counter -> device scalar (a device_put, not
        # a compiled add: swaps never compile anything, not even once)
        self.n_swaps += 1
        self.state = state._replace(
            version=jnp.asarray(self._version0 + self.n_swaps, jnp.int32))
        if self._host is not None:
            # adopt the incoming store (contents may differ; treedef-equal
            # by construction) so _reattach serves the refreshed rows
            self._host = msearch.host_tier(self.state.artifacts)
        self.stats.swap_ms.append((time.perf_counter() - t0) * 1e3)

    def submit(self, queries: np.ndarray) -> np.ndarray:
        """Run all queries through fixed-size batches (pad the tail).

        Input hardening: an empty batch returns a ``(0, k)`` int32 array
        (nothing to concatenate); a wrong-dimensionality / non-numeric
        batch raises a clear ``ValueError`` instead of surfacing as an
        XLA shape error from inside the compiled step; rows containing
        non-finite values are zeroed before batching -- so one poisoned
        row can never contaminate the rows sharing its padded batch --
        and reported as all ``-1`` ids (counted in ``stats.n_sanitized``).
        """
        queries = np.asarray(queries)
        if queries.size == 0 and queries.ndim <= 2:
            return np.zeros((0, self.k), np.int32)
        queries, bad_rows = sanitize_queries(queries, self.dim)
        if bad_rows.any():
            self.stats.n_sanitized += int(bad_rows.sum())
        out = []
        n = queries.shape[0]
        if self._host is not None:
            return self._submit_pipelined(queries, bad_rows)
        for s in range(0, n, self.batch_size):
            chunk = queries[s:s + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            t0 = time.perf_counter()
            ids, self.state = self._fn(jnp.asarray(chunk, jnp.float32),
                                       self.state)
            ids = jax.block_until_ready(ids)
            dt = time.perf_counter() - t0
            self.stats.n_batches += 1
            self.stats.n_queries += min(self.batch_size, n - s)
            self.stats.total_s += dt
            self.stats.latencies_ms.append(dt * 1e3)
            out.append(np.asarray(ids)[: self.batch_size - pad])
        result = np.concatenate(out, axis=0)
        if bad_rows.any():
            result[bad_rows] = -1      # sanitized rows: no fabricated hits
        return result

    def _submit_pipelined(self, queries: np.ndarray,
                          bad_rows: np.ndarray) -> np.ndarray:
        """Double-buffered two-level submit (host rerank tier).

        For each batch the compiled candidates step is DISPATCHED (jax's
        async dispatch returns immediately); while the device runs batch
        i+1's fine scan, the host drains batch i: block on its candidate
        ids, gather the kappa full-D rows from the host store, push them
        with a non-blocking ``device_put`` and fold the shared compiled
        ``rerank_candidates`` program over them. The host->device traffic
        is exactly the candidate rows -- batch*kappa*D*4 bytes, counted in
        ``stats.host_bytes`` against the matching lower bound -- never the
        (n, D) store.
        """
        out = []
        pending = None
        n = queries.shape[0]
        t_submit = time.perf_counter()
        for s in range(0, n, self.batch_size):
            chunk = queries[s:s + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            t0 = time.perf_counter()
            q = jnp.asarray(chunk, jnp.float32)
            cand, new_state = self._cand_fn(q, self.state)   # async dispatch
            self.state = self._reattach(new_state)
            q_full = msearch._rotate_queries(q, self.state.artifacts)
            if pending is not None:
                out.append(self._finish(pending))   # overlaps batch s's scan
            pending = (cand, q_full, self.batch_size - pad,
                       min(self.batch_size, n - s), t0)
        out.append(self._finish(pending))
        # overlapping batches: QPS comes from the submit WALL time (per-
        # batch dispatch->finish windows overlap and would double-count)
        self.stats.total_s += time.perf_counter() - t_submit
        result = np.concatenate(out, axis=0)
        if bad_rows.any():
            result[bad_rows] = -1      # sanitized rows: no fabricated hits
        return result

    def _finish(self, pending) -> np.ndarray:
        """Drain one in-flight batch: host gather of its kappa candidate
        rows, prefetch to device, compiled rerank."""
        cand_dev, q_full, keep, n_live, t0 = pending
        cand = np.asarray(cand_dev)            # blocks on the fine scan
        tp = time.perf_counter()
        rows = self._host.take(cand)           # (batch, kappa, D) host gather
        rows_dev = jax.device_put(rows)        # non-blocking H2D prefetch
        ids = msearch.rerank_candidates(q_full, rows_dev,
                                        jnp.asarray(cand), self.k)
        ids = jax.block_until_ready(ids)
        now = time.perf_counter()
        self.stats.prefetch_ms.append((now - tp) * 1e3)
        self.stats.host_bytes += rows.nbytes
        self.stats.host_bytes_lb += (cand.shape[0] * self.kappa
                                     * rows.shape[-1] * rows.itemsize)
        self.stats.n_batches += 1
        self.stats.n_queries += n_live
        self.stats.latencies_ms.append((now - t0) * 1e3)
        return np.asarray(ids)[:keep]
