"""Deterministic fault injectors for the serving lifecycle.

Each injector produces exactly the corruption a streamed serving stack
meets in production -- non-finite moments from a poisoned query batch, a
corrupted scorer leaf, an exception mid-refresh, a truncated snapshot, a
poisoned or mis-shaped query batch -- as a pure function of its inputs
(plus an explicit seed where randomness is involved), so the tier-1
recovery tests and the ``serving_faults`` bench rows replay bit-identical
failures. ``FAULTS`` names the kinds ``launch/serve.py --inject-fault``
can drill end-to-end; ``FRONTEND_FAULTS`` names the concurrency drills
the async frontend (``--frontend --inject-fault``) runs on top of them --
a stuck refresh worker, a slow (latency-spike) refresh, a poisoned query
burst, and admission-queue overflow.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as msearch
from repro.core import streaming
from repro.train import checkpoint

__all__ = ["FAULTS", "FRONTEND_FAULTS", "nan_moments",
           "corrupt_scorer_leaf", "scramble_scorer_leaf", "failing",
           "truncate_snapshot", "poison_queries", "wrong_dim_queries",
           "slow_refresh", "stuck_worker", "burst_overflow"]

# the drill-able kinds (launch/serve.py --inject-fault <kind>)
FAULTS = ("nan-moments", "corrupt-scorer", "scramble-scorer",
          "refresh-exception", "truncated-snapshot", "poison-queries",
          "wrong-dim-queries")

# concurrency drills for the async frontend
# (launch/serve.py --frontend --inject-fault <kind>)
FRONTEND_FAULTS = ("stuck-worker", "slow-refresh", "poison-burst",
                   "queue-overflow")


def nan_moments(stream: streaming.StreamingState,
                n: int = 4) -> streaming.StreamingState:
    """Poison the first ``n`` entries of K_X with NaN -- what a drifted
    batch with non-finite rows does to the Eq. 11 rank-1 updates. Every
    later ``refresh`` fits a non-finite model from these moments."""
    flat = jnp.ravel(stream.k_x).at[:n].set(jnp.nan)
    return stream._replace(k_x=flat.reshape(stream.k_x.shape))


def _scorer_leaves(scorer):
    leaves, treedef = jax.tree_util.tree_flatten(scorer)
    return leaves, treedef


def _replace_leaf(state: msearch.ServingState, idx: int, leaf):
    leaves, treedef = _scorer_leaves(state.artifacts.scorer)
    leaves[idx] = leaf
    arts = state.artifacts._replace(scorer=treedef.unflatten(leaves))
    return state._replace(artifacts=arts)


def corrupt_scorer_leaf(state: msearch.ServingState, n: int = 8,
                        value: float = float("nan")
                        ) -> msearch.ServingState:
    """Overwrite the first ``n`` entries of the scorer's largest float
    leaf with ``value`` (NaN by default): the candidate a guarded swap's
    finite scan must refuse."""
    leaves, _ = _scorer_leaves(state.artifacts.scorer)
    floats = [i for i, lf in enumerate(leaves)
              if hasattr(lf, "dtype") and jnp.issubdtype(lf.dtype,
                                                         jnp.inexact)]
    if not floats:
        raise ValueError("scorer has no float leaves to corrupt")
    idx = max(floats, key=lambda i: np.size(leaves[i]))
    lf = jnp.asarray(leaves[idx])
    bad = jnp.ravel(lf).at[:n].set(value).reshape(lf.shape)
    return _replace_leaf(state, idx, bad)


def scramble_scorer_leaf(state: msearch.ServingState) -> msearch.ServingState:
    """Roll the rows of the scorer's largest >= 2-d leaf by half the
    store: every value stays FINITE (the non-finite scan passes) but the
    code/row <-> id mapping is garbage -- only the canary battery can
    catch this one."""
    leaves, _ = _scorer_leaves(state.artifacts.scorer)
    wide = [i for i, lf in enumerate(leaves)
            if hasattr(lf, "ndim") and lf.ndim >= 2]
    if not wide:
        raise ValueError("scorer has no >=2-d leaves to scramble")
    idx = max(wide, key=lambda i: np.size(leaves[i]))
    lf = jnp.asarray(leaves[idx])
    return _replace_leaf(state, idx, jnp.roll(lf, lf.shape[0] // 2, axis=0))


class failing:
    """Wrap ``fn`` so its first ``n_failures`` calls raise (then it
    delegates): the exception-mid-refresh injector for the supervisor's
    retry path. Exposes ``calls`` / ``failures`` counters."""

    def __init__(self, fn, n_failures: int = 1,
                 exc: type = RuntimeError):
        self.fn = fn
        self.n_failures = n_failures
        self.exc = exc
        self.calls = 0
        self.failures = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if self.failures < self.n_failures:
            self.failures += 1
            raise self.exc(
                f"injected refresh failure {self.failures}/{self.n_failures}")
        return self.fn(*args, **kwargs)


def truncate_snapshot(snap_dir: str, step: Optional[int] = None,
                      what: str = "leaf") -> str:
    """Corrupt a durable snapshot step in place: halve its manifest
    (``what="manifest"`` -- undecodable json) or its largest leaf file
    (``what="leaf"`` -- ``np.load`` fails short). Returns the truncated
    path; ``lifecycle.restore`` must fall back to the previous step."""
    steps = checkpoint.available_steps(snap_dir)
    if not steps:
        raise FileNotFoundError(f"no snapshot steps under {snap_dir}")
    step = steps[-1] if step is None else step
    d = os.path.join(snap_dir, f"step_{step:08d}")
    if what == "manifest":
        path = os.path.join(d, "manifest.json")
    elif what == "leaf":
        npys = [os.path.join(d, f) for f in os.listdir(d)
                if f.endswith(".npy")]
        path = max(npys, key=os.path.getsize)
    else:
        raise ValueError(f"unknown truncation target {what!r}")
    with open(path, "rb") as f:
        data = f.read()
    with open(path, "wb") as f:
        f.write(data[: len(data) // 2])
    return path


def poison_queries(queries: np.ndarray, rows: Sequence[int] = (0,),
                   value: float = float("nan")) -> np.ndarray:
    """A copy of ``queries`` with ``value`` (NaN/inf) planted in the
    marked rows -- the poisoned batch ``ServingEngine.submit`` must
    sanitize without contaminating the rows sharing its padded batch."""
    q = np.array(queries, np.float32, copy=True)
    q[list(rows), 0] = value
    return q


def wrong_dim_queries(queries: np.ndarray) -> np.ndarray:
    """Drop the last feature: the wrong-dimensionality batch that must
    raise a clear ``ValueError`` instead of an XLA shape error."""
    return np.asarray(queries)[:, :-1]


class slow_refresh:
    """Wrap a refresh fn so every call first sleeps ``delay_s`` -- the
    latency-spike refresh (an overloaded solver, a slow remote read). A
    frontend with a background :class:`~repro.serve.frontend.
    RefreshWorker` must keep serving the current state throughout, with
    only ``staleness_s`` growing. ``sleep`` is injectable so tests can
    observe the delay without paying wall time; ``calls`` counts
    invocations."""

    def __init__(self, fn=streaming.refresh, delay_s: float = 0.2,
                 sleep=time.sleep):
        self.fn = fn
        self.delay_s = delay_s
        self.sleep = sleep
        self.calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        self.sleep(self.delay_s)
        return self.fn(*args, **kwargs)


class stuck_worker:
    """Wrap a refresh fn so every call BLOCKS until ``release`` is set
    (hung I/O, a deadlocked solve), then delegates -- the stuck-refresh-
    worker drill. The worker thread strands inside the call; the serving
    path must be unaffected (stale-but-valid state keeps answering) and
    ``RefreshWorker.stuck(timeout_s)`` must flip true. A ``timeout_s``
    backstop raises instead of pinning a test forever; ``calls`` /
    ``releases`` count entries and successful exits."""

    def __init__(self, release: threading.Event, fn=streaming.refresh,
                 timeout_s: float = 30.0):
        self.release = release
        self.fn = fn
        self.timeout_s = timeout_s
        self.calls = 0
        self.releases = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if not self.release.wait(self.timeout_s):
            raise TimeoutError(
                f"stuck_worker held past its {self.timeout_s}s backstop")
        self.releases += 1
        return self.fn(*args, **kwargs)


def burst_overflow(dim: int, n: int, seed: int = 0,
                   poison_frac: float = 0.0) -> np.ndarray:
    """A deterministic (n, dim) query burst sized to overflow a bounded
    admission queue (pick ``n`` > capacity + one bucket). With
    ``poison_frac`` > 0, that fraction of rows (seeded choice) carries a
    NaN -- the poisoned-burst drill: sanitized rows resolve as all-(-1)
    ids while their bucket-mates' results stay exact."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((n, dim)).astype(np.float32)
    if poison_frac > 0:
        n_bad = max(1, int(round(poison_frac * n)))
        rows = rng.choice(n, size=n_bad, replace=False)
        q[rows, 0] = np.nan
    return q
