"""Overload-safe async serving frontend: bounded-queue request coalescer
with deadline admission, and a supervised background refresh worker.

``launch/serve.py``'s host loop is one-batch-in-one-batch-out: a single
slow client stalls everyone behind it, and ``--stream`` blocks serving
~100ms per refresh. This module is the concurrent frontend the
fault-tolerance substrate (PR 7) and the state-passing engine (PR 4/8)
were built to protect:

* :class:`ServingFrontend` -- many concurrent clients
  ``enqueue(query, deadline_ms)`` into a FIXED-CAPACITY admission queue;
  one dispatcher drains it into padded micro-batches drawn from a small
  STATIC set of bucket shapes (:func:`bucket_shapes`), so the one
  compiled ``state_search`` / ``state_candidates`` step is reused with
  zero recompiles after warmup -- the executable cache is bounded by
  ``len(buckets)`` forever (the ``BoundedCompileCache`` analysis rule).
  Results are sliced back per request; a request coalesced into a bucket
  is bit-identical to the same query sent through
  ``ServingEngine.submit`` alone. Input hardening is shared with
  ``submit`` (:func:`repro.serve.engine.sanitize_queries`): malformed
  requests raise at ``enqueue``, poisoned rows are zeroed, resolved as
  all ``-1`` ids, and never contaminate their bucket-mates.

* **Admission control / load shedding** -- the queue refuses work it
  cannot serve in time, LOUDLY. At enqueue: a full queue or a deadline
  the wait estimate (EWMA batch latency x queue depth in buckets) says
  cannot be met raises :class:`Rejected` (backpressure to the client,
  counted in ``ServeStats.n_rejected``). At dispatch: requests whose
  deadline expired while queued are shed -- their future fails with
  ``Rejected("shed")``, counted in ``n_shed`` -- so under sustained
  overload the tail is cut instead of every request's latency
  collapsing together.

* :class:`RefreshWorker` -- the Section 3.2 refresh loop as a
  BACKGROUND thread under :class:`~repro.serve.lifecycle.
  RefreshSupervisor` (retry/backoff, stored->full escalation,
  degrade -> recover), handing finished states to
  ``GuardedEngine.swap``. Serving never waits on a refresh: the
  dispatcher reads ``engine.state`` once per batch (an atomic reference
  read -- states are immutable pytrees, and a swap is a single
  reference assignment under the GIL), so a slow, stuck, or crashed
  worker leaves the stale-but-valid state serving and only
  ``staleness_s`` grows.

The deterministic core is :meth:`ServingFrontend.drain_once` with an
injectable ``clock`` -- tests drive admission, coalescing, and shedding
without threads or wall time; the dispatcher thread is a thin loop over
it.
"""
from __future__ import annotations

import collections
import math
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streaming
from repro.serve.engine import ServingEngine, sanitize_queries
from repro.serve.lifecycle import GuardedEngine, RefreshSupervisor

__all__ = ["MAX_BUCKETS", "Rejected", "bucket_shapes", "ServingFrontend",
           "RefreshWorker"]

# Contract ceiling on the static bucket set: every dispatched batch shape
# is one of len(buckets) <= MAX_BUCKETS shapes, so the compiled-step cache
# can never grow past it. Enforced here at construction and by the
# ``BoundedCompileCache`` rule in ``repro.analysis``.
MAX_BUCKETS = 12


class Rejected(RuntimeError):
    """Backpressure error: the frontend refused (or shed) a request.

    ``reason`` is a stable slug -- ``queue-full`` (admission queue at
    capacity), ``deadline`` (the wait estimate says the budget cannot be
    met), ``shed`` (deadline expired while queued), ``shutdown`` (the
    frontend is closing). Clients retry/route elsewhere; nothing is
    dropped silently."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        super().__init__(f"request rejected ({reason}): {detail}" if detail
                         else f"request rejected ({reason})")


def bucket_shapes(max_batch: int) -> Tuple[int, ...]:
    """The static micro-batch shape set: powers of two up to (and always
    including) ``max_batch``. Small by construction -- padding waste is
    bounded at 2x while the compiled executable count stays
    O(log max_batch), and the whole set is warmable up front."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    shapes = set()
    b = 1
    while b < max_batch:
        shapes.add(b)
        b *= 2
    shapes.add(max_batch)
    out = tuple(sorted(shapes))
    if len(out) > MAX_BUCKETS:
        raise ValueError(
            f"{len(out)} bucket shapes exceed MAX_BUCKETS={MAX_BUCKETS}; "
            f"the compile-cache bound is the frontend's contract")
    return out


@dataclass
class _Request:
    """One admitted client request (a single query vector)."""

    query: np.ndarray            # (1, dim) float32, already sanitized
    poisoned: bool               # non-finite row: resolve as all -1 ids
    deadline: float              # absolute clock time (math.inf = none)
    t_enqueue: float
    future: Future


class ServingFrontend:
    """Bounded-queue request coalescer over a :class:`ServingEngine`.

    ``engine`` may be a raw :class:`ServingEngine` or a
    :class:`~repro.serve.lifecycle.GuardedEngine` (unwrapped via its
    ``.engine``). The frontend dispatches through
    ``engine.search_with(queries, engine.state)`` -- the tier-dispatching
    entry that serves both the one-step device pipeline and the two-level
    host-rerank pipeline -- and never installs the pass-through state, so
    it composes with concurrent ``GuardedEngine.swap`` from a
    :class:`RefreshWorker` without locks on the hot path.

    ``capacity`` bounds the admission queue; ``default_deadline_ms`` is
    applied when ``enqueue`` is called without a deadline (None = no
    deadline); ``est_batch_ms``/``ewma_alpha`` seed and smooth the
    admission-time wait estimate; ``clock`` is injectable for
    deterministic tests. ``start=False`` skips the dispatcher thread --
    drive :meth:`drain_once` directly.
    """

    def __init__(self, engine, capacity: int = 256,
                 buckets: Optional[Sequence[int]] = None,
                 default_deadline_ms: Optional[float] = None,
                 est_batch_ms: float = 5.0, ewma_alpha: float = 0.2,
                 clock: Callable[[], float] = time.monotonic,
                 start: bool = True, warmup: bool = True):
        self.engine: ServingEngine = getattr(engine, "engine", engine)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.buckets = tuple(sorted(buckets)) if buckets is not None \
            else bucket_shapes(self.engine.batch_size)
        if len(self.buckets) > MAX_BUCKETS:
            raise ValueError(f"{len(self.buckets)} buckets exceed "
                             f"MAX_BUCKETS={MAX_BUCKETS}")
        self.max_bucket = self.buckets[-1]
        self.default_deadline_ms = default_deadline_ms
        self.stats = self.engine.stats
        self._ewma_s = est_batch_ms / 1e3
        self._ewma_alpha = float(ewma_alpha)
        self._clock = clock
        self._cv = threading.Condition(threading.Lock())
        self._queue: collections.deque = collections.deque()
        self._closed = False
        self.dispatched_shapes: set = set()
        self._thread: Optional[threading.Thread] = None
        if warmup:
            self.warmup()
        if start:
            self._thread = threading.Thread(target=self._dispatch_loop,
                                            name="frontend-dispatch",
                                            daemon=True)
            self._thread.start()

    # -- warmup / observability ------------------------------------------
    def warmup(self) -> None:
        """Compile every bucket shape up front (one executable each; the
        engine's own warmup already covers ``batch_size``, which is a
        bucket). After this, serving ANY admissible workload through the
        frontend compiles nothing -- compile_counter-asserted by the
        tests and the bursty-arrival bench."""
        dummy_state = self.engine.state
        for b in self.buckets:
            q = np.zeros((b, self.engine.dim), np.float32)
            jax.block_until_ready(self.engine.search_with(q, dummy_state))

    @property
    def queue_depth(self) -> int:
        with self._cv:
            return len(self._queue)

    def estimated_wait_s(self, depth: Optional[int] = None) -> float:
        """Admission-time service estimate: batches ahead of (and
        including) the candidate request, times the EWMA batch latency."""
        if depth is None:
            depth = self.queue_depth
        batches = depth // self.max_bucket + 1
        return batches * self._ewma_s

    # -- admission --------------------------------------------------------
    def enqueue(self, query: np.ndarray,
                deadline_ms: Optional[float] = None) -> Future:
        """Admit one query vector; returns a ``Future`` resolving to its
        (k,) int32 ids. Malformed input raises ``ValueError`` (shared
        hardening with ``submit``); an overloaded queue or an unmeetable
        deadline raises :class:`Rejected` -- backpressure, not a silent
        drop. Poisoned (non-finite) rows are admitted but sanitized:
        zeroed for batching, resolved as all ``-1`` ids."""
        q = np.asarray(query)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[0] != 1:
            raise ValueError(
                f"enqueue takes ONE query vector per request; got shape "
                f"{np.shape(query)} (use ServingEngine.submit for batches)")
        q, bad = sanitize_queries(q, self.engine.dim)
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        now = self._clock()
        deadline = math.inf if deadline_ms is None \
            else now + deadline_ms / 1e3
        with self._cv:
            if self._closed:
                raise Rejected("shutdown", "frontend is closed")
            if len(self._queue) >= self.capacity:
                self.stats.n_rejected += 1
                raise Rejected(
                    "queue-full",
                    f"admission queue at capacity {self.capacity}")
            est = self.estimated_wait_s(len(self._queue))
            if now + est > deadline:
                self.stats.n_rejected += 1
                raise Rejected(
                    "deadline",
                    f"predicted wait {est * 1e3:.1f}ms exceeds budget "
                    f"{deadline_ms:.1f}ms at depth {len(self._queue)}")
            if bad[0]:
                self.stats.n_sanitized += 1
            req = _Request(query=q, poisoned=bool(bad[0]),
                           deadline=deadline, t_enqueue=now,
                           future=Future())
            self._queue.append(req)
            self._cv.notify()
        return req.future

    # -- dispatch ---------------------------------------------------------
    def _pick_bucket(self, n: int) -> int:
        """Smallest declared bucket holding ``n`` requests. ``n`` never
        exceeds ``max_bucket`` (the dispatcher drains at most that many),
        so the result is always a member of the static set."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_bucket

    def _take(self, timeout: Optional[float]
              ) -> Tuple[List[_Request], List[_Request]]:
        """Pop up to ``max_bucket`` requests, splitting off those whose
        deadline cannot survive one more batch window (shed)."""
        with self._cv:
            if not self._queue and timeout:
                self._cv.wait(timeout)
            batch: List[_Request] = []
            shed: List[_Request] = []
            horizon = self._clock() + self._ewma_s
            while self._queue and len(batch) < self.max_bucket:
                req = self._queue.popleft()
                (shed if req.deadline < horizon else batch).append(req)
        return batch, shed

    def drain_once(self, timeout: Optional[float] = None) -> int:
        """One dispatcher round: shed expired requests, coalesce the rest
        into one padded bucket, run the compiled step, slice results back
        per request. Returns the number of requests retired (served +
        shed). Deterministic -- the threaded dispatcher is a loop over
        this; tests call it directly."""
        batch, shed = self._take(timeout)
        for req in shed:
            self.stats.n_shed += 1
            req.future.set_exception(
                Rejected("shed", "deadline expired while queued"))
        if not batch:
            return len(shed)
        b = self._pick_bucket(len(batch))
        chunk = np.zeros((b, self.engine.dim), np.float32)
        for i, req in enumerate(batch):
            chunk[i] = req.query[0]
        t0 = self._clock()
        try:
            # one atomic reference read: a concurrent swap either lands
            # before (batch sees the fresh state) or after (stale-but-
            # valid) -- never a torn state, states being immutable pytrees
            state = self.engine.state
            ids = self.engine.search_with(chunk, state)
            ids = np.asarray(jax.block_until_ready(ids))
        except Exception as e:      # noqa: BLE001 -- fail THIS batch only
            for req in batch:
                req.future.set_exception(e)
            return len(batch) + len(shed)
        dt = self._clock() - t0
        a = self._ewma_alpha
        self._ewma_s = a * dt + (1 - a) * self._ewma_s
        self.dispatched_shapes.add(b)
        self.stats.n_batches += 1
        self.stats.n_queries += len(batch)
        self.stats.total_s += dt
        self.stats.latencies_ms.append(dt * 1e3)
        now = self._clock()
        for i, req in enumerate(batch):
            self.stats.request_ms.append((now - req.t_enqueue) * 1e3)
            if now > req.deadline:
                self.stats.n_deadline_miss += 1
            out = np.full((self.engine.k,), -1, np.int32) if req.poisoned \
                else ids[i].astype(np.int32, copy=True)
            req.future.set_result(out)
        return len(batch) + len(shed)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                if self._closed and not self._queue:
                    return
            self.drain_once(timeout=0.02)

    # -- shutdown ---------------------------------------------------------
    def close(self, drain: bool = True, timeout: float = 10.0) -> None:
        """Stop admitting; either serve the backlog (``drain=True``) or
        fail it with ``Rejected("shutdown")``. Idempotent."""
        with self._cv:
            self._closed = True
            if not drain:
                while self._queue:
                    req = self._queue.popleft()
                    req.future.set_exception(
                        Rejected("shutdown", "frontend closed"))
            self._cv.notify_all()
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout)
        if drain:
            while self.queue_depth:     # un-threaded frontends drain here
                self.drain_once()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class RefreshWorker:
    """Supervised background refresh: ``observe -> refresh ->
    refresh_state -> GuardedEngine.swap`` on its OWN thread, so serving
    never blocks on a refresh.

    The worker owns the :class:`~repro.core.streaming.StreamingState`;
    traffic threads feed it via :meth:`observe` (bounded pending buffer)
    and kick cycles via :meth:`request_refresh` (or a periodic
    ``interval_s``). Each cycle runs under the
    :class:`~repro.serve.lifecycle.RefreshSupervisor` ladder -- retry
    with backoff, stored->full escalation on ill-conditioned Eq. 12
    transitions, graceful degradation -- and a degraded cycle
    auto-``recover``s the moments from the last-known-good store so the
    NEXT cycle swaps clean. A finished state is handed to
    ``GuardedEngine.swap``: a single reference assignment, double-
    buffered against the dispatcher's atomic state read and donation-
    safe (guarded engines are non-donating by construction).

    Failure is contained by design: a refresh that HANGS strands only
    this (daemon) thread -- ``stuck(timeout_s)`` flips true,
    ``staleness_s`` grows, and the engine keeps serving the stale-but-
    valid state; a crash outside the supervisor's net is recorded in
    ``crashed`` and the loop exits, again leaving serving untouched.
    """

    def __init__(self, supervisor: RefreshSupervisor,
                 stream: streaming.StreamingState, source: str = "stored",
                 refresh_fn=streaming.refresh, interval_s: float = 0.0,
                 pending_window: int = 64,
                 clock: Callable[[], float] = time.monotonic):
        self.supervisor = supervisor
        self.guarded: GuardedEngine = supervisor.guarded
        self.stream = stream
        self.source = source
        self.refresh_fn = refresh_fn
        self.interval_s = interval_s
        self._clock = clock
        self._pending: collections.deque = collections.deque(
            maxlen=pending_window)
        self._pending_lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self.n_cycles = 0
        self.crashed: Optional[BaseException] = None
        self.last_swap_t = clock()
        self._cycle_t0: Optional[float] = None
        self._thread = threading.Thread(target=self._loop,
                                        name="refresh-worker", daemon=True)

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "RefreshWorker":
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> bool:
        """Ask the worker to exit; returns False when the thread is still
        alive (e.g. stuck inside a hung refresh -- it is a daemon thread,
        so a stuck worker never pins the process)."""
        self._stop.set()
        self._wake.set()
        if self._thread.is_alive():
            self._thread.join(timeout)
        return not self._thread.is_alive()

    # -- traffic-side API -------------------------------------------------
    def observe(self, queries: np.ndarray) -> None:
        """Queue served queries for the next cycle's K_Q update (and the
        supervisor's recovery window). Bounded buffer: under overload old
        observations drop first -- observation is best-effort, serving
        state is not."""
        q = np.asarray(queries, np.float32)
        with self._pending_lock:
            self._pending.append(q)
        self.supervisor.note_queries(q)

    def request_refresh(self) -> None:
        """Kick one supervised refresh cycle (idempotent while pending)."""
        self._wake.set()

    # -- health observables -----------------------------------------------
    @property
    def degraded(self) -> bool:
        return self.supervisor.degraded

    @property
    def in_cycle_s(self) -> float:
        """Seconds the current cycle has been running (0 when idle)."""
        t0 = self._cycle_t0
        return self._clock() - t0 if t0 is not None else 0.0

    def stuck(self, timeout_s: float) -> bool:
        """True when the in-flight cycle has exceeded ``timeout_s`` --
        the watchdog signal a stuck refresh (hung I/O, a deadlocked
        solve) raises while serving continues on the stale state."""
        return self.in_cycle_s > timeout_s

    @property
    def staleness_s(self) -> float:
        """Seconds since the last successfully swapped refresh: the
        swap-staleness the bench reports. Grows without bound under a
        stuck/crashed worker -- by design, the alert condition."""
        return self._clock() - self.last_swap_t

    @property
    def healthy(self) -> bool:
        return self.crashed is None and self._thread.is_alive()

    # -- the supervised cycle ---------------------------------------------
    def run_cycle(self) -> Optional[object]:
        """One supervised refresh cycle, synchronously (the thread loop
        calls this; tests may too). Returns the ``RefreshReport`` (None
        when there was nothing to do)."""
        self._cycle_t0 = self._clock()
        try:
            with self._pending_lock:
                pending, n = list(self._pending), len(self._pending)
                self._pending.clear()
            stream = self.stream
            for q in pending:
                stream = streaming.observe_queries(stream, jnp.asarray(q))
            self.stream = stream    # observations survive a failed refresh
            stream, report = self.supervisor.refresh_and_swap(
                stream, source=self.source, refresh_fn=self.refresh_fn)
            self.stream = stream
            self.n_cycles += 1
            if report.outcome == "ok":
                self.last_swap_t = self._clock()
            elif report.outcome == "degraded":
                # close the degrade -> recover loop: rebuild the moments
                # from the last-known-good store + retained queries so the
                # NEXT cycle's refresh swaps clean
                try:
                    self.stream = self.supervisor.recover(stream)
                except ValueError:
                    pass            # no retained queries yet: stay degraded
            return report
        finally:
            self._cycle_t0 = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            fired = self._wake.wait(
                self.interval_s if self.interval_s > 0 else None)
            if self._stop.is_set():
                return
            if fired:
                self._wake.clear()
            try:
                self.run_cycle()
            except BaseException as e:   # noqa: BLE001 -- watchdog record
                # outside the supervisor's net: record and stand down;
                # the engine keeps serving the stale-but-valid state
                self.crashed = e
                return
