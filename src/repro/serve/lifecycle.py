"""Fault-tolerant serving lifecycle over the state-passing engine.

PR 4 made ``ServingEngine.swap`` structurally safe (same treedef, same leaf
avals => zero recompiles) but SEMANTICALLY blind: it installs any
compatible state, including one full of NaNs from a poisoned moment
update or a singular Eq. 12 solve. This module adds the three layers a
streamed index needs to stay up:

* :class:`GuardedEngine` -- guarded swaps. Before a candidate state is
  installed it is (1) treedef/aval-checked (the engine's own contract, run
  FIRST so nothing below can trigger a recompile), (2) version-checked
  (monotonic: a stale candidate derived from an older generation is
  refused), (3) scanned for non-finite leaves, and (4) canary-checked: a
  pinned query battery runs through the candidate via the engine's
  ALREADY-COMPILED step (same treedef => zero recompiles) and the swap is
  rejected if its top-k overlap against the installed state collapses.
  Every rejection raises :class:`SwapRejected` BEFORE any engine field is
  touched; the previously installed state is retained so ``rollback()``
  restores it -- bit-identical results -- instantly.

* ``snapshot`` / ``restore`` -- persistence of the ``ServingState`` +
  ``StreamingState`` pair through :mod:`repro.train.checkpoint`'s atomic
  manifest-driven machinery (host-numpy leaves, one file per leaf,
  ``.tmp`` + rename). A restarted process rebuilds the pytree STRUCTURE
  from its launch flags (``template_model`` -- no refit) and restores the
  leaves into it; truncated or corrupted snapshots are detected (manifest
  json errors, missing/short ``.npy`` files) and restore falls back to
  the previous durable step.

* :class:`RefreshSupervisor` -- the streaming refresh loop as a
  supervised operation: retry with exponential backoff, escalation from
  ``source="stored"`` (Eq. 12) to ``source="full"`` re-encode when the
  transition solve is ill-conditioned (or after a failed attempt), and
  graceful degradation -- on persistent failure the engine KEEPS SERVING
  the stale-but-valid state and reports it, rather than crashing or
  installing garbage. ``recover`` rebuilds the moments from the
  last-known-good store + a retained query window, closing the
  fail -> degrade -> recover -> swap loop.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rerank_tier
from repro.core import search as msearch
from repro.core import streaming
from repro.core.gleanvec import GleanVecModel
from repro.core.leanvec_sphering import SpheringModel
from repro.serve.engine import ServingEngine
from repro.train import checkpoint

__all__ = ["SwapRejected", "GuardStats", "GuardedEngine", "RefreshReport",
           "RefreshSupervisor", "snapshot", "restore", "restore_into",
           "nonfinite_leaves", "template_model", "template_stream"]


class SwapRejected(RuntimeError):
    """A guarded swap refused the candidate state. ``reason`` is a stable
    slug (``treedef`` / ``aval`` / ``stale-version`` / ``non-finite`` /
    ``canary-overlap``); the engine's installed state is untouched."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        super().__init__(f"swap rejected ({reason}): {detail}" if detail
                         else f"swap rejected ({reason})")


def nonfinite_leaves(tree) -> List[str]:
    """Keypaths of float leaves containing any non-finite value.

    Integer / bool leaves can't be non-finite and are skipped; the scan is
    one ``all(isfinite)`` reduction per float leaf. An empty list is the
    invariant every SERVED state maintains (healthy stores are finite by
    construction: dead-slot masking uses finite ``NEG_INF`` sentinels and
    the quantizer guards empty-cluster scales).
    """
    bad = []
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for kp, leaf in flat:
        if leaf is None or not hasattr(leaf, "dtype"):
            if isinstance(leaf, float) and not np.isfinite(leaf):
                bad.append(jax.tree_util.keystr(kp))
            continue
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            if not bool(jnp.all(jnp.isfinite(leaf))):
                bad.append(jax.tree_util.keystr(kp))
    return bad


@dataclass
class GuardStats:
    """Observable health of a :class:`GuardedEngine`."""

    accepted: int = 0
    rejected: int = 0
    rollbacks: int = 0
    last_overlap: float = 1.0
    rejections: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=256))

    def reject(self, reason: str):
        self.rejected += 1
        self.rejections.append(reason)


class GuardedEngine:
    """Validating wrapper around a (non-donating) :class:`ServingEngine`.

    ``canary_queries`` (optional, (m, D) host array) pins the query
    battery; ``min_overlap`` is the mean top-k overlap vs the installed
    state below which a candidate is rejected (0 disables the canary even
    when queries are given). The wrapper never mutates the engine on a
    rejection -- ``engine.state``, ``n_swaps`` and the compiled executable
    are exactly as before the call -- and keeps the previously installed
    state as the rollback target.
    """

    def __init__(self, engine: ServingEngine,
                 canary_queries: Optional[np.ndarray] = None,
                 min_overlap: float = 0.3, check_finite: bool = True,
                 monotonic: bool = True):
        if engine.donate:
            raise ValueError(
                "GuardedEngine needs donate=False: canary validation runs "
                "candidate states through the compiled step without "
                "consuming their buffers")
        self.engine = engine
        self.min_overlap = float(min_overlap)
        self.check_finite = check_finite
        self.monotonic = monotonic
        self.health = GuardStats()
        self._prev: Optional[msearch.ServingState] = None
        self._canary = None
        self._canary_rows = 0
        if canary_queries is not None and min_overlap > 0:
            q = np.asarray(canary_queries, np.float32)
            self._canary_rows = min(q.shape[0], engine.batch_size)
            batch = np.zeros((engine.batch_size, engine.dim), np.float32)
            batch[: self._canary_rows] = q[: self._canary_rows]
            self._canary = jnp.asarray(batch)
            self._canary_ref = self._run_canary(engine.state)

    # -- delegation -------------------------------------------------------
    @property
    def state(self) -> msearch.ServingState:
        return self.engine.state

    @property
    def version(self) -> int:
        return self.engine.version

    @property
    def n_swaps(self) -> int:
        return self.engine.n_swaps

    @property
    def n_compiles(self):
        return self.engine.n_compiles

    def submit(self, queries: np.ndarray) -> np.ndarray:
        return self.engine.submit(queries)

    # -- validation -------------------------------------------------------
    def _run_canary(self, state: msearch.ServingState) -> np.ndarray:
        """Top-k ids of the pinned battery under ``state`` via the
        engine's compiled pipeline (same treedef => cache hit, no
        compile). ``search_with`` dispatches on the engine's tier shape,
        so a host-rerank candidate is canaried through its OWN host store
        -- the one guard that sees host-resident rows at all (the finite
        scan skips them by design: they are leafless aux data)."""
        ids = self.engine.search_with(self._canary, state)
        return np.asarray(jax.block_until_ready(ids))[: self._canary_rows]

    @staticmethod
    def _overlap(a: np.ndarray, b: np.ndarray) -> float:
        """Mean per-query fraction of shared ids between two (m, k)
        result sets (-1 padding slots never count as shared)."""
        hits = sum(np.intersect1d(ra[ra >= 0], rb[rb >= 0]).size
                   for ra, rb in zip(a, b))
        return hits / float(max(a.shape[0] * a.shape[1], 1))

    def validate(self, state: msearch.ServingState,
                 monotonic: Optional[bool] = None) -> Optional[np.ndarray]:
        """Run every guard against ``state``; raises :class:`SwapRejected`
        (engine untouched) or returns the candidate's canary result for
        reuse by the caller."""
        # structural check FIRST: nothing below may run a mismatched
        # treedef through the compiled step (that would recompile)
        try:
            self.engine._check_swap_compatible(state)
        except ValueError as e:
            reason = "treedef" if "treedef" in str(e) else "aval"
            self.health.reject(reason)
            raise SwapRejected(reason, str(e)) from e
        if (self.monotonic if monotonic is None else monotonic):
            v_new = int(np.asarray(jax.device_get(state.version)))
            v_old = int(np.asarray(jax.device_get(self.engine.state.version)))
            if v_new < v_old:
                self.health.reject("stale-version")
                raise SwapRejected(
                    "stale-version",
                    f"candidate version {v_new} < installed {v_old}")
        if self.check_finite:
            bad = nonfinite_leaves(state)
            if bad:
                self.health.reject("non-finite")
                raise SwapRejected("non-finite",
                                   f"non-finite leaves: {bad[:4]}")
        if self._canary is None:
            return None
        ids = self._run_canary(state)
        overlap = self._overlap(ids, self._canary_ref)
        self.health.last_overlap = overlap
        if overlap < self.min_overlap:
            self.health.reject("canary-overlap")
            raise SwapRejected(
                "canary-overlap",
                f"canary top-k overlap {overlap:.3f} < {self.min_overlap}")
        return ids

    def _install(self, state: msearch.ServingState,
                 canary_ids: Optional[np.ndarray]) -> None:
        prev = self.engine.state
        self.engine.swap(state)
        self._prev = prev
        if self._canary is not None:
            # the candidate's battery result IS the new reference (the
            # version leaf the engine rewrote doesn't affect search)
            self._canary_ref = canary_ids
        self.health.accepted += 1

    def swap(self, state: msearch.ServingState) -> None:
        """Guarded swap: validate (raising before any mutation), then
        install; the displaced state becomes the rollback target."""
        self._install(state, self.validate(state))

    def rollback(self) -> msearch.ServingState:
        """Reinstall the last-known-good state (the one displaced by the
        most recent accepted swap): bit-identical search results, zero
        recompiles, monotonically advancing version."""
        if self._prev is None:
            raise RuntimeError("no retained last-known-good state to "
                               "roll back to")
        good, self._prev = self._prev, None
        self.engine.swap(good)
        if self._canary is not None:
            self._canary_ref = self._run_canary(self.engine.state)
        self.health.rollbacks += 1
        return self.engine.state


# ---------------------------------------------------------------------------
# Snapshot / restore: ServingState + StreamingState through train.checkpoint.
# ---------------------------------------------------------------------------


def snapshot(snap_dir: str, serving: msearch.ServingState,
             stream: Optional[streaming.StreamingState] = None,
             step: Optional[int] = None, meta: Optional[dict] = None) -> str:
    """Persist the serving + streaming pair atomically under ``snap_dir``.

    ``step`` defaults to (latest durable step) + 1 so repeated snapshots
    form the fallback chain ``restore`` walks backwards on corruption.
    """
    if step is None:
        last = checkpoint.latest_step(snap_dir)
        step = 0 if last is None else last + 1
    meta = dict(meta or {})
    meta["has_stream"] = stream is not None
    # A host-tier rerank store is leafless aux data (never flattened, never
    # device-resident), so its rows ride the snapshot as an EXPLICIT dict
    # of host-numpy leaves -- written straight from host memory, no HBM
    # round-trip. None for device-tier states (their x_full is a regular
    # serving leaf), contributing no manifest paths -- old snapshots and
    # device-tier templates stay mutually compatible.
    host_full = rerank_tier.host_arrays(serving.artifacts.x_full)
    return checkpoint.save(
        snap_dir, step,
        {"serving": serving, "stream": stream, "host_full": host_full},
        meta=meta)


def restore(snap_dir: str, serving_template: msearch.ServingState,
            stream_template: Optional[streaming.StreamingState] = None,
            step: Optional[int] = None
            ) -> Tuple[msearch.ServingState,
                       Optional[streaming.StreamingState], int, dict]:
    """Load the newest restorable snapshot into the templates' treedefs.

    The templates supply STRUCTURE only (scorer/index classes + static
    config from the launch flags; ``template_model`` builds one without a
    refit) -- leaf shapes come from the snapshot (``strict_shapes=False``),
    so layout-dependent shapes (sorted-mode padding) restore exactly even
    when the template's throwaway encoding differs. A truncated manifest,
    a short/missing leaf file, or any other per-step corruption falls
    back to the previous durable step; raises ``FileNotFoundError`` when
    no step is restorable.

    Array leaves come back DEVICE-PUT (``jnp.asarray``), not host numpy:
    jit keys host arrays differently from device arrays even at equal
    avals, so a numpy-leaf state silently compiles a second executable --
    exactly the recompile the whole restore path exists to avoid.
    """
    steps = checkpoint.available_steps(snap_dir)
    if step is not None:
        steps = [s for s in steps if s <= step]
    if not steps:
        raise FileNotFoundError(f"no snapshot steps under {snap_dir}")
    # The template's own (throwaway-row) host store supplies the host_full
    # dict SHAPE -- shard count from the launch flags -- and the snapshot
    # supplies the rows, which never touch device memory on the way back.
    host_template = rerank_tier.host_arrays(
        serving_template.artifacts.x_full)
    template = {"serving": serving_template, "stream": stream_template,
                "host_full": host_template}
    errors = []
    for s in reversed(steps):
        try:
            tree, got, meta = checkpoint.restore(snap_dir, template, step=s,
                                                 strict_shapes=False)
            host_full = tree.pop("host_full")    # host numpy stays host
            tree = jax.tree.map(
                lambda l: jnp.asarray(l) if isinstance(l, np.ndarray) else l,
                tree)
            serving = tree["serving"]
            if host_full is not None:
                # unflatten reattached the TEMPLATE's aux store; rebind the
                # snapshot rows (leafless, so the treedef is unchanged)
                serving = serving._replace(artifacts=serving.artifacts._replace(
                    x_full=rerank_tier.from_host_arrays(host_full)))
            return serving, tree["stream"], got, meta
        except Exception as e:                   # corrupted step: fall back
            errors.append(f"step {s}: {type(e).__name__}: {e}")
    raise FileNotFoundError(
        f"no restorable snapshot under {snap_dir}; tried {errors}")


def restore_into(guarded: GuardedEngine,
                 serving: msearch.ServingState) -> None:
    """Install a restored state into a warm engine: validated like any
    swap (finite scan + canary; monotonicity waived -- a restore may
    legitimately rewind the generation clock), and the engine's version
    counter is rebased so the clock CONTINUES from the snapshot's value
    instead of restarting at warmup's."""
    canary_ids = guarded.validate(serving, monotonic=False)
    eng = guarded.engine
    v = int(np.asarray(jax.device_get(serving.version)))
    # after _install bumps n_swaps, version == snapshot version
    eng._version0 = v - (eng.n_swaps + 1)
    guarded._install(serving, canary_ids)


# ---------------------------------------------------------------------------
# Refresh supervision: retry + backoff, escalation, graceful degradation.
# ---------------------------------------------------------------------------


@dataclass
class RefreshReport:
    """What one supervised refresh attempt chain did."""

    outcome: str                 # "ok" | "degraded"
    source: str                  # refresh source actually used
    attempts: int = 1
    escalated: bool = False
    condition: float = 0.0       # Eq. 12 denominator condition number
    errors: List[str] = field(default_factory=list)
    elapsed_s: float = 0.0


class RefreshSupervisor:
    """Supervises ``refresh -> refresh_state -> guarded swap``.

    The escalation ladder per refresh: (1) the requested source -- but
    ``"stored"`` is promoted to ``"full"`` up front when the Eq. 12
    transition solve is ill-conditioned (``transition_condition`` above
    ``cond_threshold``: a near-dead cluster's ``pinv`` would amplify
    noise unboundedly); (2) on any failure, retry with exponential
    backoff, escalating ``"stored"`` -> ``"full"``; (3) after
    ``max_retries`` extra attempts, DEGRADE: the engine keeps serving the
    last-known-good state, ``degraded`` is set, and the UN-refreshed
    stream state is handed back so a later ``recover`` can rebuild the
    moments from the still-valid store.
    """

    def __init__(self, guarded: GuardedEngine, max_retries: int = 2,
                 backoff_s: float = 0.05, backoff_mult: float = 2.0,
                 cond_threshold: float = 1e6, query_window: int = 4096,
                 sleep=time.sleep):
        self.guarded = guarded
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_mult = backoff_mult
        self.cond_threshold = cond_threshold
        self._sleep = sleep
        self.degraded = False
        self.n_refreshes = 0
        self.n_degraded = 0
        self.n_escalations = 0
        self.n_retries = 0
        self.n_recoveries = 0
        self.reports: List[RefreshReport] = []
        self._recent_q: collections.deque = collections.deque()
        self._recent_rows = 0
        self._query_window = query_window

    def note_queries(self, queries: np.ndarray) -> None:
        """Retain a bounded window of served queries for ``recover``."""
        q = np.asarray(queries, np.float32)
        q = q[np.isfinite(q).all(axis=1)]
        if not q.size:
            return
        self._recent_q.append(q)
        self._recent_rows += q.shape[0]
        while self._recent_q and \
                self._recent_rows - self._recent_q[0].shape[0] \
                >= self._query_window:
            self._recent_rows -= self._recent_q.popleft().shape[0]

    def refresh_and_swap(self, stream: streaming.StreamingState,
                         source: str = "stored", pending=None,
                         refresh_fn=streaming.refresh
                         ) -> Tuple[streaming.StreamingState, RefreshReport]:
        """One supervised refresh. Returns ``(stream', report)``:
        ``stream'`` is the refreshed state on success and the ORIGINAL
        (so the moments survive for recovery) on degradation. The engine
        is never left mid-mutation: a failed attempt changes nothing."""
        self.n_refreshes += 1
        t0 = time.perf_counter()
        report = RefreshReport(outcome="degraded", source=source)
        src, delay = source, self.backoff_s
        for attempt in range(self.max_retries + 1):
            report.attempts = attempt + 1
            try:
                new_stream = refresh_fn(stream)
                use = src
                if use == "stored":
                    cond = streaming.transition_condition(new_stream)
                    report.condition = cond
                    if not cond < self.cond_threshold:   # inf/nan escalate
                        use = "full"
                        report.escalated = True
                        self.n_escalations += 1
                candidate = streaming.refresh_state(
                    self.guarded.engine.state, new_stream, source=use,
                    pending=pending)
                self.guarded.swap(candidate)
                report.outcome, report.source = "ok", use
                report.elapsed_s = time.perf_counter() - t0
                self.degraded = False
                self.reports.append(report)
                return new_stream, report
            except Exception as e:       # noqa: BLE001 -- supervision point
                report.errors.append(f"{type(e).__name__}: {e}")
                if src == "stored":      # ladder: stored -> full -> degrade
                    src = "full"
                    report.escalated = True
                    self.n_escalations += 1
                if attempt < self.max_retries:
                    self.n_retries += 1
                    if delay > 0:
                        self._sleep(delay)
                    delay *= self.backoff_mult
        # persistent failure: keep serving the stale-but-valid state
        self.degraded = True
        self.n_degraded += 1
        report.elapsed_s = time.perf_counter() - t0
        self.reports.append(report)
        return stream, report

    def recover(self, stream: streaming.StreamingState,
                queries: Optional[np.ndarray] = None
                ) -> streaming.StreamingState:
        """Rebuild the streaming moments from the LAST-KNOWN-GOOD serving
        store (live ``x_full`` rows under the currently served model) and
        the retained query window -- the recovery path when the moments
        themselves were poisoned. The next ``refresh_and_swap`` clears
        ``degraded``."""
        if queries is None:
            if not self._recent_q:
                raise ValueError("no retained queries to recover K_Q from; "
                                 "pass queries= explicitly")
            queries = np.concatenate(list(self._recent_q), axis=0)
        fresh = streaming.init_from_artifacts(
            self.guarded.engine.state.artifacts, jnp.asarray(queries),
            refresh_every=int(np.asarray(stream.refresh_every)))
        self.n_recoveries += 1
        return fresh


# ---------------------------------------------------------------------------
# Restart templates: same treedef as a fit pipeline, without the fit.
# ---------------------------------------------------------------------------


def template_model(mode: str, dim: int, d: int, clusters: int = 8,
                   seed: int = 0):
    """A structurally complete DR model with placeholder weights: same
    classes, same treedef as a fit one, NO training -- the restore path's
    whole point is that a restarted engine resumes from snapshot leaves
    instead of refitting. Row counts/shapes of artifacts built from it are
    throwaways (``restore`` is shape-agnostic over templates)."""
    if mode == "full":
        return None
    rng = np.random.default_rng(seed)
    eye = jnp.eye(dim, dtype=jnp.float32)
    if mode.startswith("sphering"):
        a = jnp.asarray(rng.standard_normal((d, dim)), jnp.float32) * 0.1
        return SpheringModel(a=a, b=a, p=a, w=eye, w_pinv=eye)
    centers = rng.standard_normal((clusters, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    ab = jnp.asarray(
        rng.standard_normal((clusters, d, dim)), jnp.float32) * 0.1
    return GleanVecModel(centers=jnp.asarray(centers), a=ab, b=ab, w=eye,
                         w_pinv=eye)


def template_stream(model, refresh_every: int = 1024
                    ) -> streaming.StreamingState:
    """Zero-moment :class:`StreamingState` template around ``model`` (same
    treedef/leaf-set as a live one; leaves are restored over it)."""
    dim = model.w.shape[0]
    if isinstance(model, GleanVecModel):
        k_x = jnp.zeros((model.n_clusters, dim, dim), jnp.float32)
    else:
        k_x = jnp.zeros((dim, dim), jnp.float32)
    return streaming.StreamingState(
        k_q=jnp.zeros((dim, dim), jnp.float32), k_x=k_x, model=model,
        prev_bw=model.b, updates_since=jnp.zeros((), jnp.int32),
        refresh_every=refresh_every)
