"""Candidate-retrieval serving: where the paper meets the recsys archs.

``retrieve`` scores one user against ~10^6 candidate items -- exactly the
MIPS workload GleanVec accelerates. Scoring modes are the unified Scorer
protocol's (:mod:`repro.core.scorer`), selected by string:

  * "full":               exact dot against full-D candidate embeddings;
  * "sphering":           LeanVec-Sphering multi-step (reduced scan +
    rerank);
  * "gleanvec":           GleanVec multi-step (eager per-cluster views +
    rerank);
  * "sphering-int8":      int8 SQ on top of the reduced vectors (LeanVec
    composition);
  * "gleanvec-int8":      int8 SQ on top of the per-cluster reduced vectors;
  * "gleanvec-sorted":    GleanVec in the tag-sorted (cluster-contiguous)
    layout -- one query view per block, plain matmul scan;
  * "gleanvec-int8-sorted": the int8 composition in the tag-sorted layout
    (d bytes of HBM per candidate AND no per-row view gather).

All modes run through the SAME blocked scan + rerank; there is no per-mode
code path and no model-type dispatch here -- the sorted layouts translate
their internal row order back to candidate ids inside the Scorer protocol.
The reduced scans land on the ``ip_topk`` / ``gleanvec_ip`` / ``sq_dot`` /
``gleanvec_sq`` Pallas kernels on TPU and their jnp mirrors elsewhere (see
``repro.kernels.scorer_topk``). Bandwidth per candidate drops from D*4
bytes to d*4 (+1 tag) or d*1, which is the paper's whole point.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax

from repro.core import search as msearch
from repro.core.scorer import build_scorer
from repro.index import bruteforce
from repro.serve.engine import make_search_fn

__all__ = ["RetrievalIndex", "build_retrieval_index", "retrieve"]


class RetrievalIndex(NamedTuple):
    mode: str
    artifacts: msearch.SearchArtifacts

    @property
    def x_full(self) -> jax.Array:
        return self.artifacts.x_full

    @property
    def scorer(self) -> Any:
        return self.artifacts.scorer


def build_retrieval_index(candidates: jax.Array, mode: str = "full",
                          model=None) -> RetrievalIndex:
    """Encode the candidate set for ``mode`` (see ``scorer.MODES``)."""
    artifacts = msearch.SearchArtifacts(
        scorer=build_scorer(mode, candidates, model),
        x_full=candidates, model=model)
    return RetrievalIndex(mode=mode, artifacts=artifacts)


def retrieve(index: RetrievalIndex, user_vecs: jax.Array, k: int,
             kappa: Optional[int] = None, block: int = 4096):
    """``user_vecs (B, D)`` -> top-k candidate ids (B, k)."""
    if index.mode == "full":    # exact scan IS the answer; skip the rerank
        _, ids = bruteforce.search_scorer(user_vecs, index.scorer, k, block)
        return ids
    kappa = kappa or max(k, 2 * k)
    search_fn = make_search_fn(index.artifacts, k, kappa, block)
    return search_fn(user_vecs)
