"""Candidate-retrieval serving: where the paper meets the recsys archs.

``retrieval_cand`` scores one user against ~10^6 candidate items -- exactly
the MIPS workload GleanVec accelerates. Three scoring modes:

  * "full":     exact dot against full-D candidate embeddings (baseline);
  * "sphering": LeanVec-Sphering multi-step (reduced scan + full rerank);
  * "gleanvec": GleanVec multi-step (eager per-cluster views + rerank).

The reduced scans land on the ``ip_topk`` / ``gleanvec_ip`` Pallas kernels
on TPU and their jnp mirrors elsewhere. Bandwidth per candidate drops from
D*4 bytes to d*4 (+1 tag), which is the paper's whole point.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import gleanvec as gv
from repro.core.gleanvec import GleanVecModel
from repro.core.leanvec_sphering import SpheringModel
from repro.index import bruteforce

__all__ = ["RetrievalIndex", "build_retrieval_index", "retrieve"]


class RetrievalIndex(NamedTuple):
    mode: str
    x_full: jax.Array                  # (N, D) candidate embeddings
    x_low: Optional[jax.Array]         # (N, d) reduced
    tags: Optional[jax.Array]          # (N,) gleanvec tags
    model: Optional[object]            # SpheringModel | GleanVecModel


def build_retrieval_index(candidates: jax.Array, mode: str = "full",
                          model=None) -> RetrievalIndex:
    if mode == "full":
        return RetrievalIndex("full", candidates, None, None, None)
    if mode == "sphering":
        assert isinstance(model, SpheringModel)
        return RetrievalIndex("sphering", candidates,
                              candidates @ model.b.T, None, model)
    if mode == "gleanvec":
        assert isinstance(model, GleanVecModel)
        tags, x_low = gv.encode_database(model, candidates)
        return RetrievalIndex("gleanvec", candidates, x_low, tags, model)
    raise ValueError(mode)


def retrieve(index: RetrievalIndex, user_vecs: jax.Array, k: int,
             kappa: Optional[int] = None, block: int = 4096):
    """``user_vecs (B, D)`` -> top-k candidate ids (B, k)."""
    kappa = kappa or max(k, 2 * k)
    if index.mode == "full":
        _, ids = bruteforce.search(user_vecs, index.x_full, k, block)
        return ids
    if index.mode == "sphering":
        q_low = user_vecs @ index.model.a.T
        _, cand = bruteforce.search(q_low, index.x_low, kappa, block)
    else:
        q_views = gv.project_queries_eager(index.model, user_vecs)
        _, cand = bruteforce.search_gleanvec(q_views, index.tags,
                                             index.x_low, kappa, block)
    # rerank in full precision
    vecs = index.x_full[cand]                              # (B, kappa, D)
    scores = jnp.einsum("bkd,bd->bk", vecs, user_vecs)
    top = jax.lax.top_k(scores, k)[1]
    return jnp.take_along_axis(cand, top, axis=1)
