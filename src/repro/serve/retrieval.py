"""Candidate-retrieval serving: where the paper meets the recsys archs.

``retrieve`` scores one user against ~10^6 candidate items -- exactly the
MIPS workload GleanVec accelerates. Scoring modes are the unified Scorer
protocol's (:mod:`repro.core.scorer`), selected by string:

  * "full":               exact dot against full-D candidate embeddings;
  * "sphering":           LeanVec-Sphering multi-step (reduced scan +
    rerank);
  * "gleanvec":           GleanVec multi-step (eager per-cluster views +
    rerank);
  * "sphering-int8":      int8 SQ on top of the reduced vectors (LeanVec
    composition);
  * "gleanvec-int8":      int8 SQ on top of the per-cluster reduced vectors;
  * "gleanvec-sorted":    GleanVec in the tag-sorted (cluster-contiguous)
    layout -- one query view per block, plain matmul scan;
  * "gleanvec-int8-sorted": the int8 composition in the tag-sorted layout
    (d bytes of HBM per candidate AND no per-row view gather).

All modes run through the SAME main-search + rerank; there is no per-mode
code path and no model-type dispatch here -- the sorted layouts translate
their internal row order back to candidate ids inside the Scorer protocol.
The traversal is an orthogonal axis: ``build_retrieval_index(...,
index=...)`` mounts the same scorer behind any Index protocol
implementation (flat scan by default, IVF, graph, or the sharded
placement wrapper) with zero changes to the scoring or rerank code.
The reduced scans land on the ``ip_topk`` / ``gleanvec_ip`` / ``sq_dot`` /
``gleanvec_sq`` Pallas kernels on TPU and their jnp mirrors elsewhere (see
``repro.kernels.scorer_topk``). Bandwidth per candidate drops from D*4
bytes to d*4 (+1 tag) or d*1, which is the paper's whole point.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import numpy as np

from repro.core import search as msearch
from repro.core.scorer import build_scorer
from repro.index.protocol import FlatIndex

__all__ = ["RetrievalIndex", "build_retrieval_index", "retrieve"]


class RetrievalIndex(NamedTuple):
    """``mode`` picks the scorer (representation), ``index`` the Index
    protocol traversal (None = flat blocked scan) -- the two axes are
    orthogonal, so any mode serves through any index.

    ``fn_cache`` memoizes the compiled search step keyed by
    ``(k, kappa, state treedef)``: ``retrieve`` used to rebuild AND re-jit
    its search fn on every call, recompiling Algorithm 1 per request; now
    the first call per key traces once and every later call is a cache hit
    (the state rides in as a pytree argument, so even swapping in refreshed
    artifacts reuses the executable)."""

    mode: str
    artifacts: msearch.SearchArtifacts
    index: Any = None
    fn_cache: Optional[Dict] = None

    @property
    def x_full(self) -> jax.Array:
        return self.artifacts.x_full

    @property
    def scorer(self) -> Any:
        return self.artifacts.scorer


def build_retrieval_index(candidates: jax.Array, mode: str = "full",
                          model=None, index=None,
                          scorer=None) -> RetrievalIndex:
    """Encode the candidate set for ``mode`` (see ``scorer.MODES``);
    ``index`` optionally mounts the scorer behind an Index protocol
    traversal (IVF / graph / sharded) instead of the flat scan.

    ``scorer`` overrides the mode-built one when the traversal needs a
    matching non-global scorer -- a ``ShardedIndex`` consumes the STACKED
    per-shard scorer from ``distributed.build_sharded_index``, not a
    scorer built over the global candidate set."""
    if scorer is None:
        scorer = build_scorer(mode, candidates, model)
    artifacts = msearch.SearchArtifacts(scorer=scorer, x_full=candidates,
                                        model=model)
    return RetrievalIndex(mode=mode, artifacts=artifacts, index=index,
                          fn_cache={})


def retrieve(index: RetrievalIndex, user_vecs: jax.Array, k: int,
             kappa: Optional[int] = None, block: int = 4096):
    """``user_vecs (B, D)`` -> top-k candidate ids (B, k).

    Compiles the state-passing search ONCE per ``(k, kappa, treedef)`` and
    caches it on the RetrievalIndex; repeated calls (and calls against
    refreshed same-treedef artifacts) reuse the executable.
    """
    if index.mode == "full":    # exact search IS the answer; skip the rerank
        traversal = index.index or FlatIndex(block=block)
        _, ids = traversal.search(user_vecs, index.scorer, k)
        return ids
    kappa = kappa or max(k, 2 * k)
    state = msearch.make_state(index.artifacts, index=index.index,
                               block=block)
    cache = index.fn_cache if index.fn_cache is not None else {}
    if msearch.host_tier(index.artifacts) is not None:
        # host rerank tier: only the candidates stage is compiled (x_full
        # is leafless aux data); the kappa-row gather + shared compiled
        # rerank run eagerly outside the trace
        key = ("candidates", kappa, jax.tree_util.tree_structure(state))
        fn = cache.get(key)
        if fn is None:
            fn = cache.setdefault(key, jax.jit(functools.partial(
                msearch.state_candidates, kappa=kappa)))
        cand = fn(user_vecs, state)
        return msearch.rerank(user_vecs, index.artifacts, np.asarray(cand),
                              k)
    key = (k, kappa, jax.tree_util.tree_structure(state))
    fn = cache.get(key)
    if fn is None:
        fn = cache.setdefault(key, jax.jit(functools.partial(
            msearch.state_search, k=k, kappa=kappa)))
    return fn(user_vecs, state)
