"""Training substrate: optimizer, checkpointing, data, gradient compression."""
from repro.train import checkpoint, data, grad_compress, optimizer, trainstep
from repro.train.optimizer import AdamWConfig, AdamWState
from repro.train.trainstep import make_train_step

__all__ = ["checkpoint", "data", "grad_compress", "optimizer", "trainstep",
           "AdamWConfig", "AdamWState", "make_train_step"]
