"""Fault-tolerant checkpointing: atomic, manifest-driven, reshard-on-load.

Layout:  <dir>/step_<N>/            (atomic: written to .tmp, then renamed)
             manifest.json          (step, keypaths, shapes, dtypes, meta)
             <idx>.npy              (one file per leaf)
         <dir>/LATEST               (text file: last durable step)

Restore never requires the saving mesh: leaves come back as host numpy and
are ``device_put`` with whatever shardings the *new* mesh prescribes --
that is the elastic-restart path (checkpoint written on 512 chips restores
onto 256 or 8). Training-data determinism (train/data.py derives batches
from (seed, step)) makes restarts bit-exact.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "available_steps",
           "restore_distributed"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         meta: Optional[Dict] = None) -> str:
    """Write a checkpoint atomically; returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "meta": meta or {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"{i}.npy"), arr)
        manifest["leaves"].append(
            {"path": p, "file": f"{i}.npy", "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def available_steps(ckpt_dir: str):
    """Ascending list of durable step numbers (renamed ``step_<N>``
    directories; ``.tmp`` partial writes are excluded). The fallback
    chain a corrupted-snapshot restore walks backwards."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name[len("step_"):]))
            except ValueError:
                continue
    return sorted(steps)


def restore(ckpt_dir: str, target_tree: Any,
            step: Optional[int] = None, strict_shapes: bool = True):
    """Load into the structure of ``target_tree`` (shapes must match
    unless ``strict_shapes=False`` -- then the template contributes the
    TREEDEF only and leaf shapes come from the manifest, which is how
    serving-layout templates with throwaway encodings restore).

    Returns (tree, step, meta). Leaves are host numpy; the caller
    device_puts them with the current mesh's shardings (see
    ``restore_distributed``). Template leaves that are python scalars
    (static-ish ints riding a NamedTuple) come back as their original
    python type, not 0-d arrays.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    missing = [p for p in paths if p not in by_path]
    if missing:
        raise ValueError(f"checkpoint is missing leaves {missing[:4]} "
                         f"(of {len(missing)})")
    out = []
    for p, leaf in zip(paths, leaves):
        entry = by_path[p]
        arr = np.load(os.path.join(d, entry["file"]))
        if strict_shapes:
            expect = tuple(np.shape(leaf))
            if tuple(arr.shape) != expect:
                raise ValueError(
                    f"checkpoint leaf {p} shape {arr.shape} != target "
                    f"{expect}")
        if isinstance(leaf, (bool, int, float)) \
                and not hasattr(leaf, "dtype"):
            out.append(type(leaf)(arr))
        else:
            out.append(arr)
    return treedef.unflatten(out), manifest["step"], manifest["meta"]


def restore_distributed(ckpt_dir: str, target_tree: Any, shardings: Any,
                        step: Optional[int] = None):
    """Elastic restore: load host arrays and place them with ``shardings``
    (a pytree of NamedSharding for the *current* mesh, which may differ
    from the mesh that wrote the checkpoint)."""
    tree, step, meta = restore(ckpt_dir, target_tree, step)
    placed = jax.tree.map(
        lambda arr, s: jax.device_put(arr, s), tree, shardings)
    return placed, step, meta
