"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step): restarts (fault tolerance,
elastic re-meshing) replay the exact token stream with zero pipeline state to
checkpoint. Generation happens on-device from a folded PRNG key, so the
pipeline itself shards with the batch (no host bottleneck in the dry-run
model).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["lm_batch", "criteo_batch", "bst_batch", "mind_batch",
           "graph_minibatch_seeds"]


def _key(seed: int, step, salt: int = 0):
    return jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), salt), step)


def lm_batch(seed: int, step, batch: int, seq: int,
             vocab: int) -> Dict[str, jax.Array]:
    k = _key(seed, step, 1)
    tokens = jax.random.randint(k, (batch, seq + 1), 0, vocab)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def criteo_batch(seed: int, step, batch: int, n_dense: int,
                 vocab_sizes) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(_key(seed, step, 2), 3)
    dense = jax.random.normal(k1, (batch, n_dense))
    maxes = jnp.asarray(list(vocab_sizes), jnp.int32)
    sparse = (jax.random.randint(k2, (batch, len(vocab_sizes)), 0, 1 << 30)
              % maxes[None, :])
    label = jax.random.bernoulli(k3, 0.3, (batch,)).astype(jnp.int32)
    return {"dense": dense, "sparse": sparse, "label": label}


def bst_batch(seed: int, step, batch: int, seq_len: int,
              n_items: int) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(_key(seed, step, 3), 3)
    return {"seq": jax.random.randint(k1, (batch, seq_len), 0, n_items),
            "target": jax.random.randint(k2, (batch,), 0, n_items),
            "label": jax.random.bernoulli(k3, 0.3, (batch,)).astype(jnp.int32)}


def mind_batch(seed: int, step, batch: int, seq_len: int,
               n_items: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(_key(seed, step, 4))
    return {"seq": jax.random.randint(k1, (batch, seq_len), 0, n_items),
            "target": jax.random.randint(k2, (batch,), 0, n_items)}


def graph_minibatch_seeds(seed: int, step, batch: int,
                          n_nodes: int) -> jax.Array:
    return jax.random.randint(_key(seed, step, 5), (batch,), 0, n_nodes)
