"""Gradient compression for cross-pod all-reduce (int8 + error feedback).

At 2+ pods the gradient all-reduce crosses the (slow) inter-pod links; int8
quantization cuts those bytes 4x vs fp32 (2x vs bf16). Error feedback keeps
the compression unbiased over time (the residual is carried into the next
step), which preserves convergence (1-bit Adam / EF-SGD literature).

Usage pattern (see launch/train.py): run the per-pod step inside
``jax.shard_map`` over the "pod" axis with grads averaged over the in-pod
axes first, then ``compressed_psum_mean`` over "pod".
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_mean",
           "apply_error_feedback"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (codes i8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compressed_psum_mean(tree, axis_name: str):
    """Mean-all-reduce a gradient pytree over ``axis_name`` in int8.

    Scales are all-reduced first (max) so every member quantizes onto the
    same grid; int8 codes are summed as int32 (exact), then dequantized.
    Bytes on the wire per tensor: n (codes) + 4 (scale) vs 4n for fp32.
    """
    n_members = jax.lax.psum(1, axis_name)

    def reduce_one(x):
        xf = x.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(codes, axis_name)
        return (total.astype(jnp.float32) * scale / n_members).astype(x.dtype)

    return jax.tree.map(reduce_one, tree)


def apply_error_feedback(grads, residuals):
    """g' = g + residual; returns (g', fn(compressed) -> new_residual).

    The caller compresses g' however it likes, then calls the closure with
    the values actually applied to get the next residual tree.
    """
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 grads)
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residuals)

    def new_residuals(applied):
        return jax.tree.map(lambda c, a: c - a.astype(jnp.float32),
                            corrected, applied)

    return corrected, new_residuals
