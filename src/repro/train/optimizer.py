"""AdamW from scratch (no optax): pure pytree functions.

Optimizer state mirrors the parameter pytree, so under pjit the moments
inherit the parameter shardings (ZeRO: with FSDP'd params the state is FSDP'd
too -- optimizer sharding falls out of the data layout, no extra machinery).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "AdafactorConfig", "AdafactorState", "adafactor_init",
           "adafactor_update", "global_norm", "cosine_warmup_lr"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object      # pytree like params
    nu: object


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_warmup_lr(step: jax.Array, base_lr: float, warmup: int = 100,
                     total: int = 10_000, min_frac: float = 0.1) -> jax.Array:
    stepf = step.astype(jnp.float32)
    warm = stepf / max(warmup, 1)
    prog = jnp.clip((stepf - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return base_lr * jnp.where(stepf < warmup, warm, cos)


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    stepf = step.astype(jnp.float32)
    lr_t = cfg.lr if lr is None else lr
    bc1 = 1.0 - cfg.b1 ** stepf
    bc2 = 1.0 - cfg.b2 ** stepf

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        new_p = (p.astype(jnp.float32)
                 - lr_t * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step, new_mu, new_nu), gnorm


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018): factored second moment + optional bf16
# momentum. For a 400B-param model on 256 chips, full-fp32 Adam state alone
# (12 bytes/param) exceeds the 16 GB/chip HBM budget; Adafactor stores
# O(m + n) per (m, n) matrix (~0 bytes/param) and is the standard production
# choice at this scale (T5/PaLM lineage).
# ---------------------------------------------------------------------------


class AdafactorConfig(NamedTuple):
    lr: float = 1e-2
    decay: float = 0.8            # beta2 exponent: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0   # update RMS clip
    weight_decay: float = 0.0
    momentum: Optional[float] = None    # None = no first moment
    momentum_dtype: object = jnp.bfloat16


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: object    # row second moments (factored leaves) / full v (vectors)
    vc: object    # col second moments (zeros-placeholder for vectors)
    mu: object    # momentum (bf16) or zeros-placeholder


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params, cfg: AdafactorConfig = AdafactorConfig()
                   ) -> AdafactorState:
    def vr_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)       # drop cols
        return jnp.zeros(p.shape, jnp.float32)                # full v

    def vc_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,) * max(p.ndim, 1), jnp.float32)

    def mu_init(p):
        if cfg.momentum is None:
            return jnp.zeros((1,), cfg.momentum_dtype)
        return jnp.zeros(p.shape, cfg.momentum_dtype)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr_init, params),
                          vc=jax.tree.map(vc_init, params),
                          mu=jax.tree.map(mu_init, params))


def adafactor_update(grads, state: AdafactorState, params,
                     cfg: AdafactorConfig = AdafactorConfig(),
                     lr: Optional[jax.Array] = None):
    """One Adafactor step. Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    step = state.step + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)
    lr_t = cfg.lr if lr is None else lr

    def upd(p, g, vr, vc, mu):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps
        if _factored(p):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                cfg.eps)
            vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
        else:
            vr = beta2 * vr + (1 - beta2) * g2
            vhat = vr
        u = gf * jax.lax.rsqrt(vhat + cfg.eps)
        # RMS clip (Adafactor's update clipping)
        rms = jnp.sqrt(jnp.mean(u * u) + cfg.eps)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.momentum is not None:
            mu_f = cfg.momentum * mu.astype(jnp.float32) \
                + (1 - cfg.momentum) * u
            u = mu_f
            mu = mu_f.astype(cfg.momentum_dtype)
        new_p = (p.astype(jnp.float32) - lr_t * u
                 - lr_t * cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), vr, vc, mu

    # NOTE (Perf log): a lax.map-chunked per-layer update was tried to bound
    # the f32 update temporaries; XLA hoists the xs convert out of the loop
    # and materializes a full f32 copy of the stacked weights -- measured
    # +25 GB/dev on llama4. Reverted to whole-leaf updates.
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_vr = treedef.flatten_up_to(state.vr)
    flat_vc = treedef.flatten_up_to(state.vc)
    flat_mu = treedef.flatten_up_to(state.mu)
    out = [upd(p, g, vr, vc, mu) for p, g, vr, vc, mu
           in zip(flat_p, flat_g, flat_vr, flat_vc, flat_mu)]
    return (treedef.unflatten([o[0] for o in out]),
            AdafactorState(step,
                           treedef.unflatten([o[1] for o in out]),
                           treedef.unflatten([o[2] for o in out]),
                           treedef.unflatten([o[3] for o in out])),
            gnorm)
