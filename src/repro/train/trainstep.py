"""Generic train-step builder: loss_fn + AdamW -> jit-able step."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.train.optimizer import (AdafactorConfig, AdamWConfig, AdamWState,
                                   adafactor_update, adamw_update,
                                   cosine_warmup_lr)

__all__ = ["make_train_step"]


def make_train_step(loss_fn: Callable, opt_cfg,
                    warmup: int = 100, total_steps: int = 10_000,
                    accum_steps: int = 1, accum_dtype=jnp.float32):
    """``loss_fn(params, batch) -> scalar``; returns
    ``step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    ``accum_steps > 1``: microbatched gradient accumulation -- the leading
    batch dim of every batch leaf is split into (accum, micro) and scanned;
    activation memory scales with the microbatch, the optimizer sees the
    mean gradient. This is the knob that fits the 72B/314B trainings in
    16 GB/chip (EXPERIMENTS.md section Perf).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state: AdamWState, batch: Dict):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                loss_i, grads_i = grads_of(params, mb)
                return (loss_acc + loss_i,
                        jax.tree.map(lambda a, g: a + g.astype(accum_dtype),
                                     grads_acc, grads_i)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro)
            inv = 1.0 / accum_steps
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        lr = cosine_warmup_lr(opt_state.step, opt_cfg.lr, warmup,
                              total_steps)
        if isinstance(opt_cfg, AdafactorConfig):
            new_params, new_state, gnorm = adafactor_update(
                grads, opt_state, params, opt_cfg, lr)
        else:
            new_params, new_state, gnorm = adamw_update(
                grads, opt_state, params, opt_cfg, lr)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        return new_params, new_state, metrics

    return train_step
