from repro.utils import hlo_analysis, roofline

__all__ = ["hlo_analysis", "roofline"]
