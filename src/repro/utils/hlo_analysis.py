"""Post-optimization HLO text analysis: collective bytes, per-computation
FLOPs, and while-loop trip-count correction.

Why this exists: ``compiled.cost_analysis()`` counts every ``while`` body
(scan over layers, loss chunks, gradient-accumulation microbatches, ...)
exactly ONCE (verified empirically on jax 0.8 / XLA CPU), and exposes no
collective traffic at all. We therefore parse ``compiled.as_text()``:

  * every instruction is attributed to its enclosing computation;
  * operand shapes are resolved through a module-wide definition table
    (post-opt HLO lists operands as bare %names);
  * ``while`` trip counts come from the condition computation's ROOT
    ``compare(%iv, %constant), direction=LT`` pattern; failing that, the
    caller-provided default applies. Nested loops multiply.
  * collective bytes = sum of operand-buffer sizes of all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute;
  * dot FLOPs = 2 * prod(result_shape) * contracting_size.

All byte sizes are per-device (the HLO is the post-SPMD module).

Dialect note: jax <= 0.4 / older XLA prints every name with a ``%`` sigil
and full computation signatures (``ENTRY %main.9 (p.1: f32[8]) -> f32[8]
{``); newer XLA (jax >= 0.6) drops the sigil and may print bare headers
(``ENTRY main.9 {``) and bare operand names (``add(p.1, c.2)``). Every
regex here treats the sigil and the signature as optional, and operand
extraction falls back to last-token parsing when no sigil is present --
``tests/fixtures/hlo/`` pins one fixture per dialect.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, FrozenSet, Optional, Tuple

import numpy as np

__all__ = ["analyze_hlo", "buffer_shapes", "normalize_cost", "HLOStats"]


def normalize_cost(cost) -> dict:
    """Normalize ``Compiled.cost_analysis()`` output to one properties dict.

    jax >= 0.6 returns the flat dict directly; jax 0.4/0.5 returns a
    one-element list of per-device dicts. Callers index by property name
    (``cost["flops"]``), so hand them the dict either way.
    """
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return cost

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# computation header: '%name (sig) -> ... {' (0.4) or bare 'name {' (0.6+)
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*[({]")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_DOT_DNUMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
# '%name = (' tuple results keep the FIRST element shape for the def table
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?\s*(\w+)\[([\d,]*)\]")
_SIG_RE = re.compile(r"%?([\w.\-]+):\s*(\w+)\[([\d,]*)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_NAME_TAIL_RE = re.compile(r"([\w.\-]+)\s*$")

MAX_SANE_TRIPS = 1_000_000


class HLOStats(dict):
    """keys: collective_bytes, collective_by_kind, n_collectives,
    dot_flops, write_bytes, while_trips."""


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _operand_names(s: str):
    inner = s.split("(", 1)[1]
    depth, cur = 1, ""
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    if "%" in cur:
        return re.findall(r"%([\w.\-]+)", cur)
    # sigil-less dialect: operands are 'f32[8]{0} name' or bare 'name';
    # split at depth-0 commas and keep each piece's trailing identifier
    names, depth, piece, pieces = [], 0, "", []
    for ch in cur:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            pieces.append(piece)
            piece = ""
        else:
            piece += ch
    pieces.append(piece)
    for p in pieces:
        m = _NAME_TAIL_RE.search(p.strip())
        if m:
            names.append(m.group(1))
    return names


def _result_shapes(line: str):
    """(dtype, dims) pairs of the buffer(s) an instruction DEFINES --
    tuple results contribute every element; operand shapes are excluded."""
    rhs = line.split("=", 1)[1].lstrip()
    if rhs.startswith("("):
        depth, seg = 0, ""
        for ch in rhs:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            seg += ch
        return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(seg)]
    m = _SHAPE_RE.match(rhs)
    return [(m.group(1), m.group(2))] if m else []


def buffer_shapes(hlo_text: str) -> FrozenSet[str]:
    """Every buffer shape the module DEFINES, as normalized
    ``dtype[d0,d1]`` strings: instruction results (tuple elements
    included) plus computation parameters from either dialect's
    signatures. The NoDenseScoreMatrix-style rules check forbidden shapes
    against this set -- operand mentions alone never add a shape, so a
    shape is present iff some buffer of that shape actually exists."""
    shapes = set()
    for ln in hlo_text.splitlines():
        if not ln.strip() or ln.startswith("HloModule"):
            continue
        if _DEF_RE.match(ln):
            for dt, dims in _result_shapes(ln):
                shapes.add(f"{dt}[{dims}]")
        elif ln[0] not in " \t" and "(" in ln:
            # computation header: parameters are buffers too
            for ms in _SIG_RE.finditer(ln.split("->")[0]):
                shapes.add(f"{ms.group(2)}[{ms.group(3)}]")
    return frozenset(shapes)


def analyze_hlo(hlo_text: str,
                default_trips: Optional[Dict[str, int]] = None,
                fallback_trip: int = 1) -> HLOStats:
    lines = hlo_text.splitlines()
    default_trips = default_trips or {}

    # ---- computations ------------------------------------------------------
    comp_of_line: Dict[int, str] = {}
    current = None
    for i, ln in enumerate(lines):
        if not ln.strip() or ln.startswith("HloModule"):
            continue
        if ln and not ln[0].isspace():
            m = _COMP_START_RE.match(ln)
            if m and ln.rstrip().endswith("{"):
                current = m.group(1)
        if current is not None:
            comp_of_line[i] = current

    # ---- definition table --------------------------------------------------
    defs: Dict[str, Tuple[str, str]] = {}
    line_of_def: Dict[str, int] = {}
    for i, ln in enumerate(lines):
        m = _DEF_RE.match(ln)
        if m:
            defs[m.group(1)] = (m.group(2), m.group(3))
            line_of_def[m.group(1)] = i
        elif ln and not ln[0].isspace() and "(" in ln:
            for ms in _SIG_RE.finditer(ln):
                defs[ms.group(1)] = (ms.group(2), ms.group(3))

    # constants per computation: name -> int value (for trip resolution)
    const_val: Dict[str, int] = {}
    for i, ln in enumerate(lines):
        m = _DEF_RE.match(ln)
        if m and "constant(" in ln:
            mc = _CONST_RE.search(ln)
            if mc:
                const_val[m.group(1)] = int(mc.group(1))

    # ---- while edges & trip counts -----------------------------------------
    while_edges = []
    for i, ln in enumerate(lines):
        if "while(" in ln and "condition=" in ln:
            m = _WHILE_RE.search(ln)
            if m:
                while_edges.append(
                    (comp_of_line.get(i, "ENTRY"), m.group(2), m.group(1)))

    # ROOT instruction of each condition computation + per-comp s32 consts
    root_of_comp: Dict[str, str] = {}
    s32_consts_in_comp: Dict[str, list] = defaultdict(list)
    for i, ln in enumerate(lines):
        comp = comp_of_line.get(i)
        if comp is None:
            continue
        if "ROOT" in ln:
            root_of_comp[comp] = ln
        m = _DEF_RE.match(ln)
        if m and m.group(2) == "s32" and m.group(3) == "" \
                and "constant(" in ln:
            mc = _CONST_RE.search(ln)
            if mc:
                s32_consts_in_comp[comp].append(int(mc.group(1)))

    trips_of_body: Dict[str, int] = {}
    for _parent, body, cond in while_edges:
        trips = None
        root = root_of_comp.get(cond)
        if root is not None:
            # resolve the loop bound through the ROOT's constant operand
            for name in _operand_names(root):
                if name in const_val:
                    trips = const_val[name]
                    break
        if trips is None and s32_consts_in_comp.get(cond):
            # condition computations are tiny; their largest scalar s32
            # constant is the loop bound
            trips = max(s32_consts_in_comp[cond])
        if trips is None or trips <= 0 or trips > MAX_SANE_TRIPS:
            trips = fallback_trip   # conservative under-count
        trips_of_body[body] = trips

    # call edges (fusion/call/conditional computations inherit the caller's
    # multiplier with trips=1)
    call_edges = []
    call_re = re.compile(r"calls=%?([\w.\-]+)")
    for i, ln in enumerate(lines):
        if "calls=" in ln and "while(" not in ln:
            comp = comp_of_line.get(i)
            if comp is None:
                continue
            for mc in call_re.finditer(ln):
                call_edges.append((comp, mc.group(1)))

    # nesting multipliers (fixpoint over the small call/while graph)
    mult: Dict[str, float] = defaultdict(lambda: 1.0)
    for _ in range(16):
        changed = False
        for parent, body, _c in while_edges:
            m_new = mult[parent] * trips_of_body[body]
            if mult[body] != m_new:
                mult[body] = m_new
                changed = True
        for parent, callee in call_edges:
            m_new = max(mult[callee], mult[parent])
            if mult[callee] != m_new:
                mult[callee] = m_new
                changed = True
        if not changed:
            break

    # ---- accounting ---------------------------------------------------------
    coll_bytes = 0.0
    coll_by_kind: Dict[str, float] = defaultdict(float)
    n_coll = 0
    dot_flops = 0.0
    write_bytes = 0.0
    for i, ln in enumerate(lines):
        comp = comp_of_line.get(i)
        if comp is None:
            continue
        k = mult[comp]
        s = ln.strip()
        if "=" not in s:
            continue
        shapes = [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(s)]
        if not shapes:
            continue
        res_bytes = _shape_bytes(*shapes[0])
        opcode_m = re.search(
            r"=\s*(?:\([^)]*\)\s*)?[\w\[\],{}:\s]*?(\w[\w\-]*)\(", s)
        op = opcode_m.group(1) if opcode_m else ""
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                operand_bytes = 0
                for name in _operand_names(s):
                    if name in defs:
                        operand_bytes += _shape_bytes(*defs[name])
                if operand_bytes == 0:
                    operand_bytes = res_bytes
                coll_bytes += k * operand_bytes
                coll_by_kind[kind] += k * operand_bytes
                n_coll += 1
                break
        if op == "dot":
            mdn = _DOT_DNUMS_RE.search(s)
            ops_ = _operand_names(s)
            if mdn and ops_ and ops_[0] in defs:
                lhs_dims = [int(x) for x in defs[ops_[0]][1].split(",") if x]
                cdims = [int(x) for x in mdn.group(1).split(",") if x]
                csize = int(np.prod([lhs_dims[c] for c in cdims])) \
                    if cdims else 1
                res_elems = res_bytes / max(
                    _DTYPE_BYTES.get(shapes[0][0], 4), 1)
                dot_flops += k * 2.0 * res_elems * csize
        if (op not in ("parameter", "constant", "tuple",
                       "get-tuple-element", "bitcast", "reshape",
                       # CPU-backend bf16 legalization artifacts -- absent
                       # in TPU modules (verified: f32 twins of every bf16
                       # loop carry); collectives are priced separately.
                       "convert", "copy", "copy-start", "copy-done",
                       "all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute")
                and not op.startswith("all-")
                and not comp.startswith("fused_computation")
                and not comp.startswith("wrapped_")):
            # fusion-internal results live in registers; only top-level
            # instruction results are HBM buffers
            if op == "dynamic-update-slice":
                # in-place: only the update slice is written
                ops_ = _operand_names(s)
                if len(ops_) >= 2 and ops_[1] in defs:
                    res_bytes = _shape_bytes(*defs[ops_[1]])
            elif op == "fusion" and "calls=" in s:
                # fusions whose root is a DUS also update in place: count
                # the update-slice size, not the whole (aliased) buffer
                mcall = re.search(r"calls=%?([\w.\-]+)", s)
                root = root_of_comp.get(mcall.group(1)) if mcall else None
                if root and "dynamic-update-slice(" in root:
                    r_ops = _operand_names(root)
                    if len(r_ops) >= 2 and r_ops[1] in defs:
                        res_bytes = min(res_bytes,
                                        _shape_bytes(*defs[r_ops[1]]))
            write_bytes += k * res_bytes

    return HLOStats(
        collective_bytes=coll_bytes,
        collective_by_kind=dict(coll_by_kind),
        n_collectives=n_coll,
        dot_flops=dot_flops,
        write_bytes=write_bytes,
        while_trips=dict(trips_of_body),
    )
