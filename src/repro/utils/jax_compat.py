"""Version-portability shims over the pinned jax.

The codebase is written against the modern jax surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``) but must also run on jax 0.4.x where those
either live under ``jax.experimental`` or do not exist. Every call site
routes through this module so the rest of the tree reads as if on current
jax and the fallback logic lives in exactly one place.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map", "set_mesh"]


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the concept exists.

    jax >= 0.5 wants explicit ``axis_types`` (Auto keeps the historical
    implicit-sharding behavior); jax 0.4.x predates ``AxisType`` and its
    ``make_mesh`` takes no such argument.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh=None, in_specs, out_specs):
    """Replication-check-free shard_map on either API generation.

    ``check_vma`` (jax >= 0.6) and ``check_rep`` (jax 0.4/0.5 experimental)
    are the same knob under two names; both are disabled because the scan
    carries in ``blocked_topk`` are axis-agnostic and fail the inference.
    ``mesh=None`` uses the ambient mesh (installed via :func:`set_mesh`);
    old jax requires an explicit mesh, so we resolve it from the active
    ``with mesh:`` context there.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=False, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError("shard_map needs a mesh: pass mesh= or enter "
                             "a repro.utils.jax_compat.set_mesh context")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on current jax; on 0.4.x ``Mesh`` itself is the
    context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
