"""Three-term roofline model for TPU v5e (see EXPERIMENTS.md section Roofline).

    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective = coll_bytes  / (chips * 50e9   B/s per ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` CORRECTED for
while/scan bodies being counted once: the correction adds
(trips - 1) x body counts using the per-computation accounting from
utils/hlo_analysis.py (dot-FLOP parser). collective_bytes is parsed from the
HLO text (cost_analysis does not expose it).

All quantities are per-device post-SPMD, so "chips" never appears again:
the terms are per-chip step times already.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Dict, Optional

__all__ = ["V5E", "RooflineTerms", "compute_terms"]


@dataclass(frozen=True)
class HWSpec:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # B/s per chip
    ici_bw: float = 50e9            # B/s per link (conservative, per spec)
    hbm_bytes: float = 16e9         # v5e HBM capacity


V5E = HWSpec()


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float                # per device, trip-corrected
    hlo_bytes: float                # per device, trip-corrected
    collective_bytes: float         # per device, trip-corrected
    raw_cost_flops: float           # uncorrected cost_analysis numbers
    raw_cost_bytes: float
    model_flops_total: float        # analytic 6ND-style, whole step, all chips
    n_chips: int
    useful_flops_ratio: float       # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str
    bound_s: float
    peak_fraction: float            # useful model FLOP/s / peak, at bound_s

    def to_dict(self) -> Dict:
        return asdict(self)


def compute_terms(cost: Dict[str, float], hlo_stats: Dict,
                  model_flops_total: float, n_chips: int,
                  hw: HWSpec = V5E,
                  flop_correction: Optional[float] = None) -> RooflineTerms:
    """Build the three terms.

    FLOPs = the HLO dot parser's count (honors while trip counts and the
    fusion call graph; cost_analysis counts loop bodies once). Bytes =
    2 x top-level instruction result bytes (writes ~ reads at fusion
    granularity), same trip correction; cost_analysis bytes kept as a raw
    reference and as a floor.
    """
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    flops = max(float(hlo_stats.get("dot_flops", 0.0)), raw_flops)
    bytes_ = max(2.0 * float(hlo_stats.get("write_bytes", 0.0)), raw_bytes)
    coll = float(hlo_stats.get("collective_bytes", 0.0))

    compute_s = flops / hw.peak_flops
    memory_s = bytes_ / hw.hbm_bw
    collective_s = coll / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    bound_s = terms[bottleneck]
    useful = model_flops_total / max(flops * n_chips, 1.0)
    peak_fraction = (model_flops_total / max(bound_s, 1e-12)
                     / (n_chips * hw.peak_flops))
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops=flops, hlo_bytes=bytes_, collective_bytes=coll,
        raw_cost_flops=raw_flops, raw_cost_bytes=raw_bytes,
        model_flops_total=model_flops_total, n_chips=n_chips,
        useful_flops_ratio=useful, bottleneck=bottleneck, bound_s=bound_s,
        peak_fraction=peak_fraction)
