import os
import sys

# Tests run on the real (1-device) CPU platform -- the 512-device override
# belongs to launch/dryrun.py ONLY. Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
