import os
import sys

# Tests run on the real (1-device) CPU platform -- the 512-device override
# belongs to launch/dryrun.py ONLY. Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


class CompileCounter:
    """Counts XLA backend compiles via jax.monitoring's
    ``/jax/core/compile/backend_compile_duration`` event -- every lowering
    that reaches the backend fires it exactly once, cache hits fire
    nothing. ``reset()`` after warmup, then assert ``count == 0`` across
    the region that must not recompile (e.g. ServingEngine.swap cycles)."""

    EVENT = "/jax/core/compile/backend_compile_duration"

    def __init__(self):
        self.count = 0

    def _listener(self, event, duration, **kwargs):
        if event == self.EVENT:
            self.count += 1

    def reset(self):
        self.count = 0


@pytest.fixture
def compile_counter():
    """Yields a live CompileCounter; the listener is removed on teardown."""
    from jax import monitoring
    from jax._src import monitoring as _monitoring_impl

    counter = CompileCounter()
    monitoring.register_event_duration_secs_listener(counter._listener)
    try:
        yield counter
    finally:
        unregister = getattr(
            _monitoring_impl,
            "_unregister_event_duration_listener_by_callback", None)
        if unregister is not None:
            unregister(counter._listener)
        else:       # very old/new jax: drop every listener (tests only)
            monitoring.clear_event_listeners()
