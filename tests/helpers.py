"""Shared test helpers.

``assert_same_topk`` is the parity tests' common assertion (fused vs
gathered, sharded vs local, aligned vs reference): same (value, id) SETS
per query. It lived as a private copy in test_ivf_scan / test_graph_scan;
one definition here keeps the tie-handling semantics identical everywhere.

HLO shape assertions go through ``repro.analysis.assert_rules`` with
``NoDenseScoreMatrix`` / ``BufferPresent`` -- the registry owns those
contracts; tests just pick which rule applies to which compiled program.
"""
import numpy as np


def assert_same_topk(res_a, res_b, label="", rtol=1e-5, atol=1e-5):
    """Same (value, id) sets per query (top-k order may differ on exact
    ties; ids are unique so sorting by id aligns both)."""
    va, ia = (np.asarray(x) for x in res_a)
    vb, ib = (np.asarray(x) for x in res_b)
    oa, ob = np.argsort(ia, axis=1), np.argsort(ib, axis=1)
    np.testing.assert_array_equal(np.take_along_axis(ia, oa, 1),
                                  np.take_along_axis(ib, ob, 1),
                                  err_msg=label)
    np.testing.assert_allclose(np.take_along_axis(va, oa, 1),
                               np.take_along_axis(vb, ob, 1),
                               rtol=rtol, atol=atol, err_msg=label)
