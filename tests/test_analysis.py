"""Analyzer self-tests: every rule passes on a conforming fixture and
FAILS on its seeded-violation counterexample -- a deliberately
dense-scoring toy must fail NoDenseScoreMatrix, a non-donated step must
fail DonationCoverage, a trip-heavy loop must fail WhileTripBudget, and
seeded protocol / source violations must trip their rules. This is the
meta-coverage the audit needs to be trustworthy: a rule that cannot fail
enforces nothing."""
import functools
import textwrap
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import assert_rules, registry
from repro.analysis.hlo_rules import (BufferPresent, DonationCoverage,
                                      HLOProgram, NoDenseScoreMatrix,
                                      NoGatherOnFusedPath,
                                      NoHostTransferInStep,
                                      WhileTripBudget, donated_params)
from repro.analysis.protocol_rules import (BoundedCompileCache,
                                           IdTranslationContract,
                                           LeaflessAuxHostTier,
                                           ProtocolContext, ScorerSurface,
                                           StaticConfigInTreedef,
                                           TreedefStableIndexRefresh,
                                           TreedefStableStreaming)
from repro.analysis.source_rules import (NoHostSyncInJit,
                                         NoIsinstanceDispatch, NoJaxDebug,
                                         NoRawCompatAPIs, SourceTree)

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# HLO rules
# ---------------------------------------------------------------------------

M, N_DENSE = 4, 333        # odd n: no legitimate buffer collides


@pytest.fixture(scope="module")
def dense_toy():
    """The seeded violation: dense (m, n) scoring then top-k."""

    def dense_search(q, x):
        return jax.lax.top_k(q @ x.T, 3)

    return HLOProgram.of(jax.jit(dense_search).lower(
        jnp.ones((M, 8)), jnp.ones((N_DENSE, 8))).compile())


def test_no_dense_score_matrix_fails_on_dense_toy(dense_toy):
    res = NoDenseScoreMatrix(M, N_DENSE).check(dense_toy)
    assert not res.passed and "f32[4,333]" in res.evidence
    with pytest.raises(AssertionError, match="NoDenseScoreMatrix"):
        assert_rules(dense_toy, [NoDenseScoreMatrix(M, N_DENSE)],
                     target="toy")


def test_no_dense_score_matrix_passes_on_absent_shape(dense_toy):
    assert_rules(dense_toy, [NoDenseScoreMatrix(M, N_DENSE + 1)])


def test_buffer_present_is_the_positive_twin(dense_toy):
    assert BufferPresent(M, N_DENSE).check(dense_toy).passed
    assert not BufferPresent(M, N_DENSE + 1).check(dense_toy).passed


def _donatable_step(q, state):
    a, b = state
    return q @ a, (a + 1.0, b * 2.0)


def test_donation_coverage_passes_on_donated_step():
    q = jnp.ones((4, 8))
    state = (jnp.ones((8, 8)), jnp.ones((8,)))
    donated = jax.jit(_donatable_step, donate_argnums=(1,)).lower(
        q, state).compile()
    assert donated_params(donated.as_text()) >= {1, 2}
    assert_rules(donated, [DonationCoverage([1, 2])])


def test_donation_coverage_fails_on_non_donated_step():
    q = jnp.ones((4, 8))
    state = (jnp.ones((8, 8)), jnp.ones((8,)))
    plain = jax.jit(_donatable_step).lower(q, state).compile()
    res = DonationCoverage([1, 2]).check(HLOProgram.of(plain))
    assert not res.passed and "not aliased" in res.evidence


def test_while_trip_budget_on_compiled_scan():
    def f(x):
        return jax.lax.fori_loop(0, 9, lambda i, c: c * 1.5 + i, x)

    prog = HLOProgram.of(jax.jit(f).lower(jnp.ones((16,))).compile())
    assert WhileTripBudget(16).check(prog).passed
    res = WhileTripBudget(4).check(prog)
    assert not res.passed and "over budget" in res.evidence


GATHERY_HLO = """\
HloModule toy, entry_computation_layout={(f32[64,8]{1,0}, s32[12]{0})->f32[12,8]{1,0}}

ENTRY %main.4 (p0.1: f32[64,8], p1.2: s32[12]) -> f32[12,8] {
  %p0.1 = f32[64,8]{1,0} parameter(0)
  %p1.2 = s32[12]{0} parameter(1)
  ROOT %g.3 = f32[12,8]{1,0} gather(f32[64,8]{1,0} %p0.1, s32[12]{0} %p1.2), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,8}
}
"""


def test_no_gather_fails_on_raw_text_with_gather():
    res = NoGatherOnFusedPath().check(HLOProgram(GATHERY_HLO))
    assert not res.passed and "gather" in res.evidence
    # small gathers under an explicit byte budget are tolerated
    assert NoGatherOnFusedPath(max_bytes=1 << 20).check(
        HLOProgram(GATHERY_HLO)).passed


def test_no_gather_self_skips_on_cpu_compiled(dense_toy):
    if jax.default_backend() != "cpu":
        pytest.skip("backend-skip behavior is the CPU-side contract")
    res = NoGatherOnFusedPath().check(dense_toy)
    assert res.skipped and res.passed


HOSTY_HLO = """\
HloModule toy, entry_computation_layout={(f32[8]{0})->f32[8]{0}}

ENTRY %main.5 (p0.1: f32[8]) -> f32[8] {
  %p0.1 = f32[8]{0} parameter(0)
  %tok.2 = token[] after-all()
  %out.3 = token[] outfeed(f32[8]{0} %p0.1, token[] %tok.2)
  ROOT %r.4 = f32[8]{0} copy(f32[8]{0} %p0.1)
}
"""


def test_no_host_transfer_fails_on_outfeed(dense_toy):
    res = NoHostTransferInStep().check(HLOProgram(HOSTY_HLO))
    assert not res.passed and "outfeed" in res.evidence
    assert NoHostTransferInStep().check(dense_toy).passed


# ---------------------------------------------------------------------------
# Protocol rules (shared small context; the module fixture keeps the two
# model fits to one per test session)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ctx():
    return ProtocolContext(n=256, D=16, d=4, c=2, m=8, sort_block=32,
                           seed=0)


@pytest.mark.parametrize("mode", ["full", "gleanvec", "gleanvec-sorted",
                                  "gleanvec-int8-sorted"])
def test_protocol_rules_pass_on_real_scorers(ctx, mode):
    assert_rules(ctx, [ScorerSurface(mode), IdTranslationContract(mode),
                       TreedefStableStreaming(mode)])


def test_protocol_rules_pass_on_indices_and_host_tier(ctx):
    assert_rules(ctx, [TreedefStableIndexRefresh("flat"),
                       LeaflessAuxHostTier(),
                       StaticConfigInTreedef("flat", "block"),
                       BoundedCompileCache()])


class _StubCtx:
    """Duck-typed ProtocolContext carrying one (broken) scorer."""

    def __init__(self, scorer):
        self._scorer = scorer

    def scorer(self, mode):
        return self._scorer


class _BadIdScorer:
    n_rows = 8

    def translate_ids(self, ids):
        return jnp.abs(ids)          # -1 NOT kept inert

    def globalize_ids(self, ids, shard_idx):
        return jnp.abs(ids)


def test_id_translation_fails_on_seeded_violation():
    res = IdTranslationContract("stub").check(_StubCtx(_BadIdScorer()))
    assert not res.passed and "-1" in res.evidence


def test_scorer_surface_fails_on_missing_methods():
    res = ScorerSurface("stub").check(_StubCtx(_BadIdScorer()))
    assert not res.passed and "score_block" in res.evidence


def test_treedef_streaming_fails_on_seeded_aval_change(ctx, monkeypatch):
    from repro.core import streaming

    def chopping_insert(art, rows, ids=None):
        return art._replace(x_full=art.x_full[:-1]), jnp.array([0])

    monkeypatch.setattr(streaming, "insert_rows", chopping_insert)
    res = TreedefStableStreaming("full").check(ctx)
    assert not res.passed and "aval" in res.evidence


def test_treedef_index_refresh_fails_on_seeded_retype(ctx, monkeypatch):
    from repro.index.protocol import FlatIndex, replace

    monkeypatch.setattr(
        FlatIndex, "refreshed",
        lambda self, scorer, model: replace(self, block=self.block * 2))
    res = TreedefStableIndexRefresh("flat").check(ctx)
    assert not res.passed and "treedef changed" in res.evidence


def test_static_config_fails_on_config_leaked_into_leaves(ctx):
    from repro.index.protocol import register_index_pytree

    @dataclass(frozen=True, eq=False)
    class LeakyIndex:
        block: int = 64

    # deliberately WRONG registration: config as a data leaf
    register_index_pytree(LeakyIndex, data_fields=("block",),
                          static_fields=())
    res = StaticConfigInTreedef(lambda _ctx: LeakyIndex(), "block") \
        .check(ctx)
    assert not res.passed and "treedef" in res.evidence


def test_bounded_compile_cache_fails_on_stray_dispatch(ctx, monkeypatch):
    from repro.serve.frontend import ServingFrontend

    # seeded violation: dispatch the RAW request count instead of the
    # smallest covering bucket -- odd-size batches stray off the static
    # shape set (and each stray shape grows the compile cache)
    monkeypatch.setattr(ServingFrontend, "_pick_bucket",
                        lambda self, n: n)
    res = BoundedCompileCache().check(ctx)
    assert not res.passed and "buckets" in res.evidence


def test_leafless_host_tier_fails_on_leafy_store(ctx, monkeypatch):
    from repro.core import rerank_tier

    monkeypatch.setattr(rerank_tier, "demote",
                        lambda x, shards=0: (jnp.asarray(x),))
    monkeypatch.setattr(rerank_tier, "promote", lambda s: s[0])
    res = LeaflessAuxHostTier().check(ctx)
    assert not res.passed and "leaves" in res.evidence


# ---------------------------------------------------------------------------
# Source rules (violations seeded into a temp tree)
# ---------------------------------------------------------------------------


def _tree(tmp_path, rel, body):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(body))
    return SourceTree(str(tmp_path))


def test_no_jax_debug_fails_and_respects_waiver(tmp_path):
    tree = _tree(tmp_path, "core/x.py", """\
        import jax
        def f(x):
            jax.debug.print("x={}", x)
            return x
    """)
    res = NoJaxDebug().check(tree)
    assert not res.passed and "core/x.py:3" in res.evidence
    tree = _tree(tmp_path, "core/x.py", """\
        import jax
        def f(x):
            jax.debug.print("x={}", x)  # analysis: allow-jax-debug
            return x
    """)
    assert NoJaxDebug().check(tree).passed


def test_no_isinstance_dispatch_fails_on_hot_path_only(tmp_path):
    body = """\
        def pick(s):
            if isinstance(s, LinearScorer):
                return 1
            return 0
    """
    assert not NoIsinstanceDispatch().check(
        _tree(tmp_path / "hot", "core/search.py", body)).passed
    # the same construct OUTSIDE a hot path is not this rule's business
    assert NoIsinstanceDispatch().check(
        _tree(tmp_path / "cold", "launch/tool.py", body)).passed


def test_no_host_sync_in_jit_fails_on_item_and_np(tmp_path):
    tree = _tree(tmp_path, "core/y.py", """\
        import functools
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x) + 1

        @functools.partial(jax.jit, static_argnames=())
        def g(x):
            s = x.sum()
            return s.item()

        def not_jitted(x):
            return np.asarray(x)        # fine: host-side helper
    """)
    res = NoHostSyncInJit().check(tree)
    assert not res.passed
    assert "np.asarray" in res.evidence and ".item" in res.evidence
    assert "not_jitted" not in res.evidence


def test_no_raw_compat_apis_fails_outside_shim(tmp_path):
    body = """\
        import jax
        def make(axes):
            return jax.make_mesh((2,), axes)
    """
    assert not NoRawCompatAPIs().check(
        _tree(tmp_path / "raw", "serve/z.py", body)).passed
    # the shim module itself is the one sanctioned caller
    assert NoRawCompatAPIs().check(
        _tree(tmp_path / "shim", "utils/jax_compat.py", body)).passed


def test_repo_tree_is_lint_clean():
    """Satellite: the shipped tree starts green under its own lint."""
    from repro.analysis.run import SRC_ROOT, source_rule_set

    assert_rules(SourceTree(SRC_ROOT), source_rule_set(), target="src")


# ---------------------------------------------------------------------------
# registry plumbing
# ---------------------------------------------------------------------------


def test_results_to_json_mirrors_bench_convention(dense_toy):
    results = registry.run_rules(
        dense_toy, [NoDenseScoreMatrix(M, N_DENSE),
                    NoDenseScoreMatrix(M, N_DENSE + 1)], target="toy")
    payload = registry.results_to_json(results, backend="cpu")
    assert payload["analysis"] == "audit" and not payload["passed"]
    assert payload["counts"] == {"passed": 1, "failed": 1, "skipped": 0}
    assert {r["target"] for r in payload["results"]} == {"toy"}
    assert all({"rule", "passed", "evidence", "family"} <= set(r)
               for r in payload["results"])
