"""Per-assigned-architecture smoke tests: reduced config, one real step on
CPU, output shapes + finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_bundle
from repro.train.optimizer import AdamWState

ALL_CELLS = [(a, s) for a, m in ARCHS.items() for s in m.SHAPES
             if s not in getattr(m, "SKIPS", {})]


def _materialize(args_tree):
    """Concrete values for abstract args; opt-state moments must be >= 0."""
    def mk(path, sds):
        name = jax.tree_util.keystr(path)
        if np.issubdtype(sds.dtype, np.integer) or sds.dtype == jnp.uint32:
            return jnp.zeros(sds.shape, sds.dtype)
        key = jax.random.PRNGKey(abs(hash(name)) % (1 << 31))
        x = jax.random.normal(key, sds.shape, jnp.float32) * 0.02
        if ".nu" in name or ".mu" in name:
            x = jnp.abs(x)
        return x.astype(sds.dtype)

    return jax.tree_util.tree_map_with_path(
        mk, args_tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.parametrize("arch,shape", ALL_CELLS,
                         ids=[f"{a}-{s}" for a, s in ALL_CELLS])
def test_arch_smoke(arch, shape):
    mesh = make_host_mesh()
    bundle = build_bundle(arch, shape, mesh, smoke=True)
    args = _materialize(bundle.args)
    out = jax.jit(bundle.fn)(*args)
    out_leaves = [(jax.tree_util.keystr(kp), leaf) for kp, leaf in
                  jax.tree_util.tree_flatten_with_path(out)[0]]
    assert out_leaves, "step produced no outputs"
    for name, leaf in out_leaves:
        assert leaf.shape is not None
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert not bool(jnp.any(jnp.isnan(leaf))), f"NaN in {name}"


@pytest.mark.parametrize("arch", [
    "h2o-danube-3-4b", "qwen2-72b",
    pytest.param("grok-1-314b", marks=pytest.mark.xfail(
        strict=False, reason="pre-existing bf16 prefill/decode mismatch; "
        "unrelated to the search stack (see ROADMAP open items)")),
])
def test_lm_decode_matches_prefill(arch):
    """Prefill-then-decode must agree with teacher-forced decode chain."""
    from repro.models import transformer as tfm
    from repro.models.sharding import MeshRules
    mod = ARCHS[arch]
    cfg = mod.make_config(smoke=True)
    rules = MeshRules(dp=(), fsdp=(), tp=None, ep=None)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits_p, cache = tfm.prefill_step(params, tokens, cfg, rules)
    # decode the same tokens one by one into a fresh cache
    cache_d = tfm.init_cache(cfg, 2, 12, dtype=cache["k"].dtype)
    logits_d = None
    for t in range(12):
        logits_d, cache_d = tfm.decode_step(
            params, cache_d, tokens[:, t], jnp.asarray(t, jnp.int32), cfg,
            rules)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=2e-2, atol=2e-1)


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters of the full configs."""
    q = ARCHS["qwen2-72b"].make_config()
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qkv_bias) == (80, 8192, 64, 8, 29568, 152064, True)
    n = ARCHS["nemotron-4-15b"].make_config()
    assert (n.n_layers, n.d_model, n.act, n.glu, n.vocab) == \
        (32, 6144, "squared_relu", False, 256000)
    g = ARCHS["grok-1-314b"].make_config()
    assert (g.n_layers, g.moe.n_experts, g.moe.top_k, g.d_ff) == \
        (64, 8, 2, 32768)
    l4 = ARCHS["llama4-maverick-400b-a17b"].make_config()
    assert (l4.n_layers, l4.moe.n_experts, l4.moe.top_k, l4.vocab) == \
        (48, 128, 1, 202048)
    d = ARCHS["h2o-danube-3-4b"].make_config()
    assert (d.n_layers, d.d_model, d.swa_window is not None) == \
        (24, 3840, True)
    dl = ARCHS["dlrm-mlperf"].make_config()
    assert dl.embed_dim == 128 and len(dl.vocab_sizes) == 26
    assert dl.bot_mlp == (512, 256, 128)
    fm_ = ARCHS["fm"].make_config()
    assert fm_.n_sparse == 39 and fm_.embed_dim == 10
    b = ARCHS["bst"].make_config()
    assert (b.embed_dim, b.seq_len, b.n_heads, b.n_blocks) == (32, 20, 8, 1)
    mi = ARCHS["mind"].make_config()
    assert (mi.embed_dim, mi.n_interests, mi.capsule_iters) == (64, 4, 3)
    gc = ARCHS["gcn-cora"].make_config()
    assert (gc.n_layers, gc.d_hidden, gc.norm) == (2, 16, "sym")


def test_generate_loop():
    """serve/decode.py generation: greedy continuation is deterministic and
    consistent with prefill+decode semantics."""
    import jax.numpy as jnp
    from repro.models import transformer as tfm
    from repro.models.sharding import MeshRules
    from repro.serve.decode import generate
    mod = ARCHS["h2o-danube-3-4b"]
    cfg = mod.make_config(smoke=True)
    rules = MeshRules(dp=(), fsdp=(), tp=None, ep=None)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    out1 = generate(params, prompt, 5, cfg, rules)
    out2 = generate(params, prompt, 5, cfg, rules)
    assert out1.shape == (2, 11)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :6]),
                                  np.asarray(prompt))
