"""Benchmark harness smoke: every per-figure module runs end-to-end on a
reduced dataset and emits CSV rows with the expected derived fields."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="module", autouse=True)
def small_bench(monkeypatch_module=None):
    import benchmarks.common as common
    common.BENCH_N = 1500
    common.BENCH_QUERIES = 32
    common.dataset.cache_clear()
    common.ROWS.clear()
    yield
    common.dataset.cache_clear()


def test_fig4_fig5(capsys):
    from benchmarks import fig4_fig5_linear
    res = fig4_fig5_linear.run()
    assert ("deep-ID", "sphering") in res
    loss, rec = res[("laion-OOD", "sphering")]
    assert 0 <= rec <= 1 and loss >= 0


def test_fig6():
    from benchmarks import fig6_cluster_structure
    d80_global, d80_clusters = fig6_cluster_structure.run()
    assert d80_global >= 1 and len(d80_clusters) == 16


def test_fig7():
    from benchmarks import fig7_tag_access
    total, window = fig7_tag_access.run(c=16, window=5)
    assert len(total) > 0
    assert max(total) <= 16


def test_fig8():
    from benchmarks import fig8_gleanvec
    out = fig8_gleanvec.run()
    assert any(k[0].startswith("gleanvec") for k in out)


def test_table1_and_kernels():
    from benchmarks import kernels_micro, table1_search
    table1_search.run()
    kernels_micro.run(n=5000, dim=128, d=48, c=8, m=8)
    from benchmarks.common import ROWS
    assert any(r.startswith("table1_search/") for r in ROWS)
    assert any(r.startswith("kernel/") for r in ROWS)


def test_declared_rows_must_reach_json(tmp_path):
    """A ``declare``-d row that never emits fails ``write_json_results``
    (a silently-skipped bench row can no longer pass smoke)."""
    import benchmarks.common as common
    saved_rows = list(common.RESULTS)
    saved_csv = list(common.ROWS)
    saved_decl = list(common.DECLARED)
    try:
        common.RESULTS.clear()
        common.DECLARED.clear()
        common.emit("probe/exists", 1.0, "ok=1")
        common.declare("probe/exists", "probe/never-emitted")
        with pytest.raises(RuntimeError, match="probe/never-emitted"):
            common.write_json_results(str(tmp_path))
        common.DECLARED.remove("probe/never-emitted")
        assert common.write_json_results(str(tmp_path))   # now it passes
    finally:
        common.RESULTS[:] = saved_rows
        common.ROWS[:] = saved_csv
        common.DECLARED[:] = saved_decl


def test_run_smoke_path(tmp_path):
    """The CLI harness --smoke path runs end-to-end, writes the CSV and the
    machine-readable BENCH_<name>.json files, and covers the sorted,
    fused-int8, sharded-index and reduced-probe modes."""
    import glob
    import json

    from benchmarks import run as bench_run
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baselines = {p: open(p, "rb").read()
                 for p in glob.glob(os.path.join(repo_root, "BENCH_*.json"))}
    out = tmp_path / "bench.csv"
    bench_run.main(["--smoke", "--out", str(out)])
    # mirror guard: a smoke run must leave every committed repo-root
    # full-size baseline byte-identical
    for p, before in baselines.items():
        assert open(p, "rb").read() == before, \
            f"--smoke overwrote the committed baseline {p}"
    rows = out.read_text().strip().splitlines()
    assert rows[0] == "name,us_per_call,derived"
    assert any(r.startswith("table1_search/flat/gleanvec-") and "-int8" in r
               for r in rows)
    assert any(r.startswith("table1_search/flat/gleanvec-")
               and "-sorted" in r for r in rows)
    assert any(r.startswith("table1_search/flat/gleanvec-")
               and "-int8-sorted" in r for r in rows)
    assert any(r.startswith("table1_search/ivf/") for r in rows)
    assert any(r.startswith("table1_search/ivf-rprobe/") for r in rows)
    assert any(r.startswith("table1_search/ivf-sorted-fused/") for r in rows)
    assert any(r.startswith("table1_search/ivf-sharded/") for r in rows)
    assert any(r.startswith("table1_search/graph-expand1/") for r in rows)
    assert any(r.startswith("table1_search/graph-expand4/") for r in rows)
    assert any(r.startswith("table1_search/graph-fused/") for r in rows)
    assert any(r.startswith("table1_search/graph-sharded/") for r in rows)
    assert any(r.startswith("table1_search/graph-build-numpy/")
               for r in rows)
    assert any(r.startswith("table1_search/graph-build-device/")
               for r in rows)
    assert any(r.startswith("kernel/gleanvec_sq/fused-int8") for r in rows)

    # machine-readable trajectory: one BENCH_<group>.json per bench group
    table1 = json.loads((tmp_path / "BENCH_table1_search.json").read_text())
    assert table1["bench"] == "table1_search"
    assert all("us_per_call" in e and "ops_per_s" in e
               for e in table1["results"])
    assert any(isinstance(e.get("recall10"), float)
               for e in table1["results"])
    # the R^d coarse probe must compile to ~D/d fewer probe flops
    flops = {e["name"].split("/")[1]: e["probe_flops"]
             for e in table1["results"] if "probe_flops" in e}
    assert flops["ivf-rprobe"] * 2 <= flops["ivf"], flops
    # fused sorted-IVF fine step: the range-scan kernel's HBM traffic sits
    # below the compiled gathered fine step's even at smoke shapes (the
    # paper-proportioned >= 4x floor is asserted in tests/test_ivf_scan.py)
    fused_row = next(e for e in table1["results"]
                     if e["name"].startswith("table1_search/ivf-sorted-"))
    assert fused_row["fine_bytes"] > 0
    assert fused_row["fine_bytes"] < fused_row["fine_bytes_gathered"]
    # multi-expansion beam search: expand=4 reaches matched recall in
    # fewer sequential hops
    by_prefix = {e["name"].split("/")[1]: e for e in table1["results"]}
    e1, e4 = by_prefix["graph-expand1"], by_prefix["graph-expand4"]
    assert e4["hops"] < e1["hops"], (e1["hops"], e4["hops"])
    assert e4["recall10"] >= e1["recall10"] - 0.05
    # gather-free fused traversal: the per-hop kernel traffic sits at
    # least the declared guard ratio below the compiled gathered hop
    # (table1_search.GRAPH_FUSED_MIN_RATIO raises inside the bench run
    # itself; paper-proportioned >= 3x lives in tests/test_graph_scan.py)
    gf = by_prefix["graph-fused"]
    assert gf["fine_bytes"] > 0
    assert gf["vs_gathered"] >= 2.0, gf
    assert gf["recall10"] >= e4["recall10"] - 0.05
    # on-device CAGRA-style build: recall within 1% of the numpy build
    bn, bd = by_prefix["graph-build-numpy"], by_prefix["graph-build-device"]
    assert bd["recall10"] >= bn["recall10"] - 0.01, (bn, bd)
    kern = json.loads((tmp_path / "BENCH_kernel.json").read_text())
    fused = next(e for e in kern["results"]
                 if e["name"] == "kernel/gleanvec_sq/fused-int8")
    # acceptance: the fused kernel moves >= 5x fewer HBM bytes than
    # dequantize-then-gleanvec_ip on the micro-bench shapes
    assert fused["vs_dequant_bytes"] >= 5.0
    assert isinstance(fused["bytes_per_vec"], float)

    # streaming serving trajectory: the state-passing engine swaps with
    # ZERO recompiles while the closure-rebuild baseline re-jits per swap
    assert any(r.startswith("serving_stream/steady-") for r in rows)
    stream = json.loads(
        (tmp_path / "BENCH_serving_stream.json").read_text())
    by_name = {e["name"]: e for e in stream["results"]}
    for mode in ("gleanvec-int8", "gleanvec-int8-sorted"):
        assert by_name[f"serving_stream/swap-{mode}"]["recompiles"] == 0
        assert by_name[
            f"serving_stream/rebuild_swap-{mode}"]["recompiles"] >= 1
        assert by_name[f"serving_stream/recall-{mode}"]["recall10"] > 0.5
        assert by_name[f"serving_stream/steady-{mode}"]["qps"] > 0

    # fault-tolerance section (declared rows: missing any fails the run
    # before these asserts): rejected swaps leave results bit-identical,
    # degradation keeps serving the stale-but-valid state at useful
    # recall, and the corrupted-snapshot fallback restores w/o recompiles
    for row in ("reject-nonfinite", "reject-canary"):
        e = by_name[f"serving_stream/faults/{row}"]
        assert e["swaps_rejected"] >= 1 and e["bitident"] == 1, e
    rec = by_name["serving_stream/faults/recover-nan-moments"]
    assert rec["degraded"] >= 1 and rec["outcome"] == "ok"
    assert rec["recall_degraded"] > 0.5 and rec["recall_recovered"] > 0.5
    fb = by_name["serving_stream/faults/restore-fallback"]
    assert fb["fallback"] == 1 and fb["bitident"] == 1
    assert fb["recompiles"] == 0

    # overload-safe async frontend (declared rows): bursty and diurnal
    # arrivals meet the declared SLO, sustained overload sheds instead of
    # blowing the served p99, and the background-refresh staleness row
    # lands its swap with the serving-step cache frozen
    for row in ("bursty", "diurnal"):
        e = by_name[f"serving_stream/frontend/{row}"]
        assert e["slo_ok"] == 1 and e["qps"] > 0, e
    ov = by_name["serving_stream/frontend/overload"]
    assert ov["shed_rate"] > 0 and ov["p99_ms"] <= ov["slo_ms"], ov
    st = by_name["serving_stream/frontend/staleness"]
    assert st["swaps"] >= 1 and st["serving_recompiles"] == 0
    assert st["cycles"] >= 1 and st["stale_peak_ms"] >= 0


def test_workload_field_guards_the_root_mirror(tmp_path):
    """``workload_of`` drives the run.py mirror guard: legacy or
    unreadable baselines default to the FULL workload (guard stays
    closed), and a freshly written file records the workload it actually
    ran at."""
    import json

    import benchmarks.common as common
    full = {"bench_n": common.FULL_BENCH_N,
            "bench_queries": common.FULL_BENCH_QUERIES}
    legacy = tmp_path / "BENCH_legacy.json"
    legacy.write_text(json.dumps({"bench": "legacy", "results": []}))
    assert common.workload_of(str(legacy)) == full
    junk = tmp_path / "BENCH_junk.json"
    junk.write_text("{not json")
    assert common.workload_of(str(junk)) == full
    assert common.workload_of(str(tmp_path / "missing.json")) == full

    saved = (list(common.RESULTS), list(common.ROWS), list(common.DECLARED))
    try:
        common.RESULTS.clear()
        common.DECLARED.clear()
        common.emit("probe/workload", 1.0, "ok=1")
        paths = common.write_json_results(str(tmp_path))
        ran = {"bench_n": common.BENCH_N,
               "bench_queries": common.BENCH_QUERIES}
        assert ran != full          # module fixture shrank the workload
        assert common.workload_of(paths[0]) == ran
    finally:
        common.RESULTS[:], common.ROWS[:], common.DECLARED[:] = saved
