"""Paper-algorithm correctness: LeanVec-Sphering, GleanVec, baselines,
streaming (Sections 3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (baselines, gleanvec as gv, leanvec_sphering as lvs,
                        metrics, quantization, spherical_kmeans as skm,
                        streaming)
from repro.data import vectors

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def ood_data():
    return vectors.make_dataset("ood", n=3000, d=96, n_queries=192,
                                ood=True, seed=0)


@pytest.fixture(scope="module")
def id_data():
    return vectors.make_dataset("id", n=3000, d=96, n_queries=192,
                                ood=False, seed=0)


def _recall(ds, a, b, k=10):
    qv = ds.queries_test @ np.asarray(a).T
    xv = ds.database @ np.asarray(b).T
    ids = vectors.exact_topk(qv, xv, k)
    return float(metrics.recall_at_k(jnp.asarray(ids),
                                     jnp.asarray(ds.gt[:, :k])))


def test_eq10_full_rotation_is_exact(ood_data):
    """Section 3.1: with d == D, <A'q, B'x> == <q, x> exactly (Eq. 10)."""
    ds = ood_data
    m = lvs.full_rotation_model(jnp.asarray(ds.queries_learn),
                                jnp.asarray(ds.database))
    q = ds.queries_test[:16]
    x = ds.database[:64]
    approx = (q @ np.asarray(m.a).T) @ (x @ np.asarray(m.b).T).T
    exact = q @ x.T
    assert np.abs(approx - exact).max() / np.abs(exact).max() < 1e-3


def test_truncate_is_prefix(ood_data):
    ds = ood_data
    m = lvs.full_rotation_model(jnp.asarray(ds.queries_learn),
                                jnp.asarray(ds.database))
    m32 = m.truncate(32)
    assert m32.a.shape == (32, 96)
    np.testing.assert_array_equal(np.asarray(m32.a), np.asarray(m.a)[:32])


def test_sphering_beats_svd_on_ood(ood_data):
    """Figure 5: query-aware sphering > query-agnostic SVD for OOD."""
    ds = ood_data
    X, Q = jnp.asarray(ds.database), jnp.asarray(ds.queries_learn)
    kx = jnp.einsum("nd,ne->de", X, X)
    m_sph = lvs.fit(Q, X, 32)
    m_svd = baselines.svd_fit(kx, 32)
    r_sph, r_svd = _recall(ds, m_sph.a, m_sph.b), _recall(ds, m_svd.a,
                                                          m_svd.b)
    assert r_sph > r_svd + 0.05
    l_sph = metrics.leanvec_loss(m_sph.a, m_sph.b, Q, X)
    l_svd = metrics.leanvec_loss(m_svd.a, m_svd.b, Q, X)
    assert float(l_sph) < float(l_svd)


def test_all_methods_similar_on_id(id_data):
    """Figure 4: in-distribution, sphering ~ SVD (both >= 0.8 recall)."""
    ds = id_data
    X, Q = jnp.asarray(ds.database), jnp.asarray(ds.queries_learn)
    kx = jnp.einsum("nd,ne->de", X, X)
    r_sph = _recall(ds, *lvs.fit(Q, X, 32)[:2])
    r_svd = _recall(ds, *baselines.svd_fit(kx, 32))
    assert r_sph > 0.75 and r_svd > 0.75
    assert abs(r_sph - r_svd) < 0.15


def test_fw_es_improve_over_svd_on_ood(ood_data):
    ds = ood_data
    X, Q = jnp.asarray(ds.database), jnp.asarray(ds.queries_learn)
    kq = jnp.einsum("nd,ne->de", Q, Q)
    kx = jnp.einsum("nd,ne->de", X, X)
    l_svd = metrics.leanvec_loss(*baselines.svd_fit(kx, 32), Q, X)
    l_fw = metrics.leanvec_loss(*baselines.leanvec_fw(kq, kx, 32), Q, X)
    l_es = metrics.leanvec_loss(*baselines.leanvec_es(kq, kx, 32), Q, X)
    assert float(l_fw) < float(l_svd)
    assert float(l_es) < float(l_svd)


def test_gleanvec_beats_sphering(ood_data):
    """Figure 8: piecewise-linear > linear at equal d (OOD)."""
    ds = ood_data
    X, Q = jnp.asarray(ds.database), jnp.asarray(ds.queries_learn)
    d = 24
    m = lvs.fit(Q, X, d)
    r_lin = _recall(ds, m.a, m.b)
    model = gv.fit(jax.random.PRNGKey(0), Q, X, c=8, d=d)
    tags, x_low = gv.encode_database(model, X)
    q_views = gv.project_queries_eager(model, jnp.asarray(ds.queries_test))
    scores = np.stack([
        np.asarray(gv.inner_products_eager(q_views[i], tags, x_low))
        for i in range(q_views.shape[0])])
    ids = np.argsort(-scores, axis=1)[:, :10]
    r_gv = float(metrics.recall_at_k(jnp.asarray(ids),
                                     jnp.asarray(ds.gt[:, :10])))
    assert r_gv > r_lin - 0.01  # never worse; usually strictly better


def test_lazy_eager_equivalent(ood_data):
    """Algorithms 3 and 4 compute the same scores."""
    ds = ood_data
    X, Q = jnp.asarray(ds.database), jnp.asarray(ds.queries_learn)
    model = gv.fit(jax.random.PRNGKey(0), Q, X, c=8, d=24)
    tags, x_low = gv.encode_database(model, X)
    q = jnp.asarray(ds.queries_test[0])
    lazy = gv.inner_products_lazy(model, q, tags, x_low)
    eager = gv.inner_products_eager(
        gv.project_queries_eager(model, q[None])[0], tags, x_low)
    np.testing.assert_allclose(np.asarray(lazy), np.asarray(eager),
                               rtol=1e-4, atol=1e-4)


def test_spherical_kmeans_properties():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    km = skm.fit(jax.random.PRNGKey(1), jnp.asarray(x), c=8, n_iters=15)
    norms = np.linalg.norm(np.asarray(km.centers), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)   # unit centers
    tags = skm.assign(skm.normalize_rows(jnp.asarray(x)), km.centers)
    assert len(np.unique(np.asarray(tags))) == 8        # no empty clusters
    # objective above random-centers baseline
    rand_centers = skm.normalize_rows(
        jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32)))
    rand_obj = float(jnp.mean(jnp.max(
        skm.normalize_rows(jnp.asarray(x)) @ rand_centers.T, axis=-1)))
    assert float(km.inertia) > rand_obj


def test_streaming_matches_batch(ood_data):
    """Section 3.2: moment updates + refresh == batch refit."""
    ds = ood_data
    X = jnp.asarray(ds.database[:500])
    Q = jnp.asarray(ds.queries_learn)
    k_q = jnp.einsum("nd,ne->de", Q, Q)
    k_x0 = jnp.einsum("nd,ne->de", X[:400], X[:400])
    st = streaming.init(k_q, k_x0, d=32, refresh_every=50)
    for i in range(400, 450):
        st = streaming.insert(st, X[i])
    for i in range(50):
        st = streaming.remove(st, X[i])
    st = streaming.refresh(st)
    # reference: batch fit on the same effective set X[50:450]
    k_ref = jnp.einsum("nd,ne->de", X[50:450], X[50:450])
    m_ref = lvs.fit_from_moments(k_q, k_ref, 32)
    np.testing.assert_allclose(np.asarray(st.k_x), np.asarray(k_ref),
                               rtol=2e-2, atol=2e-1)
    # A^T B products agree (up to sign/rotation of eigvecs, compare scores)
    x = np.asarray(X[:32])
    q = np.asarray(Q[:16])
    s1 = (q @ np.asarray(st.model.a).T) @ (x @ np.asarray(st.model.b).T).T
    s2 = (q @ np.asarray(m_ref.a).T) @ (x @ np.asarray(m_ref.b).T).T
    np.testing.assert_allclose(s1, s2, rtol=0.1, atol=0.5)


def test_streaming_reprojection():
    """Eq. 12: reprojection of stored vectors equals direct projection
    under the new model (full-rotation d == D case)."""
    rng = np.random.default_rng(3)
    d_full = 24
    X = jnp.asarray(rng.standard_normal((300, d_full)).astype(np.float32))
    Q = jnp.asarray(rng.standard_normal((100, d_full)).astype(np.float32))
    k_q = jnp.einsum("nd,ne->de", Q, Q)
    k_x = jnp.einsum("nd,ne->de", X, X)
    st = streaming.init(k_q, k_x, d=d_full, refresh_every=10)
    x_low = X @ st.model.b.T
    for i in range(12):
        st = streaming.insert(st, X[i] * 1.5)
    st = streaming.refresh(st)
    reproj = streaming.reproject(st, x_low)
    direct = X @ st.model.b.T
    np.testing.assert_allclose(np.asarray(reproj), np.asarray(direct),
                               rtol=1e-2, atol=1e-2)


def test_quantization_roundtrip():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((100, 64)).astype(np.float32))
    db = quantization.quantize(x)
    deq = quantization.dequantize(db)
    # max error bounded by delta/2 per entry
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert (err <= np.asarray(db.delta) * 0.5 + 1e-6).all()
    q = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    s = quantization.quantized_inner_products(q, db)
    exact = np.asarray(x) @ np.asarray(q)
    assert np.abs(np.asarray(s) - exact).max() / np.abs(exact).max() < 0.02
