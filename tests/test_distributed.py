"""Multi-device semantics, run in subprocesses with 8 fake CPU devices
(the main test process must keep the real 1-device platform)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))


def _run(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.utils.jax_compat import make_mesh, set_mesh, shard_map
        mesh = make_mesh((2, 4), ("data", "model"))
    """).format(src=REPO_SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_search_exact():
    out = _run("""
        from repro.index import distributed
        from repro.data import vectors
        rng = np.random.default_rng(0)
        X = rng.standard_normal((2048, 32)).astype(np.float32)
        Q = rng.standard_normal((16, 32)).astype(np.float32)
        gt = vectors.exact_topk(Q, X, 5)
        with set_mesh(mesh):
            xs = jax.device_put(jnp.asarray(X),
                                NamedSharding(mesh, P(("data","model"), None)))
            fn = distributed.make_sharded_search(mesh, ("data", "model"),
                                                 k=5, kappa=5, block=256)
            _, ids = jax.jit(fn)(jnp.asarray(Q), xs)
        rec = np.mean([len(set(np.asarray(ids)[i]) & set(gt[i])) / 5
                       for i in range(16)])
        print("RECALL", rec)
    """)
    assert "RECALL 1.0" in out


def test_sharded_scorer_search_matches_local():
    """Any scorer shards with the same all-gather merge: GleanVec,
    GleanVec∘int8 and both TAG-SORTED layouts match the single-device scan
    (sorted scorers emit global original ids through their permutation, so
    the merge skips the shard offset via globalize_ids)."""
    out = _run("""
        from repro.core import gleanvec as gv
        from repro.core.scorer import (GleanVecScorer,
                                       GleanVecQuantizedScorer,
                                       SortedGleanVecScorer,
                                       SortedGleanVecQuantizedScorer)
        from repro.core.quantization import quantize_per_cluster
        from repro.index import bruteforce, distributed
        rng = np.random.default_rng(0)
        n, d, dim, C = 2048, 16, 32, 4
        x_low = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
        # balanced tags: 4 clusters x 512 rows, layout block 256 -> 8
        # single-tag blocks, one per device (shards must not split blocks)
        tags = jnp.asarray(np.repeat(np.arange(C), n // C)[
            rng.permutation(n)].astype(np.int32))
        a = jnp.asarray(rng.standard_normal((C, d, dim)).astype(np.float32))
        Q = jnp.asarray(rng.standard_normal((8, dim)).astype(np.float32))
        sq = quantize_per_cluster(x_low, tags, C)
        xs, btags, perm, _ = gv.sort_by_tag(tags, x_low, block=256)
        cs, _, _, _ = gv.sort_by_tag(tags, sq.codes, block=256)
        inv = gv.inverse_permutation(perm, n)
        perm = perm.astype(jnp.int32)
        for s in (GleanVecScorer(x_low=x_low, tags=tags, a=a),
                  GleanVecQuantizedScorer(codes=sq.codes, tags=tags,
                                          lo=sq.lo, delta=sq.delta, a=a),
                  SortedGleanVecScorer(x_low=xs, block_tags=btags,
                                       perm=perm, inv_perm=inv, a=a),
                  SortedGleanVecQuantizedScorer(
                      codes=cs, block_tags=btags, perm=perm, inv_perm=inv,
                      lo=sq.lo, delta=sq.delta, a=a)):
            v_ref, i_ref = bruteforce.search_scorer(Q, s, 5, block=256)
            with set_mesh(mesh):
                fn = distributed.make_sharded_search_scorer(
                    mesh, ("data", "model"), k=5, scorer=s, kappa=5,
                    block=256)
                v, i = jax.jit(fn)(Q, s)
            assert np.allclose(np.asarray(v), np.asarray(v_ref),
                               rtol=1e-4, atol=1e-4), type(s).__name__
            assert np.array_equal(np.asarray(i), np.asarray(i_ref)), \\
                type(s).__name__
        print("SHARDED_SCORER_OK")
    """)
    assert "SHARDED_SCORER_OK" in out


def test_sharded_embedding_lookup_matches_take():
    out = _run("""
        from repro.models.embedding import make_sharded_lookup
        rng = np.random.default_rng(1)
        V, D, B, F = 64, 8, 16, 3
        table = rng.standard_normal((V, D)).astype(np.float32)
        idx = rng.integers(0, V, (B, F)).astype(np.int32)
        with set_mesh(mesh):
            t = jax.device_put(jnp.asarray(table),
                               NamedSharding(mesh, P("model", "data")))
            i = jax.device_put(jnp.asarray(idx),
                               NamedSharding(mesh, P("data", None)))
            fn = make_sharded_lookup(mesh, V, D)
            out = jax.jit(fn)(t, i)
        ref = table[idx]
        print("MAXERR", float(np.abs(np.asarray(out) - ref).max()))
    """)
    assert "MAXERR 0.0" in out


def test_compressed_psum_mean():
    out = _run("""
        from repro.train.grad_compress import compressed_psum_mean
        import functools
        rng = np.random.default_rng(2)
        g = rng.standard_normal((8, 32)).astype(np.float32)

        def local(x):
            return compressed_psum_mean({"g": x}, "data")["g"]

        fn = shard_map(local, mesh=mesh,
                       in_specs=P("data", None),
                       out_specs=P("data", None))
        with set_mesh(mesh):
            xs = jax.device_put(jnp.asarray(g),
                                NamedSharding(mesh, P("data", None)))
            out = jax.jit(fn)(xs)
        # each data row becomes the mean over the 2 'data' shards
        ref = (g[:4] + g[4:]) / 2
        got = np.asarray(out)[:4]
        rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        print("REL", rel)
        assert rel < 0.02, rel
        print("OK")
    """)
    assert "OK" in out


def test_vocab_parallel_embed_matches_take():
    out = _run("""
        from repro.models import transformer as tfm
        from repro.models.sharding import MeshRules
        rules = MeshRules(dp=("data",), fsdp=(), tp="model", ep="model")
        rng = np.random.default_rng(3)
        table = rng.standard_normal((64, 16)).astype(np.float32)
        toks = rng.integers(0, 64, (4, 8)).astype(np.int32)
        with set_mesh(mesh):
            t = jax.device_put(jnp.asarray(table),
                               NamedSharding(mesh, P("model", None)))
            tk = jax.device_put(jnp.asarray(toks),
                                NamedSharding(mesh, P("data", None)))
            fn = jax.jit(lambda a, b: tfm._embed_lookup(a, b, rules,
                                                        jnp.float32))
            got = fn(t, tk)
        ref = table[toks]
        print("MAXERR", float(np.abs(np.asarray(got) - ref).max()))
    """)
    assert "MAXERR 0.0" in out


def test_elastic_reshard_restore():
    """Checkpoint written under one sharding restores onto another mesh."""
    out = _run("""
        import tempfile
        from repro.train import checkpoint
        rng = np.random.default_rng(4)
        x = rng.standard_normal((8, 16)).astype(np.float32)
        with tempfile.TemporaryDirectory() as d:
            with set_mesh(mesh):
                xs = jax.device_put(jnp.asarray(x),
                                    NamedSharding(mesh, P("data", "model")))
                checkpoint.save(d, 1, {"x": xs})
            # restore onto a DIFFERENT layout (fully replicated 1D mesh)
            mesh2 = make_mesh((8,), ("data",))
            sh2 = {"x": NamedSharding(mesh2, P(None, None))}
            tree, step, _ = checkpoint.restore_distributed(
                d, {"x": jnp.zeros((8, 16), jnp.float32)}, sh2)
            ok = np.array_equal(np.asarray(tree["x"]), x)
            print("RESHARD_OK", ok, step)
    """)
    assert "RESHARD_OK True 1" in out
