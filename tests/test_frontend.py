"""Async serving frontend (serve/frontend.py + the frontend injectors in
serve/faults.py).

Four guarantee layers:

* COALESCING PARITY -- a request admitted through the bounded queue and
  padded into a static bucket resolves BIT-IDENTICAL to the same query
  sent through ``ServingEngine.submit`` alone, for every scorer mode, ID
  and OOD traffic; poisoned rows resolve to all ``-1`` without touching
  their bucket-mates.
* BOUNDED COMPILES -- the bucket-shape set is static and warmed up
  front: dispatching every bucket size, interleaved with guarded swaps,
  compiles NOTHING (compile_counter-asserted); every dispatched shape is
  a declared bucket.
* ADMISSION / SHEDDING -- a full queue and an unmeetable deadline reject
  at enqueue, an expired deadline sheds at dispatch, a late batch counts
  a deadline miss -- all LOUD (``Rejected`` with a stable reason slug)
  and all counted in ``ServeStats``.
* SUPERVISED BACKGROUND REFRESH -- the worker hands refreshed states to
  ``GuardedEngine.swap`` off-thread with zero serving-step cache growth;
  a persistently failing refresh degrades then auto-recovers; a stuck
  refresh strands only the worker (watchdog flags it, serving continues
  on the stale-but-valid state, release -> swap lands).
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, streaming
from repro.core import search as msearch
from repro.core.scorer import MODES
from repro.data import vectors
from repro.serve import faults, lifecycle
from repro.serve.engine import ServingEngine
from repro.serve.frontend import (MAX_BUCKETS, Rejected, RefreshWorker,
                                  ServingFrontend, bucket_shapes)

pytestmark = pytest.mark.tier1

D, N, N0, CAP = 32, 512, 384, 512
BATCH, K, KAPPA = 16, 10, 30


@pytest.fixture(scope="module")
def env():
    ds = vectors.make_dataset("frontend", n=N, d=D, n_queries=256,
                              ood=True, seed=9)
    X = jnp.asarray(ds.database)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, N0, 256)] \
        + 0.1 * rng.standard_normal((256, D)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:N0],
                   c=4, d=8)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:N0], model, capacity=CAP, sort_block=64,
        slack_blocks=2)
    return ds, X, q_init, model, arts


@pytest.fixture(scope="module")
def engine(env):
    _, _, _, _, arts = env
    return ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                         batch_size=BATCH, dim=D)


def drain_all(fe):
    while fe.queue_depth:
        fe.drain_once()


class ScriptedClock:
    """Returns the scripted instants in order, then repeats the last --
    drives admission/shed/miss paths without wall time or threads."""

    def __init__(self, *vals):
        self.vals = list(vals)

    def __call__(self):
        return self.vals.pop(0) if len(self.vals) > 1 else self.vals[0]


# ---------------------------------------------------------------------------
# Bucket shapes: the static contract surface.
# ---------------------------------------------------------------------------


def test_bucket_shapes_powers_of_two_and_max():
    assert bucket_shapes(16) == (1, 2, 4, 8, 16)
    assert bucket_shapes(1) == (1,)
    # a non-power max batch is always its own (largest) bucket
    assert bucket_shapes(24) == (1, 2, 4, 8, 16, 24)
    with pytest.raises(ValueError, match=">= 1"):
        bucket_shapes(0)
    with pytest.raises(ValueError, match="MAX_BUCKETS"):
        bucket_shapes(1 << (MAX_BUCKETS + 1))


# ---------------------------------------------------------------------------
# Coalescing parity: bucketed == unbatched submit, every mode, ID + OOD.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_coalesced_parity_every_mode(env, mode):
    ds, X, q_init, gvm, _ = env
    if mode == "full":
        model = None
    elif mode.startswith("sphering"):
        model = lvs.fit(jnp.asarray(ds.queries_learn), X[:N0], 8)
    else:
        model = gvm
    arts = msearch.build_artifacts(mode, X[:N0], model)
    eng = ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                        batch_size=BATCH, dim=D)
    fe = ServingFrontend(eng, capacity=64, start=False, warmup=False)
    # mixed traffic, deliberately NOT a bucket multiple (13 ID + 13 OOD)
    Q = np.concatenate([q_init[:13], np.asarray(ds.queries_test)[:13]])
    futs = [fe.enqueue(q) for q in Q]
    drain_all(fe)
    got = np.stack([f.result() for f in futs])
    np.testing.assert_array_equal(got, eng.submit(Q))
    assert fe.dispatched_shapes <= set(fe.buckets)


def test_poisoned_request_isolated_from_bucket_mates(env, engine):
    ds, *_ = env
    fe = ServingFrontend(engine, capacity=64, start=False, warmup=False)
    Q = np.asarray(ds.queries_test)[:8]
    bad = Q[3].copy()
    bad[0] = np.nan
    n0 = engine.stats.n_sanitized
    futs = [fe.enqueue(q) for q in Q[:3]] + [fe.enqueue(bad)] \
        + [fe.enqueue(q) for q in Q[4:]]
    drain_all(fe)
    got = np.stack([f.result() for f in futs])
    assert (got[3] == -1).all()
    assert engine.stats.n_sanitized == n0 + 1
    clean = engine.submit(Q)            # same queries, no poisoned row
    np.testing.assert_array_equal(got[:3], clean[:3])
    np.testing.assert_array_equal(got[4:], clean[4:])


def test_enqueue_hardens_input(engine):
    fe = ServingFrontend(engine, capacity=8, start=False, warmup=False)
    with pytest.raises(ValueError, match="ONE query"):
        fe.enqueue(np.zeros((2, D), np.float32))
    with pytest.raises(ValueError, match=f"\\(n, {D}\\)"):
        fe.enqueue(np.zeros(D - 1, np.float32))


# ---------------------------------------------------------------------------
# Bounded compiles: all buckets + guarded swaps, zero backend compiles.
# ---------------------------------------------------------------------------


def test_zero_recompiles_across_buckets_and_swaps(env, compile_counter):
    ds, X, q_init, model, arts = env
    eng = ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                        batch_size=BATCH, dim=D)
    guarded = lifecycle.GuardedEngine(
        eng, canary_queries=np.asarray(ds.queries_test)[:BATCH])
    fe = ServingFrontend(guarded, capacity=64, start=False)   # warms buckets
    # two legitimate refresh candidates, prepared BEFORE the counter
    # resets (the eager refresh ops compile once, separately from the
    # serving step); the first swap also warms the guard's validate path
    stream = streaming.init_from_artifacts(arts, jnp.asarray(q_init),
                                           refresh_every=64)
    stream = streaming.observe_queries(
        stream, jnp.asarray(ds.queries_test)[:64])
    stream = streaming.refresh(stream)
    cand1 = streaming.refresh_state(eng.state, stream, source="full")
    guarded.swap(cand1)
    stream2 = streaming.refresh(streaming.observe_queries(
        stream, jnp.asarray(ds.queries_test)[64:128]))
    # built AFTER the first swap so its version leaf is monotonic
    cand2 = streaming.refresh_state(eng.state, stream2, source="full")
    Q = np.asarray(ds.queries_test)

    compile_counter.reset()
    for size in fe.buckets:             # every declared bucket shape
        for q in Q[:size]:
            fe.enqueue(q)
        fe.drain_once()
    guarded.swap(cand2)                 # swap mid-traffic
    for q in Q[:5]:
        fe.enqueue(q)
    drain_all(fe)
    assert compile_counter.count == 0, \
        f"{compile_counter.count} recompiles across the bucket set"
    assert fe.dispatched_shapes == set(fe.buckets)
    assert eng.n_compiles == len(fe.buckets)


# ---------------------------------------------------------------------------
# Admission control and load shedding: loud, counted, deterministic.
# ---------------------------------------------------------------------------


def test_queue_full_rejects_loudly(env, engine):
    ds, *_ = env
    fe = ServingFrontend(engine, capacity=2, start=False, warmup=False)
    Q = np.asarray(ds.queries_test)
    n0 = engine.stats.n_rejected
    fe.enqueue(Q[0])
    fe.enqueue(Q[1])
    with pytest.raises(Rejected, match="queue-full") as ei:
        fe.enqueue(Q[2])
    assert ei.value.reason == "queue-full"
    assert engine.stats.n_rejected == n0 + 1
    drain_all(fe)                       # admitted requests still serve


def test_deadline_admission_shed_and_miss_accounting(env, engine):
    ds, *_ = env
    Q = np.asarray(ds.queries_test)
    s = engine.stats
    base = (s.n_rejected, s.n_shed, s.n_deadline_miss)

    # admission: predicted wait (1 batch x 100ms) exceeds a 50ms budget
    fe = ServingFrontend(engine, capacity=8, start=False, warmup=False,
                         est_batch_ms=100.0, ewma_alpha=0.0,
                         clock=ScriptedClock(0.0))
    with pytest.raises(Rejected, match="deadline") as ei:
        fe.enqueue(Q[0], deadline_ms=50.0)
    assert ei.value.reason == "deadline"
    assert s.n_rejected == base[0] + 1

    # shed: admitted at t=0 with a 500ms budget, drained at t=1.0
    clk = ScriptedClock(0.0, 1.0)
    fe = ServingFrontend(engine, capacity=8, start=False, warmup=False,
                         est_batch_ms=100.0, ewma_alpha=0.0, clock=clk)
    fut = fe.enqueue(Q[0], deadline_ms=500.0)
    assert fe.drain_once() == 1
    with pytest.raises(Rejected, match="shed"):
        fut.result()
    assert s.n_shed == base[1] + 1

    # miss: admitted and dispatched in time, but the batch lands at
    # t=0.1 -- past the 50ms budget; served anyway, counted as a miss
    fe = ServingFrontend(engine, capacity=8, start=False, warmup=False,
                         est_batch_ms=0.0, ewma_alpha=0.0,
                         clock=ScriptedClock(0.0, 0.0, 0.0, 0.1))
    fut = fe.enqueue(Q[0], deadline_ms=50.0)
    fe.drain_once()
    assert fut.result().shape == (K,)   # late, but answered
    assert s.n_deadline_miss == base[2] + 1
    assert s.shed_rate > 0.0


def test_burst_overflow_accounting(env, engine):
    ds, *_ = env
    burst = faults.burst_overflow(D, 24, seed=3, poison_frac=0.25)
    np.testing.assert_array_equal(burst,
                                  faults.burst_overflow(D, 24, seed=3,
                                                        poison_frac=0.25))
    assert int((~np.isfinite(burst).all(axis=1)).sum()) == 6
    fe = ServingFrontend(engine, capacity=8, start=False, warmup=False)
    admitted, rejected = [], 0
    for q in burst:
        try:
            admitted.append(fe.enqueue(q))
        except Rejected as e:
            assert e.reason == "queue-full"
            rejected += 1
    assert len(admitted) + rejected == len(burst)   # nothing silent
    assert rejected == len(burst) - 8
    drain_all(fe)
    assert all(f.done() for f in admitted)


def test_shutdown_drains_or_fails_backlog(env, engine):
    ds, *_ = env
    Q = np.asarray(ds.queries_test)
    fe = ServingFrontend(engine, capacity=8, start=False, warmup=False)
    futs = [fe.enqueue(q) for q in Q[:3]]
    fe.close(drain=True)
    assert all(f.result().shape == (K,) for f in futs)
    with pytest.raises(Rejected, match="shutdown"):
        fe.enqueue(Q[0])
    fe2 = ServingFrontend(engine, capacity=8, start=False, warmup=False)
    futs2 = [fe2.enqueue(q) for q in Q[:3]]
    fe2.close(drain=False)
    for f in futs2:
        with pytest.raises(Rejected, match="shutdown"):
            f.result()


# ---------------------------------------------------------------------------
# Supervised background refresh: swap off-thread, degrade, stick, recover.
# ---------------------------------------------------------------------------


def make_supervised(env, **kw):
    ds, X, q_init, model, arts = env
    eng = ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                        batch_size=BATCH, dim=D)
    guarded = lifecycle.GuardedEngine(
        eng, canary_queries=np.asarray(ds.queries_test)[:BATCH])
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0,
                                      sleep=lambda s: None, **kw)
    stream = streaming.init_from_artifacts(arts, jnp.asarray(q_init),
                                           refresh_every=64)
    return eng, guarded, sup, stream


def _await(cond, timeout_s=30.0):
    t0 = time.monotonic()
    while not cond():
        if time.monotonic() - t0 > timeout_s:
            return False
        time.sleep(0.01)
    return True


def test_background_worker_swaps_without_cache_growth(env):
    ds, *_ = env
    eng, guarded, sup, stream = make_supervised(env)
    n_exec, v0 = eng.n_compiles, guarded.version
    worker = RefreshWorker(sup, stream, source="stored").start()
    try:
        worker.observe(np.asarray(ds.queries_test)[:64])
        worker.request_refresh()
        assert _await(lambda: guarded.version > v0), "swap never landed"
        assert worker.n_cycles >= 1 and worker.healthy
        assert eng.n_compiles == n_exec     # serving-step cache frozen
        assert eng.submit(np.asarray(ds.queries_test)[:4]).shape == (4, K)
    finally:
        assert worker.stop()


def test_failing_refresh_degrades_then_recovers(env):
    ds, *_ = env
    eng, guarded, sup, stream = make_supervised(env, max_retries=1)
    fn = faults.failing(streaming.refresh, n_failures=100)
    worker = RefreshWorker(sup, stream, source="stored", refresh_fn=fn)
    worker.observe(np.asarray(ds.queries_test)[:64])
    rep = worker.run_cycle()            # synchronous: no thread needed
    assert rep.outcome == "degraded"
    assert sup.n_degraded >= 1
    # stale-but-valid state keeps serving while degraded
    assert not lifecycle.nonfinite_leaves(guarded.state)
    assert eng.submit(np.asarray(ds.queries_test)[:4]).shape == (4, K)
    v0 = guarded.version
    fn.n_failures = 0                   # fault clears
    worker.observe(np.asarray(ds.queries_test)[64:128])
    rep2 = worker.run_cycle()
    assert rep2.outcome == "ok" and guarded.version > v0
    assert sup.n_recoveries >= 1 and not worker.degraded


def test_stuck_worker_flags_serves_stale_then_swaps_on_release(env):
    ds, *_ = env
    eng, guarded, sup, stream = make_supervised(env)
    release = threading.Event()
    stuck = faults.stuck_worker(release, timeout_s=30.0)
    worker = RefreshWorker(sup, stream, source="stored",
                           refresh_fn=stuck).start()
    try:
        v0 = guarded.version
        worker.observe(np.asarray(ds.queries_test)[:64])
        worker.request_refresh()
        assert _await(lambda: stuck.calls >= 1), "refresh never entered"
        time.sleep(0.05)
        assert worker.stuck(0.02)       # watchdog fires
        assert guarded.version == v0    # no torn/partial swap
        # serving continues on the stale-but-valid state
        assert eng.submit(np.asarray(ds.queries_test)[:4]).shape == (4, K)
        release.set()
        assert _await(lambda: guarded.version > v0), \
            "released worker never swapped"
        assert stuck.releases == 1 and not worker.stuck(10.0)
    finally:
        release.set()
        assert worker.stop()


def test_slow_refresh_injector_counts_and_delegates(env):
    _, _, q_init, _, arts = env
    sleeps = []
    slow = faults.slow_refresh(delay_s=0.123, sleep=sleeps.append)
    stream = streaming.init_from_artifacts(arts, jnp.asarray(q_init),
                                           refresh_every=64)
    out = slow(stream)
    assert slow.calls == 1 and sleeps == [0.123]
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(stream)
