"""Streamed graph growth: ``graph.with_capacity`` + ``graph.insert_ids``
(the Vamana-style incremental insert over the two-level layout).

Guarantees:

* CONNECTIVITY -- every inserted id gets R out-edges AND >= 1 in-edge
  (the nearest beam target always yields a slot), so inserted vectors are
  reachable by greedy traversal immediately -- asserted by self-retrieval
  through the engine-compiled search, batch inserts into one region
  included (batch-mates link to each other, not only to old rows).
* TIER-AGNOSTIC -- the full-D re-rank inside the insert gathers candidate
  rows from ``x_full`` whether it is a device array or a host-tier
  store: both produce BIT-IDENTICAL edge tables.
* SHAPE STABILITY -- ``with_capacity`` pads edge rows like
  ``ivf.with_list_slack``; insert + refresh cycles swap into a serving
  engine with ZERO recompiles, and a fused (gather-free) graph re-derives
  ``nbr_rows`` so fused == gathered search results after every insert.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, streaming
from repro.core import search as msearch
from repro.data import vectors
from repro.index import graph
from repro.index.protocol import replace
from repro.serve.engine import ServingEngine

pytestmark = pytest.mark.tier1

D, N, N0, CAP = 48, 512, 400, 512
K, KAPPA, BATCH = 10, 30, 16


@pytest.fixture(scope="module")
def setup():
    ds = vectors.make_dataset("graph-insert", n=N, d=D, n_queries=64,
                              ood=True, seed=5)
    X = jnp.asarray(ds.database)
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn),
                 X[:N0], c=4, d=16)
    return ds, X, gvm


def _grown_graph(X, scorer_mode_arts, rows, ids, beam=32):
    g = graph.build(np.asarray(X[:N0]), r=8, n_iters=4, seed=0)
    g = replace(g, beam=beam, max_hops=64, expand=4)
    g = graph.with_capacity(g, CAP)
    return graph.insert_ids(g, rows, ids, scorer_mode_arts.scorer,
                            scorer_mode_arts.x_full)


def test_with_capacity_shapes(setup):
    _, X, gvm = setup
    g = graph.build(np.asarray(X[:N0]), r=8, n_iters=4, seed=0)
    r_built = g.neighbors.shape[1]           # R + n_random long-range edges
    padded = graph.with_capacity(g, CAP)
    assert padded.neighbors.shape == (CAP, r_built)
    assert (np.asarray(padded.neighbors[N0:]) == -1).all()
    np.testing.assert_array_equal(np.asarray(padded.neighbors[:N0]),
                                  np.asarray(g.neighbors))
    assert graph.with_capacity(g, N0) is g   # no-op at current size
    with pytest.raises(ValueError, match="capacity"):
        graph.with_capacity(g, N0 - 1)


def test_insert_connectivity_and_search_parity(setup):
    """Every inserted id: out-edges AND >= 1 in-edge from outside itself,
    and the grown graph's traversal serves the inserted region as well as
    the exhaustive scan does -- near-total agreement with the flat search
    on the SAME artifacts, inserted-id hits specifically recovered. (The
    flat baseline factors DR quality out: what the reduced-space scan
    can't surface, no traversal can.)"""
    ds, X, gvm = setup
    arts = streaming.build_streaming_artifacts("gleanvec-int8", X[:N0],
                                               gvm, capacity=CAP)
    rows = X[N0:]
    arts, new_ids = streaming.insert_rows(arts, rows)
    ids = np.asarray(new_ids)
    g = _grown_graph(X, arts, rows, ids)
    nbrs = np.asarray(g.neighbors)
    assert ((nbrs[ids] >= 0).sum(axis=1) > 0).all()       # out-edges
    for nid in ids:
        mask = np.ones(CAP, bool)
        mask[nid] = False                    # self-loops don't count
        assert (nbrs[mask] == nid).any(), f"id {nid} has no in-edge"
    probes = jnp.concatenate([jnp.asarray(ds.queries_test), rows[:48]])
    flat = np.asarray(msearch.state_search(
        probes, msearch.make_state(arts, block=256), K, KAPPA))
    via_g = np.asarray(msearch.state_search(
        probes, msearch.make_state(arts, index=g), K, KAPPA))
    agree = np.mean([len(set(flat[i]) & set(via_g[i])) / K
                     for i in range(len(flat))])
    assert agree > 0.9, agree
    new_flat = [(i, nid) for i in range(len(flat))
                for nid in flat[i] if nid >= N0]
    assert new_flat                          # the scan DOES serve inserts
    recovered = np.mean([nid in set(via_g[i]) for i, nid in new_flat])
    assert recovered > 0.9, (recovered, len(new_flat))


def test_insert_batch_into_sparse_region(setup):
    """A batch inserted far from the existing data must stay connected:
    batch-mates widen each row's candidate set, so the cluster links
    internally AND at least one member links back to the old graph."""
    ds, X, gvm = setup
    arts = streaming.build_streaming_artifacts("gleanvec-int8", X[:N0],
                                               gvm, capacity=CAP)
    rng = np.random.default_rng(3)
    far = np.asarray(X[:8]) * 0.2 + 5.0 \
        + 0.05 * rng.standard_normal((8, D)).astype(np.float32)
    arts, new_ids = streaming.insert_rows(arts, jnp.asarray(far))
    ids = np.asarray(new_ids)
    g = _grown_graph(X, arts, jnp.asarray(far), ids)
    nbrs = np.asarray(g.neighbors)
    # the cluster links internally: every member points at >= 1 mate
    # (beam candidates alone -- all old rows -- could never provide this)
    assert all(np.isin(nbrs[nid], np.setdiff1d(ids, [nid])).any()
               for nid in ids), nbrs[ids]
    # and the whole cluster is reachable from the old graph's entries
    from collections import deque
    seen = set(np.asarray(g.entries).tolist())
    dq = deque(seen)
    while dq:
        for v in nbrs[dq.popleft()]:
            if v >= 0 and int(v) not in seen:
                seen.add(int(v))
                dq.append(int(v))
    assert set(ids.tolist()) <= seen, sorted(set(ids.tolist()) - seen)


def test_insert_edges_identical_on_host_tier(setup):
    """The full-D re-rank inside the insert reads ``x_full`` through the
    same row-gather shim on both tiers: bit-identical edge tables."""
    ds, X, gvm = setup
    arts = streaming.build_streaming_artifacts("gleanvec-int8", X[:N0],
                                               gvm, capacity=CAP)
    rows = X[N0:]
    arts, new_ids = streaming.insert_rows(arts, rows)
    ids = np.asarray(new_ids)
    g_dev = _grown_graph(X, arts, rows, ids)
    arts_host = msearch.demote_rerank_tier(arts)
    g_host = _grown_graph(X, arts_host, rows, ids)
    np.testing.assert_array_equal(np.asarray(g_host.neighbors),
                                  np.asarray(g_dev.neighbors))


def test_fused_insert_matches_gathered(setup):
    """Insert into a FUSED graph re-derives ``nbr_rows`` against the
    sorted layout: same edges as the gathered insert, and fused search ==
    gathered search on the grown graph (same (value, id) sets)."""
    ds, X, gvm = setup
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8-sorted", X[:N0], gvm, capacity=CAP, sort_block=64,
        slack_blocks=2)
    rows = X[N0:]
    arts, new_ids = streaming.insert_rows(arts, rows)
    ids = np.asarray(new_ids)
    g0 = graph.build(np.asarray(X[:N0]), r=8, n_iters=4, seed=0)
    g0 = graph.with_capacity(replace(g0, beam=32, max_hops=64, expand=4),
                             CAP)
    gathered = graph.insert_ids(g0, rows, ids, arts.scorer, arts.x_full)
    fused0 = graph.with_fused_scan(g0, arts.scorer)
    fused = graph.insert_ids(fused0, rows, ids, arts.scorer, arts.x_full)
    assert fused.fused and fused.nbr_rows is not None
    np.testing.assert_array_equal(np.asarray(fused.neighbors),
                                  np.asarray(gathered.neighbors))
    q = jnp.asarray(ds.queries_test)
    vf, idf = fused.search(q, arts.scorer, K)
    vg, idg = gathered.search(q, arts.scorer, K)
    of, og = np.argsort(np.asarray(idf), 1), np.argsort(np.asarray(idg), 1)
    np.testing.assert_array_equal(np.take_along_axis(np.asarray(idf), of, 1),
                                  np.take_along_axis(np.asarray(idg), og, 1))
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(vf), of, 1),
        np.take_along_axis(np.asarray(vg), og, 1), rtol=1e-4, atol=1e-3)


def test_insert_cycles_zero_recompiles(setup, compile_counter):
    """The streamed-graph serving loop (submit -> insert rows -> link ->
    swap -> refresh -> swap): shape/treedef stability across
    ``insert_ids`` means zero XLA compiles after the warmup cycle."""
    ds, X, gvm = setup
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, N0, 256)] \
        + 0.1 * rng.standard_normal((256, D)).astype(np.float32)
    arts = streaming.build_streaming_artifacts("gleanvec-int8", X[:N0],
                                               gvm, capacity=CAP)
    g = graph.build(np.asarray(X[:N0]), r=8, n_iters=4, seed=0)
    g = graph.with_capacity(replace(g, beam=32, max_hops=64, expand=4),
                            CAP)
    engine = ServingEngine(msearch.make_state(arts, index=g), k=K,
                           kappa=KAPPA, batch_size=BATCH, dim=D)
    stream = streaming.init_from_artifacts(arts, jnp.asarray(q_init),
                                           refresh_every=28)
    QT = np.asarray(ds.queries_test)
    step = (CAP - N0) // 4

    def cycle(i):
        nonlocal stream
        engine.submit(QT[i * BATCH:(i + 1) * BATCH])
        rows = X[N0 + i * step: N0 + (i + 1) * step]
        arts2, new_ids = streaming.insert_rows(engine.state.artifacts,
                                               rows)
        g2 = graph.insert_ids(engine.state.index, rows,
                              np.asarray(new_ids), arts2.scorer,
                              arts2.x_full)
        engine.swap(engine.state._replace(artifacts=arts2, index=g2))
        stream = streaming.observe_queries(
            stream, jnp.asarray(QT[(i * 32) % len(QT):][:32]))
        stream = streaming.insert(stream, rows)
        stream = streaming.refresh(stream)
        engine.swap(streaming.refresh_state(engine.state, stream,
                                            source="full"))

    tree0 = jax.tree_util.tree_structure(engine.state)
    cycle(0)                                  # warmup
    compile_counter.reset()
    cycle(1)
    cycle(2)
    served = engine.submit(QT[:BATCH])
    assert compile_counter.count == 0, \
        f"{compile_counter.count} recompiles across graph-insert cycles"
    assert jax.tree_util.tree_structure(engine.state) == tree0
    assert engine.state.index.neighbors.shape == (CAP, 12)  # R + n_random
    assert served.shape == (BATCH, K)
    # grown rows are being served: some result ids exceed the seed size
    grown = msearch.state_search(
        X[N0:N0 + 2 * step], engine.state, K, KAPPA)
    assert (np.asarray(grown) >= N0).any()
