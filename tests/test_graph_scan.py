"""Gather-free graph traversal: fused Pallas beam step x on-device build.

Four layers of guarantees:

* PARITY -- a fused graph (``graph.with_fused_scan`` ->
  ``scorer.scan_neighbors`` -> ``kernels/graph_scan``) returns EXACTLY
  the gathered traversal's (value, id) sets for both sorted scorer
  families, on ID and OOD queries, with ``expand`` in {1, 4}, after
  streaming removals (dead slots), and per-shard under ``ShardedIndex``.
* SERVING -- a ``ServingEngine`` compiled with the fused traversal swaps
  streamed, ``refreshed``-re-derived states with ZERO recompiles
  (``compile_counter``); ``ShardedIndex.refreshed`` reaches every
  shard's hook and preserves treedef + leaf avals.
* COST -- the fused beam step's per-hop HBM traffic (fixed by the
  kernel's BlockSpecs + the tn-slab schedule, ``beam_step_bytes``) is
  >= 3x below the compiled gathered hop's ``cost_analysis`` bytes at the
  paper's proportions, and the gathered HLO materializes the
  (m, expand*R) / (m, beam + expand*R) score matrices the kernel never
  allocates.
* BUILD -- the vectorized reverse-edge fill matches the sequential
  reference exactly, and the on-device CAGRA-style build's recall@10
  stays within 1% of the numpy NN-descent build's at a matched beam.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, metrics, streaming
from repro.core import scorer as sc
from repro.core import search as msearch
from repro.data import vectors
from repro.index import distributed, graph
from repro.index.protocol import replace
from repro.index.topk import NEG_INF
from repro.kernels.graph_scan import beam_step_bytes, fresh_slab_count
from repro.serve.engine import ServingEngine
from repro.analysis import assert_rules
from repro.analysis.hlo_rules import BufferPresent, NoDenseScoreMatrix
from repro.utils import hlo_analysis

from helpers import assert_same_topk

pytestmark = pytest.mark.tier1

SORTED_MODES = ("gleanvec-sorted", "gleanvec-int8-sorted")

N, D, C, DLOW = 800, 48, 4, 16
BEAM, HOPS = 32, 64


@pytest.fixture(scope="module")
def setup():
    ds = vectors.make_dataset("graph-scan", n=N, d=D, n_queries=64,
                              ood=True, seed=5)
    X = jnp.asarray(ds.database)
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn), X,
                 c=C, d=DLOW)
    g = graph.build(ds.database, r=16, n_iters=4, seed=0)
    return ds, X, gvm, g


def _assert_same_topk(res_a, res_b, label=""):
    # graph traversals accumulate through more ops than the flat scans:
    # same set semantics, looser float tolerance
    assert_same_topk(res_a, res_b, label=label, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# PARITY: fused == gathered, both sorted families x expand x ID/OOD.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", SORTED_MODES)
@pytest.mark.parametrize("expand", [1, 4])
@pytest.mark.parametrize("qkind", ["id", "ood"])
def test_fused_matches_gathered(setup, mode, expand, qkind):
    """The fused beam step returns EXACTLY the gathered traversal's
    (value, id) candidate sets -- the whole traversal (pop choices, hop
    count, final beam) agrees, not just the final top-k multiset."""
    ds, X, gvm, g = setup
    q = jnp.asarray(ds.queries_test if qkind == "ood"
                    else ds.database[:48])
    scorer = sc.build_scorer(mode, X, gvm, block=64)
    gathered = replace(g, beam=BEAM, max_hops=HOPS, expand=expand)
    fused = graph.with_fused_scan(gathered, scorer)
    assert fused.fused and not gathered.fused
    res_f = fused.search(q, scorer, 10)
    res_g = gathered.search(q, scorer, 10)
    _assert_same_topk(res_f, res_g, f"{mode}/expand={expand}/{qkind}")
    assert not (np.asarray(res_f[1]) < 0).all()


@pytest.mark.parametrize("mode", SORTED_MODES)
def test_fused_streamed_dead_slots(setup, mode):
    """Removal churn: after ``remove_rows`` tombstones live slots, the
    ``refreshed``-re-derived fused graph still matches the gathered
    traversal exactly -- dead neighbors are masked in-kernel (rid = -1),
    and dead ids never enter either beam."""
    ds, X, gvm, g = setup
    q = jnp.asarray(ds.queries_test)
    arts = streaming.build_streaming_artifacts(mode, X, gvm,
                                               sort_block=64)
    gathered = replace(g, beam=BEAM, max_hops=HOPS, expand=4)
    fused = graph.with_fused_scan(gathered, arts.scorer)
    # tombstone 60 non-entry vertices, then re-derive the row translation
    entries = set(np.asarray(g.entries).tolist())
    rm = np.array([i for i in range(0, N, 13) if i not in entries],
                  np.int32)[:60]
    arts = streaming.remove_rows(arts, rm)
    fused = fused.refreshed(arts.scorer, arts.model)
    res_f = fused.search(q, arts.scorer, 10)
    res_g = gathered.search(q, arts.scorer, 10)
    _assert_same_topk(res_f, res_g, f"{mode}/streamed")
    # tombstoned ids must be gone from the results
    assert not np.isin(np.asarray(res_f[1]), rm).any()


@pytest.mark.parametrize("mode", SORTED_MODES)
def test_fused_sharded_matches_gathered(setup, mode):
    """Per-shard fused subgraphs under ShardedIndex (stacked, padded
    leaves) return exactly the gathered per-shard results after the
    all-gather merge -- the fused hop survives leaf stacking."""
    ds, X, gvm, _ = setup
    QT = jnp.asarray(ds.queries_test)
    kwargs = dict(n_shards=2, sort_block=64, beam=BEAM, max_hops=HOPS,
                  expand=4, graph_kwargs={"r": 16, "n_iters": 4, "seed": 0})
    sh, stacked = distributed.build_sharded_index("graph", mode, X, gvm,
                                                  fused_graph=True,
                                                  **kwargs)
    assert sh.sub_index.fused
    sh_g, stacked_g = distributed.build_sharded_index("graph", mode, X,
                                                      gvm, **kwargs)
    fused = sh.search_local(QT, stacked, 10, kappa=20)
    gathered = sh_g.search_local(QT, stacked_g, 10, kappa=20)
    _assert_same_topk(fused, gathered, f"{mode}/sharded")


def test_fused_sharded_needs_sorted_mode(setup):
    _, X, gvm, _ = setup
    with pytest.raises(ValueError, match="sorted"):
        distributed.build_sharded_index("graph", "gleanvec", X, gvm,
                                        n_shards=2, fused_graph=True)


# ---------------------------------------------------------------------------
# SERVING: zero-recompile streamed swaps + per-shard refreshed wiring.
# ---------------------------------------------------------------------------


def test_engine_swap_zero_recompiles_fused_graph(setup, compile_counter):
    """A ServingEngine mounted on a fused graph survives removal churn +
    ``refresh_state`` (which re-derives ``nbr_rows`` through the
    ``refreshed`` hook) with ZERO recompiles after warmup: the re-derived
    index has the same treedef and leaf avals, and ``fused``/``scan_tn``
    ride the treedef as static aux data."""
    ds, X, gvm, g = setup
    Q = np.asarray(ds.queries_test[:16])
    arts = streaming.build_streaming_artifacts("gleanvec-int8-sorted", X,
                                               gvm, sort_block=64)
    fused = graph.with_fused_scan(replace(g, beam=BEAM, max_hops=HOPS,
                                          expand=4), arts.scorer)
    engine = ServingEngine(msearch.make_state(arts, index=fused), k=10,
                           kappa=20, batch_size=16, dim=D)
    entries = set(np.asarray(g.entries).tolist())
    safe = [i for i in range(0, N, 7) if i not in entries]

    def remove_cycle(rm_ids):
        arts2 = streaming.remove_rows(engine.state.artifacts,
                                      np.asarray(rm_ids, np.int32))
        st2 = streaming.refresh_state(
            engine.state._replace(artifacts=arts2), None)
        engine.swap(st2)
        return engine.submit(Q)

    engine.submit(Q)                       # warmup compile
    remove_cycle(safe[:8])                 # warmup the swapped executable
    compile_counter.reset()
    out = remove_cycle(safe[8:16])
    assert compile_counter.count == 0, \
        f"{compile_counter.count} recompiles across fused-graph swaps"
    assert engine.n_compiles in (None, 1)
    assert not np.isin(np.asarray(out), safe[:16]).any()


def test_sharded_refreshed_reaches_every_shard(setup):
    """``ShardedIndex.refreshed`` fans out to each shard's hook with THAT
    shard's scorer slice: corrupting the stacked ``nbr_rows`` and
    refreshing restores every shard's own translation (wrong slices would
    leave garbage), with treedef and leaf avals preserved -- the
    zero-recompile swap contract."""
    ds, X, gvm, _ = setup
    sh, stacked = distributed.build_sharded_index(
        "graph", "gleanvec-sorted", X, gvm, n_shards=2, sort_block=64,
        beam=BEAM, max_hops=HOPS, fused_graph=True,
        graph_kwargs={"r": 16, "n_iters": 4, "seed": 0})
    good = sh.sub_index.nbr_rows
    broken = replace(sh, sub_index=replace(sh.sub_index,
                                           nbr_rows=jnp.zeros_like(good)))
    fixed = broken.refreshed(stacked, gvm)
    np.testing.assert_array_equal(np.asarray(fixed.sub_index.nbr_rows),
                                  np.asarray(good))
    assert jax.tree_util.tree_structure(fixed) == \
        jax.tree_util.tree_structure(sh)
    for a, b in zip(jax.tree_util.tree_leaves(fixed),
                    jax.tree_util.tree_leaves(sh)):
        assert a.shape == b.shape and a.dtype == b.dtype


# ---------------------------------------------------------------------------
# COST: >= 3x fewer per-hop HBM bytes at the paper's proportions.
# ---------------------------------------------------------------------------


def test_fused_beam_step_moves_3x_fewer_bytes():
    """Cost assertion at the paper's proportions (d = D/4, int8 codes,
    c = 16 clusters, R = 32, expand = 4, beam = 96): the fused beam
    step's schedule-determined HBM traffic (``beam_step_bytes`` over the
    hop's actual fresh-slab count) is >= 3x below the compiled gathered
    hop's ``cost_analysis`` bytes, and the gathered HLO materializes the
    (m, expand*R) neighbor-score and (m, beam + expand*R) merge matrices
    the kernel never allocates."""
    m, beam, e, tn = 32, 96, 4, 8
    ds = vectors.make_dataset("graphscan-cost", n=4096, d=256,
                              n_queries=m, ood=True, seed=13)
    X = jnp.asarray(ds.database)
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn), X,
                 c=16, d=64)
    s = sc.sorted_gleanvec_quantized_scorer(gvm, X, block=64)
    g = graph.build(ds.database, r=32, n_iters=3, seed=0)
    gf = graph.with_fused_scan(replace(g, beam=beam, expand=e), s, tn=tn)
    R = int(g.neighbors.shape[1])
    qstate = s.prepare_queries(jnp.asarray(ds.queries_test[:m]))

    # one representative hop: e random frontier vertices per query
    rng = np.random.default_rng(0)
    best_ids = jnp.asarray(rng.integers(0, 4096, size=(m, e)).astype(
        np.int32))
    sel_ok = jnp.ones((m, e), bool)
    vals = jnp.full((m, beam), NEG_INF)
    ids = jnp.full((m, beam), -1, jnp.int32)
    visited = jnp.zeros((m, beam), bool)

    def hop(scorer, qs, nbr_tbl, vals, ids, visited, best_ids, sel_ok):
        def score_ids(cids):
            return scorer.score_ids(qs, jnp.where(cids >= 0, cids, 0))
        return graph.gathered_beam_step(score_ids, nbr_tbl, vals, ids,
                                        visited, best_ids, sel_ok, beam)

    compiled = jax.jit(hop).lower(s, qstate, g.neighbors, vals, ids,
                                  visited, best_ids, sel_ok).compile()
    gathered_bytes = hlo_analysis.normalize_cost(
        compiled.cost_analysis())["bytes accessed"]
    assert_rules(compiled,
                 [BufferPresent(m, e * R, dtypes=("f32",)),
                  BufferPresent(m, beam + e * R, dtypes=("f32",))],
                 target="graph/gathered-hop")

    # the fused program never allocates either matrix: each tn-slab's
    # scores live in VMEM-resident registers and fold straight into the
    # beam (interpret-mode lowering of the actual kernel)
    from repro import kernels
    nrows_j = jnp.asarray(
        np.asarray(gf.nbr_rows)[np.asarray(best_ids)].reshape(m, e * R))
    fused_compiled = jax.jit(
        lambda *a: kernels.graph_scan_beam_step(
            *a, layout_block=64, tn=tn, interpret=True)).lower(
        qstate.q_scaled, qstate.q_lo, s.block_tags, s.perm, s.codes,
        nrows_j, vals, ids).compile()
    # f32 only: the s32 (m, expand*R) neighbor-row table is a legitimate
    # kernel INPUT; the forbidden buffers are the float score matrices
    assert_rules(fused_compiled,
                 [NoDenseScoreMatrix(m, e * R, dtypes=("f32",)),
                  NoDenseScoreMatrix(m, beam + e * R, dtypes=("f32",))],
                 target="graph/fused-hop")

    fused_bytes = beam_step_bytes(m, fresh_slab_count(np.asarray(nrows_j),
                                                      tn), tn,
                                  d=64, c=16, beam=beam, s=e * R)
    ratio = gathered_bytes / fused_bytes
    assert fused_bytes * 3 <= gathered_bytes, \
        f"fused hop only {ratio:.2f}x below gathered " \
        f"({fused_bytes} vs {gathered_bytes} bytes)"


# ---------------------------------------------------------------------------
# BUILD: vectorized reverse fill parity + device-build recall.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_reverse_edge_fill_matches_ref(seed):
    """The argsort/bincount slot assignment reproduces the sequential
    first-come-first-served reference loop EXACTLY, including duplicate
    forward edges, empty rows and rows with no free slots. Rows are
    front-packed (live prefix, -1 tail) -- the shape ``_robust_prune``
    emits and both implementations assume."""
    rng = np.random.default_rng(seed)
    n, r = 120, 8
    nbrs = rng.integers(0, n, size=(n, r)).astype(np.int64)
    fill = rng.integers(0, r + 1, size=n)   # live counts, front-packed
    nbrs[np.arange(r)[None, :] >= fill[:, None]] = -1
    nbrs[:7] = -1                           # fully-free rows
    nbrs[7] = rng.integers(0, n)            # fully-occupied duplicate row
    np.testing.assert_array_equal(
        graph._reverse_edge_fill(nbrs.copy(), r),
        graph._reverse_edge_fill_ref(nbrs.copy(), r))


def test_dedupe_rows_contract(setup):
    """Both builds emit duplicate-free neighbor rows (the fused/gathered
    parity contract: the kernel scores each distinct neighbor once, the
    gathered expand=1 path scores every slot)."""
    _, _, _, g = setup
    nbrs = np.asarray(g.neighbors)
    for row in nbrs:
        live = row[row >= 0]
        assert live.size == np.unique(live).size


def test_device_build_recall_matches_numpy():
    """The on-device CAGRA-style build (fused-kernel k-NN self-join +
    rank-based detour pruning) holds recall@10 within 1% of the numpy
    NN-descent build at a matched beam, on bimodal data."""
    ds = vectors.make_dataset("graph-build", n=1200, d=48, n_queries=128,
                              ood=True, seed=7)
    X = jnp.asarray(ds.database)
    q = jnp.asarray(ds.queries_test)
    scorer = sc.build_scorer("full", X, None, block=64)
    gt = jax.lax.top_k(q @ X.T, 10)[1]
    g_np = graph.build(ds.database, r=16, n_iters=4, seed=0,
                       method="numpy")
    g_dev = graph.build(ds.database, r=16, seed=0, method="device")
    assert g_np.neighbors.shape == g_dev.neighbors.shape

    def recall(gr):
        _, ids = replace(gr, beam=BEAM, max_hops=128).search(q, scorer, 10)
        return float(metrics.recall_at_k(ids, gt))

    r_np, r_dev = recall(g_np), recall(g_dev)
    assert r_np > 0.85, f"numpy build recall degenerate: {r_np:.3f}"
    assert r_dev >= r_np - 0.01, \
        f"device build recall {r_dev:.3f} vs numpy {r_np:.3f}"
