"""Roofline HLO parser: trip counts, collective bytes, dot FLOPs on a real
compiled module with known structure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import hlo_analysis, roofline


def test_scan_trip_correction():
    """A scan of length 7 over a (64x64)@(64x64) matmul body: parsed dot
    FLOPs must be ~7x one body (cost_analysis counts it once)."""
    def body(c, _):
        return c @ c * 0.001, None

    def fn(x):
        return jax.lax.scan(body, x, None, length=7)[0]

    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    stats = hlo_analysis.analyze_hlo(compiled.as_text())
    one_matmul = 2 * 64 * 64 * 64
    assert 6 * one_matmul <= stats["dot_flops"] <= 9 * one_matmul
    assert any(v == 7 for v in stats["while_trips"].values())
    cost = hlo_analysis.normalize_cost(compiled.cost_analysis())
    # raw cost counts the body once
    assert cost["flops"] < 2.5 * one_matmul


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 1e12, "bytes accessed": 1e9}
    stats = {"dot_flops": 2e12, "write_bytes": 1e12,
             "collective_bytes": 1e10}
    terms = roofline.compute_terms(cost, stats, model_flops_total=1e14,
                                   n_chips=256)
    assert terms.compute_s == 2e12 / 197e12
    assert terms.memory_s == 2e12 / 819e9
    assert terms.collective_s == 1e10 / 50e9
    assert terms.bottleneck == "memory"
    assert 0 < terms.useful_flops_ratio < 1


def test_dus_counted_at_slice_size():
    """In-place stacking: write bytes reflect the slice, not the stack."""
    def fn(x):
        def body(c, _):
            return c + 1.0, c
        _, ys = jax.lax.scan(body, x, None, length=100)
        return ys

    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    stats = hlo_analysis.analyze_hlo(compiled.as_text())
    # 100 slice writes of 64KB each ~ 6.5MB + carry adds; NOT 100 x 6.5MB
    assert stats["write_bytes"] < 5e7
