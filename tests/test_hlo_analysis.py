"""Roofline HLO parser: trip counts, collective bytes, dot FLOPs on a real
compiled module with known structure, plus dialect-pinning fixtures that
hold the parser to BOTH HLO text styles (jax 0.4 prints ``%`` sigils,
full computation signatures, and typed operands; jax 0.6+/newer XLA
drops all three)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import hlo_analysis, roofline

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def test_scan_trip_correction():
    """A scan of length 7 over a (64x64)@(64x64) matmul body: parsed dot
    FLOPs must be ~7x one body (cost_analysis counts it once)."""
    def body(c, _):
        return c @ c * 0.001, None

    def fn(x):
        return jax.lax.scan(body, x, None, length=7)[0]

    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    stats = hlo_analysis.analyze_hlo(compiled.as_text())
    one_matmul = 2 * 64 * 64 * 64
    assert 6 * one_matmul <= stats["dot_flops"] <= 9 * one_matmul
    assert any(v == 7 for v in stats["while_trips"].values())
    cost = hlo_analysis.normalize_cost(compiled.cost_analysis())
    # raw cost counts the body once
    assert cost["flops"] < 2.5 * one_matmul


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 1e12, "bytes accessed": 1e9}
    stats = {"dot_flops": 2e12, "write_bytes": 1e12,
             "collective_bytes": 1e10}
    terms = roofline.compute_terms(cost, stats, model_flops_total=1e14,
                                   n_chips=256)
    assert terms.compute_s == 2e12 / 197e12
    assert terms.memory_s == 2e12 / 819e9
    assert terms.collective_s == 1e10 / 50e9
    assert terms.bottleneck == "memory"
    assert 0 < terms.useful_flops_ratio < 1


def test_dus_counted_at_slice_size():
    """In-place stacking: write bytes reflect the slice, not the stack."""
    def fn(x):
        def body(c, _):
            return c + 1.0, c
        _, ys = jax.lax.scan(body, x, None, length=100)
        return ys

    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    stats = hlo_analysis.analyze_hlo(compiled.as_text())
    # 100 slice writes of 64KB each ~ 6.5MB + carry adds; NOT 100 x 6.5MB
    assert stats["write_bytes"] < 5e7


@pytest.mark.parametrize("dialect", ["dialect_jax04.hlo",
                                     "dialect_jax06.hlo"])
def test_parser_pins_both_hlo_dialects(dialect):
    """The SAME logical program rendered in both text dialects parses to
    the SAME pinned numbers: a 7-trip while around a (32,64)@(64,2048)
    dot, one all-reduce of the (32,2048) result, and two donated params.

    Pins:
      dot_flops        = 7 trips x 2*32*2048*64  = 58,720,256
      collective_bytes = 32*2048*4               = 262,144
      donated          = {1, 2} (input_output_alias header entries)
    """
    with open(os.path.join(FIXTURES, dialect)) as f:
        text = f.read()

    stats = hlo_analysis.analyze_hlo(text)
    assert stats["while_trips"] == {"while_body.20": 7}
    assert stats["dot_flops"] == 7 * 2 * 32 * 2048 * 64
    assert stats["collective_bytes"] == 32 * 2048 * 4
    assert stats["n_collectives"] == 1

    shapes = hlo_analysis.buffer_shapes(text)
    assert {"f32[32,2048]", "f32[32,64]", "f32[64,2048]"} <= shapes

    from repro.analysis.hlo_rules import donated_params
    assert donated_params(text) == {1, 2}


def test_both_dialect_fixtures_parse_identically():
    """Dialect must be cosmetics only: every stat equal across the two."""
    texts = {}
    for name in ("dialect_jax04.hlo", "dialect_jax06.hlo"):
        with open(os.path.join(FIXTURES, name)) as f:
            texts[name] = f.read()
    a = hlo_analysis.analyze_hlo(texts["dialect_jax04.hlo"])
    b = hlo_analysis.analyze_hlo(texts["dialect_jax06.hlo"])
    assert a == b
    assert hlo_analysis.buffer_shapes(texts["dialect_jax04.hlo"]) == \
        hlo_analysis.buffer_shapes(texts["dialect_jax06.hlo"])
