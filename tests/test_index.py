"""Index substrate: flat/IVF/graph search + multi-step (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import search as msearch
from repro.data import vectors
from repro.index import bruteforce, graph, ivf, topk

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def ds():
    return vectors.make_dataset("idx", n=4000, d=64, n_queries=64, ood=True,
                                seed=2)


def test_bruteforce_exact(ds):
    """Flat scan == numpy ground truth in full dimension."""
    vals, ids = bruteforce.search(jnp.asarray(ds.queries_test),
                                  jnp.asarray(ds.database), 10, block=512)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) == 1.0


def test_merge_topk():
    va = jnp.asarray([[5.0, 3.0]]); ia = jnp.asarray([[1, 2]])
    vb = jnp.asarray([[4.0, 6.0]]); ib = jnp.asarray([[3, 4]])
    v, i = topk.merge_topk(va, ia, vb, ib, 2)
    assert v.tolist() == [[6.0, 5.0]] and i.tolist() == [[4, 1]]


def test_multi_step_search_recall(ds):
    """Algorithm 1 end-to-end: reduced main search + rerank ~ exact."""
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    model = lvs.fit(Q, X, 24)
    art = msearch.build_artifacts_sphering(model, X, use_rotated_full=False)

    def index_search(q_low, artifacts, kappa):
        _, ids = bruteforce.search(q_low, artifacts.x_low, kappa)
        return ids

    ids = msearch.multi_step_search(jnp.asarray(ds.queries_test), art,
                                    index_search, k=10, kappa=50)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.95


def test_multi_step_rotated_storage(ds):
    """Section 3.1 storage: rerank from the SAME rotated vectors."""
    X = jnp.asarray(ds.database)
    model = lvs.full_rotation_model(jnp.asarray(ds.queries_learn), X)
    art = msearch.build_artifacts_sphering(model, X, use_rotated_full=True)
    assert art.x_full is art.x_low   # single storage

    def index_search(q_low, artifacts, kappa):
        _, ids = bruteforce.search(q_low[:, :24], artifacts.x_low[:, :24],
                                   kappa)
        return ids

    ids = msearch.multi_step_search(jnp.asarray(ds.queries_test), art,
                                    index_search, k=10, kappa=50)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.95


def test_graph_search_recall(ds):
    g = graph.build(ds.database, r=24, n_iters=5, seed=0)
    model = lvs.fit(jnp.asarray(ds.queries_learn),
                    jnp.asarray(ds.database), 32)
    q_low = jnp.asarray(ds.queries_test) @ model.a.T
    x_low = jnp.asarray(ds.database) @ model.b.T
    _, ids = graph.beam_search(q_low, x_low, g, k=10, beam=96, max_hops=250)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.8


def test_graph_search_gleanvec_traced(ds):
    g = graph.build(ds.database, r=24, n_iters=5, seed=0)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn),
                   jnp.asarray(ds.database), c=8, d=32)
    tags, x_low = gv.encode_database(model, jnp.asarray(ds.database))
    q_views = gv.project_queries_eager(model, jnp.asarray(ds.queries_test))
    _, ids, hops, tag_hist = graph.beam_search_traced(
        q_views, tags, x_low, g, k=10, beam=96, max_hops=250)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.8
    th = np.asarray(tag_hist)
    assert (th < 8).all() and int(hops) > 0
    # Figure-7 property: distinct visited tags << C * hops
    distinct = np.mean([len(np.unique(r[r >= 0])) for r in th])
    assert distinct <= 8


def test_ivf_search(ds):
    X = jnp.asarray(ds.database)
    iv = ivf.build(jax.random.PRNGKey(0), X, n_lists=16)
    model = lvs.fit(jnp.asarray(ds.queries_learn), X, 32)
    q_low = jnp.asarray(ds.queries_test) @ model.a.T
    x_low = X @ model.b.T
    _, ids = ivf.search(q_low, jnp.asarray(ds.queries_test), x_low, iv,
                        k=10, nprobe=8)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.7


def test_quantized_flat_search(ds):
    from repro.core.quantization import quantize
    X = jnp.asarray(ds.database)
    model = lvs.fit(jnp.asarray(ds.queries_learn), X, 32)
    x_low = X @ model.b.T
    db = quantize(x_low)
    q_low = jnp.asarray(ds.queries_test) @ model.a.T
    _, ids = bruteforce.search_quantized(q_low, db.codes, db.lo,
                                         db.delta, 30)
    # rerank in full precision
    art = msearch.build_artifacts_sphering(model, X, use_rotated_full=False)
    final = msearch.rerank(jnp.asarray(ds.queries_test), art, ids, 10)
    rec = metrics.recall_at_k(final, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.85


def test_sorted_gleanvec_scan_matches_unsorted(ds):
    """Tag-sorted (cluster-contiguous) scan == gather-based scan."""
    X = jnp.asarray(ds.database)
    model = gv.fit(jax.random.PRNGKey(3), jnp.asarray(ds.queries_learn), X,
                   c=8, d=24)
    tags, x_low = gv.encode_database(model, X)
    q_views = gv.project_queries_eager(model,
                                       jnp.asarray(ds.queries_test[:16]))
    v1, i1 = bruteforce.search_gleanvec(q_views, tags, x_low, 10, block=256)
    xs, btags, perm, _ = gv.sort_by_tag(tags, x_low, block=256)
    v2, i2s = bruteforce.search_gleanvec_sorted(q_views, btags, xs, 10,
                                                block=256)
    i2 = jnp.asarray(np.asarray(perm)[np.asarray(i2s)])
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.sort(np.asarray(i1), 1),
                          np.sort(np.asarray(i2), 1))
