"""Index protocol: flat/IVF/graph conformance, reduced-space coarse
probing (recall parity + R^d cost assertion), and sharded IVF / sharded
graph parity with their single-device counterparts on a 4-way CPU mesh
for every scorer family (ID and OOD query regimes)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import scorer as sc
from repro.core import search as msearch
from repro.data import vectors
from repro.index import FlatIndex, bruteforce, distributed, graph, ivf
from repro.index.protocol import replace
from repro.utils import hlo_analysis

pytestmark = pytest.mark.tier1

REPO_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))

ALL_MODES = ["full", "sphering", "gleanvec", "sphering-int8",
             "gleanvec-int8", "gleanvec-sorted", "gleanvec-int8-sorted"]


@pytest.fixture(scope="module")
def setup():
    ds = vectors.make_dataset("idxproto", n=2048, d=64, n_queries=64,
                              ood=True, seed=7)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    lin = lvs.fit(Q, X, 24)
    gvm = gv.fit(jax.random.PRNGKey(0), Q, X, c=8, d=24)
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=16)
    return ds, X, lin, gvm, iv


def _model_for(mode, lin, gvm):
    if mode == "full":
        return None
    return lin if mode.startswith("sphering") else gvm


def test_flat_index_is_the_blocked_scan(setup):
    """FlatIndex.search == bruteforce.search_scorer, bit-identical."""
    ds, X, lin, gvm, _ = setup
    QT = jnp.asarray(ds.queries_test)
    s = sc.gleanvec_scorer(gvm, X)
    v1, i1 = bruteforce.search_scorer(QT, s, 10, block=512)
    v2, i2 = FlatIndex(block=512).search(QT, s, 10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))


def test_ivf_build_packing_vectorized(setup):
    """The argsort/bincount list packing == the per-list np.where
    reference (same buckets, same within-list order)."""
    rng = np.random.default_rng(0)
    tags = rng.integers(0, 13, size=1000).astype(np.int32)
    tags[tags == 11] = 0                     # force an empty list
    packed = ivf._pack_lists(tags, 13)
    buckets = [np.where(tags == c)[0] for c in range(13)]
    max_len = max(1, max(len(b) for b in buckets))
    ref = np.full((13, max_len), -1, np.int32)
    for c, b in enumerate(buckets):
        ref[c, : len(b)] = b
    np.testing.assert_array_equal(packed, ref)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_reduced_probe_recall_all_scorers(setup, mode):
    """IVF with centers projected into the scorer's reduced space reaches
    the full-D probe's recall@10 - tolerance at MATCHED nprobe, for every
    scorer family."""
    ds, X, lin, gvm, iv = setup
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    model = _model_for(mode, lin, gvm)
    s = sc.build_scorer(mode, X, model, block=256)
    _, i_full = ivf.search_scorer(QT, s, iv, k=10, nprobe=8)
    ivr = ivf.with_reduced_centers(iv, s, model)
    assert ivr.center_scorer is not None
    _, i_red = ivf.search_scorer(QT, s, ivr, k=10, nprobe=8)
    r_full = float(metrics.recall_at_k(i_full, gt))
    r_red = float(metrics.recall_at_k(i_red, gt))
    assert r_red >= r_full - 0.06, (mode, r_full, r_red)


def test_reduced_probe_paper_config_recall():
    """Paper-proportioned config (d/D = 160/512 as in gleanvec_paper's
    search shapes, scaled down): reduced-space probing stays within
    tolerance of full-D probing at matched nprobe."""
    ds = vectors.make_dataset("idxproto-paper", n=4096, d=256,
                              n_queries=64, ood=True, seed=11)
    X = jnp.asarray(ds.database)
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn), X,
                 c=16, d=80)
    s = sc.gleanvec_quantized_scorer(gvm, X)
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=32)
    _, i_full = ivf.search_scorer(QT, s, iv, k=10, nprobe=8)
    _, i_red = ivf.search_scorer(QT, s,
                                 ivf.with_reduced_centers(iv, s, gvm),
                                 k=10, nprobe=8)
    r_full = float(metrics.recall_at_k(i_full, gt))
    r_red = float(metrics.recall_at_k(i_red, gt))
    assert r_full > 0.6, r_full
    assert r_red >= r_full - 0.05, (r_full, r_red)


def test_reduced_probe_runs_in_reduced_dim():
    """normalize_cost assertion: the compiled coarse probe touches ~D/d
    fewer flops AND bytes once the centers live in R^d."""
    ds = vectors.make_dataset("idxproto-cost", n=2048, d=256,
                              n_queries=64, ood=True, seed=3)
    X = jnp.asarray(ds.database)
    QT = jnp.asarray(ds.queries_test)
    lin = lvs.fit(jnp.asarray(ds.queries_learn), X, 64)   # d = D / 4
    s = sc.linear_scorer(lin, X)
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=32)
    ivr = ivf.with_reduced_centers(iv, s, lin)
    qs_full = iv.prepare_queries(s, QT)
    qs_red = ivr.prepare_queries(s, QT)
    assert qs_full.q_coarse is not None and qs_red.q_coarse is None
    cost_f = hlo_analysis.normalize_cost(
        jax.jit(ivf.coarse_scores).lower(iv, qs_full).compile()
        .cost_analysis())
    cost_r = hlo_analysis.normalize_cost(
        jax.jit(ivf.coarse_scores).lower(ivr, qs_red).compile()
        .cost_analysis())
    # D/d = 4: require at least a 2x drop on both axes
    assert cost_r["flops"] * 2 <= cost_f["flops"], (cost_r, cost_f)
    assert cost_r["bytes accessed"] * 2 <= cost_f["bytes accessed"], \
        (cost_r, cost_f)


def test_multi_step_and_serving_accept_index_protocol(setup):
    """Algorithm 1, the serving search fn and the retrieval layer all take
    an Index-protocol object -- index x scorer orthogonality end to end."""
    from repro.serve import retrieval
    from repro.serve.engine import make_search_fn
    ds, X, lin, gvm, iv = setup
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    g = replace(graph.build(ds.database, r=16, n_iters=4, seed=0),
                beam=96, max_hops=200)
    art = msearch.build_artifacts("gleanvec-int8", X, gvm)
    ivr = ivf.with_reduced_centers(iv, art.scorer, gvm)
    for index in (FlatIndex(block=512), replace(iv, nprobe=8), ivr, g):
        ids = msearch.multi_step_search(QT, art, index, 10, 50)
        rec = float(metrics.recall_at_k(ids, gt))
        assert rec > 0.8, (type(index).__name__, rec)
        fn = make_search_fn(art, k=10, kappa=50, index=index)
        ids2 = jax.jit(fn)(QT)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids2))
    # the reduced-center companion is scorer-family-specific: build the
    # retrieval index's probe from ITS scorer
    s_gl = sc.gleanvec_scorer(gvm, X)
    ri = retrieval.build_retrieval_index(
        X, "gleanvec", gvm, index=ivf.with_reduced_centers(iv, s_gl, gvm))
    ids = retrieval.retrieve(ri, QT, 10, kappa=50)
    assert float(metrics.recall_at_k(jnp.asarray(ids), gt)) > 0.8


def test_sharded_local_reference_recall(setup):
    """Mesh-free ShardedIndex (the placement axis without devices): flat /
    IVF / graph sharded searches stay near their unsharded recall."""
    ds, X, lin, gvm, _ = setup
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    for kind, floor in (("flat", 0.85), ("ivf", 0.8), ("graph", 0.7)):
        sh, stacked = distributed.build_sharded_index(
            kind, "gleanvec", X, gvm, n_shards=4,
            key=jax.random.PRNGKey(1), n_lists=16, nprobe=8,
            graph_kwargs={"r": 12, "n_iters": 3, "seed": 0})
        _, ids = sh.search(QT, stacked, 10, kappa=40)
        rec = float(metrics.recall_at_k(ids, gt))
        assert rec > floor, (kind, rec)
    # the retrieval layer mounts the sharded placement too: the STACKED
    # scorer rides in via the scorer= override
    from repro.serve import retrieval
    ri = retrieval.build_retrieval_index(X, "gleanvec", gvm, index=sh,
                                         scorer=stacked)
    ids = retrieval.retrieve(ri, QT, 10, kappa=40)
    assert float(metrics.recall_at_k(jnp.asarray(ids), gt)) > 0.7


def test_protocol_contracts_via_registry(setup):
    """The scorer/index contracts are defined ONCE, in
    ``repro.analysis``: run the registry's rules against THIS module's
    fixtures instead of re-asserting the method surface, the -1 id
    convention, and the static-config treedef discipline inline."""
    from repro.analysis import assert_rules
    from repro.analysis import protocol_rules as prules

    ds, X, lin, gvm, _ = setup

    class Ctx:
        """Adapter: this module's fixture as the rules' context."""

        sort_block = 64

        def __init__(self):
            self.X = X
            self.Q = jnp.asarray(ds.queries_test[:8])
            self._cache = {}

        def model_for(self, mode):
            return _model_for(mode, lin, gvm)

        def scorer(self, mode):
            if mode not in self._cache:
                self._cache[mode] = sc.build_scorer(
                    mode, X, self.model_for(mode), block=self.sort_block)
            return self._cache[mode]

    ctx = Ctx()
    rules = []
    for mode in ALL_MODES:
        rules += [prules.ScorerSurface(mode),
                  prules.IdTranslationContract(mode)]
    rules += [prules.TreedefStableIndexRefresh("flat"),
              prules.StaticConfigInTreedef("flat", "block"),
              prules.StaticConfigInTreedef("ivf", "nprobe")]
    assert_rules(ctx, rules)


# ---------------------------------------------------------------------------
# Multi-device parity (subprocess: the main process must keep 1 device).
# ---------------------------------------------------------------------------


def _run(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.jax_compat import make_mesh, set_mesh
        from repro.core import gleanvec as gv, leanvec_sphering as lvs
        from repro.core import scorer as sc
        from repro.data import vectors
        from repro.index import distributed, ivf
        mesh = make_mesh((4,), ("shard",))
        ALL_MODES = {modes!r}
    """).format(src=REPO_SRC, modes=ALL_MODES) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("regime", ["ood", "id"])
@pytest.mark.parametrize("kind", ["ivf", "graph"])
def test_sharded_parity_all_scorers(kind, regime):
    """Sharded IVF and sharded graph on a 4-way CPU mesh return IDENTICAL
    (value, id) results to their single-device counterparts (the same
    per-shard searches merged on one device) for every scorer family,
    sorted layouts included."""
    out = _run(f"""
        ood = {regime!r} == "ood"
        ds = vectors.make_dataset("par-{kind}-{regime}", n=2048, d=64,
                                  n_queries=16, ood=ood, seed=3)
        X = jnp.asarray(ds.database)
        Q = jnp.asarray(ds.queries_learn)
        QT = jnp.asarray(ds.queries_test)
        gvm = gv.fit(jax.random.PRNGKey(0), Q, X, c=8, d=24)
        lin = lvs.fit(Q, X, 24)
        for mode in ALL_MODES:
            model = (None if mode == "full"
                     else lin if mode.startswith("sphering") else gvm)
            sh, stacked = distributed.build_sharded_index(
                {kind!r}, mode, X, model, mesh=mesh,
                key=jax.random.PRNGKey(1), n_lists=16, nprobe=8,
                graph_kwargs=dict(r=12, n_iters=3, seed=0))
            ref_v, ref_i = sh.search_local(QT, stacked, 10, kappa=20)
            with set_mesh(mesh):
                v, i = jax.jit(
                    lambda q, s: sh.search(q, s, 10, kappa=20))(QT, stacked)
            assert np.allclose(np.asarray(v), np.asarray(ref_v),
                               rtol=1e-5, atol=1e-5), mode
            assert np.array_equal(np.asarray(i), np.asarray(ref_i)), mode
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def test_sharded_ivf_matches_global_ivf():
    """Row-sharded posting lists + replicated coarse quantizer probe the
    SAME lists as the global IVF, so the merged top-k equals the global
    single-index search exactly (non-quantized modes: per-shard scorer
    encodes are float-identical row slices of the global encode)."""
    out = _run("""
        ds = vectors.make_dataset("par-global", n=2048, d=64,
                                  n_queries=16, ood=True, seed=5)
        X = jnp.asarray(ds.database)
        Q = jnp.asarray(ds.queries_learn)
        QT = jnp.asarray(ds.queries_test)
        gvm = gv.fit(jax.random.PRNGKey(0), Q, X, c=8, d=24)
        lin = lvs.fit(Q, X, 24)
        key = jax.random.PRNGKey(1)
        for mode in ("full", "sphering", "gleanvec"):
            model = (None if mode == "full"
                     else lin if mode.startswith("sphering") else gvm)
            s_global = sc.build_scorer(mode, X, model)
            iv = ivf.build(key, X, n_lists=16)
            gv_v, gv_i = ivf.search_scorer(QT, s_global, iv, k=10, nprobe=8)
            sh, stacked = distributed.build_sharded_index(
                "ivf", mode, X, model, mesh=mesh, key=key, n_lists=16,
                nprobe=8)
            with set_mesh(mesh):
                v, i = jax.jit(
                    lambda q, s: sh.search(q, s, 10, kappa=10))(QT, stacked)
            order_g = np.argsort(np.asarray(gv_i), axis=1)
            order_s = np.argsort(np.asarray(i), axis=1)
            assert np.array_equal(np.take_along_axis(np.asarray(i),
                                                     order_s, 1),
                                  np.take_along_axis(np.asarray(gv_i),
                                                     order_g, 1)), mode
            assert np.allclose(np.take_along_axis(np.asarray(v),
                                                  order_s, 1),
                               np.take_along_axis(np.asarray(gv_v),
                                                  order_g, 1),
                               rtol=1e-5, atol=1e-5), mode
        print("GLOBAL_PARITY_OK")
    """)
    assert "GLOBAL_PARITY_OK" in out
