"""Gather-free traversals: the fused sorted-IVF range scan and the
multi-expansion beam search.

Three layers of guarantees:

* PARITY -- the fused fine step (``IVFIndex(aligned_layout=True)`` ->
  ``scorer.scan_lists`` -> ``kernels/ivf_scan``) returns EXACTLY the
  gathered ``score_ids`` path's (value, id) sets for both sorted scorer
  families, on ID and OOD queries, with ``slack_blocks``, after streaming
  insert/remove cycles (dead slots), and per-shard under ``ShardedIndex``;
  ``expand=1`` beam search reproduces the classic best-first loop
  bit-for-bit and ``expand>1`` holds recall while cutting hop count.
* SERVING -- a ``ServingEngine`` compiled with the fused path swaps
  streamed states with ZERO recompiles (``compile_counter``).
* COST -- the fused fine step's HBM traffic (fixed by the kernel's
  BlockSpecs, ``fine_step_bytes``) is >= 4x below the compiled gathered
  fine step's ``cost_analysis`` bytes at the paper's proportions, and the
  fused path compiles WITHOUT the (m, nprobe*L) gather the old path
  materializes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, metrics, streaming
from repro.core import scorer as sc
from repro.core import search as msearch
from repro.data import vectors
from repro.index import distributed, graph, ivf
from repro.index.protocol import replace
from repro.index.topk import NEG_INF
from repro.kernels.ivf_scan import fine_step_bytes
from repro.serve.engine import ServingEngine
from repro.analysis import assert_rules
from repro.analysis.hlo_rules import BufferPresent, NoDenseScoreMatrix
from repro.utils import hlo_analysis

from helpers import assert_same_topk as _assert_same_topk

pytestmark = pytest.mark.tier1

SORTED_MODES = ("gleanvec-sorted", "gleanvec-int8-sorted")


def _sorted_scorer(mode, model, X, block=64, slack_blocks=0):
    if mode == "gleanvec-sorted":
        return sc.sorted_gleanvec_scorer(model, X, block=block,
                                         slack_blocks=slack_blocks)
    return sc.sorted_gleanvec_quantized_scorer(model, X, block=block,
                                               slack_blocks=slack_blocks)


@pytest.fixture(scope="module")
def setup():
    ds = vectors.make_dataset("ivfscan", n=2048, d=64, n_queries=32,
                              ood=True, seed=9)
    ds_id = vectors.make_dataset("ivfscan-id", n=2048, d=64, n_queries=32,
                                 ood=False, seed=9)
    X = jnp.asarray(ds.database)
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn), X,
                 c=8, d=24)
    return ds, ds_id, X, gvm


@pytest.mark.parametrize("slack", [0, 2])
@pytest.mark.parametrize("regime", ["ood", "id"])
@pytest.mark.parametrize("mode", SORTED_MODES)
def test_fused_matches_gathered(setup, mode, regime, slack):
    """Aligned-IVF fused range scan == gathered score_ids path, exactly,
    for both sorted families, ID and OOD queries, with and without
    streaming slack blocks."""
    ds, ds_id, X, gvm = setup
    QT = jnp.asarray((ds if regime == "ood" else ds_id).queries_test)
    s = _sorted_scorer(mode, gvm, X, slack_blocks=slack)
    iva = ivf.build_aligned(gvm, X, nprobe=4)
    fused = iva.search(QT, s, 10)
    gathered = replace(iva, aligned_layout=False).search(QT, s, 10)
    _assert_same_topk(fused, gathered, f"{mode}/{regime}/slack={slack}")


def test_fused_composes_with_reduced_probe(setup):
    """The R^d coarse probe and the fused fine step are orthogonal: same
    results as the full-D probe at matched nprobe (identical probe order
    -- the companion scores the same centers)."""
    ds, _, X, gvm = setup
    QT = jnp.asarray(ds.queries_test)
    s = _sorted_scorer("gleanvec-int8-sorted", gvm, X)
    iva = ivf.build_aligned(gvm, X, nprobe=4)
    ivr = ivf.with_reduced_centers(iva, s, gvm)
    assert ivr.aligned_layout and ivr.center_scorer is not None
    _assert_same_topk(iva.search(QT, s, 10), ivr.search(QT, s, 10))


def test_fused_unfilled_slots_strip_to_minus_one(setup):
    """Fewer live candidates than k: the -inf winners' ids come back -1 on
    BOTH paths (never a resurrected padding slot)."""
    ds, _, X, gvm = setup
    QT = jnp.asarray(ds.queries_test[:4])
    s = _sorted_scorer("gleanvec-sorted", gvm, X[:64], block=64)
    iva = ivf.build_aligned(gvm, X[:64], nprobe=1)   # one tiny cluster
    vals, ids = iva.search(QT, s, 60)
    vals, ids = np.asarray(vals), np.asarray(ids)
    assert (ids[vals <= NEG_INF] == -1).all()
    assert (vals > NEG_INF).any()


@pytest.mark.parametrize("mode", SORTED_MODES)
def test_fused_after_streaming_cycles(setup, mode, compile_counter):
    """Insert/remove cycles through the fixed-capacity store + aligned
    posting lists: the fused path stays EXACT vs the gathered path on the
    churned state, and the compiled engine swaps every cycle with zero
    recompiles."""
    ds, _, X, gvm = setup
    N0, CAP, STEP = 1536, 2048, 128
    arts = streaming.build_streaming_artifacts(mode, X[:N0], gvm,
                                               capacity=CAP, sort_block=64,
                                               slack_blocks=3)
    index = ivf.with_list_slack(ivf.build_aligned(gvm, X[:N0], nprobe=3),
                                4 * STEP // gvm.n_clusters + 8)
    index = ivf.with_reduced_centers(index, arts.scorer, gvm)
    engine = ServingEngine(msearch.make_state(arts, index=index), k=10,
                           kappa=20, batch_size=16, dim=X.shape[1])
    QT = np.asarray(ds.queries_test[:16])

    def cycle_fn(cycle):
        engine.submit(QT)
        rows = X[N0 + cycle * STEP: N0 + (cycle + 1) * STEP]
        arts2, new_ids = streaming.insert_rows(engine.state.artifacts, rows)
        idx2 = ivf.insert_ids(engine.state.index, rows, new_ids)
        rm = np.arange(cycle * 20, cycle * 20 + 10, dtype=np.int32)
        arts2 = streaming.remove_rows(arts2, rm)
        idx2 = ivf.remove_ids(idx2, rm)
        engine.swap(engine.state._replace(artifacts=arts2, index=idx2))

    # cycle 0 is the warmup: compiles the serving step AND every eager op
    # of the host-side streaming loop once
    cycle_fn(0)
    compile_counter.reset()
    for cycle in (1, 2):
        cycle_fn(cycle)
    assert compile_counter.count == 0, \
        f"{mode}: {compile_counter.count} recompiles across swap cycles"
    assert engine.n_compiles in (None, 1)
    # the churned store: dead slots and filled slack must agree exactly
    st = engine.state
    fused = st.index.search(jnp.asarray(QT), st.artifacts.scorer, 10)
    gathered = replace(st.index, aligned_layout=False).search(
        jnp.asarray(QT), st.artifacts.scorer, 10)
    _assert_same_topk(fused, gathered, mode)
    assert not (np.asarray(fused[1]) < 0).all()


@pytest.mark.parametrize("mode", SORTED_MODES)
def test_fused_sharded_matches_gathered(setup, mode):
    """Per-shard aligned sub-indexes under ShardedIndex (stacked, padded
    leaves) return exactly the per-shard gathered results after the
    all-gather merge -- the fused path survives leaf padding."""
    ds, _, X, gvm = setup
    QT = jnp.asarray(ds.queries_test)
    sh, stacked = distributed.build_sharded_index(
        "ivf", mode, X, gvm, n_shards=4, nprobe=4, aligned=True,
        sort_block=64)
    assert sh.sub_index.aligned_layout
    fused = sh.search_local(QT, stacked, 10, kappa=20)
    sh_g = replace(sh, sub_index=replace(sh.sub_index,
                                         aligned_layout=False))
    gathered = sh_g.search_local(QT, stacked, 10, kappa=20)
    _assert_same_topk(fused, gathered, mode)


def test_sharded_aligned_needs_sorted_mode(setup):
    _, _, X, gvm = setup
    with pytest.raises(ValueError, match="sorted"):
        distributed.build_sharded_index("ivf", "gleanvec", X, gvm,
                                        n_shards=4, aligned=True)


def test_fused_fine_step_moves_4x_fewer_bytes():
    """Cost assertion at the paper's proportions (d = D/4, int8 codes,
    full-ish blocks): the range-scan kernel's BlockSpec-determined HBM
    traffic is >= 4x below the compiled gathered fine step's
    ``cost_analysis`` bytes, and the fused HLO contains no
    (m, nprobe * max_len) gather buffer."""
    ds = vectors.make_dataset("ivfscan-cost", n=4096, d=256, n_queries=32,
                              ood=True, seed=13)
    X = jnp.asarray(ds.database)
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(ds.queries_learn), X,
                 c=16, d=64)
    s = sc.sorted_gleanvec_quantized_scorer(gvm, X, block=64)
    iva = ivf.build_aligned(gvm, X, nprobe=4)
    QT = jnp.asarray(ds.queries_test)
    m, kappa = QT.shape[0], 50

    ivg = replace(iva, aligned_layout=False)
    qs = ivg.prepare_queries(s, QT)
    gathered_cost = hlo_analysis.normalize_cost(
        ivf._probe_and_score.lower(qs, s, ivg, kappa).compile()
        .cost_analysis())
    gathered_bytes = float(gathered_cost["bytes accessed"])

    ranges = np.asarray(s.list_block_ranges)
    visited = m * iva.nprobe * (ranges >= 0).sum() / ranges.shape[0]
    fused_bytes = fine_step_bytes(m, visited, s.layout_block,
                                  s.codes.shape[1], gvm.n_clusters,
                                  code_bytes=1, k=kappa)
    assert fused_bytes * 4 <= gathered_bytes, (fused_bytes, gathered_bytes)

    # no (m, nprobe*L) candidate/score matrix in the fused program, in
    # any dtype of interest -- and the gathered path really materializes
    # it (the registry rules own both contracts; see docs/static_analysis)
    p = iva.nprobe * iva.max_len
    assert_rules(ivf._probe_and_score.lower(qs, s, ivg, kappa).compile(),
                 [BufferPresent(m, p, dtypes=("f32",))],
                 target="ivf/gathered")
    assert_rules(ivf._probe_and_scan.lower(
        iva.prepare_queries(s, QT), s, iva, kappa).compile(),
                 [NoDenseScoreMatrix(m, p)], target="ivf/fused")


def test_insert_ids_vectorized_matches_sequential(setup):
    """The argsort/bincount slot assignment == the per-insert first-free
    reference, and out-of-slack raises the same message."""
    _, _, X, gvm = setup
    iva = ivf.with_list_slack(ivf.build_aligned(gvm, X[:1024], nprobe=3),
                              40)
    rng = np.random.default_rng(4)
    rows = X[1024:1024 + 64]
    ids = rng.permutation(np.arange(5000, 5064)).astype(np.int32)
    got = ivf.insert_ids(iva, rows, ids)
    # sequential reference (the pre-vectorization semantics)
    from repro.core import spherical_kmeans
    x_unit = spherical_kmeans.normalize_rows(jnp.asarray(rows, jnp.float32))
    tags = np.asarray(spherical_kmeans.assign(x_unit, iva.centers))
    ref = np.asarray(iva.lists).copy()
    for t, i in zip(tags, ids):
        free = np.nonzero(ref[t] < 0)[0]
        ref[t, free[0]] = int(i)
    np.testing.assert_array_equal(np.asarray(got.lists), ref)
    # out-of-slack: same error, names the full list
    tight = ivf.build_aligned(gvm, X[:64], nprobe=2)
    with pytest.raises(ValueError, match="posting list .* is full"):
        ivf.insert_ids(tight, X[64:1064],
                       np.arange(2000, 3000, dtype=np.int32))


# ---------------------------------------------------------------------------
# Multi-expansion beam search.
# ---------------------------------------------------------------------------


def _legacy_beam(qstate, scorer, g, k, beam, max_hops):
    """The pre-multi-expansion traversal (argmax pop, O(beam*R*beam)
    dedupe broadcast), kept verbatim as the expand=1 exactness oracle."""
    batch = qstate.shape[0]
    nbr_tbl = g.neighbors
    r = nbr_tbl.shape[1]

    def score_ids(ids):
        return scorer.score_ids(qstate, jnp.where(ids >= 0, ids, 0))

    n_entry = g.entries.shape[0]
    entry = jnp.broadcast_to(g.entries[None, :], (batch, n_entry))
    e_scores = jnp.where(entry >= 0, score_ids(entry), NEG_INF)
    ids = jnp.concatenate(
        [entry, jnp.full((batch, beam - n_entry), -1, jnp.int32)], 1)
    scores = jnp.concatenate(
        [e_scores, jnp.full((batch, beam - n_entry), NEG_INF)], 1)
    visited = jnp.zeros((batch, beam), bool)
    hop = 0
    while hop < max_hops:
        expandable = (~visited) & (ids >= 0)
        if not bool(jnp.any(expandable)):
            break
        masked = jnp.where(expandable, scores, NEG_INF)
        best = jnp.argmax(masked, 1)
        has_work = jnp.any(expandable, 1)
        best_ids = jnp.take_along_axis(ids, best[:, None], 1)[:, 0]
        visited = visited.at[jnp.arange(batch), best].set(
            visited[jnp.arange(batch), best] | has_work)
        nbrs = nbr_tbl[jnp.where(best_ids >= 0, best_ids, 0)]
        nbrs = jnp.where((nbrs >= 0) & has_work[:, None], nbrs, -1)
        nscores = jnp.where(nbrs >= 0, score_ids(nbrs), NEG_INF)
        present = jnp.any(nbrs[:, :, None] == ids[:, None, :], 2)
        nscores = jnp.where(present, NEG_INF, nscores)
        all_scores = jnp.concatenate([scores, nscores], 1)
        all_ids = jnp.concatenate([ids, nbrs], 1)
        all_vis = jnp.concatenate(
            [visited, jnp.zeros((batch, r), bool)], 1)
        scores, sel = jax.lax.top_k(all_scores, beam)
        ids = jnp.take_along_axis(all_ids, sel, 1)
        visited = jnp.take_along_axis(all_vis, sel, 1)
        hop += 1
    top, sel = jax.lax.top_k(scores, k)
    return top, jnp.take_along_axis(ids, sel, 1), hop


@pytest.fixture(scope="module")
def graph_setup(setup):
    ds, _, X, gvm = setup
    g = graph.build(ds.database, r=16, n_iters=4, seed=0)
    s = sc.gleanvec_scorer(gvm, X)
    return ds, X, gvm, g, s


def test_expand1_reproduces_classic_traversal(graph_setup):
    """expand=1 == the legacy argmax/broadcast loop: identical visit
    order (same hop count), identical winner ids, scores equal to jit
    fusion rounding -- the sort-based dedupe is a pure refactor."""
    ds, X, gvm, g, s = graph_setup
    qstate = s.prepare_queries(jnp.asarray(ds.queries_test))
    v_ref, i_ref, hops_ref = _legacy_beam(qstate, s, g, 10, 48, 120)
    v, i, hops, _ = graph._beam_qstate(qstate, s, g, 10, 48, 120, expand=1)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                               rtol=1e-6, atol=1e-4)
    assert int(hops) == hops_ref


@pytest.mark.parametrize("expand", [2, 4])
def test_expand_cuts_hops_at_matched_recall(graph_setup, expand):
    """Multi-expansion: ~expand-fold fewer while_loop iterations, recall
    within tolerance of the classic traversal at the same beam."""
    ds, X, gvm, g, s = graph_setup
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :10])
    qstate = s.prepare_queries(QT)
    v1, i1, h1, _ = graph._beam_qstate(qstate, s, g, 10, 48, 120, expand=1)
    ve, ie, he, _ = graph._beam_qstate(qstate, s, g, 10, 48, 120,
                                       expand=expand)
    r1 = float(metrics.recall_at_k(i1, gt))
    re = float(metrics.recall_at_k(ie, gt))
    assert int(he) * (expand - 1) < int(h1) * expand, (int(h1), int(he))
    assert re >= r1 - 0.03, (expand, r1, re)
    # the protocol honors the static field
    ge = replace(g, beam=48, max_hops=120, expand=expand)
    _, i_proto = ge.search(QT, s, 10)
    np.testing.assert_array_equal(
        np.asarray(i_proto),
        np.asarray(jnp.where(ve > NEG_INF, ie, -1)))


def test_graph_candidates_strip_inf_ids(graph_setup):
    """Unfilled beam slots (-inf) come back as id -1 from
    GraphIndex.candidates, like the IVF path."""
    ds, X, gvm, g, s = graph_setup
    QT = jnp.asarray(ds.queries_test[:4])
    g0 = replace(g, beam=48, max_hops=0)       # no hops: only the entries
    vals, ids = g0.search(QT, s, 40)
    vals, ids = np.asarray(vals), np.asarray(ids)
    assert (ids[vals <= NEG_INF] == -1).all()
    assert (vals > NEG_INF).any()
